"""Tests for optimizer, checkpointing (refinable-timestamp MVCC),
trainer fault tolerance, gradient compression, and the dynamic-graph
pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import MVCheckpointStore
from repro.core.clock import Order, Stamp, compare
from repro.optim import AdamWConfig, adamw, compress, make_train_step


def quad_loss(params, batch):
    err = params["w"] @ batch["x"] - batch["y"]
    loss = jnp.mean(jnp.square(err))
    return loss, {"loss": loss}


def make_batch(rng, d=4):
    x = rng.normal(size=(d, 8)).astype(np.float32)
    w_true = rng.normal(size=(d, d)).astype(np.float32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(w_true @ x)}


class TestAdamW:
    def test_loss_decreases(self):
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)}
        batch = make_batch(rng)
        step = make_train_step(quad_loss,
                               AdamWConfig(lr=3e-2, warmup_steps=1,
                                           total_steps=200,
                                           weight_decay=0.0))
        opt = adamw.init(params)
        first = None
        for i in range(100):
            params, opt, m = step(params, opt, batch)
            if first is None:
                first = float(m["loss"])
        assert float(m["loss"]) < first * 0.1

    def test_clip_and_schedule(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          schedule="cosine")
        lr0 = float(adamw.schedule_lr(cfg, jnp.asarray(1)))
        lr_mid = float(adamw.schedule_lr(cfg, jnp.asarray(10)))
        lr_end = float(adamw.schedule_lr(cfg, jnp.asarray(100)))
        assert lr0 < lr_mid
        assert lr_end < 1e-3
        g = {"w": jnp.full((10,), 100.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5


class TestGradCompression:
    def test_int8_roundtrip_with_error_feedback(self):
        rng = np.random.default_rng(0)
        g = {"a": jnp.asarray(rng.normal(size=(256,)), jnp.float32)}
        ef = compress.init_error_feedback(g)
        total_sent = jax.tree_util.tree_map(jnp.zeros_like, g)
        total_true = jax.tree_util.tree_map(jnp.zeros_like, g)
        for _ in range(20):
            q, ef = compress.compress_grads(g, ef)
            deq = compress.decompress_grads(q)
            total_sent = jax.tree_util.tree_map(jnp.add, total_sent, deq)
            total_true = jax.tree_util.tree_map(jnp.add, total_true, g)
        # error feedback: accumulated quantized sum tracks the true sum
        np.testing.assert_allclose(np.asarray(total_sent["a"]),
                                   np.asarray(total_true["a"]),
                                   rtol=0.02, atol=0.05)


class TestMVCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        store = MVCheckpointStore(str(tmp_path), n_writers=2, writer_id=0)
        params = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                  "b": {"x": jnp.ones((4,), jnp.bfloat16)}}
        st = store.save(params, step=5)
        got, info = store.restore(params)
        assert info.step == 5
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(params["w"]))
        assert got["b"]["x"].dtype == jnp.bfloat16

    def test_latest_orders_by_stamp(self, tmp_path):
        store = MVCheckpointStore(str(tmp_path), n_writers=1)
        p = {"w": jnp.zeros((2,))}
        store.save(p, step=1)
        store.save({"w": jnp.ones((2,))}, step=2)
        info = store.latest()
        assert info.step == 2

    def test_concurrent_writers_refined_consistently(self, tmp_path):
        """Two writers with concurrent stamps: the oracle decision is
        monotonic — latest() returns the same winner every time."""
        a = MVCheckpointStore(str(tmp_path), n_writers=2, writer_id=0)
        b = MVCheckpointStore(str(tmp_path), n_writers=2, writer_id=1)
        a.save({"w": jnp.zeros((2,))}, step=10)
        b.save({"w": jnp.ones((2,))}, step=11)
        reader = MVCheckpointStore(str(tmp_path), n_writers=2)
        first = reader.latest().path
        for _ in range(5):
            assert reader.latest().path == first

    def test_epoch_bump_orders_after(self, tmp_path):
        store = MVCheckpointStore(str(tmp_path), n_writers=1)
        s1 = store.save({"w": jnp.zeros((2,))}, step=50)
        store.bump_epoch()
        s2 = store.save({"w": jnp.ones((2,))}, step=10)   # lower step!
        assert compare(s1, s2) is Order.BEFORE
        assert store.latest().step == 10                  # stamp wins

    def test_gc_keeps_newest(self, tmp_path):
        store = MVCheckpointStore(str(tmp_path), n_writers=1, keep=2)
        for i in range(5):
            store.save({"w": jnp.full((2,), float(i))}, step=i)
        infos = store.list_checkpoints()
        assert len(infos) == 2
        assert store.latest().step == 4

    def test_torn_checkpoint_ignored(self, tmp_path):
        store = MVCheckpointStore(str(tmp_path), n_writers=1)
        store.save({"w": jnp.zeros((2,))}, step=1)
        os.makedirs(tmp_path / "v_e0_99", exist_ok=True)  # no MANIFEST
        assert store.latest().step == 1


class TestTrainerFaultTolerance:
    def test_checkpoint_restart_resumes(self, tmp_path):
        from repro.runtime import Trainer, TrainerConfig
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)}
        batch = make_batch(rng)
        def batches():
            while True:
                yield batch
        cfg = TrainerConfig(total_steps=30, ckpt_every=10,
                            ckpt_dir=str(tmp_path), log_every=1000)
        t1 = Trainer(quad_loss, params,
                     AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=30),
                     cfg)
        t1.fit(batches(), until=20)
        assert t1.step == 20
        # simulated crash: brand-new trainer resumes from stamp
        t2 = Trainer(quad_loss, params,
                     AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=30),
                     cfg)
        assert t2.try_resume()
        assert t2.step == 20
        np.testing.assert_allclose(np.asarray(t2.params["w"]),
                                   np.asarray(t1.params["w"]))
        t2.fit(batches())
        assert t2.step == 30

    def test_failure_bumps_epoch(self, tmp_path):
        from repro.runtime import Trainer, TrainerConfig
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)}
        batch = make_batch(rng)
        def batches():
            while True:
                yield batch
        cfg = TrainerConfig(total_steps=20, ckpt_every=5,
                            ckpt_dir=str(tmp_path), log_every=1000)
        t = Trainer(quad_loss, params,
                    AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=20),
                    cfg)
        t.fit(batches(), until=10)
        t.on_failure()
        assert t.store.epoch == 1
        assert t.step == 10
        t.fit(batches())
        last = t.store.latest()
        assert last.stamp.epoch == 1

    def test_straggler_detection(self):
        from repro.runtime import HeartbeatMonitor
        m = HeartbeatMonitor(n_workers=3, factor=3.0)
        now = 0.0
        for step in range(8):
            for w in range(3):
                if not (w == 2 and step >= 4):
                    m.beat(w, now + 0.01 * w)
            now += 1.0
        flagged = m.check(now)
        assert 2 in flagged


class TestDynamicGraphPipeline:
    def test_snapshot_batches_under_mutation(self):
        from repro.core import Weaver, WeaverConfig
        from repro.data.pipeline import DynamicGraphPipeline
        w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=2, seed=1))
        tx = w.begin_tx()
        for i in range(8):
            tx.create_vertex(f"d{i}")
        for i in range(7):
            tx.create_edge(f"d{i}", f"d{i+1}")
        assert w.run_tx(tx).ok
        pipe = DynamicGraphPipeline(w, d_feat=4, n_classes=3,
                                    pad_nodes=32, pad_edges=64)
        def mutate(wv):
            tx = wv.begin_tx()
            tx.create_vertex(f"new{wv.sim.now}")
            assert wv.run_tx(tx).ok
        it = pipe.batches(mutate_between=mutate)
        b1 = next(it)
        b2 = next(it)
        assert b1["x"].shape == (32, 4)
        # the second snapshot saw the mutation (one more live node)
        assert b2["label_mask"].sum() == b1["label_mask"].sum() + 1
