"""Ragged frontier payloads — vectorized ``get_edges`` (ragged per-entry
replies) and the 3-phase ``clustering`` wedge-closing protocol:
randomized churn equivalence frontier == scalar == analytics at
identical stamps (including GC/compaction and mid-query write churn),
payload routing/merging units, and coalescing on/off equality for the
new payload kinds through the simulator.  Seeded-random, tier-1."""

import numpy as np
import pytest

from repro.core import Weaver, WeaverConfig
from repro.core import analytics as A
from repro.core import frontier as F
from repro.core.analytics import SnapshotEngine
from repro.core.clock import Stamp
from repro.core.frontier import Ragged, RaggedReply
from repro.core.nodeprog import REGISTRY

from test_frontier_prog import _Stamps, make_weaver, mutate


def _both(w, name, entries, at, **kw):
    place = lambda vid: w.store.place(vid)
    r_f, s_f = F.run_local(w, name, entries, at, use_frontier=True,
                           shard_of=place, **kw)
    r_s, s_s = F.run_local(w, name, entries, at, use_frontier=False,
                           shard_of=place)
    return r_f, r_s, s_f, s_s


class TestRaggedUnits:
    def test_take_and_concat_rebase(self):
        rg = Ragged(offsets=np.array([0, 2, 2, 5], np.int64),
                    values=np.array([3, 7, 1, 4, 9], np.int64),
                    keys=np.array([10, 11, 12], np.int64),
                    extra={"w": np.array([0, 1, 2, 3, 4], np.int64)})
        sub = rg.take(np.array([2, 0]))
        assert sub.offsets.tolist() == [0, 3, 5]
        assert sub.values.tolist() == [1, 4, 9, 3, 7]
        assert sub.keys.tolist() == [12, 10]
        assert sub.extra["w"].tolist() == [2, 3, 4, 0, 1]
        cat = Ragged.concat([rg, sub])
        assert len(cat) == 5 and cat.offsets.tolist() == [0, 2, 2, 5, 8, 10]
        assert cat.values.tolist() == [3, 7, 1, 4, 9, 1, 4, 9, 3, 7]

    def test_merge_frontiers_rebases_tags(self):
        r1 = Ragged(offsets=np.array([0, 1], np.int64),
                    values=np.array([5], np.int64),
                    keys=np.array([100], np.int64))
        r2 = Ragged(offsets=np.array([0, 2], np.int64),
                    values=np.array([6, 7], np.int64),
                    keys=np.array([200], np.int64))
        f1 = F.Frontier(np.array([5], np.int64), tags=np.array([0]),
                        ragged=r1, depth=1)
        f2 = F.Frontier(np.array([6, 7], np.int64), tags=np.array([0, 0]),
                        ragged=r2, depth=1)
        m = F._merge_frontiers([f1, f2])
        assert m.tags.tolist() == [0, 1, 1]
        assert m.ragged.keys.tolist() == [100, 200]
        # routing subsets rows per destination and re-bases tags again
        out = F.route_frontier(m, _FakeIntern(["x"] * 8),
                               lambda vid: 0)
        (sid, fr), = out.items()
        assert fr.ragged.keys.tolist() == [100, 200]
        assert fr.tags.tolist() == [0, 1, 1]

    def test_reply_nbytes_models_columns(self):
        rep = RaggedReply(_FakeIntern(["a", "b"]),
                          np.array([0], np.int64),
                          np.array([0, 2], np.int64),
                          np.array([1, 2], np.int64),
                          np.array([1, 1], np.int64))
        assert rep.nbytes() > 64 + 8 * 4
        assert F.reply_nbytes([rep, ["plain"]]) == rep.nbytes() + 32
        assert rep.lists() == [[(1, "b"), (2, "b")]]


class _FakeIntern:
    def __init__(self, vids):
        self.vids = vids
        self.ids = {v: i for i, v in enumerate(vids)}


class TestRaggedEquivalence:
    """get_edges / clustering: frontier == scalar at identical stamps
    under full churn (vertex deletes, GC purges, forced compaction)."""

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_random_churn(self, seed):
        rng = np.random.default_rng(seed)
        w = make_weaver(seed)
        sg = _Stamps(2)
        live, edges = set(), []
        for round_i in range(8):
            mutate(rng, w, sg, live, edges, round_i)
            if round_i % 3 == 2:   # interleave GC (may purge + compact)
                horizon = Stamp(0, tuple(sg.clock), -1, 0)
                for sh in w.shards:
                    sh.partition.collect(horizon)
                    cols = sh.partition.columns
                    if cols.dead_fraction() > 0:
                        cols.compact()
            at = sg.query()
            pool = sorted(live)
            roots = [str(v) for v in
                     rng.choice(pool, min(4, len(pool)), replace=False)]
            for src in roots:
                cases = [
                    ("get_edges", [(src, None)]),
                    ("get_edges", [(src, {"props": ("rel", "weight")})]),
                    ("clustering", [(src, {"phase": 0})]),
                ]
                for name, entries in cases:
                    r_f, r_s, _, _ = _both(w, name, entries, at)
                    assert r_f == r_s, (name, src, at, r_f, r_s)
            # multi-root batch (exercises ragged routing across shards;
            # sorted-sid iteration keeps the reduced outputs aligned)
            multi = [(v, None) for v in roots]
            r_f, r_s, st_f, st_s = _both(w, "get_edges", multi, at)
            assert r_f == r_s
            multi_c = [(v, {"phase": 0}) for v in roots]
            r_f, r_s, _, _ = _both(w, "clustering", multi_c, at)
            assert r_f == r_s

    def test_matches_analytics_reference(self):
        """Three-way agreement on a delete-free, self-loop-free graph:
        program results == ``clustering_coefficients_np`` / CSR rows of
        the engine snapshot (with GC + forced compaction interleaved)."""
        rng = np.random.default_rng(9)
        w = make_weaver(9)
        sg = _Stamps(2)
        live, edges = set(), []
        part = lambda v: w.shards[w.store.place(v)].partition
        for i in range(18):
            vid = f"n{i}"
            part(vid).create_vertex(vid, sg.next())
            live.add(vid)
        pool = sorted(live)
        for _ in range(120):
            a, b = rng.integers(0, len(pool), 2)
            if a == b:
                continue                       # no self-loops
            part(pool[a]).create_edge(pool[a], pool[b], sg.next())
        # churn: delete some edges, then GC + force compaction
        for sh in w.shards:
            cols = sh.partition.columns
            for vid, v in list(sh.partition.vertices.items()):
                for eid in list(v.out_edges)[:1]:
                    sh.partition.delete_edge(vid, eid, sg.next())
        horizon = Stamp(0, tuple(sg.clock), -1, 0)
        for sh in w.shards:
            sh.partition.collect(horizon)
            if sh.partition.columns.dead_fraction() > 0:
                sh.partition.columns.compact()
        at = sg.query()
        ga = SnapshotEngine(w).snapshot(at)
        cc = np.asarray(A.clustering_coefficients_np(
            ga.edge_src, ga.edge_dst, ga.n_nodes))
        deg = np.bincount(ga.edge_src, minlength=ga.n_nodes)
        for vid in pool:
            i = ga.index[vid]
            r_f, r_s, _, _ = _both(w, "clustering",
                                   [(vid, {"phase": 0})], at)
            assert r_f == r_s == cc[i], vid
            e_f, e_s, _, _ = _both(w, "get_edges", [(vid, None)], at)
            assert e_f == e_s
            assert len(e_f) == int(deg[i])
            got = sorted(d for _, d in e_f)
            want = sorted(ga.vids[j] for j in
                          ga.edge_dst[ga.edge_src == i].tolist())
            assert got == want, vid

    def test_invisible_neighbour_never_replies(self):
        """A deleted neighbour silently drops the wedge request — the
        origin never completes and the reduce falls back to 0.0 on BOTH
        paths (the scalar protocol's exact behaviour)."""
        w = make_weaver(4)
        sg = _Stamps(2)
        part = lambda v: w.shards[w.store.place(v)].partition
        for v in ("u", "a", "b"):
            part(v).create_vertex(v, sg.next())
        for d in ("a", "b"):
            part("u").create_edge("u", d, sg.next())
        part("a").create_edge("a", "b", sg.next())
        part("b").delete_vertex("b", sg.next())
        at = sg.query()
        r_f, r_s, _, _ = _both(w, "clustering", [("u", {"phase": 0})], at)
        assert r_f == r_s == 0.0
        # get_edges still lists the dangling edge (source-side adjacency)
        e_f, e_s, _, _ = _both(w, "get_edges", [("u", None)], at)
        assert e_f == e_s and sorted(d for _, d in e_f) == ["a", "b"]

    def test_mid_query_churn_snapshot_isolated(self):
        """Writes committing between hops (plan delta refresh, dedup'd
        adjacency cache invalidation) must not change results at the
        fixed query stamp."""
        rng = np.random.default_rng(5)
        w = make_weaver(5)
        sg = _Stamps(2)
        live, edges = set(), []
        for round_i in range(4):
            mutate(rng, w, sg, live, edges, round_i, deletes=False)
        at = sg.query()
        pool = sorted(live)
        src = str(pool[0])
        part = lambda v: w.shards[w.store.place(v)].partition

        def churn(hop):
            for _ in range(5):
                a, b = rng.integers(0, len(pool), 2)
                if a != b:
                    part(str(pool[a])).create_edge(str(pool[a]),
                                                   str(pool[b]), sg.next())

        place = lambda vid: w.store.place(vid)
        r_ref, _ = F.run_local(w, "clustering", [(src, {"phase": 0})], at,
                               use_frontier=False, shard_of=place)
        for delta in (True, False):
            r_c, st = F.run_local(w, "clustering", [(src, {"phase": 0})],
                                  at, use_frontier=True, shard_of=place,
                                  on_hop=churn, plan_delta=delta)
            assert r_c == r_ref, (delta, r_c, r_ref)
        r_e, _ = F.run_local(w, "get_edges", [(src, None)], at,
                             use_frontier=False, shard_of=place)
        r_ec, st = F.run_local(w, "get_edges", [(src, None)], at,
                               use_frontier=True, shard_of=place,
                               on_hop=churn)
        assert r_ec == r_e


class TestRaggedSimulator:
    def _social(self, w, n=50, m=420, seed=2):
        rng = np.random.default_rng(seed)
        tx = w.begin_tx()
        for i in range(n):
            tx.create_vertex(f"u{i}")
        seen = set()
        for _ in range(m):
            a, b = rng.integers(0, n, 2)
            if a != b and (a, b) not in seen:
                seen.add((a, b))
                e = tx.create_edge(f"u{a}", f"u{b}")
                if (a + b) % 3 == 0:
                    tx.set_edge_prop(e, "rel", "F")
        assert w.run_tx(tx).ok

    def test_end_to_end_both_paths(self):
        for name, entries in [
            ("get_edges", [("u1", None)]),
            ("get_edges", [("u1", {"props": ("rel",)})]),
            ("clustering", [("u0", {"phase": 0})]),
            ("clustering", [("u7", {"phase": 0})]),
        ]:
            res = {}
            for fron in (True, False):
                w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=3,
                                        seed=4, frontier_progs=fron))
                self._social(w)
                r, _, _ = w.run_program(name, entries, timeout=60.0)
                res[fron] = r
                c = w.counters()
                if fron:
                    assert c["frontier_batches"] > 0
                    if name == "get_edges":
                        assert c["ragged_replies"] > 0
                        assert c["ragged_values"] == len(r)
                else:
                    assert c["frontier_batches"] == 0
            assert res[True] == res[False], (name, res)

    def test_coalescing_on_off_equal_for_ragged_kinds(self):
        """Same graph, coalescing on/off: identical results, strictly
        fewer executions, and the merge counter proves the new payload
        kinds (phase-1 ragged tables, phase-2 tag replies, root packs)
        actually coalesced."""
        old_cl = REGISTRY["clustering"].reduce
        old_ge = REGISTRY["get_edges"].reduce
        from repro.core.nodeprog import _edge_lists
        # order-insensitive reductions: delivery order differs between
        # the two runs, which is exactly what coalescing may reorder
        REGISTRY["clustering"].reduce = lambda xs: sorted(xs)
        REGISTRY["get_edges"].reduce = \
            lambda xs: sorted(map(sorted, _edge_lists(xs)))
        try:
            for name, mk in (("clustering",
                              lambda i: (f"u{i}", {"phase": 0})),
                             ("get_edges", lambda i: (f"u{i}", None))):
                res, execs, merged = {}, {}, {}
                for co in (True, False):
                    w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=4,
                                            seed=4, frontier_coalesce=co))
                    self._social(w, n=40, m=420)
                    r, _, _ = w.run_program(
                        name, [mk(i) for i in range(10)], timeout=120.0)
                    res[co] = r
                    c = w.counters()
                    execs[co] = c["frontier_batches"]
                    merged[co] = c["frontier_coalesced"]
                assert res[True] == res[False], name
                assert merged[False] == 0
                if name == "clustering":
                    # phase-1 ragged tables and phase-2 tag replies from
                    # several source shards merge into one execution ...
                    assert merged[True] > 0
                    assert execs[True] < execs[False], name
                else:
                    # ... while get_edges is single-hop: one delivery
                    # per shard per program, nothing to merge — the
                    # payload kind must simply survive the toggle
                    assert merged[True] == 0
                    assert execs[True] == execs[False]
        finally:
            REGISTRY["clustering"].reduce = old_cl
            REGISTRY["get_edges"].reduce = old_ge
