"""Deterministic fault injection + chaos property test (ISSUE 6 tentpole c).

Every named crash point (``repro.core.faultinject.CRASH_POINTS``) must
leave the cluster in a state where (a) every client submission resolves
— commit, abort, or a surfaced budget-exhaustion error, never a hang —
(b) every ACKED transaction's effects survive recovery, and (c) no
transaction commits twice (a duplicate ``create_vertex`` would abort
with "exists", so its absence doubles as the double-commit detector).

The chaos test draws randomized kill schedules from
:meth:`FaultPlan.random` and checks the same invariants, comparing the
surviving state against a fault-free run of the identical workload.
"""

import numpy as np
import pytest

from repro.core import Weaver, WeaverConfig
from repro.core.faultinject import FaultAction, FaultPlan

from test_recovery import assert_replay_equals_walk


def make_weaver(plan=None, **kw):
    kw.setdefault("n_gatekeepers", 2)
    kw.setdefault("n_shards", 3)
    kw.setdefault("seed", 7)
    return Weaver(WeaverConfig(fault_plan=plan, **kw))


def seed_hub(w):
    """Fault-free setup traffic (callers disarm the injector first)."""
    tx = w.begin_tx()
    tx.create_vertex("hub")
    assert w.run_tx(tx).ok


def submit_unique(w, i, results):
    """One self-contained tx on a unique key; result lands in
    ``results[vid]`` whenever the session resolves it."""
    v = f"x{i}"
    tx = w.begin_tx()
    tx.create_vertex(v)
    tx.create_edge(v, "hub")
    tx.set_vertex_prop(v, "score", float(i))
    w.submit_tx(tx, lambda r, v=v: results.__setitem__(v, r))
    return v


def check_acked_visible(w, results):
    """Every acked tx is in the store AND at its (live) shard replica;
    no tx re-executed (no "exists" abort)."""
    assert not any("exists" in (r.error or "")
                   for r in results.values()), "a committed tx re-executed"
    acked = [v for v, r in results.items() if r.ok]
    for v in acked:
        sv = w.store.vertices.get(v)
        assert sv is not None and sv.delete_ts is None, f"acked {v} lost"
        assert sv.props["score"][-1][0] == float(v[1:])
        assert any(dst == "hub" and dts is None
                   for dst, _, dts in sv.edges.values()), f"{v} edge lost"
        sh = w.shards[w.store.place(v)]
        if sh.alive:
            assert v in sh.partition.vertices, f"acked {v} missing at shard"
    return acked


class TestCrashPoints:
    # mid_window occurs once per admitted tx (skip one), pre/post_wal
    # once per committed window (fire on the first)
    @pytest.mark.parametrize("point,after", [("mid_window", 1),
                                             ("pre_wal", 0),
                                             ("post_wal", 0)])
    def test_gatekeeper_crash_point(self, point, after):
        plan = FaultPlan([FaultAction("crash", point=point, target="gk0",
                                      after=after)])
        w = make_weaver(plan, write_group_commit=0.5e-3)
        w.sim.fault.disarm()
        seed_hub(w)
        w.sim.fault.arm()
        results = {}
        for i in range(12):
            submit_unique(w, i, results)
        w.settle(1.0)
        c = w.sim.counters
        assert c.crashes_injected == 1
        assert len(results) == 12, "a client session hung"
        acked = check_acked_visible(w, results)
        assert len(acked) == 12, "a lost tx was never retried to success"
        if point == "mid_window":
            # the admitted-but-unflushed window is counted, not silent
            assert c.group_txs_lost > 0
        if point == "post_wal":
            # classic lost ack: durable commit, dead server — the
            # resubmission must answer from the recorded outcome
            assert c.tx_dedup_hits >= 1
            assert any(r.retries > 0 for r in results.values())

    def test_mid_wal_torn_tail(self):
        """The store's group append is cut short: the torn entries are
        on the log but never acked; clients re-drive them to the
        survivor and replay truncates the tail."""
        plan = FaultPlan([FaultAction("torn", point="mid_wal", target="gk0",
                                      after=0, arg=1)])
        w = make_weaver(plan, write_group_commit=0.5e-3)
        w.sim.fault.disarm()
        seed_hub(w)
        w.sim.fault.arm()
        results = {}
        for i in range(10):
            submit_unique(w, i, results)
        w.settle(1.0)
        assert w.sim.counters.crashes_injected == 1
        assert len(results) == 10
        acked = check_acked_visible(w, results)
        assert len(acked) == 10
        # replay across the torn record truncates (and agrees with the walk)
        torn0 = w.sim.counters.wal_torn_truncated
        assert_replay_equals_walk(w)
        assert w.sim.counters.wal_torn_truncated > torn0

    def test_mid_shard_apply(self):
        # vertex->shard placement follows the per-process string hash;
        # target the shard that receives the most tx applies so the
        # after=2 crash point always fires (a fixed "shard1" is flaky
        # under unlucky PYTHONHASHSEED draws — 12 txs over 3 shards can
        # leave it with fewer than 3 items)
        probe = make_weaver()
        counts = [0] * len(probe.shards)
        for i in range(12):
            counts[probe.store.place(f"x{i}")] += 1
        plan = FaultPlan([FaultAction("crash", point="mid_shard_apply",
                                      target=f"shard{int(np.argmax(counts))}",
                                      after=2)])
        w = make_weaver(plan)
        w.sim.fault.disarm()
        seed_hub(w)
        w.sim.fault.arm()
        results = {}
        for i in range(12):
            submit_unique(w, i, results)
        w.settle(1.0)
        assert w.sim.counters.crashes_injected == 1
        assert w.manager.epoch >= 1, "shard death never promoted"
        assert len(results) == 12
        acked = check_acked_visible(w, results)
        assert len(acked) == 12
        assert_replay_equals_walk(w)

    def test_epoch_barrier_second_failure(self):
        """A second actor dies INSIDE the epoch barrier commit; the next
        heartbeat check promotes it in a follow-up epoch."""
        plan = FaultPlan([FaultAction("crash", point="epoch_barrier",
                                      target="shard2")])
        w = make_weaver(plan)
        w.sim.fault.disarm()
        seed_hub(w)
        results = {}
        for i in range(6):
            submit_unique(w, i, results)
        w.settle(20e-3)
        w.sim.fault.arm()
        w.kill("gk0")                    # first failure triggers the barrier
        w.settle(1.0)
        c = w.sim.counters
        assert c.crashes_injected == 1
        assert w.manager.epoch >= 2, "barrier victim never re-promoted"
        assert all(sh.alive for sh in w.shards)
        for i in range(6, 12):
            submit_unique(w, i, results)
        w.settle(1.0)
        assert len(results) == 12
        acked = check_acked_visible(w, results)
        assert len(acked) == 12


class TestClientSession:
    def test_retry_budget_exhausted_surfaces_error(self):
        """With every gatekeeper dead and promotion disabled, the
        bounded retry budget surfaces an error instead of hanging."""
        w = make_weaver(heartbeat_period=10.0)
        seed_hub(w)
        w.kill("gk0")
        w.kill("gk1")
        results = {}
        submit_unique(w, 0, results)
        w.settle(1.5)
        r = results["x0"]
        assert not r.ok
        assert r.error == "client retry budget exhausted"
        assert r.retries == w.cfg.client_retry_budget
        assert w.sim.counters.client_gaveup == 1

    def test_message_faults_counted_and_survived(self):
        """Dropped acks are re-asked (dedup answers), duplicated
        submissions are consumed by the in-flight gate, delays just
        add latency — and each is tallied."""
        plan = FaultPlan([
            FaultAction("drop", target="reply", after=0, count=2),
            FaultAction("dup", target="submit_tx", after=1, count=2),
            FaultAction("delay", target="reply", after=4, count=3,
                        delay=2e-3),
        ])
        w = make_weaver(plan)
        w.sim.fault.disarm()
        seed_hub(w)
        w.sim.fault.arm()
        results = {}
        for i in range(10):
            submit_unique(w, i, results)
        w.settle(1.0)
        c = w.sim.counters
        assert c.msgs_dropped >= 1
        assert c.msgs_duplicated >= 1
        assert c.msgs_delayed >= 1
        assert len(results) == 10
        acked = check_acked_visible(w, results)
        assert len(acked) == 10


class TestChaosProperty:
    """Randomized kill schedules: every acked tx survives recovery and
    the surviving state matches the fault-free run of the same workload
    on the acked prefix (fixed seeds keep this tier-1 deterministic)."""

    N = 24

    def _run(self, plan, **kw):
        w = make_weaver(plan, write_group_commit=0.5e-3, **kw)
        if w.sim.fault is not None:
            w.sim.fault.disarm()
        seed_hub(w)
        if w.sim.fault is not None:
            w.sim.fault.arm()
        results = {}
        for i in range(self.N):
            submit_unique(w, i, results)
        w.settle(2.0)
        if w.sim.fault is not None:
            w.sim.fault.disarm()         # verification traffic is fault-free
        return w, results

    @pytest.mark.parametrize("chaos_seed", [0, 1, 2, 3, 4, 5])
    def test_acked_txs_survive_randomized_faults(self, chaos_seed):
        ref, ref_results = self._run(None)
        assert all(r.ok for r in ref_results.values())

        plan = FaultPlan.random(chaos_seed, n_gk=2, n_shards=3)
        w, results = self._run(plan)
        assert len(results) == self.N, "a client session hung"
        acked = check_acked_visible(w, results)
        # only a surfaced budget error may stand between a client and an ack
        for v, r in results.items():
            if not r.ok:
                assert r.error == "client retry budget exhausted", \
                    f"{v}: unexplained abort {r.error!r}"
        # acked state == the fault-free run's committed prefix
        for v in acked:
            sv, rv = w.store.vertices[v], ref.store.vertices[v]
            assert sv.props["score"][-1][0] == rv.props["score"][-1][0]
            assert sorted(dst for dst, _, dts in sv.edges.values()
                          if dts is None) == \
                sorted(dst for dst, _, dts in rv.edges.values()
                       if dts is None)
        # both recovery paths still agree after the dust settles
        assert_replay_equals_walk(w)
