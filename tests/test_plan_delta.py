"""Frontier plans under write traffic: ShardPlan delta refresh must be
indistinguishable from a cold rebuild (randomized churn incl. GC and
compaction), programs must survive transactions committing BETWEEN hops
(snapshot isolation + delta plans, never cold rebuilds on the happy
path), same-(prog, stamp) deliveries must coalesce at the shard, and a
plan cache lagging the bounded compaction-event history must fall back
cold — including invalidating *settled* plans.  Seeded-random, tier-1."""

import numpy as np
import pytest

from repro.core import Weaver, WeaverConfig
from repro.core import analytics as A
from repro.core import frontier as F
from repro.core.analytics import SnapshotEngine
from repro.core.clock import Stamp
from repro.core.mvgraph import MVGraphPartition


class _Stamps:
    """Totally-ordered synthetic stamps (round-robin gatekeepers)."""

    def __init__(self, n_gk):
        self.n_gk = n_gk
        self.clock = [0] * n_gk
        self.i = 0

    def next(self):
        g = self.i % self.n_gk
        self.i += 1
        self.clock[g] += 1
        return Stamp(0, tuple(self.clock), g, self.clock[g])

    def query(self):
        g = self.i % self.n_gk
        self.i += 1
        self.clock = [c + 1 for c in self.clock]
        return Stamp(0, tuple(self.clock), g, self.clock[g])


def make_weaver(seed=0, n_shards=3, **kw):
    return Weaver(WeaverConfig(n_gatekeepers=2, n_shards=n_shards,
                               gc_period=0, seed=seed, **kw))


def mutate_partition(rng, p, sg, live, edges, round_i):
    """One churn round against a single MVGraphPartition."""
    for _ in range(int(rng.integers(5, 25))):
        op = rng.integers(0, 100)
        if op < 25 or not live:
            vid = f"v{round_i}_{rng.integers(0, 1 << 30)}"
            if vid in live:
                continue
            p.create_vertex(vid, sg.next())
            live.append(vid)
        elif op < 55:
            s, d = str(rng.choice(live)), str(rng.choice(live))
            e = p.create_edge(s, d, sg.next())
            edges.append((s, e.eid))
            if rng.random() < 0.5:
                p.set_edge_prop(s, e.eid, "weight",
                                float(rng.integers(1, 5)), sg.next())
            if rng.random() < 0.3:
                p.set_edge_prop(s, e.eid, "rel",
                                str(rng.choice(["F", "G"])), sg.next())
        elif op < 70 and edges:
            s, eid = edges[int(rng.integers(0, len(edges)))]
            if s not in live:
                continue
            e = p.vertices[s].out_edges.get(eid)
            if e is not None and e.delete_ts is None:
                p.delete_edge(s, eid, sg.next())
        elif op < 82 and live:
            vid = str(rng.choice(live))
            p.set_vertex_prop(vid, "value", int(rng.integers(0, 9)),
                              sg.next())
        elif len(live) > 2:
            vid = str(rng.choice(live))
            p.delete_vertex(vid, sg.next())
            live.remove(vid)


class TestPlanRefreshEqualsCold:
    """ShardPlan.refresh == fresh ShardPlan at the same stamp, for every
    derived structure, under randomized churn + GC + compaction and
    advancing stamps."""

    def _assert_equal(self, warm, cold, tag):
        assert np.array_equal(warm.v_visible, cold.v_visible), tag
        assert np.array_equal(warm.e_vis, cold.e_vis), tag
        assert np.array_equal(warm.e_keep, cold.e_keep), tag
        # CSR: same (src, dst, slot) multiset, src-sorted (parallel-edge
        # order within equal (src, dst) is unspecified)
        tw = sorted(zip(warm.esrc.tolist(), warm.edst.tolist(),
                        warm.eslot.tolist()))
        tc = sorted(zip(cold.esrc.tolist(), cold.edst.tolist(),
                        cold.eslot.tolist()))
        assert tw == tc, tag
        if warm.esrc.size:
            assert np.all(np.diff(warm.esrc) >= 0), tag
        assert warm.settled == cold.settled, tag
        for t in ("v", "e"):
            assert np.array_equal(warm._p_before[t], cold._p_before[t]), tag
        for table, key in (("e", "weight"), ("e", "rel"), ("v", "value")):
            iw, nw = warm._prop_arrays(table, key)
            ic, nc = cold._prop_arrays(table, key)
            assert np.array_equal(iw, ic), (tag, table, key)
            assert np.array_equal(np.isnan(nw), np.isnan(nc)), tag
            assert np.array_equal(nw[~np.isnan(nw)], nc[~np.isnan(nc)]), tag

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_randomized_churn(self, seed):
        rng = np.random.default_rng(seed)
        p = MVGraphPartition(2)
        sg = _Stamps(2)
        live, edges = [], []
        mutate_partition(rng, p, sg, live, edges, 0)
        at = sg.query()
        warm = F.ShardPlan(p.columns, at, 2)
        # populate per-key caches so refreshes must delta-patch them
        warm._prop_arrays("e", "weight")
        warm._prop_arrays("v", "value")
        compactions = 0
        for r in range(1, 35):
            mutate_partition(rng, p, sg, live, edges, r)
            if r % 4 == 0:
                p.collect(Stamp(0, tuple(sg.clock), -1, 0))
            if r % 7 == 0 and p.columns.dead_fraction() > 0:
                p.columns.compact()
            if r % 3 == 0:
                at = sg.query()      # advance the stamp sometimes
            assert warm.refresh(at), (seed, r)
            cold = F.ShardPlan(p.columns, at, 2)
            cold._prop_arrays("e", "weight")
            cold._prop_arrays("v", "value")
            self._assert_equal(warm, cold, (seed, r))
            compactions = p.columns.n_compactions
        assert compactions > 0, "compaction path never exercised"

    def test_refresh_refuses_backward_stamp(self):
        p = MVGraphPartition(2)
        sg = _Stamps(2)
        p.create_vertex("a", sg.next())
        s1 = sg.query()
        s2 = sg.query()
        plan = F.ShardPlan(p.columns, s2, 2)
        assert not plan.refresh(s1)     # s1 ≺ s2: plans only move forward


class TestInterleavedWrites:
    """Transactions committing between program hops: results must be the
    snapshot at T_prog (frontier == scalar == analytics), and plans must
    delta-refresh, not cold-rebuild."""

    def _seed_graph(self, w, sg, rng, n=60, m=260):
        part = lambda v: w.shards[w.store.place(v)].partition
        vids = [f"u{i}" for i in range(n)]
        for v in vids:
            part(v).create_vertex(v, sg.next())
        eids = []
        seen = set()
        for _ in range(m):
            a, b = rng.integers(0, n, 2)
            if a == b or (a, b) in seen:
                continue
            seen.add((a, b))
            e = part(vids[a]).create_edge(vids[a], vids[b], sg.next())
            part(vids[a]).set_edge_prop(vids[a], e.eid, "weight",
                                        float(1 + (e.eid % 4)), sg.next())
            eids.append((vids[a], e.eid, vids[b]))
        return vids, eids

    @pytest.mark.parametrize("seed", [0, 1])
    def test_on_hop_churn_frontier_scalar_analytics(self, seed):
        rng = np.random.default_rng(seed)
        w = make_weaver(seed, n_shards=4)
        sg = _Stamps(2)
        vids, eids = self._seed_graph(w, sg, rng)
        part = lambda v: w.shards[w.store.place(v)].partition
        at = sg.query()
        place = lambda vid: w.store.place(vid)

        def churn(hop):
            """~1% of edges mutated between hops, stamps AFTER the query
            stamp — invisible at T_prog by snapshot isolation."""
            for _ in range(3):
                s, eid, _ = eids[int(rng.integers(0, len(eids)))]
                e = part(s).vertices[s].out_edges.get(eid)
                if e is not None and e.delete_ts is None:
                    part(s).delete_edge(s, eid, sg.next())
            for _ in range(3):
                a, b = rng.integers(0, len(vids), 2)
                if a != b:
                    e = part(vids[a]).create_edge(vids[a], vids[b],
                                                  sg.next())
                    eids.append((vids[a], e.eid, vids[b]))
                    part(vids[a]).set_edge_prop(
                        vids[a], e.eid, "weight", 2.0, sg.next())

        src, tgt = vids[0], vids[7]
        # analytics reference BEFORE any churn (churn is invisible at
        # `at`, so it must also match the post-churn runs)
        ga = SnapshotEngine(w).snapshot(at)
        lv = np.asarray(A.bfs_levels_ga(ga, [ga.index[src]]))
        want = sorted(ga.vids[i] for i in np.nonzero(lv < A.INF)[0])

        r_delta, st_delta = F.run_local(
            w, "traverse", [(src, {"depth": 0})], at, use_frontier=True,
            shard_of=place, on_hop=churn, plan_delta=True)
        r_cold, st_cold = F.run_local(
            w, "traverse", [(src, {"depth": 0})], at, use_frontier=True,
            shard_of=place, on_hop=churn, plan_delta=False)
        r_scalar, _ = F.run_local(
            w, "traverse", [(src, {"depth": 0})], at, use_frontier=False,
            shard_of=place)
        assert r_delta == r_cold == r_scalar == want
        # the patch-consumption counter proves refreshes were DELTA:
        # at most one cold build per shard, the rest consumed patches
        assert st_delta["plan_cold"] <= len(w.shards)
        assert st_delta["plan_delta"] > 0
        assert st_delta["plan_rows"] > 0
        # the forced-cold baseline rebuilt beyond the initial builds
        assert st_cold["plan_cold"] > st_delta["plan_cold"]
        assert st_cold["plan_delta"] == 0

        # sssp through the same churn (prop columns delta-refreshed too)
        q = [(src, {"target": tgt, "max_depth": 64})]
        d_delta, st2 = F.run_local(w, "sssp", q, at, use_frontier=True,
                                   shard_of=place, on_hop=churn)
        d_scalar, _ = F.run_local(w, "sssp", q, at, use_frontier=False,
                                  shard_of=place)
        assert d_delta == d_scalar
        assert st2["plan_delta"] > 0

    @pytest.mark.parametrize("seed", [5])
    def test_simulator_interleaved_schedule(self, seed):
        """Randomized schedule of committed transactions between
        programs through the full simulator.  During churn, each
        deployment's result must equal the engine snapshot at the
        program's OWN stamp (write stamps concurrent with T_prog are
        refined per deployment, so frontier and scalar deployments may
        legitimately serialize the same history differently — their
        results are only directly comparable on a quiescent graph,
        asserted at the end).  The shard plan caches must delta-refresh
        across the write traffic (cold builds bounded by shard count)."""
        rng = np.random.default_rng(seed)
        cfgs = dict(n_gatekeepers=2, n_shards=4, seed=seed)
        w_f = Weaver(WeaverConfig(frontier_progs=True, **cfgs))
        w_s = Weaver(WeaverConfig(frontier_progs=False, **cfgs))
        n = 50

        def do_tx(build):
            for w in (w_f, w_s):
                tx = w.begin_tx()
                build(tx)
                assert w.run_tx(tx).ok

        do_tx(lambda tx: [tx.create_vertex(f"u{i}") for i in range(n)])
        seen = set()

        def fresh_pairs(k=6):
            """One precomputed batch, applied IDENTICALLY to both
            deployments."""
            out = []
            for _ in range(k):
                a, b = rng.integers(0, n, 2)
                if a != b and (a, b) not in seen:
                    seen.add((a, b))
                    out.append((f"u{a}", f"u{b}"))
            return out

        def wr(tx):
            for a, b in pairs:
                tx.create_edge(a, b)

        def reference(w, src, stamp):
            ga = A.snapshot_arrays(w, stamp)
            if src not in ga.index:
                return [src] if any(
                    src in sh.partition.vertices for sh in w.shards) \
                    else []
            lv = np.asarray(A.bfs_levels_ga(ga, [ga.index[src]]))
            return sorted(ga.vids[i] for i in np.nonzero(lv < A.INF)[0])

        pairs = fresh_pairs(20)
        do_tx(wr)
        for round_i in range(8):
            seen_r = set(seen)
            pairs = fresh_pairs()
            do_tx(wr)
            src = f"u{int(rng.integers(0, n))}"
            for w in (w_f, w_s):
                r, stamp, _ = w.run_program(
                    "traverse", [(src, {"depth": 0})], timeout=60.0)
                assert r == reference(w, src, stamp), (round_i, src)
            assert seen_r != seen      # writes really interleaved
        # quiescent graph: now the two deployments must agree exactly
        for w in (w_f, w_s):
            w.settle(50e-3)
        r_f, _, _ = w_f.run_program("traverse", [("u0", {"depth": 0})],
                                    timeout=60.0)
        r_s, _, _ = w_s.run_program("traverse", [("u0", {"depth": 0})],
                                    timeout=60.0)
        assert r_f == r_s
        c = w_f.counters()
        assert c["plan_delta_refreshes"] > 0, "delta path never used"
        # cold builds only on first contact per shard (plus rare
        # stamp-regression rebuilds); far fewer than one per query
        assert c["plan_cold_builds"] <= 2 * len(w_f.shards)


class TestCoalescing:
    """Same-(prog, stamp) frontier deliveries waiting at a shard merge
    into ONE execution; results and termination are unchanged."""

    def _social(self, w, n=120, m=900, seed=0):
        rng = np.random.default_rng(seed)
        tx = w.begin_tx()
        for i in range(n):
            tx.create_vertex(f"u{i}")
        seen = set()
        for _ in range(m):
            a, b = rng.integers(0, n, 2)
            if a != b and (a, b) not in seen:
                seen.add((a, b))
                tx.create_edge(f"u{a}", f"u{b}")
        assert w.run_tx(tx).ok

    def test_merged_executions_same_results(self):
        cfgs = dict(n_gatekeepers=2, n_shards=6, seed=9)
        w_on = Weaver(WeaverConfig(frontier_coalesce=True, **cfgs))
        w_off = Weaver(WeaverConfig(frontier_coalesce=False, **cfgs))
        w_s = Weaver(WeaverConfig(frontier_progs=False, **cfgs))
        for w in (w_on, w_off, w_s):
            self._social(w)
        results = {}
        for name, w in (("on", w_on), ("off", w_off), ("scalar", w_s)):
            r, _, _ = w.run_program("traverse", [("u0", {"depth": 0})],
                                    timeout=60.0)
            results[name] = r
        assert results["on"] == results["off"] == results["scalar"]
        assert len(results["on"]) > 20
        c_on, c_off = w_on.counters(), w_off.counters()
        assert c_on["frontier_coalesced"] > 0
        assert c_off["frontier_coalesced"] == 0
        # frontier_batches counts EXECUTIONS: with many source shards
        # per hop, coalescing collapses them to O(active shards) per hop
        assert c_on["frontier_batches"] < c_off["frontier_batches"]

    def test_sssp_coalesces_with_payload(self):
        """Payload-carrying frontiers (sssp dists) merge too — the
        segment-min inside the step folds the concatenated offers."""
        cfgs = dict(n_gatekeepers=2, n_shards=6, seed=3)
        w_on = Weaver(WeaverConfig(frontier_coalesce=True, **cfgs))
        w_s = Weaver(WeaverConfig(frontier_progs=False, **cfgs))
        for w in (w_on, w_s):
            self._social(w, seed=4)
        ent = [("u0", {"target": "u97", "max_depth": 64})]
        r_on, _, _ = w_on.run_program("sssp", ent, timeout=60.0)
        r_s, _, _ = w_s.run_program("sssp", ent, timeout=60.0)
        assert r_on == r_s
        assert w_on.counters()["frontier_coalesced"] > 0


class TestCompactionLag:
    """A plan cache lagging the bounded (8-event) CompactionEvent
    history must rebuild cold — and never keep serving a stale settled
    plan across stamps."""

    def test_run_local_compacts_between_hops(self):
        """>8 forced compactions between hops: the mid-query refresh
        fails, the fallback rebuilds cold, results stay == scalar."""
        rng = np.random.default_rng(2)
        w = make_weaver(2, n_shards=3)
        sg = _Stamps(2)
        part = lambda v: w.shards[w.store.place(v)].partition
        vids = [f"u{i}" for i in range(40)]
        for v in vids:
            part(v).create_vertex(v, sg.next())
        for _ in range(180):
            a, b = rng.integers(0, 40, 2)
            if a != b:
                part(vids[a]).create_edge(vids[a], vids[b], sg.next())
        at = sg.query()
        place = lambda vid: w.store.place(vid)

        def compact_storm(hop):
            # churn (stamps after `at`, invisible) + >8 compactions per
            # shard: every plan's event cursor falls off the history
            for _ in range(3):
                a, b = rng.integers(0, 40, 2)
                if a != b:
                    part(vids[a]).create_edge(vids[a], vids[b], sg.next())
            for sh in w.shards:
                for _ in range(9):
                    sh.partition.columns.compact()

        r_f, st = F.run_local(w, "traverse", [(vids[0], {"depth": 0})],
                              at, use_frontier=True, shard_of=place,
                              on_hop=compact_storm)
        r_s, _ = F.run_local(w, "traverse", [(vids[0], {"depth": 0})],
                             at, use_frontier=False, shard_of=place)
        assert r_f == r_s
        assert st["hops"] > 1, "graph too small to span hops"
        # the storm forced cold fallbacks beyond the initial builds
        assert st["plan_cold"] > len(w.shards)

    def test_settled_plan_invalidated_by_lagged_history(self):
        """A SETTLED plan (reusable across stamps on a quiet shard) must
        be discarded — not reused — when writes + >8 compactions race
        past its cursor."""
        w = make_weaver(1, n_shards=1)
        sh = w.shards[0]
        sg = _Stamps(2)
        p = sh.partition
        p.create_vertex("a", sg.next())
        p.create_vertex("b", sg.next())
        e = p.create_edge("a", "b", sg.next())
        s1 = sg.query()
        plan1 = sh._frontier_plan(s1)
        assert plan1.settled
        assert sh._frontier_plan(sg.query()) is plan1   # settled reuse
        # a visible-at-later-stamps delete, then blow the event history
        p.delete_edge("a", e.eid, sg.next())
        for _ in range(9):
            p.columns.compact()
        assert p.columns.events_dropped > 0
        s3 = sg.query()
        plan3 = sh._frontier_plan(s3)
        assert plan3 is not plan1, "stale settled plan reused"
        # and the new plan sees the delete
        gid = np.asarray([w.intern.ids["a"]], np.int64)
        assert int(plan3.out_degree(gid)[0]) == 0
        assert int(plan1.out_degree(np.asarray(
            [w.intern.ids["a"]], np.int64))[0]) == 1   # the stale view
        c = w.sim.counters
        assert c.plan_cold_builds >= 2

    def test_refresh_fails_cleanly_when_history_dropped(self):
        p = MVGraphPartition(2)
        sg = _Stamps(2)
        p.create_vertex("x", sg.next())
        at = sg.query()
        plan = F.ShardPlan(p.columns, at, 2)
        p.create_vertex("y", sg.next())
        for _ in range(9):
            p.columns.compact()
        assert p.columns.events_dropped > 0
        assert not plan.refresh(sg.query())
