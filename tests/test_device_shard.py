"""Device-sharded columnar data plane (``device_shard_columns``).

The sharded visibility path must be *bit-identical* to the host-global
oracle under randomized churn — creates, deletes, re-creates, GC purges
and forced compaction remaps — because it is the same int32 comparison
over the same packed stamp rows, only resident per mesh device.

The equivalence body runs three ways:

* in-process on the default single CPU device (tier-1, always),
* in subprocesses under ``--xla_force_host_platform_device_count={2,4}``
  so ``shard_map`` really distributes blocks over multiple devices
  (jax locks the device count at first init, hence subprocesses; CI
  runs these under its forced-8-device stage as well).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Shared equivalence body.  Drives IDENTICAL synthetic mutation streams
# into two Weavers (device-sharded vs host-global), snapshots both every
# round (warm/delta engines plus a cold engine on the sharded side) and
# asserts the columnar arrays are bit-equal.  Exercises GC purges and a
# forced compaction so CompactionEvent remaps flow through the plane's
# block re-upload path.
CHURN_SRC = '''
def churn_equivalence(seed, rounds=10, forced_compaction_round=5):
    import numpy as np
    from repro.core import Weaver, WeaverConfig
    from repro.core.analytics import SnapshotEngine
    from repro.core.clock import Stamp

    class Stamps:
        def __init__(self, n_gk):
            self.n_gk = n_gk
            self.clock = [0] * n_gk
            self.i = 0

        def next(self):
            g = self.i % self.n_gk
            self.i += 1
            self.clock[g] += 1
            return Stamp(0, tuple(self.clock), g, self.clock[g])

        def query(self):
            self.i += 1
            self.clock = [c + 1 for c in self.clock]
            return Stamp(0, tuple(self.clock), 0, self.clock[0])

    def mk(flag):
        return Weaver(WeaverConfig(n_gatekeepers=2, n_shards=3,
                                   gc_period=0, seed=seed,
                                   device_shard_columns=flag))

    def apply(w, sg, op):
        part = lambda v: w.shards[w.store.place(v)].partition
        kind = op[0]
        if kind == "cv":
            part(op[1]).create_vertex(op[1], sg.next())
        elif kind == "ce":
            part(op[1]).create_edge(op[1], op[2], sg.next())
        elif kind == "de":
            s, eid = op[1], op[2]
            e = part(s).vertices[s].out_edges.get(eid)
            if e is not None and e.delete_ts is None:
                part(s).delete_edge(s, eid, sg.next())
        elif kind == "dv":
            part(op[1]).delete_vertex(op[1], sg.next())
        elif kind == "gc":
            horizon = Stamp(0, tuple(sg.clock), -1, 0)
            for sh in w.shards:
                sh.partition.collect(horizon)
        elif kind == "compact":
            for sh in w.shards:
                cols = sh.partition.columns
                if cols is not None:
                    cols.compact()

    w_dev, w_host = mk(True), mk(False)
    assert w_dev.device_plane is not None
    assert w_host.device_plane is None
    sg_dev, sg_host = Stamps(2), Stamps(2)
    eng_dev, eng_host = SnapshotEngine(w_dev), SnapshotEngine(w_host)

    rng = np.random.default_rng(seed)
    live, dead, edges = set(), set(), []
    compacted = False
    for round_i in range(rounds):
        ops = []
        for _ in range(int(rng.integers(5, 25))):
            roll = rng.integers(0, 100)
            if roll < 35 or not live:
                vid = "v%d_%d" % (round_i, rng.integers(0, 1 << 30))
                if vid in live or vid in dead:
                    continue
                ops.append(("cv", vid))
                live.add(vid)
            elif roll < 65:
                s = str(rng.choice(sorted(live)))
                d = str(rng.choice(sorted(live | dead)))
                ops.append(("ce", s, d))
                edges.append((s, round_i))
            elif roll < 75 and edges:
                s, _ = edges[int(rng.integers(0, len(edges)))]
                if s in live:
                    ops.append(("de", s, 1))
            elif roll < 88 and len(live) > 1:
                vid = str(rng.choice(sorted(live)))
                ops.append(("dv", vid))
                live.discard(vid)
                dead.add(vid)
            else:
                ops.append(("gc",))
        if round_i == forced_compaction_round:
            ops.append(("gc",))
            ops.append(("compact",))
            compacted = True
        for op in ops:
            apply(w_dev, sg_dev, op)
            apply(w_host, sg_host, op)
        assert sg_dev.clock == sg_host.clock
        at_dev, at_host = sg_dev.query(), sg_host.query()

        # like-for-like bit-identity: delta vs delta and cold vs cold
        # (cold rebuilds order rows from post-GC slot order, delta keeps
        # history order — comparing across engines would need canon())
        pairs = [
            (eng_dev.snapshot(at_dev), eng_host.snapshot(at_host)),
            (SnapshotEngine(w_dev).snapshot(at_dev),
             SnapshotEngine(w_host).snapshot(at_host)),
        ]
        for got, want in pairs:
            assert got.vids[:got.n_nodes] == want.vids[:want.n_nodes]
            assert np.array_equal(got.edge_src, want.edge_src)
            assert np.array_equal(got.edge_dst, want.edge_dst)

    stats = w_dev.device_plane.stats
    assert stats["launches"] > 0, stats
    assert stats["rebuilds"] >= 1, stats
    assert compacted and stats["block_uploads"] > 0, stats
    assert eng_dev.stats["delta"] + eng_dev.stats["delta_noop"] > 0
    return stats
'''

_NS = {}
exec(CHURN_SRC, _NS)
_churn_equivalence = _NS["churn_equivalence"]


def run_sub(body: str, devices: int) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, {os.path.join(REPO, 'src')!r})
        import jax
        assert len(jax.devices()) == {devices}
    """) + CHURN_SRC + textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\n" \
                                 f"STDERR:\n{proc.stderr[-3000:]}"
    return proc.stdout


class TestShardedEqualsHostGlobal:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_single_device_churn(self, seed):
        """In-process coverage on the default 1-device CPU mesh."""
        stats = _churn_equivalence(seed, rounds=8)
        assert stats["launches"] >= 8

    @pytest.mark.parametrize("devices", [2, 4])
    def test_forced_multi_device_churn(self, devices):
        """Real multi-device shard_map: blocks distributed over forced
        host devices, masks still bit-identical to the host oracle."""
        out = run_sub(f"""
            import jax
            stats = churn_equivalence(0, rounds=6)
            # the mesh really had {devices} devices
            from repro.launch.mesh import make_columns_mesh
            assert make_columns_mesh().devices.size == {devices}
            print("DEVICE_SHARD_OK", stats)
        """, devices=devices)
        assert "DEVICE_SHARD_OK" in out

    def test_program_path_through_sharded_plans(self):
        """run_program (ShardPlan cold builds) agrees end-to-end through
        the real tx pipeline with sharding on vs off."""
        results = {}
        for flag in (False, True):
            from repro.core import Weaver, WeaverConfig
            w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=3, seed=11,
                                    device_shard_columns=flag))
            tx = w.begin_tx()
            for i in range(14):
                tx.create_vertex(f"n{i}")
            for i in range(13):
                tx.create_edge(f"n{i}", f"n{i+1}")
            assert w.run_tx(tx).ok
            tx = w.begin_tx()
            tx.delete_vertex("n7")
            assert w.run_tx(tx).ok
            r_reach, _, _ = w.run_program(
                "reachable", [("n0", {"target": "n13"})])
            r_count, _, _ = w.run_program("count_edges", [("n3", None)])
            results[flag] = (r_reach, r_count)
            if flag:
                assert w.device_plane.stats["launches"] > 0
        assert results[True] == results[False]
