"""Change-feed read replicas: the replica-consistency battery (ISSUE 10).

The contract under test: a replica-served read at stamp ``w`` is
**bit-identical** to the primary-served read at ``w`` — not eventually,
not approximately, but at the same stamp, because replicas only serve
at stamps the primary has *settled* (bound to a feed position covering
every write visible at ``w``) and only once their applied position
reaches that token.  ``frontier.run_local`` — the synchronous primary-
partition executor every equivalence test in this repo leans on — is
the oracle: each simulated read's callback immediately re-executes the
program locally at the SAME stamp and compares.  Verification has to be
immediate (inside the callback): after a program completes, GC may
prune the versions its stamp needs, so end-of-run re-execution at old
stamps would be unsound.

The battery covers the quiet path, randomized chaos (feed drop / dup /
delay, replica lag bursts, actor crashes — across GC, compaction and
write churn), primary kill + replica promotion, and the session
guarantees (read-your-writes, monotonic reads) when consecutive reads
of one session land on different replicas and pods.
"""

import pytest

from repro.core import Weaver, WeaverConfig
from repro.core import frontier as F
from repro.core.faultinject import FaultAction, FaultPlan


def make_weaver(plan=None, **kw):
    kw.setdefault("n_gatekeepers", 2)
    kw.setdefault("n_shards", 3)
    kw.setdefault("n_replicas", 2)
    kw.setdefault("seed", 7)
    kw.setdefault("read_group_commit", 1e-3)
    kw.setdefault("read_window_alias", True)
    return Weaver(WeaverConfig(fault_plan=plan, **kw))


def seed_graph(w, n=16):
    """A little multi-shard web: a hub, a chain, some props."""
    tx = w.begin_tx()
    tx.create_vertex("hub")
    for i in range(n):
        tx.create_vertex(f"v{i}")
    for i in range(n):
        tx.create_edge(f"v{i}", "hub")
        if i + 1 < n:
            tx.create_edge(f"v{i}", f"v{i+1}")
        tx.set_vertex_prop(f"v{i}", "score", float(i))
    assert w.run_tx(tx).ok
    w.settle(50e-3)          # let replicas cold-sync the seed state


class BitIdentityChecker:
    """Read callback factory: every completed read is IMMEDIATELY
    re-executed on the primary partitions at the same stamp via
    ``run_local`` and compared.  Collects mismatches instead of raising
    so a failure reports every divergent read at once."""

    def __init__(self, w):
        self.w = w
        self.checked = 0
        self.unresolved = 0
        self.mismatches = []

    def cb(self, name, entries):
        def _cb(r, s, l):
            if r is None:          # surfaced retry-budget error: the
                self.unresolved += 1   # session resolved, nothing to check
                return
            ref, _ = F.run_local(self.w, name, entries, s)
            self.checked += 1
            if r != ref:
                self.mismatches.append((name, entries, s, r, ref))
        return _cb

    def assert_clean(self, min_checked):
        assert not self.mismatches, self.mismatches[:3]
        assert self.checked >= min_checked, \
            (self.checked, self.unresolved)


READS = [("count_edges", lambda i: [(f"v{i % 16}", None)]),
         ("traverse", lambda i: [(f"v{i % 16}", {"depth": 0,
                                                 "max_depth": 2})]),
         ("get_node", lambda i: [(f"v{i % 16}", None)])]


def churn_and_read(w, chk, rounds=8, reads_per_round=4):
    """Interleave write churn with sequential read windows.  Sequential
    quiescent reads alias onto one shared stamp: the first window is
    primary-served (and settles the stamp), later ones are eligible for
    replica serving — the hot path under test."""
    for i in range(rounds):
        tx = w.begin_tx()
        tx.create_vertex(f"w{i}")
        tx.create_edge(f"v{i % 16}", f"w{i}")
        tx.set_vertex_prop(f"v{i % 16}", "score", 100.0 + i)
        w.submit_tx(tx, lambda r: None)
        w.settle(2e-3)
        for j in range(reads_per_round):
            name, mk = READS[(i + j) % len(READS)]
            entries = mk(i + j)
            w.run_program(name, entries, timeout=5.0)
            # re-submit through the checker path too (async; verified
            # in-callback whenever it completes)
            w.submit_program(name, entries, chk.cb(name, entries))
            w.settle(2e-3)
    w.settle(0.2)


class TestReplicaServing:
    def test_quiescent_reads_hit_replicas_bit_identically(self):
        w = make_weaver()
        seed_graph(w)
        chk = BitIdentityChecker(w)
        for j in range(10):
            name, mk = READS[j % len(READS)]
            entries = mk(j)
            w.submit_program(name, entries, chk.cb(name, entries))
            w.settle(3e-3)
        w.settle(0.1)
        chk.assert_clean(min_checked=10)
        c = w.sim.counters
        assert c.replica_reads_served > 0, c.snapshot()
        assert c.stamps_settled > 0

    def test_replicas_off_is_bit_identical_noop(self):
        """n_replicas=0 keeps the whole feed/settlement machinery cold:
        zero replica counters, identical results."""
        w = make_weaver(n_replicas=0)
        seed_graph(w)
        out = [w.run_program("count_edges", [("v0", None)])[0]
               for _ in range(4)]
        assert out == [out[0]] * 4
        c = w.sim.counters
        assert c.replica_feed_pulls == 0
        assert c.stamps_settled == 0
        assert c.replica_reads_served == 0

    def test_feed_survives_gc_and_churn(self):
        """Write churn + periodic GC truncate the feed tail; replicas
        keep up (or cold-resync) and reads stay bit-identical."""
        w = make_weaver(gc_period=10e-3)
        seed_graph(w)
        chk = BitIdentityChecker(w)
        churn_and_read(w, chk, rounds=10)
        chk.assert_clean(min_checked=30)
        c = w.sim.counters
        assert c.replica_feed_entries > 0     # incremental path exercised
        assert c.replica_reads_served > 0


class TestReplicaChaos:
    """Randomized fault schedules over the change-feed channel (drop /
    dup / delay, sustained lag bursts) plus actor crashes: every
    resolved read must still be bit-identical to the primary at its
    stamp — replicas may fall behind or hand reads back, but may never
    serve a stale or divergent answer."""

    @pytest.mark.parametrize("chaos_seed", [0, 1, 2, 3])
    def test_chaos_bit_identity(self, chaos_seed):
        plan = FaultPlan.random(chaos_seed, n_gk=2, n_shards=3,
                                n_crashes=1, replica_faults=True)
        w = make_weaver(plan, write_group_commit=0.5e-3,
                        read_retry_timeout=20e-3,
                        gc_period=20e-3)
        w.sim.fault.disarm()
        seed_graph(w)
        w.sim.fault.arm()
        chk = BitIdentityChecker(w)
        churn_and_read(w, chk, rounds=8)
        w.sim.fault.disarm()
        w.settle(0.5)
        chk.assert_clean(min_checked=16)
        # the schedule actually hit the feed channel
        c = w.sim.counters
        assert (c.msgs_dropped + c.msgs_duplicated + c.msgs_delayed
                + c.crashes_injected) > 0, c.snapshot()

    def test_feed_faults_only_replicas_still_serve(self):
        """Feed-channel-only faults (no crashes): strict cursor
        matching absorbs drop/dup/delay, replicas catch back up and
        keep serving bit-identically."""
        plan = FaultPlan([
            FaultAction("drop", target="feed_apply", after=2, count=3),
            FaultAction("dup", target="feed_apply", after=6, count=3),
            FaultAction("delay", target="feed_pull", after=1, count=4,
                        delay=3e-3),
            FaultAction("dup", target="feed_reset", after=0, count=2),
        ])
        w = make_weaver(plan)
        w.sim.fault.disarm()
        seed_graph(w)
        w.sim.fault.arm()
        chk = BitIdentityChecker(w)
        churn_and_read(w, chk, rounds=8)
        w.sim.fault.disarm()
        w.settle(0.5)
        chk.assert_clean(min_checked=24)
        c = w.sim.counters
        assert c.msgs_dropped + c.msgs_duplicated + c.msgs_delayed > 0
        assert c.replica_reads_served > 0, c.snapshot()


class TestReplicaPromotion:
    def test_primary_kill_promotes_most_caught_up_replica(self):
        w = make_weaver(heartbeat_period=2e-3)
        seed_graph(w)
        chk = BitIdentityChecker(w)
        # pre-kill reads (and their settled stamps)
        churn_and_read(w, chk, rounds=3)
        pre = w.run_program("traverse", [("v0", {"depth": 0})])[0]
        w.kill("shard0")
        w.settle(0.3)           # heartbeat loss -> promote_backup
        c = w.sim.counters
        assert c.replica_promotions == 1, c.snapshot()
        assert len(w.replicas[0]) == 1    # the promoted one left the pool
        # bit-identity holds across the promotion: the adopted partition
        # answers exactly like the dead primary did
        post = w.run_program("traverse", [("v0", {"depth": 0})])[0]
        assert post == pre
        churn_and_read(w, chk, rounds=3)
        chk.assert_clean(min_checked=12)
        # survivors resubscribed to the new incarnation
        assert c.replica_cold_resyncs > w.cfg.n_shards * w.cfg.n_replicas

    def test_promotion_disabled_falls_back_to_wal_recovery(self):
        w = make_weaver(heartbeat_period=2e-3, replica_promotion=False)
        seed_graph(w)
        before = w.run_program("count_edges", [("v0", None)])[0]
        w.kill("shard0")
        w.settle(0.3)
        c = w.sim.counters
        assert c.replica_promotions == 0
        assert len(w.replicas[0]) == 2    # pool untouched
        assert w.run_program("count_edges", [("v0", None)])[0] == before

    def test_killing_a_replica_is_harmless(self):
        w = make_weaver()
        seed_graph(w)
        w.kill("shard0r0")
        out = [w.run_program("count_edges", [("v0", None)])[0]
               for _ in range(6)]
        assert out == [out[0]] * 6
        assert not w.replicas[0][0].alive


class TestSessionGuarantees:
    """The session guarantees must hold by stamp-frontier gating — not
    by luck of which server answered — so both tests run with replicas
    serving, message-delay faults in flight, and assert the replica
    path was actually taken."""

    def test_read_your_writes_across_replicas(self):
        """After an acked write, the very next read must see it even
        when earlier reads were replica-served: the write bumps the
        store's mutation seqno, so the next window gets a FRESH stamp
        (never aliased onto a pre-write settled stamp)."""
        plan = FaultPlan([
            FaultAction("delay", target="feed_apply", after=0, count=20,
                        delay=4e-3),
        ])
        w = make_weaver(plan, read_your_writes=True)
        w.sim.fault.disarm()
        tx = w.begin_tx()
        tx.create_vertex("s")
        assert w.run_tx(tx).ok
        w.settle(50e-3)
        w.sim.fault.arm()
        for i in range(10):
            # warm reads: eligible for replica serving
            for _ in range(2):
                assert w.run_program("count_edges", [("s", None)])[0] == i
            tx = w.begin_tx()
            tx.create_vertex(f"e{i}")
            tx.create_edge("s", f"e{i}")
            assert w.run_tx(tx).ok        # acked = applied (RYW config)
            # read-your-write: immediately visible, laggy replicas
            # cannot be chosen for the fresh (unsettled) stamp
            assert w.run_program("count_edges", [("s", None)])[0] == i + 1
        w.sim.fault.disarm()
        assert w.sim.counters.replica_reads_served > 0, \
            w.counters()
        assert w.sim.counters.msgs_delayed > 0

    def test_monotonic_reads_across_pods(self):
        """One session's consecutive reads land on different servers in
        different pods (round-robin over eligible replicas + primary
        fallback); the observed counter must never step backwards —
        per-gatekeeper stamp monotonicity plus frontier gating, not
        server stickiness."""
        plan = FaultPlan([
            FaultAction("delay", target="feed_apply", after=3, count=12,
                        delay=3e-3),
            FaultAction("delay", target="feed_pull", after=5, count=8,
                        delay=2e-3),
        ])
        w = make_weaver(plan, pods=2, read_your_writes=True)
        w.sim.fault.disarm()
        tx = w.begin_tx()
        tx.create_vertex("s")
        assert w.run_tx(tx).ok
        w.settle(50e-3)
        w.sim.fault.arm()
        seen = []
        done = []
        for i in range(8):
            tx = w.begin_tx()
            tx.create_vertex(f"m{i}")
            tx.create_edge("s", f"m{i}")
            w.submit_tx(tx, done.append)
            # several reads pinned to gk0 while the write settles: some
            # windows alias (replica-eligible), some are fresh (primary)
            for _ in range(3):
                box = []
                w.submit_program("count_edges", [("s", None)],
                                 lambda r, s, l: box.append(r),
                                 gatekeeper=0)
                while not box and w.sim.pending():
                    w.sim.run(until=w.sim.now + 2e-3)
                seen.append(box[0])
        w.sim.fault.disarm()
        w.settle(0.3)
        assert all(b <= a for b, a in zip(seen, seen[1:])), seen
        assert sum(r.ok for r in done) == 8
        c = w.sim.counters
        assert c.replica_reads_served > 0, c.snapshot()
        assert c.cross_pod_msgs > 0


class TestPodTopology:
    def test_cross_pod_surcharge_only_between_pods(self):
        """Single-pod deployments never pay the surcharge; multi-pod
        ones tally every cross-pod hop."""
        w1 = make_weaver(pods=1)
        seed_graph(w1, n=4)
        assert w1.sim.counters.cross_pod_msgs == 0
        w2 = make_weaver(pods=2)
        seed_graph(w2, n=4)
        assert w2.sim.counters.cross_pod_msgs > 0

    def test_pod_map_overrides_round_robin(self):
        pm = {"gk0": 0, "gk1": 0, "shard0": 0, "shard1": 0, "shard2": 0}
        for s in range(3):
            for r in range(2):
                pm[f"shard{s}r{r}"] = 1
        w = make_weaver(pods=2, pod_map=pm)
        assert all(gk.pod == 0 for gk in w.gatekeepers)
        assert all(sh.pod == 0 for sh in w.shards)
        assert all(rep.pod == 1 for reps in w.replicas.values()
                   for rep in reps)

    def test_in_pod_replica_preferred(self):
        """With one replica co-located with the gatekeepers and one
        remote, the router prefers the in-pod replica — visible in the
        ``replica_read`` spans' replica ids."""
        pm = {"gk0": 0, "gk1": 0}
        for s in range(3):
            pm[f"shard{s}"] = 1
            pm[f"shard{s}r0"] = 0     # in-pod with the gatekeepers
            pm[f"shard{s}r1"] = 1
        w = make_weaver(pods=2, pod_map=pm, trace_sample_rate=1.0)
        seed_graph(w)
        for j in range(10):
            w.run_program("count_edges", [("v0", None)])
            w.settle(2e-3)
        served = [s for s in w.sim.tracer.spans
                  if s.stage == "replica_read"]
        assert served, "no replica-served reads recorded"
        assert all(s.attrs["replica"] == 0 for s in served), \
            [(s.attrs["shard"], s.attrs["replica"]) for s in served]
