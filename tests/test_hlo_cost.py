"""Validate the loop-aware HLO cost model against ground truth:
scan vs unroll must agree (XLA's own cost_analysis does NOT — it counts
while bodies once; this is the undercount the roofline correction fixes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


class TestLoopAwareCosting:
    def test_scan_matches_unroll_flops(self):
        d, n = 128, 8
        x = jax.ShapeDtypeStruct((d, d), jnp.float32)
        ws = jax.ShapeDtypeStruct((n, d, d), jnp.float32)

        def scanned(x, ws):
            def body(c, w):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, ws)
            return y

        def unrolled(x, ws):
            for i in range(n):
                x = x @ ws[i]
            return x

        expected = 2.0 * d ** 3 * n
        r_scan = hlo_cost.analyze(_compile(scanned, x, ws).as_text())
        r_unroll = hlo_cost.analyze(_compile(unrolled, x, ws).as_text())
        assert r_unroll.flops == pytest.approx(expected, rel=0.01)
        assert r_scan.flops == pytest.approx(expected, rel=0.01), \
            f"scan flops {r_scan.flops} != {expected} " \
            f"(trips seen: {r_scan.while_trips})"
        # XLA's own analysis undercounts the scan by ~n
        ca = _compile(scanned, x, ws).cost_analysis()
        if isinstance(ca, (list, tuple)):   # older jax returns [dict]
            ca = ca[0]
        assert ca["flops"] < expected / 2

    def test_trip_count_parsed(self):
        d, n = 64, 12
        x = jax.ShapeDtypeStruct((d,), jnp.float32)

        def f(x):
            def body(c, _):
                return c * 2.0, None
            y, _ = jax.lax.scan(body, x, None, length=n)
            return y

        r = hlo_cost.analyze(_compile(f, x).as_text())
        assert any(abs(t - n) <= 1 for t in r.while_trips.values()), \
            r.while_trips

    def test_collectives_inside_scan_multiplied(self):
        if len(jax.devices()) < 2:
            pytest.skip("needs >1 device (run under forced host devices)")
        from jax.sharding import PartitionSpec as P

        from repro import dist

        ndev = len(jax.devices())
        mesh = jax.make_mesh((ndev,), ("d",))
        d, n = 64, 8
        x = jax.ShapeDtypeStruct((ndev, d), jnp.float32)

        def scanned(x):
            def inner(xs):
                def body(c, _):
                    return c + jax.lax.psum(c, "d"), None
                y, _ = jax.lax.scan(body, xs, None, length=n)
                return y
            return dist.shard_map(inner, mesh=mesh, in_specs=P("d"),
                                  out_specs=P("d"))(x)

        def unrolled(x):
            def inner(xs):
                c = xs
                for _ in range(n):
                    c = c + jax.lax.psum(c, "d")
                return c
            return dist.shard_map(inner, mesh=mesh, in_specs=P("d"),
                                  out_specs=P("d"))(x)

        r_scan = hlo_cost.analyze(_compile(scanned, x).as_text())
        r_unroll = hlo_cost.analyze(_compile(unrolled, x).as_text())
        # the unrolled body materializes n distinct all-reduces; the scan
        # must charge its single in-loop all-reduce n times to match
        assert r_unroll.total_collective_bytes > 0
        assert r_scan.total_collective_bytes == pytest.approx(
            r_unroll.total_collective_bytes, rel=0.05), \
            (r_scan.collective_bytes, r_unroll.collective_bytes,
             r_scan.while_trips)

    def test_dot_contraction_flops(self):
        a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        b = jax.ShapeDtypeStruct((64, 16), jnp.float32)
        r = hlo_cost.analyze(_compile(lambda a, b: a @ b, a, b).as_text())
        assert r.flops == pytest.approx(2 * 32 * 64 * 16, rel=0.01)

    def test_bytes_nonzero_and_sane(self):
        d = 256
        x = jax.ShapeDtypeStruct((d, d), jnp.float32)
        r = hlo_cost.analyze(_compile(lambda x: x @ x + 1.0, x).as_text())
        # at least: read x, write result
        assert r.bytes_accessed >= 2 * d * d * 4
