"""WAL-replay recovery == store-walk oracle (ISSUE 6 tentpole a).

The redo WAL (``repro.core.writepath.WalRecord`` +
``BackingStore.recover_shard``) must rebuild a failed shard's partition
bit-identically to the original ``vertices``-walk recovery
(``recover_shard_walk``) across randomized mutation / GC / compaction
streams — same multi-version state, same snapshots, same frontier
results — including torn-tail truncation and checkpoint rewrites.
"""

import numpy as np
import pytest

from repro.core import Weaver, WeaverConfig
from repro.core import frontier as F
from repro.core.clock import Stamp
from repro.core.mvgraph import MVGraphPartition
from repro.core.writepath import WalRecord, wal_replay_shard


def make_weaver(**kw):
    n_gk = kw.pop("n_gk", 2)
    n_shards = kw.pop("n_shards", 3)
    seed = kw.pop("seed", 7)
    return Weaver(WeaverConfig(n_gatekeepers=n_gk, n_shards=n_shards,
                               seed=seed, **kw))


def _versions(vers):
    return tuple((v.value, v.ts.key()) for v in vers)


def fingerprint(partition):
    """Canonical multi-version state of one partition: every vertex,
    edge and property version WITH its original stamp key."""
    out = {}
    for vid, v in partition.vertices.items():
        edges = tuple(sorted(
            (eid, e.dst, e.create_ts.key(),
             None if e.delete_ts is None else e.delete_ts.key(),
             tuple(sorted((k, _versions(vers))
                          for k, vers in e.props.items())))
            for eid, e in v.out_edges.items()))
        props = tuple(sorted((k, _versions(vers))
                             for k, vers in v.props.items()))
        out[vid] = (v.create_ts.key(),
                    None if v.delete_ts is None else v.delete_ts.key(),
                    edges, props)
    return out


def rebuild(w, ops):
    """Apply a redo stream to a fresh partition (what a promoted backup
    shard does in ``Shard.recover_from``)."""
    p = MVGraphPartition(w.cfg.n_gatekeepers, intern=w.intern)
    for op in ops:
        p.apply_op(op, op["ts"])
    return p


def _plan_state(w, p, at):
    """Observable plan state: visible vertex gids, sorted CSR edge keys,
    and a property column view — the frontier path's full input."""
    plan = F.ShardPlan(p.columns, at, w.cfg.n_gatekeepers)
    gids = p.columns.v_gid.view()[plan.v_visible]
    ids, num = plan._prop_arrays("v", "score")
    # value-intern ids depend on apply order; presence + the numeric
    # mirror capture the observable property state
    return (np.sort(gids).tolist(), np.sort(plan._ekey).tolist(),
            (ids >= 0).tolist(),
            [None if np.isnan(x) else x for x in num.tolist()])


def assert_replay_equals_walk(w, at=None):
    """The property under test, checked shard by shard."""
    for sid in range(w.cfg.n_shards):
        p_wal = rebuild(w, w.store.recover_shard(sid, use_wal=True))
        p_walk = rebuild(w, w.store.recover_shard_walk(sid))
        assert fingerprint(p_wal) == fingerprint(p_walk), \
            f"shard {sid}: WAL replay diverged from store walk"
        if at is not None:
            assert _plan_state(w, p_wal, at) == _plan_state(w, p_walk, at)


def _churn(w, rng, n_tx, group=False):
    """Randomized committed mutation stream; returns live bookkeeping."""
    vids = []
    edges = []       # (src, eid)
    results = []
    for i in range(n_tx):
        tx = w.begin_tx()
        roll = rng.random()
        if roll < 0.45 or len(vids) < 4:
            v = f"v{len(vids)}"
            tx.create_vertex(v)
            vids.append(v)
            if len(vids) >= 2 and rng.random() < 0.7:
                tx.set_vertex_prop(v, "score", float(len(vids)))
        elif roll < 0.75:
            a, b = rng.choice(len(vids), 2, replace=False)
            tx.create_edge(vids[a], vids[b])
        elif roll < 0.9 and edges:
            src, eid = edges[int(rng.integers(len(edges)))]
            tx.set_edge_prop(src, "weight", float(i), eid=eid)
        elif edges:
            src, eid = edges.pop(int(rng.integers(len(edges))))
            tx.delete_edge(src, eid)
        else:
            tx.set_vertex_prop(vids[0], "score", float(i))
        if group:
            w.submit_tx(tx, results.append)
            if i % 8 == 7:
                w.settle(5e-3)
        else:
            results.append(w.run_tx(tx))
        # harvest created edge ids for later edge ops
        if results and results[-1] is not None:
            pass
        for v in (vids[-1],) if roll < 0.45 or len(vids) <= 4 else ():
            sv = w.store.vertices.get(v)
        # track committed edges from the store directory
        if i % 5 == 4:
            edges = [(vid, eid)
                     for vid, sv in w.store.vertices.items()
                     for eid, (_, _, dts) in sv.edges.items()
                     if dts is None]
    if group:
        w.settle(30e-3)
    return vids, results


class TestReplayEqualsWalk:
    @pytest.mark.parametrize("seed", [0, 1, 7])
    def test_randomized_stream_per_tx(self, seed):
        rng = np.random.default_rng(seed)
        w = make_weaver(seed=seed)
        _churn(w, rng, 60)
        at = w.gatekeepers[0]._tick()
        assert w.sim.counters.wal_records > 0
        assert_replay_equals_walk(w, at)
        assert w.sim.counters.wal_replay_ops > 0

    @pytest.mark.parametrize("seed", [3, 11])
    def test_randomized_stream_group_commit(self, seed):
        rng = np.random.default_rng(seed)
        w = make_weaver(seed=seed, write_group_commit=0.5e-3)
        _, results = _churn(w, rng, 48, group=True)
        assert any(r.ok for r in results)
        at = w.gatekeepers[0]._tick()
        assert_replay_equals_walk(w, at)

    def test_gc_checkpoint_rewrite(self):
        """GC past a delete rewrites the log as one checkpoint record;
        replay after the rewrite still matches the walk and does NOT
        resurrect the dropped vertex."""
        w = make_weaver(gc_period=0, wal_checkpoint_every=16)
        rng = np.random.default_rng(5)
        vids, _ = _churn(w, rng, 40)
        tx = w.begin_tx()
        tx.create_vertex("doomed")
        assert w.run_tx(tx).ok
        tx = w.begin_tx()
        tx.delete_vertex("doomed")
        assert w.run_tx(tx).ok
        w.settle(5e-3)
        w._gc()                              # horizon dominates the delete
        assert w.sim.counters.wal_ckpts > 0
        assert len(w.store.wal) <= 2         # ckpt + at most new records
        assert "doomed" not in w.store.vertices
        sid = w.store.place("doomed")
        ops = w.store.recover_shard(sid)
        assert not any(op.get("vid") == "doomed" for op in ops), \
            "replay resurrected a GC-dropped vertex"
        assert_replay_equals_walk(w, w.gatekeepers[0]._tick())

    def test_checkpoint_triggered_by_log_length(self):
        w = make_weaver(gc_period=0, wal_checkpoint_every=8)
        for i in range(12):
            tx = w.begin_tx()
            tx.create_vertex(f"n{i}")
            assert w.run_tx(tx).ok
        assert len(w.store.wal) > 8
        w._gc()
        assert w.sim.counters.wal_ckpts >= 1
        assert len(w.store.wal) <= 2
        assert_replay_equals_walk(w)

    def test_compaction_mid_stream(self):
        """Column compactions between commits don't disturb either
        recovery path (the WAL carries ops, not slots)."""
        w = make_weaver(gc_period=0)
        rng = np.random.default_rng(9)
        _churn(w, rng, 50)
        w._gc()                   # purge + maybe_compact at the shards
        for sh in w.shards:
            sh.partition.columns.compact()
        _churn(w, rng, 20)
        assert_replay_equals_walk(w, w.gatekeepers[0]._tick())


class TestTornTail:
    def test_torn_group_append_truncated(self):
        """A group record cut short mid-append: entries past ``valid``
        are on the log but MUST NOT replay."""
        w = make_weaver()
        sg = w.gatekeepers[0]
        items = []
        for i in range(4):
            items.append(([{"op": "create_vertex", "vid": f"t{i}"}],
                          sg._tick(), 100 + i))
        res = w.store.apply_batch(items, torn_limit=2)
        assert [r[0] for r in res] == [True, True, False, False]
        rec = w.store.wal[-1]
        assert rec.kind == "group" and rec.valid == 2
        assert len(rec.entries) == 3          # 2 committed + 1 torn
        torn0 = w.sim.counters.wal_torn_truncated
        for sid in range(w.cfg.n_shards):
            p = rebuild(w, w.store.recover_shard(sid))
        assert w.sim.counters.wal_torn_truncated > torn0
        recovered = set()
        for sid in range(w.cfg.n_shards):
            recovered |= set(rebuild(
                w, w.store.recover_shard(sid)).vertices)
        assert {"t0", "t1"} <= recovered
        assert not ({"t2", "t3"} & recovered), "torn tail replayed"
        # only the committed prefix is in the store (walk oracle agrees)
        assert "t2" not in w.store.vertices

    def test_torn_results_not_acked(self):
        """Transactions past the torn point have NO recorded outcome —
        a resubmission re-executes them instead of reading a lie."""
        w = make_weaver()
        sg = w.gatekeepers[0]
        items = [([{"op": "create_vertex", "vid": f"u{i}"}],
                  sg._tick(), 200 + i) for i in range(3)]
        w.store.apply_batch(items, torn_limit=1)
        assert 200 in w.store.tx_results
        assert w.store.tx_results[200][0] is True
        assert 201 not in w.store.tx_results
        assert 202 not in w.store.tx_results


class TestPromotionPaths:
    def _load(self, w, n=18):
        vids = [f"p{i}" for i in range(n)]
        tx = w.begin_tx()
        for v in vids:
            tx.create_vertex(v)
        assert w.run_tx(tx).ok
        tx = w.begin_tx()
        for i in range(n):
            tx.create_edge(vids[i], vids[(i + 1) % n])
        tx.set_vertex_prop(vids[0], "score", 1.5)
        assert w.run_tx(tx).ok
        # an edge property, so walk recovery must re-emit it
        sv = w.store.vertices[vids[0]]
        eid = next(iter(sv.edges))
        tx = w.begin_tx()
        tx.set_edge_prop(vids[0], "weight", 2.5, eid=eid)
        assert w.run_tx(tx).ok
        return vids, (vids[0], eid)

    @pytest.mark.parametrize("use_wal", [True, False])
    def test_shard_kill_recovery(self, use_wal):
        w = make_weaver(wal_replay=use_wal)
        vids, (src, eid) = self._load(w)
        at = w.gatekeepers[0]._tick()
        r0, _ = F.run_local(w, "traverse", [(vids[0], {"depth": 0})], at)
        w.kill("shard1")
        w.settle(100e-3)
        assert w.manager.epoch == 1
        r1, _ = F.run_local(w, "traverse", [(vids[0], {"depth": 0})], at)
        assert r0 == r1
        if use_wal:
            assert w.sim.counters.wal_replay_ops > 0
        else:
            assert w.sim.counters.wal_replay_ops == 0
        # edge property survived recovery on whichever path
        sh = w.shards[w.store.place(src)]
        e = sh.partition.vertices[src].out_edges[eid]
        assert e.props["weight"][-1].value == 2.5

    def test_both_paths_identical_post_promotion(self):
        """Two identical deployments, one per recovery path: killing the
        same shard must leave bit-identical recovered partitions."""
        parts = {}
        for use_wal in (True, False):
            w = make_weaver(wal_replay=use_wal, seed=13)
            self._load(w)
            w.kill("shard0")
            w.settle(100e-3)
            parts[use_wal] = fingerprint(w.shards[0].partition)
        assert parts[True] == parts[False]
