"""Tests for the JAX analytics bridge (snapshot -> arrays -> traversals)."""
import numpy as np
import pytest
import jax.numpy as jnp

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import networkx as nx

from repro.core import Weaver, WeaverConfig
from repro.core import analytics as A
from repro.core.clock import Stamp


def _random_edges(rng, n, m):
    src = rng.integers(0, n, size=m).astype(np.int32)
    dst = rng.integers(0, n, size=m).astype(np.int32)
    return src, dst


class TestFrontierPrograms:
    def test_bfs_levels_line_graph(self):
        src = np.array([0, 1, 2], dtype=np.int32)
        dst = np.array([1, 2, 3], dtype=np.int32)
        lv = np.asarray(A.bfs_levels(jnp.asarray(src), jnp.asarray(dst), 4,
                                     jnp.asarray([0])))
        assert lv.tolist() == [0, 1, 2, 3]

    def test_bfs_unreachable_is_inf(self):
        src = np.array([0], dtype=np.int32)
        dst = np.array([1], dtype=np.int32)
        lv = np.asarray(A.bfs_levels(jnp.asarray(src), jnp.asarray(dst), 3,
                                     jnp.asarray([0])))
        assert lv[2] == A.INF

    @given(st.integers(2, 30), st.integers(1, 80), st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_bfs_matches_networkx(self, n, m, seed):
        rng = np.random.default_rng(seed)
        src, dst = _random_edges(rng, n, m)
        g = nx.DiGraph()
        g.add_nodes_from(range(n))
        g.add_edges_from(zip(src.tolist(), dst.tolist()))
        ref = nx.single_source_shortest_path_length(g, 0)
        lv = np.asarray(A.bfs_levels(jnp.asarray(src), jnp.asarray(dst), n,
                                     jnp.asarray([0])))
        for v in range(n):
            if v in ref:
                assert lv[v] == ref[v], (v, lv[v], ref[v])
            else:
                assert lv[v] == A.INF

    @given(st.integers(2, 25), st.integers(0, 60), st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_connected_components_match_networkx(self, n, m, seed):
        rng = np.random.default_rng(seed)
        src, dst = _random_edges(rng, n, m)
        g = nx.Graph()
        g.add_nodes_from(range(n))
        g.add_edges_from(zip(src.tolist(), dst.tolist()))
        lab = np.asarray(A.connected_components(jnp.asarray(src),
                                                jnp.asarray(dst), n))
        for comp in nx.connected_components(g):
            labs = {int(lab[v]) for v in comp}
            assert len(labs) == 1

    def test_pagerank_sums_to_one(self):
        rng = np.random.default_rng(0)
        src, dst = _random_edges(rng, 50, 300)
        pr = np.asarray(A.pagerank(jnp.asarray(src), jnp.asarray(dst), 50))
        # dangling mass leaks in this formulation only if a node has no
        # out-edges; with 300 random edges over 50 nodes that's unlikely
        assert pr.min() > 0

    def test_sssp_weighted(self):
        src = np.array([0, 0, 1], dtype=np.int32)
        dst = np.array([1, 2, 2], dtype=np.int32)
        w = np.array([1.0, 5.0, 1.0], dtype=np.float32)
        d = np.asarray(A.sssp_weighted(jnp.asarray(src), jnp.asarray(dst),
                                       jnp.asarray(w), 3, jnp.asarray([0])))
        assert d[2] == pytest.approx(2.0)

    def test_clustering_jax_matches_np(self):
        rng = np.random.default_rng(1)
        src, dst = _random_edges(rng, 20, 80)
        keep = src != dst
        src, dst = src[keep], dst[keep]
        # dedupe parallel edges (the numpy reference uses sets)
        pairs = sorted(set(zip(src.tolist(), dst.tolist())))
        src = np.asarray([p[0] for p in pairs], np.int32)
        dst = np.asarray([p[1] for p in pairs], np.int32)
        ref = A.clustering_coefficients_np(src, dst, 20)
        got = np.asarray(A.clustering_coefficients_jax(src, dst, 20,
                                                       max_deg=20))
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)


class TestSnapshotBridge:
    def test_snapshot_matches_node_program(self):
        w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=3, seed=2))
        tx = w.begin_tx()
        for v in "abcde":
            tx.create_vertex(v)
        for s, d in [("a", "b"), ("b", "c"), ("c", "d"), ("a", "e")]:
            tx.create_edge(s, d)
        assert w.run_tx(tx).ok
        # delete one edge
        eid = [e for e, dd in w.read_vertex("b")["edges"].items()][0]
        tx2 = w.begin_tx()
        tx2.delete_edge("b", eid)
        assert w.run_tx(tx2).ok

        res, stamp, _ = w.run_program("traverse", [("a", {"depth": 0})])
        ga = A.snapshot_arrays(w, stamp)
        lv = np.asarray(A.bfs_levels(jnp.asarray(ga.edge_src),
                                     jnp.asarray(ga.edge_dst), ga.n_nodes,
                                     jnp.asarray([ga.index["a"]])))
        reachable = sorted(ga.vids[i] for i in range(ga.n_nodes)
                           if lv[i] < A.INF)
        assert reachable == res

    def test_visibility_kernel_path_matches(self):
        w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=2, seed=3))
        tx = w.begin_tx()
        for v in "xyz":
            tx.create_vertex(v)
        e1 = tx.create_edge("x", "y")
        tx.create_edge("y", "z")
        assert w.run_tx(tx).ok
        tx2 = w.begin_tx()
        tx2.delete_edge(e1)
        assert w.run_tx(tx2).ok
        res, stamp, _ = w.run_program("count_edges", [("x", None)])
        assert res == 0
        ga = A.snapshot_arrays(w, stamp, keep_raw=True)
        vsrc, vdst, mask = A.visible_edges_at(ga, stamp,
                                              w.cfg.n_gatekeepers)
        # filtered edges equal the snapshot edge list
        got = sorted(zip(vsrc.tolist(), vdst.tolist()))
        want = sorted(zip(ga.edge_src.tolist(), ga.edge_dst.tolist()))
        assert got == want


class TestBaselines:
    def test_twopl_store_basic(self):
        from repro.core.twopl import TwoPLStore
        s = TwoPLStore(n_shards=3, seed=0)
        s.load_graph([("a", "b"), ("b", "c")])
        done = []
        s.submit([{"op": "get_vertex", "vid": "a"}], done.append)
        s.sim.run(until=0.1)
        assert done and done[0]["ok"]
        assert done[0]["reads"]["a"]["edges"]

    def test_twopl_contention_serializes(self):
        from repro.core.twopl import TwoPLStore
        s = TwoPLStore(n_shards=2, seed=0)
        s.load_graph([("h", "x")])
        done = []
        for i in range(10):
            s.submit([{"op": "set_vertex_prop", "vid": "h", "key": "k",
                       "value": i}], done.append)
        s.sim.run(until=1.0)
        assert len(done) == 10
        assert s.sim.counters.lock_waits > 0

    def test_bsp_sync_and_async_reach_target(self):
        from repro.core.bsp import BSPEngine
        e = BSPEngine(n_workers=3, seed=0)
        e.load_graph([(f"v{i}", f"v{i+1}") for i in range(20)])
        out = []
        e.bfs_sync("v0", "v20", out.append)
        e.sim.run(until=1.0)
        assert out and out[0]["reached"]
        assert out[0]["levels"] >= 20
        out2 = []
        e.bfs_async("v0", "v20", out2.append)
        e.sim.run(until=2.0)
        assert out2 and out2[0]["reached"]
