"""Multi-device numerics: the shard_map variants (a2a MoE, grad_sync,
distributed top-k) must agree with their single-device references.

Tests shell out to a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` because jax locks
the device count at first init (the main test process must stay at 1
device for the smoke tests).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, {os.path.join(REPO, 'src')!r})
        import numpy as np
        import jax
        import jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\n" \
                                 f"STDERR:\n{proc.stderr[-3000:]}"
    return proc.stdout


class TestShardMapVariants:
    def test_moe_a2a_matches_scatter(self):
        run_sub("""
            import dataclasses
            from repro import dist
            from repro.models import moe as moe_mod
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            dist.set_mesh(mesh)
            cfg = moe_mod.MoEConfig(n_experts=8, top_k=2, d_expert=16,
                                    capacity_factor=4.0)
            key = jax.random.PRNGKey(0)
            p = moe_mod.init_moe(key, 32, cfg, dtype=jnp.float32)
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32),
                                  jnp.float32)
            with mesh:
                y_scatter, aux1 = jax.jit(
                    lambda p, x: moe_mod.moe_block(p, x, cfg))(p, x)
                cfg2 = dataclasses.replace(cfg, moe_impl="a2a",
                                           capacity_factor=8.0)
                y_a2a, aux2 = jax.jit(
                    lambda p, x: moe_mod.moe_block(p, x, cfg2))(p, x)
            err = float(jnp.max(jnp.abs(y_scatter - y_a2a)))
            scale = float(jnp.max(jnp.abs(y_scatter))) + 1e-9
            assert err / scale < 2e-4, (err, scale)
            print("MOE_A2A_OK", err / scale)
        """)

    def test_moe_a2a_matches_scatter_under_overflow(self):
        """Drop parity: with per-expert capacity far below demand, the
        a2a path must drop the SAME (token, slot) pairs as the jit-level
        scatter path (global-capacity semantics, ROADMAP item)."""
        run_sub("""
            import dataclasses
            from repro import dist
            from repro.models import moe as moe_mod
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            dist.set_mesh(mesh)
            cfg = moe_mod.MoEConfig(n_experts=8, top_k=2, d_expert=16,
                                    capacity_factor=0.25)
            key = jax.random.PRNGKey(0)
            p = moe_mod.init_moe(key, 32, cfg, dtype=jnp.float32)
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32),
                                  jnp.float32)
            with mesh:
                y_scatter, _ = jax.jit(
                    lambda p, x: moe_mod.moe_block(p, x, cfg))(p, x)
                cfg2 = dataclasses.replace(cfg, moe_impl="a2a")
                y_a2a, _ = jax.jit(
                    lambda p, x: moe_mod.moe_block(p, x, cfg2))(p, x)
                cfg3 = dataclasses.replace(cfg, capacity_factor=8.0)
                y_ample, _ = jax.jit(
                    lambda p, x: moe_mod.moe_block(p, x, cfg3))(p, x)
            err = float(jnp.max(jnp.abs(y_scatter - y_a2a)))
            scale = float(jnp.max(jnp.abs(y_scatter))) + 1e-9
            assert err / scale < 2e-4, (err, scale)
            # the regime is REAL: drops changed the output vs ample
            assert float(jnp.max(jnp.abs(y_scatter - y_ample))) > 1e-3
            print("MOE_A2A_OVERFLOW_OK", err / scale)
        """)

    def test_a2a_requires_capacity_or_explicit_local(self):
        """overflow='global' without a capacity is an asserted config
        error; unknown modes too (single-device — pure config check)."""
        import pytest

        import sys
        sys.path.insert(0, os.path.join(REPO, "src"))
        from repro.dist import collectives

        class FakeMesh:
            shape = {"model": 2}
            axis_names = ("model",)

        import numpy as np
        xf = np.zeros((8, 4), np.float32)
        with pytest.raises(ValueError, match="capacity"):
            collectives.moe_alltoall_block(
                xf, None, np.zeros((2, 4, 4)), None, None, FakeMesh(),
                top_k=1, c_dev=4, overflow="global")
        with pytest.raises(ValueError, match="overflow"):
            collectives.moe_alltoall_block(
                xf, None, np.zeros((2, 4, 4)), None, None, FakeMesh(),
                top_k=1, c_dev=4, overflow="banana")
        # local mode: the legacy drop-rule formula IS the exact size
        # (host math only — no shard_map launched)
        got = collectives.moe_alltoall_exact_c_dev(
            np.zeros((8, 4), np.float32), FakeMesh(), top_k=1,
            overflow="local", local_capacity_factor=2.0)
        assert got == 4, got

    def test_moe_a2a_two_phase_exact_sizing(self):
        """Phase-1 counting shrinks the wire buffer below the static
        bound, the exact-sized dispatch is bit-identical to the
        statically-clamped one, and sizing under jit is an asserted
        config error (the count must be a static shape)."""
        run_sub("""
            from repro import dist
            from repro.dist import collectives
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            dist.set_mesh(mesh)
            e, d, f, k, t, cap = 8, 32, 16, 2, 256, 32
            ks = jax.random.split(jax.random.PRNGKey(0), 5)
            xf = jax.random.normal(ks[0], (t, d), jnp.float32)
            logits = jax.random.normal(ks[1], (t, e), jnp.float32)
            wg = jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.1
            wu = jax.random.normal(ks[3], (e, d, f), jnp.float32) * 0.1
            wd = jax.random.normal(ks[4], (e, f, d), jnp.float32) * 0.1
            t_loc, e_loc = t // 8, e // 4
            bound = min(t_loc * k, e_loc * cap)
            with mesh:
                exact = collectives.moe_alltoall_exact_c_dev(
                    logits, mesh, k, capacity=cap)
                assert exact % 8 == 0 and 8 <= exact <= bound, (exact, bound)
                # the point of phase 1: strictly smaller wire buffer
                assert exact < bound, (exact, bound)
                y_ref = collectives.moe_alltoall_block(
                    xf, logits, wg, wu, wd, mesh, k, c_dev=0,
                    capacity=cap)
                y_exact = collectives.moe_alltoall_block(
                    xf, logits, wg, wu, wd, mesh, k, c_dev=exact,
                    capacity=cap, exact_c_dev=True)
            assert np.array_equal(np.asarray(y_ref),
                                  np.asarray(y_exact)), "not bit-identical"
            try:
                jax.jit(lambda lg: collectives.moe_alltoall_exact_c_dev(
                    lg, mesh, k, capacity=cap))(logits)
                raise SystemExit("expected ValueError under jit")
            except ValueError as exc:
                assert "outside jit" in str(exc), exc
            print("MOE_A2A_TWO_PHASE_OK", exact, bound)
        """)

    def test_cross_pod_allreduce(self):
        """The standalone cross-pod hook: pod-sharded input averages
        across pods (plain + int8-compressed), replicated input is the
        identity, and grad_sync's pod hop shares the same body."""
        run_sub("""
            from repro.dist import collectives
            mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
            x_np = np.arange(48.0).reshape(4, 12).astype(np.float32)
            with mesh:
                x = jax.device_put(x_np, NamedSharding(mesh,
                                                       P("pod", None)))
                out = collectives.cross_pod_allreduce(
                    mesh, x, in_spec=P("pod", None))
                out_q = collectives.cross_pod_allreduce(
                    mesh, x, compress=True, in_spec=P("pod", None))
                xr = jax.device_put(x_np, NamedSharding(mesh, P()))
                ident = collectives.cross_pod_allreduce(mesh, xr)
            ref = np.tile(x_np.reshape(2, 2, 12).mean(0), (2, 1))
            np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6)
            np.testing.assert_allclose(np.asarray(out_q), ref,
                                       rtol=0.02, atol=0.5)
            np.testing.assert_allclose(np.asarray(ident), x_np, rtol=1e-6)
            print("CROSS_POD_OK")
        """)

    def test_grad_sync_matches_mean(self):
        run_sub("""
            from repro.dist import collectives
            mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
            g = {"w": jnp.arange(24.0).reshape(4, 6) / 10.0,
                 "b": jnp.ones((7,))}
            # replicated grads: sync must be the identity (mean of equal
            # replicas), for both compressed and uncompressed paths
            with mesh:
                out = collectives.grad_sync(mesh, g, int8_cross_pod=False)
                out_q = collectives.grad_sync(mesh, g, int8_cross_pod=True)
            np.testing.assert_allclose(np.asarray(out["w"]),
                                       np.asarray(g["w"]), rtol=1e-6)
            np.testing.assert_allclose(np.asarray(out_q["w"]),
                                       np.asarray(g["w"]),
                                       rtol=0.02, atol=0.02)
            print("GRAD_SYNC_OK")
        """)

    def test_serve_topk_matches_dense(self):
        run_sub("""
            from repro import dist
            from repro.launch import input_specs
            from repro.models import sasrec
            import dataclasses
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            dist.set_mesh(mesh)
            spec_cfg = sasrec.SASRecConfig(name="t", n_items=4064,
                                           seq_len=8, d_embed=16)
            params = sasrec.init_params(jax.random.PRNGKey(0), spec_cfg)
            hist = jax.random.randint(jax.random.PRNGKey(1), (8, 8), 1,
                                      spec_cfg.n_items + 1)

            class FakeSpec:
                config = spec_cfg
            low = input_specs._rec_serve(FakeSpec, {"batch": 8}, mesh,
                                         "baseline")
            with mesh:
                v, idx = jax.jit(low.fn)(params, hist)
            scores = np.asarray(sasrec.score_catalog(params, hist,
                                                     spec_cfg))
            ref_idx = np.argsort(-scores, axis=1)[:, :100]
            ref_v = np.take_along_axis(scores, ref_idx, axis=1)
            np.testing.assert_allclose(np.asarray(v), ref_v, rtol=1e-5)
            print("TOPK_OK")
        """)

    def test_lm_train_step_lowers_on_8dev_mesh(self):
        """End-to-end: the sharded train step compiles AND runs with real
        numbers on a small mesh, loss is finite."""
        run_sub("""
            import dataclasses
            from repro import dist
            from repro.configs import get_arch
            from repro.models import transformer
            from repro.optim import AdamWConfig, adamw, make_train_step
            from repro.dist import sharding as sh
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            dist.set_mesh(mesh)
            cfg = dataclasses.replace(
                get_arch("gemma3-1b").config, n_layers=2, d_model=32,
                n_heads=4, n_kv=1, d_head=8, d_ff=64, vocab=128,
                dtype="float32", loss_chunks=4)
            params = transformer.init_params(jax.random.PRNGKey(0), cfg)
            step = make_train_step(
                lambda p, b: transformer.lm_loss(p, b, cfg),
                AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4))
            opt = adamw.init(params)
            toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                      cfg.vocab)
            batch = {"tokens": toks, "labels": toks}
            with mesh:
                p2, o2, m = jax.jit(step)(params, opt, batch)
            loss = float(m["loss"])
            assert np.isfinite(loss) and loss > 0
            # cross-check against the unsharded (1-device-semantics) loss
            from repro import dist as d2
            d2.set_mesh(None)
            l_ref, _ = transformer.lm_loss(params, batch, cfg)
            assert abs(loss - float(l_ref)) / float(l_ref) < 1e-3
            print("LM_SHARDED_OK", loss)
        """)


class TestSplitKDecode:
    def test_splitk_matches_gather_decode(self):
        run_sub("""
            import dataclasses
            from repro import dist
            from repro.configs import get_arch
            from repro.models import transformer
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            dist.set_mesh(mesh)
            cfg = dataclasses.replace(
                get_arch("gemma3-1b").config, n_layers=3, d_model=32,
                n_heads=4, n_kv=1, d_head=8, d_ff=64, vocab=64,
                dtype="float32", window_pattern=(4, 0))
            params = transformer.init_params(jax.random.PRNGKey(0), cfg)
            toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                      cfg.vocab)
            _, cache = transformer.prefill(params, toks, cfg, max_len=16)
            nxt = jax.random.randint(jax.random.PRNGKey(2), (2, 1), 0,
                                     cfg.vocab)
            with mesh:
                lg_g, _ = jax.jit(lambda p, c, t: transformer.decode_step(
                    p, c, t, cfg))(params, cache, nxt)
                cfg2 = dataclasses.replace(cfg, decode_attn="splitk")
                lg_s, _ = jax.jit(lambda p, c, t: transformer.decode_step(
                    p, c, t, cfg2))(params, cache, nxt)
            err = float(jnp.max(jnp.abs(lg_g - lg_s)))
            scale = float(jnp.max(jnp.abs(lg_g))) + 1e-9
            assert err / scale < 5e-5, (err, scale)
            print("SPLITK_OK", err / scale)
        """)
