"""Batched read admission + serving-path machinery (ISSUE 7).

* :class:`AdaptiveWindow` AIMD controller unit behavior (closed start,
  backlog-driven growth, cap, hold, shrink-and-snap-to-zero);
* the read-window stale-timer regression (mirror of the write path's
  ``test_stale_window_timer_does_not_shorten_next_window``);
* windowed reads == per-program reads on a quiescent graph after write
  churn, including a windowed deployment running under drop/dup message
  faults recovered by client read sessions;
* dropped / duplicated read windows: sessions resubmit, the coordinator
  dup-report guard absorbs replays, every submission completes;
* gatekeeper admission backpressure: shed reads are recovered by the
  session layer (``progs_shed > 0``, zero give-ups);
* read-your-writes acks: a tx ack implies shard-side visibility for a
  program submitted from inside the ack callback;
* clean-window revalidation skip (``revalidations_skipped``) and dirty
  concurrent windows still committing correctly;
* windowed-admission counters and histograms.
"""

import numpy as np
import pytest

from repro.core import Weaver, WeaverConfig
from repro.core.faultinject import FaultAction, FaultPlan
from repro.core.gatekeeper import AdaptiveWindow


def make_weaver(seed=0, n_shards=4, n_gk=2, **kw):
    return Weaver(WeaverConfig(n_gatekeepers=n_gk, n_shards=n_shards,
                               gc_period=0, seed=seed, **kw))


def seed_vertices(w, n):
    vids = [f"u{i}" for i in range(n)]
    tx = w.begin_tx()
    for v in vids:
        tx.create_vertex(v)
    assert w.run_tx(tx).ok
    w.settle(10e-3)
    return vids


# ---------------------------------------------------------------------------
# AdaptiveWindow (AIMD controller)
# ---------------------------------------------------------------------------

class TestAdaptiveWindow:
    def test_starts_closed_and_enters_at_floor_on_backlog(self):
        a = AdaptiveWindow(1e-3)
        assert a.current == 0.0
        a.on_flush(1, 64, backlog=0.0)       # idle singleton: stays closed
        assert a.current == 0.0
        a.on_flush(1, 64, backlog=5e-6)      # serve backlog: open at floor
        assert a.current == pytest.approx(1e-3 / 16)

    def test_full_windows_grow_to_max_and_cap(self):
        a = AdaptiveWindow(1e-3)
        for _ in range(12):
            a.on_flush(64, 64, backlog=0.0)
        assert a.current == pytest.approx(1e-3)
        a.on_flush(64, 64, backlog=1e-3)     # already at max: stays there
        assert a.current == pytest.approx(1e-3)

    def test_midsize_flush_holds(self):
        a = AdaptiveWindow(1e-3)
        a.on_flush(64, 64, 0.0)
        cur = a.current
        a.on_flush(8, 64, 0.0)               # neither full nor singleton
        assert a.current == cur

    def test_singleton_idle_flushes_shrink_then_snap_to_zero(self):
        a = AdaptiveWindow(1e-3)
        a.on_flush(64, 64, 0.0)
        a.on_flush(64, 64, 0.0)              # floor * 2 = max/8
        assert a.current == pytest.approx(1e-3 / 8)
        a.on_flush(1, 64, 0.0)
        assert a.current == pytest.approx(1e-3 / 16)   # at the floor: kept
        a.on_flush(1, 64, 0.0)
        assert a.current == 0.0              # below the floor: snaps closed


# ---------------------------------------------------------------------------
# windowed read admission
# ---------------------------------------------------------------------------

class TestReadWindow:
    def test_stale_read_window_timer_does_not_shorten_next_window(self):
        """The write path's stale-timer contract, on the read window: a
        timer armed for a window that a max-count trigger already
        flushed must not fire into the NEXT window."""
        w = make_weaver(seed=8, read_group_commit=10e-3, read_group_max=4)
        seed_vertices(w, 4)
        base = w.counters()["prog_batches"]
        out = []
        cb = lambda r, s, l: out.append(r)
        for i in range(4):              # fills read_group_max -> instant flush
            w.submit_program("get_node", [(f"u{i}", None)], cb, gatekeeper=0)
        w.settle(4e-3)                  # stale timer now armed ~t+10ms
        assert w.counters()["prog_batches"] == base + 1
        for i in range(2):              # new window, deadline ~t+14ms
            w.submit_program("get_node", [(f"u{i}", None)], cb, gatekeeper=0)
        w.settle(8e-3)                  # ~t+12ms: past the stale deadline,
        assert w.counters()["prog_batches"] == base + 1, \
            "second read window flushed early (stale timer)"
        w.settle(4e-3)                  # past the real deadline
        assert w.counters()["prog_batches"] == base + 2
        w.settle(10e-3)                 # drain the second window's reads
        assert len(out) == 6 and all(r is not None for r in out)

    def test_adaptive_read_window_opens_under_load(self):
        """From ``current == 0`` the serve-backlog signal must open the
        window (batch size alone never could: a zero window only ever
        flushes singletons)."""
        w = make_weaver(seed=12, n_gk=1, read_group_commit=500e-6,
                        read_group_max=4, adaptive_admission=True)
        seed_vertices(w, 8)
        cb = lambda r, s, l: None
        for _ in range(6):
            for i in range(8):
                w.submit_program("get_node", [(f"u{i}", None)], cb,
                                 gatekeeper=0)
            w.settle(2e-3)
        assert w.gatekeepers[0]._awin.current > 0.0
        c = w.counters()
        assert c["prog_batches"] > 0
        assert c["prog_batch_size_sum"] > c["prog_batches"], \
            "adaptive window never batched anything"

    def test_windowed_counters_and_histograms(self):
        w = make_weaver(seed=11, read_group_commit=300e-6, read_group_max=8)
        seed_vertices(w, 8)
        cb = lambda r, s, l: None
        for _ in range(3):
            for i in range(8):
                w.submit_program("get_node", [(f"u{i}", None)], cb,
                                 gatekeeper=0)
            w.settle(5e-3)
        c = w.counters()
        assert c["prog_batches"] >= 3
        mean = c["prog_batch_size_sum"] / c["prog_batches"]
        assert mean > 1.0, "fixed 300us window never formed a batch"
        hists = w.sim.metrics.hists
        assert hists.get("admission_window_us_r"), \
            "read admission-window histogram empty"
        assert hists.get("admission_depth_r"), \
            "read admission-depth histogram empty"


# ---------------------------------------------------------------------------
# batched == per-program equivalence (quiescent reads after churn)
# ---------------------------------------------------------------------------

class TestEquivalence:
    @pytest.mark.parametrize("seed", [0, 3])
    def test_windowed_reads_equal_per_program(self, seed):
        """Identical write churn into three deployments — per-program
        (the semantic oracle), windowed+adaptive, and windowed under
        drop/dup faults with read sessions — then the same quiescent
        reads: result lists must match exactly (windows share one stamp,
        so only results are compared, and reads are side-effect-free so
        fault-driven re-execution cannot change them)."""
        variants = [
            dict(),
            dict(read_group_commit=200e-6, read_group_max=16,
                 adaptive_admission=True),
            dict(read_group_commit=200e-6, read_group_max=16,
                 read_retry_timeout=2e-3,
                 fault_plan=FaultPlan([
                     FaultAction("drop", target="deliver_prog_batch",
                                 after=0, count=1),
                     FaultAction("dup", target="deliver_prog_batch",
                                 after=2, count=1)])),
        ]
        outs = []
        for extra in variants:
            w = make_weaver(seed=seed, **extra)
            if w.sim.fault is not None:
                w.sim.fault.disarm()
            vids = seed_vertices(w, 16)
            rng = np.random.default_rng(seed + 1)
            for i in range(40):                       # write churn
                a, b = (int(x) for x in rng.integers(0, 16, size=2))
                tx = w.begin_tx()
                if a == b:
                    tx.set_vertex_prop(vids[a], "score", float(i))
                else:
                    tx.create_edge(vids[a], vids[b])
                w.submit_tx(tx, lambda r: None)
            w.settle(60e-3)
            if w.sim.fault is not None:
                w.sim.fault.arm()
            results = []
            for i in range(24):                       # quiescent reads
                name = ("get_edges", "count_edges", "get_node")[i % 3]
                w.submit_program(name, [(vids[i % 16], None)],
                                 lambda r, s, l, i=i:
                                 results.append((i, repr(r))))
            w.settle(60e-3)
            assert len(results) == 24, "a read never completed"
            outs.append(sorted(results))
        assert outs[0] == outs[1], "windowed reads diverged from oracle"
        assert outs[0] == outs[2], "faulted windowed reads diverged"


# ---------------------------------------------------------------------------
# fault injection on the read path
# ---------------------------------------------------------------------------

class TestReadFaults:
    @pytest.mark.parametrize("target", ["submit_program",
                                        "deliver_prog_batch"])
    def test_dropped_window_recovered_by_read_sessions(self, target):
        """A dropped client submission or a dropped whole window: the
        read sessions time out, abandon the dead attempt, and resubmit
        with a fresh prog_id — every caller still gets a result."""
        plan = FaultPlan([FaultAction("drop", target=target,
                                      after=0, count=1)])
        w = make_weaver(seed=3, read_group_commit=200e-6, read_group_max=8,
                        read_retry_timeout=2e-3, fault_plan=plan)
        w.sim.fault.disarm()
        seed_vertices(w, 8)
        w.sim.fault.arm()
        out = {}
        for i in range(8):
            w.submit_program("get_node", [(f"u{i}", None)],
                             lambda r, s, l, i=i: out.__setitem__(i, r),
                             gatekeeper=0)
        w.settle(80e-3)
        assert len(out) == 8 and all(r is not None for r in out.values())
        c = w.counters()
        assert c["prog_retries"] > 0
        assert c["prog_gaveup"] == 0

    def test_duplicated_window_completes_each_program_once(self):
        """A duplicated window delivery re-executes side-effect-free
        reads; the coordinator's per-delivery report guard absorbs the
        replayed reports so each program completes exactly once with the
        correct result."""
        plan = FaultPlan([FaultAction("dup", target="deliver_prog_batch",
                                      after=0, count=2)])
        w = make_weaver(seed=4, read_group_commit=200e-6, read_group_max=8,
                        fault_plan=plan)
        w.sim.fault.disarm()
        seed_vertices(w, 8)
        w.sim.fault.arm()
        out = []
        for i in range(8):
            w.submit_program("get_node", [(f"u{i}", None)],
                             lambda r, s, l, i=i: out.append((i, r)),
                             gatekeeper=0)
        w.settle(60e-3)
        assert sorted(i for i, _ in out) == list(range(8)), \
            "a duplicated delivery double-completed or lost a program"
        assert all(r is not None and r["id"] == f"u{i}" for i, r in out)


# ---------------------------------------------------------------------------
# backpressure / load leveling
# ---------------------------------------------------------------------------

class TestBackpressure:
    def test_shed_reads_are_recovered_by_sessions(self):
        w = make_weaver(seed=5, n_gk=1, admission_queue_limit=4,
                        read_retry_timeout=2e-3)
        seed_vertices(w, 8)
        out = {}
        for i in range(48):
            w.submit_program("get_node", [(f"u{i % 8}", None)],
                             lambda r, s, l, i=i: out.__setitem__(i, r),
                             gatekeeper=0)
        w.settle(150e-3)
        c = w.counters()
        assert c["progs_shed"] > 0, "queue limit never tripped"
        assert len(out) == 48, "a shed read was never recovered"
        assert all(r is not None for r in out.values())
        assert c["prog_retries"] > 0
        assert c["prog_gaveup"] == 0

    def test_give_up_surfaces_none_instead_of_hanging(self):
        """With every gatekeeper shedding forever (limit saturated by a
        dead-end deployment), the bounded budget must surface
        ``callback(None, None, latency)``."""
        w = make_weaver(seed=13, n_gk=1, admission_queue_limit=1,
                        read_retry_timeout=0.5e-3, client_retry_budget=2)
        seed_vertices(w, 2)
        # wedge the only admission slot: a gatekeeper whose serve queue
        # never drains because we keep it saturated below the limit is
        # hard to build deterministically, so saturate by flooding far
        # past what the budgeted retries can drain in time
        out = []
        for i in range(64):
            w.submit_program("get_node", [("u0", None)],
                             lambda r, s, l, i=i: out.append(r),
                             gatekeeper=0)
        w.settle(200e-3)
        assert len(out) == 64, "a session neither completed nor gave up"
        assert w.counters()["progs_shed"] > 0


# ---------------------------------------------------------------------------
# read-your-writes acks
# ---------------------------------------------------------------------------

class TestReadYourWrites:
    def test_ack_implies_shard_visibility(self):
        """In read_your_writes mode a tx ack means every destination
        shard applied the write: a program submitted from inside the ack
        callback must see the edge."""
        w = make_weaver(seed=6, n_gk=1, read_your_writes=True)
        tx = w.begin_tx()
        tx.create_vertex("a")
        tx.create_vertex("b")
        tx.create_edge("a", "b")
        out = {}

        def on_ack(r):
            assert r.ok
            w.submit_program("get_edges", [("a", None)],
                             lambda res, s, l: out.__setitem__("r", res))

        w.submit_tx(tx, on_ack)
        w.settle(60e-3)
        assert "r" in out, "read-your-writes read never completed"
        edges = out["r"]
        assert edges and any(dst == "b" for _eid, dst in edges), \
            f"acked edge not visible to the follow-up read: {edges!r}"
        assert w.counters()["acks_deferred"] >= 1

    def test_acks_not_deferred_by_default(self):
        w = make_weaver(seed=6, n_gk=1)
        tx = w.begin_tx()
        tx.create_vertex("a")
        assert w.run_tx(tx).ok
        assert w.counters()["acks_deferred"] == 0


# ---------------------------------------------------------------------------
# revalidation skip (LastUpdateTable.mutations seqno)
# ---------------------------------------------------------------------------

class TestRevalidationSkip:
    def test_clean_commit_skips_revalidation(self):
        """Sequential single-gatekeeper traffic: nothing mutates the
        LastUpdateTable between admission and the durability instant, so
        the second validation pass is skipped."""
        w = make_weaver(seed=7, n_gk=1, n_shards=2)
        for i in range(5):
            tx = w.begin_tx()
            tx.create_vertex(f"v{i}")
            assert w.run_tx(tx).ok
        assert w.counters()["revalidations_skipped"] >= 5

    def test_clean_window_skips_revalidation_batched(self):
        w = make_weaver(seed=7, n_gk=1, write_group_commit=0.5e-3,
                        write_group_max=16)
        results = []
        for i in range(6):
            tx = w.begin_tx()
            tx.create_vertex(f"v{i}")
            w.submit_tx(tx, results.append, gatekeeper=0)
        w.settle(30e-3)
        assert all(r.ok for r in results)
        c = w.counters()
        assert c["tx_batches"] >= 1
        assert c["revalidations_skipped"] >= 1

    def test_dirty_concurrent_windows_still_commit_correctly(self):
        """Two gatekeepers writing the same vertex concurrently: the
        mutations seqno moves between admission and commit, forcing the
        real revalidation path — every write must still commit and
        every version must land in the store."""
        w = make_weaver(seed=9, n_gk=2, write_group_commit=0.5e-3,
                        write_group_max=16)
        tx = w.begin_tx()
        tx.create_vertex("r")
        assert w.run_tx(tx).ok
        results = []
        for i in range(12):
            tx = w.begin_tx()
            tx.set_vertex_prop("r", "a", i)
            w.submit_tx(tx, results.append, gatekeeper=i % 2)
        w.settle(80e-3)
        assert len(results) == 12 and all(r.ok for r in results)
        vers = w.store.vertices["r"].props["a"]
        assert sorted(v[0] for v in vers) == list(range(12))


# ---------------------------------------------------------------------------
# shed NACKs (immediate re-route) + open-loop watchdog
# ---------------------------------------------------------------------------

class TestShedNack:
    def _burst(self, shed_nack):
        """Read burst pinned to gatekeeper 0 with a tiny admission queue
        on a two-gatekeeper deployment; gatekeeper 1 sits idle, so every
        shed could be served immediately by re-routing."""
        w = make_weaver(seed=5, n_gk=2, n_shards=1,
                        admission_queue_limit=2,
                        read_retry_timeout=4e-3, shed_nack=shed_nack)
        seed_vertices(w, 8)
        lats = {}
        for i in range(40):
            w.submit_program(
                "get_node", [(f"u{i % 8}", None)],
                lambda r, s, l, i=i: lats.__setitem__(i, (r, l)),
                gatekeeper=0)
        w.settle(300e-3)
        assert len(lats) == 40, "a shed read was never recovered"
        assert all(r is not None for r, _ in lats.values())
        mean = sum(l for _, l in lats.values()) / len(lats)
        return mean, w.counters()

    def test_nack_reroutes_cut_recovery_latency(self):
        mean_on, c_on = self._burst(shed_nack=True)
        mean_off, c_off = self._burst(shed_nack=False)
        # both shed; only nack mode re-routes inside the attempt
        assert c_on["progs_shed"] > 0 and c_off["progs_shed"] > 0
        assert c_on["shed_nacks"] > 0
        assert c_on["nack_reroutes"] > 0
        assert c_off["shed_nacks"] == 0 and c_off["nack_reroutes"] == 0
        # silent sheds wait out the full ack-timeout backoff; NACKed
        # sessions re-route in one network hop
        assert mean_on < mean_off, (mean_on, mean_off)
        assert c_on["prog_retries"] <= c_off["prog_retries"]

    def test_tx_shed_nack_reroutes(self):
        """The tx-session mirror: shed writes re-route without burning
        the retry timer and all commit."""
        w = make_weaver(seed=7, n_gk=2, n_shards=1,
                        admission_queue_limit=1, shed_nack=True)
        seed_vertices(w, 4)
        res = []
        for i in range(24):
            tx = w.begin_tx()
            tx.set_vertex_prop(f"u{i % 4}", "k", i)
            w.submit_tx(tx, res.append, gatekeeper=0)
        w.settle(300e-3)
        assert len(res) == 24 and all(r.ok for r in res)
        c = w.counters()
        assert c["txs_shed"] > 0
        assert c["shed_nacks"] > 0
        assert c["nack_reroutes"] > 0


class TestOpenLoopWatchdog:
    def _server(self, **kw):
        from repro.runtime.server import GraphQueryServer
        w = make_weaver(**kw)
        seed_vertices(w, 4)
        return GraphQueryServer(w)

    def test_silent_drop_raises_with_diagnostic(self):
        """shed_nack off + no read sessions: a shed program's callback
        never fires.  The watchdog must fail the run with a diagnostic
        instead of spinning to the wall-clock timeout."""
        srv = self._server(seed=3, n_gk=1, n_shards=1,
                           admission_queue_limit=1,
                           read_retry_timeout=0.0, shed_nack=False)
        with pytest.raises(RuntimeError) as ei:
            srv.run_open_loop(
                rate=20000.0, n_requests=40,
                make_request=lambda i: ("prog",
                                        ("get_node", [(f"u{i % 4}", None)])),
                timeout=5.0, request_deadline=50e-3)
        msg = str(ei.value)
        assert "watchdog" in msg
        assert "progs_shed=" in msg and "oldest stuck" in msg

    def test_healthy_run_returns_normally(self):
        srv = self._server(seed=3, n_gk=2, n_shards=1,
                           read_retry_timeout=4e-3)
        out = srv.run_open_loop(
            rate=2000.0, n_requests=30,
            make_request=lambda i: ("prog",
                                    ("get_node", [(f"u{i % 4}", None)])),
            timeout=10.0)
        assert out["completed"] == 30 and out["ok"] == 30
