"""Per-kernel validation: shape/dtype sweeps, interpret=True vs the
pure-jnp ref.py oracles, plus hypothesis property tests."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import clock
from repro.core.clock import Stamp


# ------------------------------------------------------------ mv_visibility
class TestMVVisibility:
    def _rand_rows(self, rng, n, g, frac_nostamp=0.3):
        rows = rng.integers(0, 6, size=(n, g + 1)).astype(np.int32)
        rows[:, 0] = rng.integers(0, 2, size=n)        # epochs
        no = rng.random(n) < frac_nostamp
        rows[no] = clock.NO_STAMP
        return rows

    @pytest.mark.parametrize("n,g", [(7, 1), (64, 2), (300, 3), (1024, 4),
                                     (2500, 8)])
    def test_matches_ref_and_core(self, n, g):
        from repro.kernels.mv_visibility import ops
        rng = np.random.default_rng(n * 31 + g)
        creates = rng.integers(0, 6, size=(n, g + 1)).astype(np.int32)
        creates[:, 0] = 0
        deletes = self._rand_rows(rng, n, g, frac_nostamp=0.5)
        q = np.asarray([0] + list(rng.integers(0, 6, g)), np.int32)
        got = np.asarray(ops.visibility_mask(creates, deletes, q))
        ref = np.asarray(ops.visibility_mask(creates, deletes, q,
                                             use_ref=True))
        core = clock.visibility_mask_np(creates, deletes, q)
        np.testing.assert_array_equal(got, ref)
        np.testing.assert_array_equal(got, core)

    @given(st.integers(1, 5), st.integers(1, 200), st.integers(0, 10**6))
    @settings(max_examples=30, deadline=None)
    def test_property_matches_core(self, g, n, seed):
        from repro.kernels.mv_visibility import ops
        rng = np.random.default_rng(seed)
        creates = rng.integers(0, 4, size=(n, g + 1)).astype(np.int32)
        creates[:, 0] = rng.integers(0, 2, size=n)
        deletes = self._rand_rows(rng, n, g)
        q = rng.integers(0, 4, size=g + 1).astype(np.int32)
        got = np.asarray(ops.visibility_mask(creates, deletes, q,
                                             block_n=128))
        core = clock.visibility_mask_np(creates, deletes, q)
        np.testing.assert_array_equal(got, core)


# -------------------------------------------------------------- segment_mp
class TestSegmentMP:
    @pytest.mark.parametrize("n,e,d,f,dtype", [
        (64, 256, 16, 32, jnp.float32),
        (128, 1000, 64, 64, jnp.float32),
        (300, 2000, 32, 8, jnp.float32),
        (128, 512, 128, 128, jnp.bfloat16),
        (17, 3, 8, 16, jnp.float32),          # tiny/ragged
    ])
    def test_matches_ref(self, n, e, d, f, dtype):
        from repro.kernels.segment_mp import ops
        from repro.kernels.segment_mp.ref import segment_matmul_reduce_ref
        rng = np.random.default_rng(e + d)
        x = jnp.asarray(rng.normal(size=(n, d)), dtype)
        w = jnp.asarray(rng.normal(size=(d, f)), dtype)
        src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
        dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
        got = ops.segment_matmul_reduce(x, w, src, dst, n,
                                        block_n=32, block_e=64)
        # bf16: the kernel accumulates fp32 across tiles, so compare to
        # the fp32 ground truth with bf16-eps-scaled tolerance
        ref = segment_matmul_reduce_ref(
            x.astype(jnp.float32), w.astype(jnp.float32), src, dst, n)
        if dtype == jnp.bfloat16:
            tol = dict(rtol=3e-2, atol=3e-1)
        else:
            tol = dict(rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32), **tol)

    def test_skewed_degrees(self):
        """Power-law dst distribution (one hub node)."""
        from repro.kernels.segment_mp import ops
        from repro.kernels.segment_mp.ref import segment_matmul_reduce_ref
        rng = np.random.default_rng(0)
        n, e, d, f = 100, 3000, 16, 16
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(d, f)), jnp.float32)
        src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
        dst = np.where(rng.random(e) < 0.5, 7,
                       rng.integers(0, n, e)).astype(np.int32)
        got = ops.segment_matmul_reduce(x, w, src, jnp.asarray(dst), n,
                                        block_n=32, block_e=128)
        ref = segment_matmul_reduce_ref(x, w, src, jnp.asarray(dst), n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_mp_seam_switches(self):
        """repro.models.mp routes through the kernel when enabled."""
        from repro.models import mp
        rng = np.random.default_rng(1)
        n, e, d, f = 40, 200, 8, 8
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(d, f)), jnp.float32)
        src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
        dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
        base = mp.propagate_matmul(x, w, src, dst, n)
        mp.set_use_pallas(True)
        try:
            fused = mp.propagate_matmul(x, w, src, dst, n)
        finally:
            mp.set_use_pallas(False)
        np.testing.assert_allclose(np.asarray(base), np.asarray(fused),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------- flash_attention
class TestFlashAttention:
    @pytest.mark.parametrize("bh,sq,sk,d,causal,window,dtype", [
        (2, 128, 128, 64, True, None, jnp.float32),
        (1, 256, 256, 32, True, None, jnp.float32),
        (3, 128, 128, 64, False, None, jnp.float32),
        (2, 128, 128, 64, True, 64, jnp.float32),     # sliding window
        (2, 128, 256, 64, True, None, jnp.float32),   # decode-ish sk>sq
        (2, 256, 256, 128, True, None, jnp.bfloat16),
    ])
    def test_matches_ref(self, bh, sq, sk, d, causal, window, dtype):
        from repro.kernels.flash_attention.kernel import \
            flash_attention_pallas
        from repro.kernels.flash_attention.ref import attention_ref
        rng = np.random.default_rng(sq + sk + d)
        q = jnp.asarray(rng.normal(size=(bh, sq, d)), dtype)
        k = jnp.asarray(rng.normal(size=(bh, sk, d)), dtype)
        v = jnp.asarray(rng.normal(size=(bh, sk, d)), dtype)
        got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                     block_q=64, block_k=64)
        ref = attention_ref(q, k, v, causal=causal, window=window)
        tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    def test_gqa_wrapper_matches_model_attention(self):
        from repro.kernels.flash_attention import ops
        from repro.models.layers import attention as model_attention
        rng = np.random.default_rng(5)
        b, s, hq, hkv, d = 2, 128, 8, 2, 32
        q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        got = ops.mha(q, k, v, causal=True)
        ref = model_attention(q, k, v, pos, pos, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @given(st.sampled_from([64, 128]), st.sampled_from([64, 128]),
           st.sampled_from([32, 64]), st.integers(0, 10**6))
    @settings(max_examples=10, deadline=None)
    def test_property_rowsums(self, sq, sk, d, seed):
        """Attention output of constant-V must be ~V (probs sum to 1)."""
        from repro.kernels.flash_attention.kernel import \
            flash_attention_pallas
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(1, sq, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, sk, d)), jnp.float32)
        v = jnp.ones((1, sk, d), jnp.float32) * 3.5
        got = flash_attention_pallas(q, k, v, causal=False,
                                     block_q=64, block_k=64)
        np.testing.assert_allclose(np.asarray(got), 3.5, rtol=1e-5)


# ------------------------------------------------------------ embedding_bag
class TestEmbeddingBag:
    @pytest.mark.parametrize("v,d,b,l,weighted,mode,dtype", [
        (50, 128, 4, 6, False, "sum", jnp.float32),
        (200, 128, 8, 10, True, "sum", jnp.float32),
        (100, 256, 3, 5, True, "mean", jnp.float32),
        (64, 128, 16, 4, False, "mean", jnp.float32),
        (32, 128, 5, 7, True, "sum", jnp.bfloat16),
    ])
    def test_matches_ref(self, v, d, b, l, weighted, mode, dtype):
        from repro.kernels.embedding_bag import ops
        rng = np.random.default_rng(v + b)
        table = jnp.asarray(rng.normal(size=(v, d)), dtype)
        idx = rng.integers(0, v, size=(b, l)).astype(np.int32)
        idx[rng.random((b, l)) < 0.2] = -1             # padding
        # positive weights: mean-mode normalizes by sum(w), which must
        # stay away from 0 for a well-conditioned comparison
        w = jnp.asarray(np.abs(rng.normal(size=(b, l))) + 0.1,
                        jnp.float32) if weighted else None
        got = ops.embedding_bag(table, jnp.asarray(idx), w, mode=mode)
        ref = ops.embedding_bag(table, jnp.asarray(idx), w, mode=mode,
                                use_ref=True)
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    def test_all_padding_bag_is_zero(self):
        from repro.kernels.embedding_bag import ops
        table = jnp.ones((10, 128), jnp.float32)
        idx = jnp.full((2, 3), -1, jnp.int32)
        got = ops.embedding_bag(table, idx)
        np.testing.assert_array_equal(np.asarray(got), 0.0)
