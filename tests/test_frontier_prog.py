"""Frontier-batched node-program runtime: randomized equivalence with the
per-vertex path at identical stamps (under churn, GC, property writes),
message/entry accounting, property-column exposure, and the sorted
segment-op helpers.  Seeded-random, tier-1."""

import numpy as np
import pytest

from repro.core import Weaver, WeaverConfig
from repro.core import analytics as A
from repro.core import frontier as F
from repro.core.analytics import SnapshotEngine
from repro.core.clock import Stamp
from repro.core.nodeprog import REGISTRY


class _Stamps:
    """Totally-ordered synthetic stamps (round-robin gatekeepers)."""

    def __init__(self, n_gk):
        self.n_gk = n_gk
        self.clock = [0] * n_gk
        self.i = 0

    def next(self):
        g = self.i % self.n_gk
        self.i += 1
        self.clock[g] += 1
        return Stamp(0, tuple(self.clock), g, self.clock[g])

    def query(self):
        g = self.i % self.n_gk
        self.i += 1
        self.clock = [c + 1 for c in self.clock]
        return Stamp(0, tuple(self.clock), g, self.clock[g])


def make_weaver(seed=0, n_shards=3):
    return Weaver(WeaverConfig(n_gatekeepers=2, n_shards=n_shards,
                               gc_period=0, seed=seed))


def mutate(rng, w, sg, live, edges, round_i, props=True, deletes=True):
    part = lambda v: w.shards[w.store.place(v)].partition
    for _ in range(int(rng.integers(5, 30))):
        op = rng.integers(0, 100)
        if op < 25 or not live:                       # create vertex
            vid = f"v{round_i}_{rng.integers(0, 1 << 30)}"
            if vid in live:
                continue
            part(vid).create_vertex(vid, sg.next())
            live.add(vid)
        elif op < 55:                                 # create edge
            s = str(rng.choice(sorted(live)))
            d = str(rng.choice(sorted(live)))
            e = part(s).create_edge(s, d, sg.next())
            edges.append((s, e.eid))
            if props and rng.random() < 0.6:
                part(s).set_edge_prop(s, e.eid, "rel",
                                      str(rng.choice(["F", "G"])),
                                      sg.next())
            if props and rng.random() < 0.6:
                part(s).set_edge_prop(s, e.eid, "weight",
                                      float(rng.integers(1, 6)), sg.next())
        elif op < 70 and edges:                       # delete edge
            s, eid = edges[int(rng.integers(0, len(edges)))]
            if s not in live:
                continue
            e = part(s).vertices[s].out_edges.get(eid)
            if e is not None and e.delete_ts is None:
                part(s).delete_edge(s, eid, sg.next())
        elif op < 80 and props and live:              # vertex prop
            vid = str(rng.choice(sorted(live)))
            part(vid).set_vertex_prop(vid, "value",
                                      int(rng.integers(0, 9)), sg.next())
        elif op < 88 and deletes and len(live) > 2:   # delete vertex
            vid = str(rng.choice(sorted(live)))
            part(vid).delete_vertex(vid, sg.next())
            live.discard(vid)
        elif props and edges:                         # re-set edge prop
            s, eid = edges[int(rng.integers(0, len(edges)))]
            if s in live and eid in part(s).vertices[s].out_edges:
                part(s).set_edge_prop(s, eid, "weight",
                                      float(rng.integers(1, 6)), sg.next())


class TestFrontierEquivalence:
    """Frontier path == per-vertex path at identical stamps."""

    def _both(self, w, name, entries, at):
        place = lambda vid: w.store.place(vid)
        r_f, s_f = F.run_local(w, name, entries, at, use_frontier=True,
                               shard_of=place)
        r_s, s_s = F.run_local(w, name, entries, at, use_frontier=False,
                               shard_of=place)
        return r_f, r_s, s_f, s_s

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_random_churn(self, seed):
        rng = np.random.default_rng(seed)
        w = make_weaver(seed)
        sg = _Stamps(2)
        live, edges = set(), []
        for round_i in range(8):
            mutate(rng, w, sg, live, edges, round_i)
            if round_i % 3 == 2:   # interleave GC (may purge + compact)
                horizon = Stamp(0, tuple(sg.clock), -1, 0)
                for sh in w.shards:
                    sh.partition.collect(horizon)
            at = sg.query()
            pool = sorted(live)
            src = str(rng.choice(pool))
            tgt = str(rng.choice(pool))
            cases = [
                ("get_node", [(src, None)]),
                ("count_edges", [(src, None)]),
                ("traverse", [(src, {"depth": 0})]),
                ("traverse", [(src, {"depth": 0, "max_depth": 2})]),
                ("traverse", [(src, {"depth": 0,
                                     "edge_property": ("rel", "F")})]),
                ("reachable", [(src, {"target": tgt})]),
                ("sssp", [(src, {"target": tgt, "max_depth": 128})]),
            ]
            for name, entries in cases:
                r_f, r_s, _, _ = self._both(w, name, entries, at)
                assert r_f == r_s, (name, at, r_f, r_s)

    def test_matches_analytics_reference(self):
        """traverse == BFS-reachable set on the engine snapshot;
        count_edges == snapshot out-degree (three-way agreement)."""
        rng = np.random.default_rng(5)
        w = make_weaver(5)
        sg = _Stamps(2)
        live, edges = set(), []
        mutate(rng, w, sg, live, edges, 0, props=False, deletes=False)
        mutate(rng, w, sg, live, edges, 1, props=False, deletes=False)
        at = sg.query()
        ga = SnapshotEngine(w).snapshot(at)
        src = sorted(live)[0]
        r_f, r_s, _, _ = self._both(w, "traverse", [(src, {"depth": 0})], at)
        lv = np.asarray(A.bfs_levels_ga(ga, [ga.index[src]]))
        want = sorted(ga.vids[i] for i in np.nonzero(lv < A.INF)[0])
        assert r_f == r_s == want
        deg = np.bincount(ga.edge_src, minlength=ga.n_nodes)
        for vid in sorted(live)[:5]:
            c_f, c_s, _, _ = self._both(w, "count_edges", [(vid, None)], at)
            assert c_f == c_s == int(deg[ga.index[vid]])

    def test_block_render_multiset(self):
        """block_render (order-insensitive: reduce is the raw list)."""
        w = make_weaver(3)
        sg = _Stamps(2)
        part = lambda v: w.shards[w.store.place(v)].partition
        part("blk").create_vertex("blk", sg.next())
        for i in range(6):
            part(f"tx{i}").create_vertex(f"tx{i}", sg.next())
            e = part("blk").create_edge("blk", f"tx{i}", sg.next())
            if i % 2 == 0:
                part("blk").set_edge_prop("blk", e.eid, "type", "contains",
                                          sg.next())
            part(f"tx{i}").set_vertex_prop(f"tx{i}", "value", 10 * i,
                                           sg.next())
        at = sg.query()
        r_f, r_s, _, _ = self._both(w, "block_render",
                                    [("blk", {"hop": 0})], at)
        key = lambda d: (d["tx"], d["value"], d["n_out"])
        assert sorted(r_f, key=key) == sorted(r_s, key=key)
        assert {d["tx"] for d in r_f} == {f"tx{i}" for i in range(0, 6, 2)}

    def test_fallback_on_unsupported_params(self):
        """Unhashable filter constants force the scalar path (and the
        driver agrees with it)."""
        assert not REGISTRY["traverse"].frontier_ok(
            {"edge_property": ("rel", ["unhashable"])})
        w = make_weaver(1)
        sg = _Stamps(2)
        part = lambda v: w.shards[w.store.place(v)].partition
        for v in "ab":
            part(v).create_vertex(v, sg.next())
        part("a").create_edge("a", "b", sg.next())
        at = sg.query()
        r_f, r_s, _, _ = self._both(
            w, "traverse",
            [("a", {"depth": 0, "edge_property": ("rel", ["unhashable"])})],
            at)
        assert r_f == r_s == ["a"]


class TestFrontierMessaging:
    def _social(self, w, n=60, m=400, seed=0):
        rng = np.random.default_rng(seed)
        tx = w.begin_tx()
        for i in range(n):
            tx.create_vertex(f"u{i}")
        seen = set()
        for _ in range(m):
            a, b = rng.integers(0, n, 2)
            if a != b and (a, b) not in seen:
                seen.add((a, b))
                tx.create_edge(f"u{a}", f"u{b}")
        assert w.run_tx(tx).ok

    def test_entry_collapse_vs_scalar(self):
        """Same query, same graph: the batched path delivers packed
        frontiers (dedup'd entries), the scalar path one entry per
        emitted vertex."""
        w_f = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=4, seed=9,
                                  frontier_progs=True))
        w_s = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=4, seed=9,
                                  frontier_progs=False))
        self._social(w_f)
        self._social(w_s)
        r_f, _, _ = w_f.run_program("traverse", [("u0", {"depth": 0})],
                                    timeout=60.0)
        r_s, _, _ = w_s.run_program("traverse", [("u0", {"depth": 0})],
                                    timeout=60.0)
        assert r_f == r_s and len(r_f) > 10
        c_f, c_s = w_f.counters(), w_s.counters()
        assert c_f["frontier_batches"] > 0
        assert c_f["scalar_deliveries"] == 0
        assert c_s["frontier_batches"] == 0
        # packed frontiers dedup per (hop, shard): strictly fewer entries
        assert c_f["prog_entries_delivered"] < c_s["prog_entries_delivered"]
        # per-hop message count is O(shards): each delivery emits at most
        # one message per destination shard, so total deliveries are
        # bounded by shards^2 per hop — while the scalar path's payload
        # grows with emitted vertices
        st = w_f.coordinator.last_prog_stats
        assert st["batches"] == c_f["frontier_batches"]
        assert st["entries"] == c_f["prog_entries_delivered"]

    def test_results_identical_both_paths_end_to_end(self):
        for name, entries in [
            ("get_node", [("u1", None)]),
            ("count_edges", [("u2", None)]),
            ("reachable", [("u0", {"target": "u41"})]),
            ("sssp", [("u0", {"target": "u17", "max_depth": 64})]),
        ]:
            w_f = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=3, seed=4,
                                      frontier_progs=True))
            w_s = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=3, seed=4,
                                      frontier_progs=False))
            self._social(w_f, seed=2)
            self._social(w_s, seed=2)
            r_f, _, _ = w_f.run_program(name, entries, timeout=60.0)
            r_s, _, _ = w_s.run_program(name, entries, timeout=60.0)
            assert r_f == r_s, (name, r_f, r_s)


class TestPropColumns:
    def test_engine_vertex_prop_exposure(self):
        """SnapshotEngine property columns == dict-path prop_at."""
        w = make_weaver(0)
        sg = _Stamps(2)
        part = lambda v: w.shards[w.store.place(v)].partition
        rng = np.random.default_rng(0)
        for i in range(12):
            part(f"p{i}").create_vertex(f"p{i}", sg.next())
        for i in range(12):
            for _ in range(int(rng.integers(0, 3))):   # versions
                part(f"p{i}").set_vertex_prop(f"p{i}", "rank",
                                              int(rng.integers(0, 100)),
                                              sg.next())
        mid = sg.query()
        for i in range(0, 12, 2):                      # later versions
            part(f"p{i}").set_vertex_prop(f"p{i}", "rank", 777, sg.next())
        eng = SnapshotEngine(w)
        ga = eng.snapshot(mid)
        vals, num = eng.vertex_prop_column("rank")
        for i, vid in enumerate(ga.vids):
            p = part(vid)
            want = p.vertex_prop_at(vid, "rank", mid)
            assert vals[i] == want
            if want is not None:
                assert num[i] == float(want)
        # later stamp sees the overwrites
        at2 = sg.query()
        eng.snapshot(at2)
        vals2, _ = eng.vertex_prop_column("rank")
        for i, vid in enumerate(ga.vids):
            assert vals2[i] == part(vid).vertex_prop_at(vid, "rank", at2)

    def test_engine_edge_prop_exposure(self):
        w = make_weaver(0)
        sg = _Stamps(2)
        part = lambda v: w.shards[w.store.place(v)].partition
        part("a").create_vertex("a", sg.next())
        part("b").create_vertex("b", sg.next())
        e1 = part("a").create_edge("a", "b", sg.next())
        part("a").set_edge_prop("a", e1.eid, "rel", "OWNS", sg.next())
        part("a").set_edge_prop("a", e1.eid, "rel", "LIKES", sg.next())
        eng = SnapshotEngine(w)
        eng.snapshot(sg.query())
        got = eng.edge_prop_rows("rel")
        assert list(got.values()) == ["LIKES"]

    def test_props_purged_on_recreate(self):
        """Dict path drops property history on vertex re-create; the
        columns must agree (no resurrection on the data plane)."""
        w = make_weaver(0)
        sg = _Stamps(2)
        part = lambda v: w.shards[w.store.place(v)].partition
        part("x").create_vertex("x", sg.next())
        part("x").set_vertex_prop("x", "value", 41, sg.next())
        part("x").delete_vertex("x", sg.next())
        part("x").create_vertex("x", sg.next())
        at = sg.query()
        eng = SnapshotEngine(w)
        eng.snapshot(at)
        vals, _ = eng.vertex_prop_column("value")
        assert vals[eng.index["x"]] is None
        assert part("x").vertex_prop_at("x", "value", at) is None


class TestCompactionDelta:
    def test_gc_compaction_delta_interleaved(self):
        """Churn + GC + forced-threshold compaction, with a warm engine
        delta-refreshing throughout: results always equal cold + seed
        reference, and the warm engine NEVER rebuilds cold (vertex
        deletes ride the tombstone/backfill path, compactions the event
        remap path)."""
        rng = np.random.default_rng(7)
        w = make_weaver(7)
        sg = _Stamps(2)
        live, edges = set(), []
        warm = SnapshotEngine(w)
        compactions = 0

        def canon(ga):
            vids = ga.vids[:ga.n_nodes]
            return (sorted(vids),
                    sorted(zip((vids[i] for i in ga.edge_src.tolist()),
                               (vids[i] for i in ga.edge_dst.tolist()))))

        for round_i in range(10):
            mutate(rng, w, sg, live, edges, round_i)
            if round_i % 2 == 1:
                horizon = Stamp(0, tuple(sg.clock), -1, 0)
                for sh in w.shards:
                    sh.partition.collect(horizon)
                    # force compaction at ANY dead fraction
                    cols = sh.partition.columns
                    if cols.dead_fraction() > 0:
                        cols.compact()
            at = sg.query()
            delta = warm.snapshot(at)
            cold = SnapshotEngine(w).snapshot(at)
            ref = A.snapshot_arrays_python(w, at)
            assert canon(delta) == canon(cold) == canon(ref), round_i
            compactions = sum(sh.partition.columns.n_compactions
                              for sh in w.shards)
        assert compactions > 0, "compaction never exercised"
        assert warm.stats["cold"] == 1, "delta path fell back to cold"
        assert warm.stats["delta"] > 0

    def test_compact_remaps_slots_and_props(self):
        from repro.core.mvgraph import MVGraphPartition
        p = MVGraphPartition(2)
        s = _Stamps(2)
        for i in range(6):
            p.create_vertex(f"n{i}", s.next())
        e = p.create_edge("n5", "n0", s.next())
        p.set_edge_prop("n5", e.eid, "weight", 3.0, s.next())
        p.set_vertex_prop("n5", "value", 9, s.next())
        for i in range(4):
            p.delete_vertex(f"n{i}", s.next())
        p.collect(Stamp(0, (999, 999), -1, 0))
        cols = p.columns
        cols.compact()
        assert cols.n_v == 2 and len(cols.events) >= 1
        # slot dicts renumbered; writes after compaction still work
        p.create_vertex("n9", s.next())
        e2 = p.create_edge("n9", "n5", s.next())
        p.delete_edge("n9", e2.eid, s.next())
        # prop rows survived with remapped owners
        assert cols.e_props.n == 1 and cols.v_props.n == 1
        ow = int(cols.e_props.owner.view()[0])
        assert cols.e_src.view()[ow] == cols.intern.intern("n5")


class TestSortedSegmentOps:
    @pytest.mark.parametrize("op", ["min", "max", "sum"])
    def test_matches_dense_reference(self, op):
        from repro.kernels.segment_mp import ops as smp
        rng = np.random.default_rng(3)
        keys = np.sort(rng.integers(0, 20, 100))
        vals = rng.normal(size=100)
        uniq, red = smp.segment_reduce_sorted(vals, keys, op, use_jax=False)
        uj, rj = smp.segment_reduce_sorted(vals, keys, op, use_jax=True)
        np.testing.assert_array_equal(uniq, uj)
        np.testing.assert_allclose(red, rj, rtol=1e-5, atol=1e-6)
        fn = {"min": np.min, "max": np.max, "sum": np.sum}[op]
        for k, r in zip(uniq.tolist(), red.tolist()):
            np.testing.assert_allclose(r, fn(vals[keys == k]), rtol=1e-12)

    def test_empty(self):
        from repro.kernels.segment_mp import ops as smp
        u, r = smp.segment_reduce_sorted(np.zeros(0), np.zeros(0, np.int64))
        assert u.size == 0 and r.size == 0


class TestSortedPipelineBatches:
    def test_pipeline_emits_dst_sorted(self):
        from repro.data.pipeline import DynamicGraphPipeline
        w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=2, seed=1))
        tx = w.begin_tx()
        for i in range(10):
            tx.create_vertex(f"v{i}")
        rng = np.random.default_rng(0)
        for _ in range(25):
            a, b = rng.integers(0, 10, 2)
            if a != b:
                tx.create_edge(f"v{a}", f"v{b}")
        assert w.run_tx(tx).ok
        pipe = DynamicGraphPipeline(w, d_feat=4, n_classes=2,
                                    pad_nodes=16, pad_edges=64)
        sb = pipe.snapshot_batch()
        assert np.all(np.diff(sb.edge_dst) >= 0), "dst not sorted"
        # sorted-claim reductions agree with the unsorted baseline
        import jax.numpy as jnp
        from repro.models import mp
        msgs = jnp.asarray(rng.normal(size=(sb.edge_dst.size, 3))
                           .astype(np.float32))
        base = np.asarray(mp.scatter_sum(msgs, jnp.asarray(sb.edge_dst), 16))
        srt = np.asarray(mp.scatter_sum(msgs, jnp.asarray(sb.edge_dst), 16,
                                        sorted_ids=True))
        np.testing.assert_allclose(base, srt, rtol=1e-6)
        x = jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32))
        wm = jnp.asarray(rng.normal(size=(3, 3)).astype(np.float32))
        p0 = np.asarray(mp.propagate_matmul(x, wm,
                                            jnp.asarray(sb.edge_src),
                                            jnp.asarray(sb.edge_dst), 16))
        p1 = np.asarray(mp.propagate_matmul(x, wm,
                                            jnp.asarray(sb.edge_src),
                                            jnp.asarray(sb.edge_dst), 16,
                                            dst_sorted=True))
        np.testing.assert_allclose(p0, p1, rtol=1e-5)

    def test_module_default_flag(self):
        from repro.models import mp
        try:
            mp.set_sorted_indices(True)
            assert mp._sorted(False) is True
        finally:
            mp.set_sorted_indices(False)
        assert mp._sorted(False) is False
