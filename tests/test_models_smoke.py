"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.data import graphs as G
from repro.data import synth
from repro.models import gnn, sasrec, transformer
from repro.models.moe import MoEConfig
from repro.optim import AdamWConfig, adamw, make_train_step

RNG = np.random.default_rng(0)


def reduced_lm(cfg: transformer.LMConfig) -> transformer.LMConfig:
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=4,
                                  top_k=min(moe.top_k, 2), d_expert=16)
    return dataclasses.replace(
        cfg, n_layers=2, d_model=32,
        n_heads=4, n_kv=max(1, cfg.n_kv * 4 // cfg.n_heads), d_head=8,
        d_ff=64, vocab=128, moe=moe, dtype="float32")


def _check(x, shape=None):
    arr = np.asarray(x)
    if shape is not None:
        assert arr.shape == shape, (arr.shape, shape)
    assert np.all(np.isfinite(arr)), "NaN/Inf in output"


LM_ARCHS = [a for a, s in ARCHS.items() if s.family == "lm"]
GNN_ARCHS = [a for a, s in ARCHS.items() if s.family == "gnn"]


@pytest.mark.parametrize("arch_id", LM_ARCHS)
class TestLMArchs:
    def test_forward_and_train_step(self, arch_id):
        spec = get_arch(arch_id)
        cfg = reduced_lm(spec.config)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        batch = synth.lm_batch(RNG, cfg.vocab, batch=2, seq=16)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        logits, aux = jax.jit(
            lambda p, t: transformer.forward(p, t, cfg))(params,
                                                         batch["tokens"])
        _check(logits, (2, 16, cfg.vocab))
        # one optimizer step
        step = make_train_step(
            lambda p, b: transformer.lm_loss(p, b, cfg),
            AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10))
        opt = adamw.init(params)
        params2, opt2, metrics = jax.jit(step)(params, opt, batch)
        _check(metrics["loss"])
        assert metrics["loss"] > 0

    def test_prefill_decode_consistent(self, arch_id):
        """Decode after prefill must match full-sequence forward logits."""
        spec = get_arch(arch_id)
        cfg = reduced_lm(spec.config)
        params = transformer.init_params(jax.random.PRNGKey(1), cfg)
        toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 8)), jnp.int32)
        full_logits, _ = transformer.forward(params, toks, cfg)
        pre_logits, cache = transformer.prefill(params, toks[:, :-1], cfg,
                                                max_len=16)
        step_logits, cache = transformer.decode_step(
            params, cache, toks[:, -1:], cfg)
        # prefill last-position logits == forward at position S-2
        np.testing.assert_allclose(np.asarray(pre_logits[:, 0]),
                                   np.asarray(full_logits[:, -2]),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                                   np.asarray(full_logits[:, -1]),
                                   rtol=2e-4, atol=2e-4)


def reduced_gnn(cfg: gnn.GNNConfig, d_feat=8, n_classes=3) -> gnn.GNNConfig:
    return dataclasses.replace(cfg, n_layers=2, d_hidden=16, d_feat=d_feat,
                               n_classes=n_classes)


@pytest.mark.parametrize("arch_id", GNN_ARCHS)
class TestGNNArchs:
    def test_forward_and_train_step(self, arch_id):
        spec = get_arch(arch_id)
        cfg = reduced_gnn(spec.config)
        n, e = 20, 60
        src, dst = G.random_graph(RNG, n, e)
        if cfg.kind == "dimenet":
            batch = {
                "species": jnp.asarray(RNG.integers(0, 8, n), jnp.int32),
                "pos": jnp.asarray(RNG.normal(size=(n, 3)), jnp.float32),
                "edge_src": jnp.asarray(src), "edge_dst": jnp.asarray(dst),
                "graph_ids": jnp.zeros((n,), jnp.int32), "n_graphs": 1,
                "labels": jnp.asarray([1.0], jnp.float32),
            }
            tin, tout = G.build_triplets(src, dst, max_per_edge=4)
            batch["trip_in"] = jnp.asarray(tin)
            batch["trip_out"] = jnp.asarray(tout)
        else:
            batch = {
                "x": jnp.asarray(RNG.normal(size=(n, cfg.d_feat)),
                                 jnp.float32),
                "edge_src": jnp.asarray(src), "edge_dst": jnp.asarray(dst),
                "graph_ids": jnp.zeros((n,), jnp.int32), "n_graphs": 1,
                "labels": jnp.asarray(RNG.integers(0, cfg.n_classes, n),
                                      jnp.int32),
            }
        params = gnn.init_params(jax.random.PRNGKey(0), cfg)
        out = gnn.forward(params, batch, cfg)
        _check(out)
        if cfg.task == "energy":
            assert out.shape == (1,)
        else:
            assert out.shape == (n, cfg.n_classes)
        step = make_train_step(lambda p, b: gnn.gnn_loss(p, b, cfg),
                               AdamWConfig(lr=1e-3, warmup_steps=1,
                                           total_steps=5))
        opt = adamw.init(params)
        p2, o2, metrics = step(params, opt, batch)
        _check(metrics["loss"])

    def test_molecule_batching(self, arch_id):
        spec = get_arch(arch_id)
        cfg = reduced_gnn(spec.config)
        mb = G.batch_molecules(RNG, n_graphs=4, n_nodes=6, n_edges=10,
                               d_feat=cfg.d_feat, with_pos=True)
        if cfg.kind == "dimenet":
            tin, tout = G.build_triplets(mb["edge_src"], mb["edge_dst"],
                                         max_per_edge=4)
            batch = dict(mb, trip_in=jnp.asarray(tin),
                         trip_out=jnp.asarray(tout),
                         species=jnp.asarray(mb["species"]),
                         pos=jnp.asarray(mb["pos"]))
            out = gnn.forward(gnn.init_params(jax.random.PRNGKey(0), cfg),
                              batch, cfg)
            assert out.shape == (4,)
        else:
            cfgg = dataclasses.replace(cfg, task="graph")
            out = gnn.forward(gnn.init_params(jax.random.PRNGKey(0), cfgg),
                              {**mb, "x": jnp.asarray(mb["x"]),
                               "edge_src": jnp.asarray(mb["edge_src"]),
                               "edge_dst": jnp.asarray(mb["edge_dst"]),
                               "graph_ids": jnp.asarray(mb["graph_ids"])},
                              cfgg)
            assert out.shape == (4, cfg.n_classes)
        _check(out)


class TestSASRec:
    def _cfg(self):
        spec = get_arch("sasrec")
        return dataclasses.replace(spec.config, n_items=200, seq_len=12,
                                   d_embed=16)

    def test_train_step(self):
        cfg = self._cfg()
        params = sasrec.init_params(jax.random.PRNGKey(0), cfg)
        batch = synth.sasrec_batch(RNG, cfg.n_items, batch=4,
                                   seq=cfg.seq_len)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        step = make_train_step(lambda p, b: sasrec.bce_loss(p, b, cfg),
                               AdamWConfig(lr=1e-3, warmup_steps=1,
                                           total_steps=5))
        opt = adamw.init(params)
        p2, o2, m = jax.jit(step)(params, opt, batch)
        _check(m["loss"])

    def test_serving_paths(self):
        cfg = self._cfg()
        params = sasrec.init_params(jax.random.PRNGKey(0), cfg)
        hist = jnp.asarray(RNG.integers(1, cfg.n_items + 1, (3, cfg.seq_len)),
                           jnp.int32)
        scores = sasrec.score_catalog(params, hist, cfg)
        _check(scores, (3, sasrec.table_rows(cfg)))
        cands = jnp.asarray(RNG.integers(1, cfg.n_items + 1, (3, 50)),
                            jnp.int32)
        cs = sasrec.score_candidates(params, hist, cands, cfg)
        _check(cs, (3, 50))
        # candidate scores must agree with the catalog path
        np.testing.assert_allclose(
            np.asarray(cs),
            np.take_along_axis(np.asarray(scores), np.asarray(cands),
                               axis=1), rtol=1e-5)

    def test_neighbor_sampler(self):
        from repro.data.sampler import NeighborSampler
        src, dst = G.random_graph(RNG, 200, 2000)
        csr = G.build_csr(src, dst, 200)
        s = NeighborSampler(csr, fanouts=[5, 3], seed=0)
        max_n, max_e = NeighborSampler.max_sizes(8, [5, 3])
        sub = s.sample(np.arange(8), pad_to=(max_n, max_e))
        assert sub.node_ids.shape[0] == max_n
        assert sub.edge_src.shape[0] == max_e
        assert sub.n_real_nodes <= max_n
        # all real edges reference real nodes
        assert sub.edge_src[:sub.n_real_edges].max() < sub.n_real_nodes
