"""Columnar snapshot engine: delta-vs-cold equivalence under random
mutation streams, Pallas/numpy visibility bit-equality (incl. padded
tail), batched oracle refinement call counts, and the sorted-CSR helper
paths.  Seeded-random (no hypothesis dependency) so this file always
runs in the tier-1 suite."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import Weaver, WeaverConfig
from repro.core import analytics as A
from repro.core import clock
from repro.core.analytics import SnapshotEngine
from repro.core.clock import NO_STAMP, Stamp


def canon(ga):
    """Order-free canonical form: vid set + vid-pair edge multiset."""
    vids = ga.vids[:ga.n_nodes]
    pairs = sorted(zip((vids[i] for i in ga.edge_src.tolist()),
                       (vids[i] for i in ga.edge_dst.tolist())))
    return sorted(vids), pairs


class _Stamps:
    """Totally-ordered synthetic stamps (round-robin gatekeepers)."""

    def __init__(self, n_gk):
        self.n_gk = n_gk
        self.clock = [0] * n_gk
        self.i = 0

    def next(self):
        g = self.i % self.n_gk
        self.i += 1
        self.clock[g] += 1
        return Stamp(0, tuple(self.clock), g, self.clock[g])

    def query(self):
        g = self.i % self.n_gk
        self.i += 1
        self.clock = [c + 1 for c in self.clock]
        return Stamp(0, tuple(self.clock), g, self.clock[g])


class TestDeltaEqualsCold:
    def _mutate(self, rng, w, sg, live, dead, edges, round_i):
        part = lambda v: w.shards[w.store.place(v)].partition
        for _ in range(int(rng.integers(1, 25))):
            op = rng.integers(0, 100)
            if op < 35 or not live:                      # create vertex
                vid = f"v{round_i}_{rng.integers(0, 1 << 30)}"
                if vid in live or vid in dead:
                    continue
                part(vid).create_vertex(vid, sg.next())
                live.add(vid)
            elif op < 65:                                # create edge
                s = str(rng.choice(sorted(live)))
                d = str(rng.choice(sorted(live | dead)))
                e = part(s).create_edge(s, d, sg.next())
                edges.append((s, e.eid))
            elif op < 75 and edges:                      # delete edge
                s, eid = edges[int(rng.integers(0, len(edges)))]
                if s not in live:
                    continue
                e = part(s).vertices[s].out_edges.get(eid)
                if e is not None and e.delete_ts is None:
                    part(s).delete_edge(s, eid, sg.next())
            elif op < 85 and len(live) > 1:              # delete vertex
                vid = str(rng.choice(sorted(live)))
                part(vid).delete_vertex(vid, sg.next())
                live.discard(vid)
                dead.add(vid)
            elif op < 92 and dead:                       # re-create
                vid = str(rng.choice(sorted(dead)))
                part(vid).create_vertex(vid, sg.next())
                dead.discard(vid)
                live.add(vid)
            else:                                        # GC at now
                horizon = Stamp(0, tuple(sg.clock), -1, 0)
                for sh in w.shards:
                    sh.partition.collect(horizon)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_random_mutation_stream(self, seed):
        rng = np.random.default_rng(seed)
        w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=3, gc_period=0,
                                seed=seed))
        sg = _Stamps(w.cfg.n_gatekeepers)
        live, dead, edges = set(), set(), []
        warm = SnapshotEngine(w)          # refreshed incrementally
        for round_i in range(12):
            self._mutate(rng, w, sg, live, dead, edges, round_i)
            at = sg.query()
            delta = warm.snapshot(at)
            cold = SnapshotEngine(w).snapshot(at)
            ref = A.snapshot_arrays_python(w, at)
            assert canon(delta) == canon(cold) == canon(ref)
            # CSR/CSC invariants on the incremental snapshot
            k = (delta.edge_src.astype(np.int64) << 32) | delta.edge_dst
            assert np.all(np.diff(k) >= 0)
            k2 = (delta.csc_dst.astype(np.int64) << 32) | delta.csc_src
            assert np.all(np.diff(k2) >= 0)
            assert (sorted(zip(delta.csc_src.tolist(),
                               delta.csc_dst.tolist()))
                    == sorted(zip(delta.edge_src.tolist(),
                                  delta.edge_dst.tolist())))
        assert warm.stats["delta"] + warm.stats["delta_noop"] > 0

    def test_noop_refresh_reuses_arrays(self):
        w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=2, gc_period=0,
                                seed=0))
        sg = _Stamps(2)
        part = lambda v: w.shards[w.store.place(v)].partition
        for v in "abc":
            part(v).create_vertex(v, sg.next())
        part("a").create_edge("a", "b", sg.next())
        eng = SnapshotEngine(w)
        g1 = eng.snapshot(sg.query())
        g2 = eng.snapshot(sg.query())
        assert g2.edge_src is g1.edge_src       # zero-copy noop refresh
        assert eng.stats["delta_noop"] == 1

    def test_weaver_end_to_end_with_cache(self):
        """Through the real transaction pipeline, snapshots at successive
        program stamps (cache active) match the seed reference."""
        w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=3, seed=5))
        tx = w.begin_tx()
        for i in range(12):
            tx.create_vertex(f"n{i}")
        for i in range(11):
            tx.create_edge(f"n{i}", f"n{i+1}")
        assert w.run_tx(tx).ok
        for step in range(4):
            tx = w.begin_tx()
            tx.create_edge(f"n{step}", f"n{11 - step}")
            if step == 2:
                tx.delete_vertex("n7")
            assert w.run_tx(tx).ok
            _, stamp, _ = w.run_program("count_edges", [("n0", None)])
            got = A.snapshot_arrays(w, stamp)
            want = A.snapshot_arrays_python(w, stamp)
            assert canon(got) == canon(want)


class TestBatchedRefinement:
    def test_oracle_calls_no_higher_than_seed(self):
        """Stamps truly concurrent with the query are refined through ONE
        oracle request; the seed path pays one per object."""
        w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=2, gc_period=0,
                                seed=0))
        part = lambda v: w.shards[w.store.place(v)].partition
        # writes advance only gk0's component; the query stamp advances
        # only gk1's -> vector-incomparable (paper Fig. 5 shape)
        for i in range(6):
            s = Stamp(0, (i + 1, 0), 0, i + 1)
            part(f"c{i}").create_vertex(f"c{i}", s)
        q = Stamp(0, (0, 9), 1, 9)

        base = w.sim.counters.oracle_calls
        ref = A.snapshot_arrays_python(w, q)
        seed_calls = w.sim.counters.oracle_calls - base

        base = w.sim.counters.oracle_calls
        got = SnapshotEngine(w).snapshot(q)
        col_calls = w.sim.counters.oracle_calls - base

        assert canon(got) == canon(ref)
        assert seed_calls >= 6          # one refine per concurrent object
        assert 1 <= col_calls <= seed_calls

    def test_conservative_mode_skips_oracle(self):
        w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=2, gc_period=0,
                                seed=0))
        part = lambda v: w.shards[w.store.place(v)].partition
        part("x").create_vertex("x", Stamp(0, (1, 0), 0, 1))
        q = Stamp(0, (0, 5), 1, 5)
        base = w.sim.counters.oracle_calls
        got = SnapshotEngine(w).snapshot(q, refine_concurrent=False)
        assert w.sim.counters.oracle_calls == base
        ref = A.snapshot_arrays_python(w, q, refine_concurrent=False)
        assert canon(got) == canon(ref)


class TestVisibilityKernelBitEquality:
    def _rows(self, rng, n, g, frac_no):
        rows = rng.integers(0, 6, size=(n, g + 1)).astype(np.int32)
        rows[:, 0] = rng.integers(0, 2, size=n)
        rows[rng.random(n) < frac_no] = NO_STAMP
        return rows

    @pytest.mark.parametrize("n", [1, 5, 127, 128, 129, 300])
    @pytest.mark.parametrize("g", [1, 3])
    def test_pallas_matches_np(self, n, g):
        from repro.kernels.mv_visibility import ops
        rng = np.random.default_rng(n * 7 + g)
        creates = self._rows(rng, n, g, 0.2)
        deletes = self._rows(rng, n, g, 0.5)
        q = np.asarray([1] + list(rng.integers(0, 6, g)), np.int32)
        want = clock.visibility_mask_np(creates, deletes, q)
        got = np.asarray(ops.visibility_mask(
            jnp.asarray(creates), jnp.asarray(deletes), jnp.asarray(q),
            block_n=128, interpret=True))
        np.testing.assert_array_equal(got, want)

    def test_padded_tail_is_invisible(self):
        """The pad rows the ops layer appends (all NO_STAMP) must come
        out False from the kernel itself."""
        from repro.kernels.mv_visibility.kernel import visibility_pallas
        rng = np.random.default_rng(0)
        n, g, block = 100, 2, 128
        creates = self._rows(rng, n, g, 0.0)
        deletes = self._rows(rng, n, g, 0.5)
        pad = block - n
        c_cm = np.pad(creates.T, ((0, 0), (0, pad)),
                      constant_values=NO_STAMP)
        d_cm = np.pad(deletes.T, ((0, 0), (0, pad)),
                      constant_values=NO_STAMP)
        q = np.asarray([1, 3, 3], np.int32)
        full = np.asarray(visibility_pallas(jnp.asarray(c_cm),
                                            jnp.asarray(d_cm),
                                            jnp.asarray(q),
                                            block_n=block, interpret=True))
        assert not full[n:].any()
        np.testing.assert_array_equal(
            full[:n], clock.visibility_mask_np(creates, deletes, q))

    def test_engine_kernel_path_matches_np_path(self):
        """FORCE_KERNEL=True routes the engine through the Pallas kernel
        (interpret on CPU); results must be identical."""
        def build():
            w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=2,
                                    gc_period=0, seed=0))
            sg = _Stamps(2)
            part = lambda v: w.shards[w.store.place(v)].partition
            for i in range(9):
                part(f"k{i}").create_vertex(f"k{i}", sg.next())
            for i in range(8):
                part(f"k{i}").create_edge(f"k{i}", f"k{i+1}", sg.next())
            part("k0").delete_edge("k0", 1, sg.next())
            return w, sg.query()

        w, q = build()
        got_np = SnapshotEngine(w).snapshot(q)
        old = A.FORCE_KERNEL
        A.FORCE_KERNEL = True
        try:
            got_k = SnapshotEngine(w).snapshot(q)
        finally:
            A.FORCE_KERNEL = old
        assert canon(got_np) == canon(got_k)
        np.testing.assert_array_equal(got_np.edge_src, got_k.edge_src)
        np.testing.assert_array_equal(got_np.edge_dst, got_k.edge_dst)


class TestSortedTraversalHelpers:
    def _snapshot(self, seed=0, n=30, m=120):
        rng = np.random.default_rng(seed)
        w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=3, gc_period=0,
                                seed=seed))
        sg = _Stamps(2)
        part = lambda v: w.shards[w.store.place(v)].partition
        for i in range(n):
            part(f"t{i}").create_vertex(f"t{i}", sg.next())
        for _ in range(m):
            s, d = rng.integers(0, n, 2)
            part(f"t{s}").create_edge(f"t{s}", f"t{d}", sg.next())
        return SnapshotEngine(w).snapshot(sg.query())

    def test_bfs_ga_matches_plain(self):
        ga = self._snapshot()
        src_i = ga.index["t0"]
        got = np.asarray(A.bfs_levels_ga(ga, [src_i]))
        want = np.asarray(A.bfs_levels(jnp.asarray(ga.edge_src),
                                       jnp.asarray(ga.edge_dst),
                                       ga.n_nodes, jnp.asarray([src_i])))
        np.testing.assert_array_equal(got, want)

    def test_pagerank_ga_matches_plain(self):
        ga = self._snapshot(seed=1)
        got = np.asarray(A.pagerank_ga(ga))
        want = np.asarray(A.pagerank(jnp.asarray(ga.edge_src),
                                     jnp.asarray(ga.edge_dst), ga.n_nodes))
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_cc_ga_matches_plain(self):
        ga = self._snapshot(seed=2)
        got = np.asarray(A.connected_components_ga(ga))
        want = np.asarray(A.connected_components(jnp.asarray(ga.edge_src),
                                                 jnp.asarray(ga.edge_dst),
                                                 ga.n_nodes))
        np.testing.assert_array_equal(got, want)

    def test_indptr_lazy(self):
        ga = self._snapshot(seed=3)
        ip = ga.indptr
        assert ip.shape == (ga.n_nodes + 1,)
        assert ip[0] == 0 and ip[-1] == ga.edge_src.size
        for u in range(ga.n_nodes):
            assert np.all(ga.edge_src[ip[u]:ip[u + 1]] == u)


class TestClusteringCSR:
    @staticmethod
    def _reference(edge_src, edge_dst, n_nodes):
        nbrs = [set() for _ in range(n_nodes)]
        for s, d in zip(edge_src.tolist(), edge_dst.tolist()):
            if s != d:
                nbrs[s].add(d)
        out = np.zeros(n_nodes)
        for u in range(n_nodes):
            k = len(nbrs[u])
            if k < 2:
                continue
            links = sum(len(nbrs[v] & nbrs[u]) for v in nbrs[u])
            out[u] = links / (k * (k - 1))
        return out

    @pytest.mark.parametrize("seed", range(6))
    def test_matches_set_reference(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 50))
        m = int(rng.integers(0, 260))
        src = rng.integers(0, n, m).astype(np.int32)
        dst = rng.integers(0, n, m).astype(np.int32)
        got = A.clustering_coefficients_np(src, dst, n)
        np.testing.assert_allclose(got, self._reference(src, dst, n),
                                   rtol=1e-12)

    def test_build_csr(self):
        src = np.asarray([2, 0, 0, 1, 0], np.int32)
        dst = np.asarray([1, 2, 1, 1, 2], np.int32)
        indptr, nbrs = A.build_csr(src, dst, 3, dedup=True,
                                   drop_self_loops=True)
        assert indptr.tolist() == [0, 2, 2, 3]
        assert nbrs.tolist() == [1, 2, 1]
