"""Store-side GC hook (LastUpdateTable + StoredVertex records): bounded
growth, absence-classifies-as-BEFORE semantics, and dict-mirror
equality across GC.  Tier-1."""

import numpy as np

from repro.core import Weaver, WeaverConfig
from repro.core.clock import Stamp
from repro.core.writepath import (OK, RETRY, LastUpdateTable,
                                  classify_write_sets)


def _stamp(clock, gk=0, epoch=0):
    return Stamp(epoch, tuple(clock), gk, clock[gk])


class TestLastUpdateTableCollect:
    def test_drops_rows_strictly_before_horizon(self):
        t = LastUpdateTable()
        t.record(["a", "b"], _stamp([1, 0]))
        t.record(["c"], _stamp([5, 5]))
        n = t.collect(_stamp([3, 3], gk=-1))
        assert n == 2
        assert t.get("a") is None and t.get("b") is None
        assert t.get("c") == _stamp([5, 5])
        assert t.rows.n == 1

    def test_concurrent_with_horizon_is_kept(self):
        t = LastUpdateTable()
        t.record(["x"], _stamp([4, 0]))          # incomparable with (3,3)
        assert t.collect(_stamp([3, 3], gk=-1)) == 0
        assert t.get("x") is not None

    def test_absence_classifies_ok_for_later_tx(self):
        """A future tx stamp dominates the horizon, so a dropped row must
        classify exactly like the kept row would: ``upd ≺ tx`` -> OK."""
        t = LastUpdateTable()
        t.record(["v"], _stamp([1, 1]))
        verdicts, _ = classify_write_sets(t, [["v"]], [_stamp([9, 9])])
        assert verdicts[0].status == OK and not verdicts[0].concurrent
        t.collect(_stamp([5, 5], gk=-1))
        verdicts, _ = classify_write_sets(t, [["v"]], [_stamp([9, 9])])
        assert verdicts[0].status == OK and not verdicts[0].concurrent
        # ... and a STALE tx stamp must still retry against a KEPT row
        t.record(["v"], _stamp([10, 10]))
        verdicts, _ = classify_write_sets(t, [["v"]], [_stamp([2, 2])])
        assert verdicts[0].status == RETRY

    def test_rerecord_after_collect(self):
        t = LastUpdateTable()
        t.record(["a", "b", "c"], _stamp([1, 0]))
        t.collect(_stamp([4, 4], gk=-1))
        t.record(["b"], _stamp([6, 6]))
        assert t.get("b") == _stamp([6, 6]) and t.get("a") is None
        rows, stamps = t.gather(["a", "b"])
        assert stamps[0] is None and stamps[1] == _stamp([6, 6])


class TestStoreGC:
    def _churn(self, w, n_rounds=6, n_per=8):
        rng = np.random.default_rng(0)
        made = []
        for r in range(n_rounds):
            tx = w.begin_tx()
            for i in range(n_per):
                vid = f"g{r}_{i}"
                tx.create_vertex(vid)
                made.append(vid)
            a, b = rng.choice(n_per, 2, replace=False)
            tx.create_edge(f"g{r}_{int(a)}", f"g{r}_{int(b)}")
            assert w.run_tx(tx).ok
            if r % 2 == 1:                       # delete an older round's
                tx = w.begin_tx()                # vertices
                for i in range(n_per):
                    vid = f"g{r - 1}_{i}"
                    if w.read_vertex(vid) is not None:
                        tx.delete_vertex(vid)
                assert w.run_tx(tx).ok
            w.settle(0.12)                       # > gc_period: GC runs
        return made

    def test_table_and_store_bounded(self):
        w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=2, seed=1,
                                gc_period=50e-3))
        made = self._churn(w)
        c = w.counters()
        assert c["store_lastupdate_gcd"] > 0
        assert c["store_vertices_gcd"] > 0
        # quiescent horizon dominates every commit: the table drains
        assert w.store.last_updates.rows.n < len(made)
        # deleted-and-collected vertices left the store record map too
        assert any(vid not in w.store.vertices for vid in made)

    def test_mirror_invariant_across_gc(self):
        """table.get(vid) == StoredVertex.last_update for every live
        record, before and after the horizon sweeps (both sides clear)."""
        w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=2, seed=2,
                                gc_period=50e-3))
        self._churn(w, n_rounds=4)
        for vid, v in w.store.vertices.items():
            assert w.store.last_updates.get(vid) == v.last_update, vid

    def test_writes_after_gc_validate_identically(self):
        """Grouped and per-tx deployments replay the same op stream with
        GC sweeping between rounds: outcomes and final reads agree (a
        GC'd last-update row must not change any verdict)."""
        results = {}
        for window in (0.0, 2e-3):
            w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=2, seed=3,
                                    gc_period=30e-3,
                                    write_group_commit=window))
            self._churn(w, n_rounds=5)
            # one more write to a long-quiet vertex: its row was GC'd in
            # at least one deployment; must commit cleanly
            tx = w.begin_tx()
            tx.set_vertex_prop("g4_0", "score", 7)
            r = w.run_tx(tx)
            assert r.ok
            w.settle(0.05)
            results[window] = {
                vid: w.read_vertex(vid)
                for vid in (f"g{r}_{i}" for r in range(5) for i in range(8))
            }
        assert results[0.0] == results[2e-3]
