"""End-to-end behaviour tests for the Weaver system (strict
serializability, snapshot isolation, fault tolerance, GC)."""
import numpy as np
import pytest

from repro.core import Weaver, WeaverConfig
from repro.core.clock import Order, compare


def make_weaver(**kw):
    cfg = WeaverConfig(n_gatekeepers=kw.pop("n_gk", 2),
                       n_shards=kw.pop("n_shards", 3),
                       seed=kw.pop("seed", 7), **kw)
    return Weaver(cfg)


def build_path(w, vids):
    tx = w.begin_tx()
    for v in vids:
        tx.create_vertex(v)
    handles = []
    for a, b in zip(vids, vids[1:]):
        handles.append(tx.create_edge(a, b))
    r = w.run_tx(tx)
    assert r.ok, r.error
    return handles


class TestTransactions:
    def test_commit_and_read(self):
        w = make_weaver()
        tx = w.begin_tx()
        tx.create_vertex("u")
        tx.create_vertex("p")
        e = tx.create_edge("u", "p")
        tx.set_edge_prop(e, "rel", "OWNS")
        r = w.run_tx(tx)
        assert r.ok
        got = w.read_vertex("u")
        assert got["edges"] == {e.eid: "p"}

    def test_logical_error_aborts_atomically(self):
        w = make_weaver()
        build_path(w, ["a", "b"])
        tx = w.begin_tx()
        tx.create_vertex("c")
        tx.create_edge("c", "zzz_missing")     # logical error
        r = w.run_tx(tx)
        assert not r.ok
        assert w.read_vertex("c") is None      # nothing applied
        assert w.counters()["tx_aborted"] >= 1

    def test_fig2_photo_transaction(self):
        """The paper's Fig. 2 access-control transaction, atomically."""
        w = make_weaver()
        build_path(w, ["user", "n1"])
        build_path(w, ["n2"])
        tx = w.begin_tx()
        photo = tx.create_vertex("photo")
        own = tx.create_edge("user", photo)
        tx.set_edge_prop(own, "rel", "OWNS")
        for nbr in ["n1", "n2"]:
            acc = tx.create_edge(photo, nbr)
            tx.set_edge_prop(acc, "rel", "VISIBLE")
        r = w.run_tx(tx)
        assert r.ok
        res, _, _ = w.run_program("get_edges", [("photo", None)])
        assert sorted(d for _, d in res) == ["n1", "n2"]

    def test_duplicate_create_aborts(self):
        w = make_weaver()
        build_path(w, ["x"])
        tx = w.begin_tx()
        tx.create_vertex("x")
        r = w.run_tx(tx)
        assert not r.ok

    def test_many_sequential_transactions(self):
        w = make_weaver()
        for i in range(30):
            tx = w.begin_tx()
            tx.create_vertex(f"n{i}")
            if i > 0:
                tx.create_edge(f"n{i}", f"n{i-1}")
            assert w.run_tx(tx).ok
        res, _, _ = w.run_program("traverse", [("n29", {"depth": 0})])
        assert len(res) == 30


class TestSnapshotIsolation:
    def test_fig1_no_phantom_path(self):
        """Paper Fig. 1: concurrent link churn must never yield a path that
        existed at no instant.  n1->n3->n5, n5->n7 created while n3->n5 is
        deleted in ONE transaction; a traversal sees either the old graph
        or the new one, never the phantom n1..n7 path THROUGH n5 unless a
        consistent version contains it."""
        w = make_weaver(n_shards=4)
        tx = w.begin_tx()
        for v in ["n1", "n3", "n5", "n7"]:
            tx.create_vertex(v)
        tx.create_edge("n1", "n3")
        e35 = tx.create_edge("n3", "n5")
        assert w.run_tx(tx).ok

        # atomic reconfiguration: delete (n3,n5), add (n5,n7)
        results = []
        tx2 = w.begin_tx()
        tx2.delete_edge(e35)
        tx2.create_edge("n5", "n7")
        w.submit_tx(tx2, results.append)
        # concurrent traversal racing the update
        progs = []
        w.submit_program("reachable", [("n1", {"target": "n7"})],
                         lambda r, s, l: progs.append(r))
        w.sim.run(until=w.sim.now + 0.2)
        assert results and results[0].ok
        assert progs, "traversal did not finish"
        # n7 was NEVER reachable from n1 in any committed version
        assert progs[0] is False

    def test_long_read_sees_consistent_snapshot(self):
        w = make_weaver()
        build_path(w, [f"p{i}" for i in range(10)])
        # submit traversal and a concurrent edge deletion
        progs = []
        w.submit_program("traverse", [("p0", {"depth": 0})],
                         lambda r, s, l: progs.append(r))
        edges = w.read_vertex("p4")["edges"]
        eid = next(iter(edges))
        tx = w.begin_tx()
        tx.delete_edge("p4", eid)
        box = []
        w.submit_tx(tx, box.append)
        w.sim.run(until=w.sim.now + 0.3)
        assert progs and box and box[0].ok
        # snapshot semantics: all 10 (prog before delete) or 5 (after)
        assert len(progs[0]) in (5, 10), progs[0]

    def test_historical_query(self):
        """Multi-version store supports reads at past stamps (§2, §4.5
        with GC disabled)."""
        w = make_weaver(gc_period=0)
        build_path(w, ["h1", "h2"])
        r1 = w.run_tx(self._mk_delete_all_edges(w, "h1"))
        assert r1.ok
        # read at a stamp AFTER the delete -> no edges
        res, stamp, _ = w.run_program("count_edges", [("h1", None)])
        assert res == 0

    @staticmethod
    def _mk_delete_all_edges(w, vid):
        tx = w.begin_tx()
        for eid in w.read_vertex(vid)["edges"]:
            tx.delete_edge(vid, eid)
        return tx


class TestStrictSerializability:
    def test_concurrent_writers_consistent_across_shards(self):
        """Run interleaved transactions from all gatekeepers touching
        shared vertices; verify every shard applied them in one coherent
        total order (same relative order for overlapping pairs)."""
        w = make_weaver(n_gk=3, n_shards=4, seed=3)
        tx = w.begin_tx()
        for v in ["s1", "s2", "s3", "s4"]:
            tx.create_vertex(v)
        assert w.run_tx(tx).ok

        results = []
        for i in range(40):
            tx = w.begin_tx()
            e = tx.create_edge(f"s{(i % 4) + 1}", f"s{((i + 1) % 4) + 1}")
            tx.set_edge_prop(e, "i", i)
            w.submit_tx(tx, results.append, gatekeeper=i % 3)
        w.sim.run(until=w.sim.now + 1.0)
        assert len(results) == 40
        assert all(r.ok for r in results)
        # all committed stamps must be totally orderable via oracle+vclock
        stamps = [r.stamp for r in results]
        oracle = w.oracle.oracle
        for i in range(len(stamps)):
            for j in range(i + 1, len(stamps)):
                o = compare(stamps[i], stamps[j])
                if o is Order.CONCURRENT:
                    q = oracle.query_order(stamps[i].key(), stamps[j].key())
                    # unresolved pairs are fine only if they never shared
                    # a shard; here every tx touches overlapping vertices,
                    # so queue heads met pairwise at some shard OR their
                    # order is implied transitively.
                    pass
        # edge count correct (no lost updates)
        total = 0
        for v in ["s1", "s2", "s3", "s4"]:
            res, _, _ = w.run_program("count_edges", [(v, None)])
            total += res
        assert total == 40

    def test_wall_clock_order_respected(self):
        """If tx2 is invoked after tx1's response, tx1 ≺ tx2 (§4.4 part 2)."""
        w = make_weaver(n_gk=2)
        build_path(w, ["w1"])
        tx1 = w.begin_tx()
        tx1.set_vertex_prop("w1", "color", "red")
        r1 = w.run_tx(tx1)
        tx2 = w.begin_tx()
        tx2.set_vertex_prop("w1", "color", "blue")
        r2 = w.run_tx(tx2)
        assert r1.ok and r2.ok
        o = compare(r1.stamp, r2.stamp)
        if o is Order.CONCURRENT:
            q = w.oracle.oracle.query_order(r1.stamp.key(), r2.stamp.key())
            assert q is Order.BEFORE
        else:
            assert o is Order.BEFORE
        # latest read must be blue
        res, _, _ = w.run_program("get_node", [("w1", None)])
        got = w.read_vertex("w1")["props"]["color"]
        assert got == "blue"


class TestFaultTolerance:
    def test_shard_failure_recovers_from_backing_store(self):
        w = make_weaver(n_shards=3)
        build_path(w, [f"f{i}" for i in range(12)])
        pre, _, _ = w.run_program("traverse", [("f0", {"depth": 0})])
        assert len(pre) == 12
        w.kill("shard1")
        w.sim.run(until=w.sim.now + 0.1)   # detection + promotion + barrier
        assert w.manager.epoch == 1
        post, _, _ = w.run_program("traverse", [("f0", {"depth": 0})])
        assert post == pre

    def test_gatekeeper_failure_epoch_monotonic(self):
        w = make_weaver(n_gk=2)
        build_path(w, ["g1", "g2"])
        tx_old = w.begin_tx()
        tx_old.set_vertex_prop("g1", "k", 1)
        r_old = w.run_tx(tx_old)
        w.kill("gk1")
        w.sim.run(until=w.sim.now + 0.1)
        assert w.manager.epoch == 1
        tx_new = w.begin_tx()
        tx_new.set_vertex_prop("g1", "k", 2)
        r_new = w.run_tx(tx_new)
        assert r_new.ok
        assert r_new.stamp.epoch == 1
        # every pre-failure stamp precedes every post-failure stamp
        assert compare(r_old.stamp, r_new.stamp) is Order.BEFORE

    def test_writes_after_recovery_apply(self):
        w = make_weaver(n_shards=2)
        build_path(w, ["r1", "r2"])
        w.kill("shard0")
        w.sim.run(until=w.sim.now + 0.1)
        tx = w.begin_tx()
        tx.create_vertex("r3")
        tx.create_edge("r3", "r1")
        assert w.run_tx(tx).ok
        res, _, _ = w.run_program("get_edges", [("r3", None)])
        assert [d for _, d in res] == ["r1"]


class TestGC:
    def test_old_versions_collected(self):
        w = make_weaver(gc_period=10e-3)
        build_path(w, ["gc1", "gc2"])
        eid = next(iter(w.read_vertex("gc1")["edges"]))
        tx = w.begin_tx()
        tx.delete_edge("gc1", eid)
        assert w.run_tx(tx).ok
        # let several GC periods elapse
        w.settle(0.2)
        sid = w.store.shard_of("gc1")
        v = w.shards[sid].partition.vertices["gc1"]
        assert len(v.out_edges) == 0     # deleted version reclaimed

    def test_oracle_events_collected(self):
        w = make_weaver(gc_period=10e-3, n_gk=3, seed=11)
        build_path(w, ["o1", "o2", "o3"])
        for i in range(20):
            tx = w.begin_tx()
            tx.set_vertex_prop(f"o{(i % 3) + 1}", "i", i)
            assert w.run_tx(tx).ok
        before = len(w.oracle.oracle.events)
        w.settle(0.3)
        assert len(w.oracle.oracle.events) <= before


class TestCoordinationKnobs:
    def test_tau_tradeoff_direction(self):
        """Fig. 14 trend: smaller tau -> more announce messages and fewer
        oracle calls; larger tau -> the reverse."""
        def run(tau):
            w = make_weaver(n_gk=3, n_shards=3, tau=tau, seed=5)
            build_path(w, [f"t{i}" for i in range(6)])
            for i in range(30):
                tx = w.begin_tx()
                tx.set_vertex_prop(f"t{i % 6}", "x", i)
                w.submit_tx(tx, lambda r: None, gatekeeper=i % 3)
            w.sim.run(until=w.sim.now + 0.5)
            c = w.counters()
            return c["announce_messages"], c["oracle_calls"]

        a_small, o_small = run(0.2e-3)
        a_big, o_big = run(20e-3)
        assert a_small > a_big
        assert o_big >= o_small
