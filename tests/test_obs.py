"""Deployment-wide observability (ISSUE 9): causal spans, metrics
timeline, critical-path attribution, trace invariants, and the two
ride-along optimisations (shared AIMD load signal, read-window
aliasing + clustering wire dedup).

The load-bearing property throughout: tracing is *pure observation*.
A traced run and an untraced run of the same seeded workload must be
bit-identical in results and in every counter except the obs tallies
themselves (``OBS_COUNTER_FIELDS``).
"""

import json

import pytest

from repro.core import Weaver, WeaverConfig
from repro.core.faultinject import FaultPlan
from repro.core.obs import (OBS_COUNTER_FIELDS, attribution_table,
                            check_completeness, check_replica_staleness,
                            export_trace, run_invariant_checks,
                            validate_trace_events)


def _tx_read_workload(rate: float, seed: int = 11):
    """The equivalence workload: adaptive admission, bounded queues,
    retry sessions — enough machinery that any tracing side effect
    (an extra event, an RNG draw) would shift results or counters."""
    cfg = WeaverConfig(trace_sample_rate=rate, write_group_commit=1e-3,
                       read_group_commit=1e-3, adaptive_admission=True,
                       admission_queue_limit=8, read_retry_timeout=4e-3,
                       seed=seed)
    w = Weaver(cfg)
    results = []
    for i in range(30):
        tx = w.begin_tx()
        tx.create_vertex(f"v{i}")
        if i:
            tx.create_edge(f"v{i - 1}", f"v{i}")
        r = w.run_tx(tx)
        results.append((r.ok, round(r.latency, 12)))
    for i in range(10):
        res = w.run_program("count_edges", [(f"v{i}", None)])
        results.append((res[0], round(res[2], 12)))
    w.settle()
    return w, results


class TestPureObservation:
    def test_tracing_changes_nothing(self):
        """Results and non-obs counters are bit-identical across
        sampling rates 0.0 / 0.5 / 1.0 on the same seeded workload."""
        runs = {}
        for rate in (0.0, 0.5, 1.0):
            w, results = _tx_read_workload(rate)
            c = w.counters()
            for f in OBS_COUNTER_FIELDS:
                c.pop(f, None)
            runs[rate] = (results, c)
        base_res, base_c = runs[0.0]
        for rate in (0.5, 1.0):
            res, c = runs[rate]
            assert res == base_res, f"rate {rate} changed results"
            diff = {k: (base_c.get(k), c.get(k))
                    for k in set(base_c) | set(c)
                    if base_c.get(k) != c.get(k)}
            assert not diff, f"rate {rate} changed counters: {diff}"

    def test_disabled_tracer_records_nothing(self):
        w, _ = _tx_read_workload(0.0)
        assert w.sim.tracer is None
        assert w.sim.counters.spans_recorded == 0


class TestAttribution:
    def test_stage_sums_match_e2e(self):
        """The critical-path analyzer tiles every sampled request's
        root exactly: per-request stage sums equal measured e2e."""
        w, _ = _tx_read_workload(1.0)
        tr = w.sim.tracer
        assert tr.spans and len(tr.traces()) >= 10
        attr = attribution_table(tr)
        rows = [r for r in attr["requests"] if "e2e" in r]
        assert rows, "no complete traces to attribute"
        assert attr["max_rel_err"] < 0.01, attr["max_rel_err"]
        for r in rows:
            assert abs(sum(r["stages"].values()) - r["e2e"]) \
                <= 0.01 * max(r["e2e"], 1e-12)
        # the stage taxonomy actually shows up (not everything "network")
        stages = set(attr["stages"])
        assert {"gk_stamp", "store_commit"} <= stages, stages

    def test_chrome_trace_export(self, tmp_path):
        w, _ = _tx_read_workload(1.0)
        path = tmp_path / "trace.json"
        doc = export_trace(w.sim.tracer, str(path))
        assert validate_trace_events(doc) == []
        on_disk = json.loads(path.read_text())
        assert validate_trace_events(on_disk) == []
        evs = on_disk["traceEvents"]
        assert any(e["ph"] == "X" for e in evs)
        assert any(e["ph"] == "M" and e["name"] == "thread_name"
                   for e in evs)
        # counter parity: every span became exactly one complete event
        assert sum(e["ph"] == "X" for e in evs) \
            == w.sim.counters.spans_recorded == len(w.sim.tracer.spans)


class TestMetricsTimeline:
    def test_periodic_sampling_and_hists(self):
        cfg = WeaverConfig(write_group_commit=1e-3, read_group_commit=1e-3,
                           adaptive_admission=True, metrics_period=2e-3,
                           seed=13)
        w = Weaver(cfg)
        for i in range(20):
            tx = w.begin_tx()
            tx.create_vertex(f"m{i}")
            assert w.run_tx(tx).ok
        for i in range(8):
            w.run_program("get_node", [(f"m{i}", None)])
        w.settle()
        m = w.sim.metrics
        assert w.sim.counters.metrics_samples > 0
        out = m.export()
        assert out["timeline"], "periodic timer never sampled"
        # gauges carry the shared load + queue depth signals
        names = {k for s in out["timeline"] for k in s}
        assert any(k.startswith("gk_admitted:") for k in names), names
        assert any(k.startswith("shard_queue:") for k in names), names
        # admission histograms moved off the ad-hoc Counters lists
        assert m.hists.get("admission_window_us_w") \
            or m.hists.get("admission_window_us_r")
        c = w.counters()
        assert "admission_window_hist" not in c
        assert "admission_depth_hist" not in c


class TestTraceInvariantsUnderFaults:
    """Chaos schedules from the fault-injection harness: every sampled
    request still yields a complete, invariant-clean trace."""

    @pytest.mark.parametrize("chaos_seed", [0, 2, 4])
    def test_complete_and_invariant_clean(self, chaos_seed):
        plan = FaultPlan.random(chaos_seed, n_gk=2, n_shards=3)
        cfg = WeaverConfig(n_gatekeepers=2, n_shards=3, seed=7,
                           write_group_commit=0.5e-3,
                           trace_sample_rate=1.0, fault_plan=plan)
        w = Weaver(cfg)
        w.sim.fault.disarm()           # fault-free setup traffic
        tx = w.begin_tx()
        tx.create_vertex("hub")
        assert w.run_tx(tx).ok
        w.sim.fault.arm()
        results = {}
        for i in range(24):
            v = f"x{i}"
            tx = w.begin_tx()
            tx.create_vertex(v)
            tx.create_edge(v, "hub")
            w.submit_tx(tx, lambda r, v=v: results.__setitem__(v, r))
        w.settle(2.0)
        w.sim.fault.disarm()
        assert len(results) == 24, "a client session hung"

        tr = w.sim.tracer
        assert check_completeness(tr) == []
        checks = run_invariant_checks(tr)
        for name, findings in checks.items():
            assert findings == [], (chaos_seed, name, findings[:5])
        # attribution still tiles the completed requests
        attr = attribution_table(tr)
        assert attr["max_rel_err"] < 0.01


class TestReplicaStalenessInvariant:
    """The replica-staleness checker over fault-injected replicated
    traces: no read may be served by a replica whose applied frontier
    is behind the stamp's settlement token."""

    @pytest.mark.parametrize("chaos_seed", [1, 3])
    def test_replicated_chaos_traces_clean(self, chaos_seed):
        plan = FaultPlan.random(chaos_seed, n_gk=2, n_shards=3,
                                n_crashes=0, replica_faults=True)
        cfg = WeaverConfig(n_gatekeepers=2, n_shards=3, n_replicas=2,
                           seed=7, trace_sample_rate=1.0,
                           read_group_commit=1e-3, fault_plan=plan)
        w = Weaver(cfg)
        w.sim.fault.disarm()
        tx = w.begin_tx()
        for i in range(8):
            tx.create_vertex(f"v{i}")
        for i in range(7):
            tx.create_edge(f"v{i}", f"v{i+1}")
        assert w.run_tx(tx).ok
        w.settle(50e-3)
        w.sim.fault.arm()
        for i in range(16):
            w.run_program("count_edges", [(f"v{i % 8}", None)])
            w.settle(2e-3)
        w.sim.fault.disarm()
        w.settle(0.2)
        tr = w.sim.tracer
        served = [s for s in tr.spans if s.stage == "replica_read"]
        assert served, "no replica-served reads to check"
        checks = run_invariant_checks(tr)
        for name, findings in checks.items():
            assert findings == [], (chaos_seed, name, findings[:5])

    def test_checker_flags_fabricated_violations(self):
        """Negative control: a hand-built stale replica_read span is
        reported by the checker (both failure shapes)."""
        cfg = WeaverConfig(trace_sample_rate=1.0, seed=3)
        w = Weaver(cfg)
        tx = w.begin_tx()
        tx.create_vertex("a")
        assert w.run_tx(tx).ok
        tr = w.sim.tracer
        assert check_replica_staleness(tr) == []
        ctx = (tr.spans[0].trace, tr.spans[0].sid)
        tr.span("replica_read", 0.0, 0.0, actor="shard0r0", ctx=ctx,
                shard=0, replica=0, settle_pos=7, applied_pos=3)
        tr.span("replica_read", 0.0, 0.0, actor="shard1r1", ctx=ctx,
                shard=1, replica=1, settle_pos=-1, applied_pos=0)
        errs = check_replica_staleness(tr)
        assert len(errs) == 2, errs
        assert any("behind settle_pos" in e for e in errs)
        assert any("without a settlement token" in e for e in errs)


class TestSharedLoadSignal:
    def _hammer(self, shared: bool):
        cfg = WeaverConfig(write_group_commit=1e-3, adaptive_admission=True,
                           admission_queue_limit=4, shed_nack=True,
                           shared_load_signal=shared, seed=4)
        w = Weaver(cfg)
        done = []
        # all load on gk0: it saturates and sheds; with the shared
        # signal its peers (serving the NACK reroutes) see the
        # deployment-level pressure and grow their windows
        for i in range(60):
            tx = w.begin_tx()
            tx.create_vertex(f"x{i}")
            w.submit_tx(tx, done.append, gatekeeper=0)
        while len(done) < 60 and w.sim.pending():
            w.sim.run(until=w.sim.now + 5e-3)
        return done, w.counters()

    def test_peer_load_grows_windows(self):
        done_off, c_off = self._hammer(False)
        done_on, c_on = self._hammer(True)
        assert sum(r.ok for r in done_off) == 60
        assert sum(r.ok for r in done_on) == 60
        assert c_off["window_grows_shared"] == 0
        assert c_on["window_grows_shared"] > 0, c_on


class TestReadWindowAliasing:
    def _reads(self, alias: bool):
        cfg = WeaverConfig(read_group_commit=1e-3, read_window_alias=alias,
                           seed=5)
        w = Weaver(cfg)
        tx = w.begin_tx()
        tx.create_vertex("a")
        tx.create_vertex("b")
        tx.create_edge("a", "b")
        assert w.run_tx(tx).ok
        out = [w.run_program("count_edges", [("a", None)])[0]
               for _ in range(6)]
        return out, w.sim.counters.read_windows_aliased

    def test_quiescent_reads_alias(self):
        res_on, aliased_on = self._reads(True)
        res_off, aliased_off = self._reads(False)
        assert aliased_on > 0 and aliased_off == 0
        assert res_on == res_off == [1] * 6

    def test_write_invalidates_alias(self):
        """A mutation between read windows must bump the seqno and
        force a fresh stamp — the next read sees the write."""
        cfg = WeaverConfig(read_group_commit=1e-3, seed=5)
        w = Weaver(cfg)
        tx = w.begin_tx()
        tx.create_vertex("a")
        tx.create_vertex("b")
        tx.create_edge("a", "b")
        assert w.run_tx(tx).ok
        assert w.run_program("count_edges", [("a", None)])[0] == 1
        tx = w.begin_tx()
        tx.create_vertex("c")
        tx.create_edge("a", "c")
        assert w.run_tx(tx).ok
        assert w.run_program("count_edges", [("a", None)])[0] == 2


class TestClusteringWireDedup:
    def _clique_run(self, alias: bool):
        """Dense 14-clique; repeated clustering queries pinned to gk0 so
        they share stamps (the round-robin router would otherwise split
        them across gatekeepers and defeat the same-stamp cache)."""
        cfg = WeaverConfig(read_group_commit=2e-3, read_window_alias=alias,
                           seed=7)
        w = Weaver(cfg)
        tx = w.begin_tx()
        N = 14
        for i in range(N):
            tx.create_vertex(f"k{i}")
        for i in range(N):
            for j in range(N):
                if i != j:
                    tx.create_edge(f"k{i}", f"k{j}")
        assert w.run_tx(tx).ok
        done = []
        for _ in range(2):
            w.submit_program("clustering", [("k0", {"phase": 0})],
                             lambda r, s, l: done.append(r), gatekeeper=0)
        while len(done) < 2 and w.sim.pending():
            w.sim.run(until=w.sim.now + 5e-3)
        done2 = []
        w.submit_program("clustering", [("k0", {"phase": 0})],
                         lambda r, s, l: done2.append(r), gatekeeper=0)
        while len(done2) < 1 and w.sim.pending():
            w.sim.run(until=w.sim.now + 5e-3)
        return (done + done2, w.sim.counters.bytes_sent,
                w.sim.counters.nbr_rows_cached)

    def test_bytes_regression(self):
        res_on, bytes_on, cached_on = self._clique_run(alias=True)
        res_off, bytes_off, cached_off = self._clique_run(alias=False)
        assert res_on == res_off, "dedup changed clustering results"
        assert all(r == pytest.approx(1.0) for r in res_on), res_on
        assert cached_on > cached_off > 0, (cached_on, cached_off)
        assert bytes_on < bytes_off, \
            f"aliased windows shipped no fewer bytes ({bytes_on} vs " \
            f"{bytes_off})"
