"""Group-commit write engine (repro.core.writepath, ISSUE 4).

* randomized batched == per-tx commit equivalence: identical op streams
  through two deployments (``write_group_commit`` on/off), interleaved
  windows, logical aborts — final committed state and read results must
  match;
* reads at stamps straddling batch boundaries: a stamp captured between
  windows must return bit-identical results via frontier, scalar, and
  analytics snapshot AFTER later batches commit (later windows
  invisible at the earlier stamp);
* ``LastUpdateTable`` vs the per-vertex dict walk (property test) and
  the vectorized batch classifier vs ``clock.compare``;
* the duplicate-stamp ``order_events`` regression (benchmarks/
  coordination ``CycleError``);
* shard plan LRU: mutually concurrent query stamps keep separate plans
  (no thrash), budget evictions counted;
* scalar delivery coalescing at the shard (same-(prog, stamp) entry
  lists merge into one ``run_entries_scalar`` execution).
"""

import numpy as np
import pytest

from repro.core import Weaver, WeaverConfig
from repro.core import analytics as A
from repro.core import frontier as F
from repro.core.analytics import SnapshotEngine
from repro.core.clock import Order, Stamp, compare
from repro.core.oracle import TimelineOracle
from repro.core.simulation import Simulator
from repro.core.store import BackingStore
from repro.core.writepath import (OK, RETRY, LastUpdateTable,
                                  classify_write_sets)


def make_weaver(seed=0, n_shards=4, n_gk=2, **kw):
    return Weaver(WeaverConfig(n_gatekeepers=n_gk, n_shards=n_shards,
                               gc_period=0, seed=seed, **kw))


class _Stamps:
    """Totally-ordered synthetic stamps (round-robin gatekeepers)."""

    def __init__(self, n_gk):
        self.n_gk = n_gk
        self.clock = [0] * n_gk
        self.i = 0

    def next(self):
        g = self.i % self.n_gk
        self.i += 1
        self.clock[g] += 1
        return Stamp(0, tuple(self.clock), g, self.clock[g])

    def query(self):
        g = self.i % self.n_gk
        self.i += 1
        self.clock = [c + 1 for c in self.clock]
        return Stamp(0, tuple(self.clock), g, self.clock[g])


# ---------------------------------------------------------------------------
# oracle regression
# ---------------------------------------------------------------------------

class TestOracleDuplicates:
    def test_duplicate_stamps_with_constraints(self):
        """order_events used to raise a spurious CycleError when the
        request repeated a stamp that had pending predecessors (Kahn
        counted duplicates, the ready set deduped) — the
        benchmarks/coordination tau-sweep failure."""
        o = TimelineOracle()
        a = Stamp(0, (1, 0), 0, 1)
        b = Stamp(0, (0, 1), 1, 1)
        first = o.order_events([a, b])       # commits one direction
        again = o.order_events([b, a, b, b, a])
        assert again == first and len(again) == 2

    def test_many_duplicates_random(self):
        rng = np.random.default_rng(0)
        o = TimelineOracle()
        pool = [Stamp(0, (int(rng.integers(1, 6)), int(rng.integers(1, 6))),
                      int(rng.integers(0, 2)), i) for i in range(12)]
        for _ in range(50):
            req = [pool[int(rng.integers(len(pool)))]
                   for _ in range(int(rng.integers(2, 9)))]
            chain = o.order_events(req)      # must never cycle
            assert len(chain) == len({s.key() for s in req})


# ---------------------------------------------------------------------------
# LastUpdateTable / classifier
# ---------------------------------------------------------------------------

class TestLastUpdateTable:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_matches_dict_walk(self, seed):
        """The packed table must agree with StoredVertex.last_update
        after a random stream of per-tx and batched commits, including
        aborted transactions (no table side effects)."""
        rng = np.random.default_rng(seed)
        sim = Simulator(seed=seed)
        store = BackingStore(sim, 4)
        sg = _Stamps(2)
        vids = [f"v{i}" for i in range(30)]
        live = set()
        for r in range(60):
            ops = []
            for _ in range(int(rng.integers(1, 6))):
                c = rng.random()
                v = vids[int(rng.integers(len(vids)))]
                if c < 0.35:
                    ops.append({"op": "create_vertex", "vid": v})
                elif c < 0.6 and live:
                    s = str(rng.choice(sorted(live)))
                    d = vids[int(rng.integers(len(vids)))]
                    ops.append({"op": "create_edge", "src": s, "dst": d})
                elif c < 0.8 and live:
                    s = str(rng.choice(sorted(live)))
                    ops.append({"op": "set_vertex_prop", "vid": s,
                                "key": "k", "value": int(rng.integers(9))})
                elif live:
                    s = str(rng.choice(sorted(live)))
                    ops.append({"op": "delete_vertex", "vid": s})
            if not ops:
                continue
            if rng.random() < 0.5:
                try:
                    store.apply(ops, sg.next())
                except ValueError:
                    pass
                else:
                    self._track(ops, live)
            else:
                # batch of 1-3 (the remaining ops split arbitrarily)
                cut = sorted(rng.choice(max(len(ops), 1),
                                        size=min(2, len(ops)),
                                        replace=False).tolist())
                parts, prev = [], 0
                for c in cut + [len(ops)]:
                    if ops[prev:c]:
                        parts.append(ops[prev:c])
                    prev = c
                res = store.apply_batch([(p, sg.next(), None) for p in parts])
                for (ok, _, _), p in zip(res, parts):
                    if ok:
                        self._track(p, live)
            # invariant: table == dict walk, for every vid ever seen
            for v in vids:
                assert store.last_updates.get(v) == store.last_update_of(v)

    @staticmethod
    def _track(ops, live):
        for op in ops:
            if op["op"] == "create_vertex":
                live.add(op["vid"])
            elif op["op"] == "delete_vertex":
                live.discard(op["vid"])

    def test_classifier_matches_compare(self):
        """classify_write_sets must reproduce clock.compare semantics
        per (tx, vid) row: AFTER -> retry, CONCURRENT -> refine residue,
        BEFORE/absent -> pass."""
        rng = np.random.default_rng(3)
        table = LastUpdateTable()
        stamps = {}
        for i in range(40):
            s = Stamp(0, (int(rng.integers(0, 6)), int(rng.integers(0, 6))),
                      int(rng.integers(0, 2)), i + 1)
            vid = f"v{i}"
            table.record([vid], s)
            stamps[vid] = s
        for _ in range(200):
            tx = Stamp(0, (int(rng.integers(0, 6)), int(rng.integers(0, 6))),
                       int(rng.integers(0, 2)), 1000)
            ws = [f"v{int(rng.integers(0, 50))}"      # incl. absent vids
                  for _ in range(int(rng.integers(1, 5)))]
            (verdict,), rows = classify_write_sets(table, [ws], [tx])
            assert rows == len(ws)
            want_retry, want_conc = False, []
            for v in ws:
                upd = stamps.get(v)
                if upd is None:
                    continue
                o = compare(upd, tx)
                if o is Order.AFTER:
                    want_retry = True
                elif o is Order.CONCURRENT:
                    want_conc.append(upd)
            if want_retry:
                assert verdict.status == RETRY
            else:
                assert verdict.status == OK
                assert verdict.concurrent == want_conc


# ---------------------------------------------------------------------------
# batched == per-tx equivalence
# ---------------------------------------------------------------------------

def _fingerprint(w):
    """Mode-invariant committed state (eids/stamps legitimately differ
    between modes: multisets of live edges + property versions)."""
    out = {}
    for vid, v in w.store.vertices.items():
        alive = v.delete_ts is None
        edges = sorted(dst for dst, _, dts in v.edges.values()
                       if dts is None) if alive else []
        props = sorted((k, val) for k, vs in v.props.items()
                       for val, _ in vs)
        out[vid] = (alive, edges, props)
    return out


def _gen_wave(rng, vids, known, wave_i):
    """One wave of tx specs (identical across modes)."""
    wave = []
    for _ in range(int(rng.integers(6, 14))):
        c = rng.random()
        v = vids[int(rng.integers(len(vids)))]
        if c < 0.25:
            wave.append(("create", v))           # may abort: exists
        elif c < 0.65 and known:
            u = str(rng.choice(sorted(known)))
            wave.append(("edge", u, v))          # may abort: src dead
        elif c < 0.8 and known:
            u = str(rng.choice(sorted(known)))
            wave.append(("prop", u, float(wave_i)))
        elif known:
            u = str(rng.choice(sorted(known)))
            wave.append(("delete", u))           # may abort: already dead
    return wave


def _submit_wave(w, wave, results, gatekeeper=None):
    for i, spec in enumerate(wave):
        tx = w.begin_tx()
        if spec[0] == "create":
            tx.create_vertex(spec[1])
        elif spec[0] == "edge":
            tx.create_edge(spec[1], spec[2])
        elif spec[0] == "prop":
            tx.set_vertex_prop(spec[1], "score", spec[2])
        else:
            tx.delete_vertex(spec[1])
        g = (i % len(w.gatekeepers)) if gatekeeper is None else gatekeeper
        w.submit_tx(tx, results.append, gatekeeper=g)


class TestGroupCommitEquivalence:
    @pytest.mark.parametrize("seed", [0, 1, 7])
    def test_batched_equals_per_tx_single_gk(self, seed):
        """Identical randomized op streams — including conflicting
        creates/deletes and logical aborts — through both modes on ONE
        gatekeeper (admission order pins the serial order in both, so
        per-tx commit/abort outcomes must match exactly), with reads
        between interleaved windows."""
        modes = {}
        for window in (0.0, 0.25e-3):
            rng = np.random.default_rng(seed)
            w = make_weaver(seed=seed, write_group_commit=window,
                            write_group_max=8)
            vids = [f"n{i}" for i in range(24)]
            known = set()
            reads, outcomes = [], []
            for wave_i in range(8):
                wave = _gen_wave(rng, vids, known, wave_i)
                results = []
                _submit_wave(w, wave, results, gatekeeper=0)
                w.settle(30e-3)          # quiesce: interleaved windows done
                assert len(results) == len(wave)
                outcomes.append([r.ok for r in results])
                for spec, r in zip(wave, results):
                    if r.ok:
                        if spec[0] == "create":
                            known.add(spec[1])
                        elif spec[0] == "delete":
                            known.discard(spec[1])
                if known:
                    root = sorted(known)[0]
                    trav, _, _ = w.run_program(
                        "traverse", [(root, {"depth": 2})])
                    cnt, _, _ = w.run_program("count_edges", [(root, None)])
                    reads.append((sorted(trav), cnt))
            modes[window] = (outcomes, reads, _fingerprint(w), w.counters())
        (o1, r1, f1, c1), (o2, r2, f2, c2) = modes[0.0], modes[0.25e-3]
        assert o1 == o2, "commit/abort outcomes diverged"
        assert r1 == r2, "quiescent read results diverged"
        assert f1 == f2, "final committed state diverged"
        assert c2["tx_batches"] > 0
        assert c2["tx_batch_size_sum"] >= c2["tx_batches"]
        assert c1["tx_batches"] == 0

    @pytest.mark.parametrize("seed", [2, 11])
    def test_batched_equals_per_tx_cross_gk(self, seed):
        """Cross-gatekeeper concurrency (the refinement residue): a
        conflict-free write mix — edges and same-vertex property writes
        from BOTH gatekeepers — must commit fully in both modes and
        converge to the same state.  (Which of two cross-gk logical
        conflicts wins is timing-dependent in BOTH modes, so the strict
        outcome comparison lives in the single-gk test.)"""
        modes = {}
        for window in (0.0, 0.25e-3):
            rng = np.random.default_rng(seed)
            w = make_weaver(seed=seed, write_group_commit=window,
                            write_group_max=8)
            vids = [f"n{i}" for i in range(20)]
            tx = w.begin_tx()
            for v in vids:
                tx.create_vertex(v)
            assert w.run_tx(tx).ok
            reads = []
            for wave_i in range(6):
                results = []
                wave = []
                for _ in range(12):
                    u = vids[int(rng.integers(len(vids)))]
                    v = vids[int(rng.integers(len(vids)))]
                    if rng.random() < 0.6:
                        wave.append(("edge", u, v))
                    else:
                        wave.append(("prop", u, float(wave_i)))
                _submit_wave(w, wave, results)   # round-robin both gks
                w.settle(30e-3)
                assert len(results) == len(wave)
                assert all(r.ok for r in results)
                trav, _, _ = w.run_program("traverse",
                                           [(vids[0], {"depth": 2})])
                reads.append(sorted(trav))
            modes[window] = (reads, _fingerprint(w), w.counters())
        (r1, f1, c1), (r2, f2, c2) = modes[0.0], modes[0.25e-3]
        assert r1 == r2, "quiescent read results diverged"
        assert f1 == f2, "final committed state diverged"
        assert c2["tx_batches"] > 0
        assert c2["conflict_rows_checked"] > 0

    def test_reads_straddle_batch_boundaries(self):
        """A stamp captured between windows must read bit-identically
        via frontier, scalar, and analytics AFTER later windows commit
        (later batches invisible at the earlier stamp)."""
        w = make_weaver(seed=3, write_group_commit=0.25e-3,
                        write_group_max=8)
        vids = [f"s{i}" for i in range(12)]
        tx = w.begin_tx()
        for v in vids:
            tx.create_vertex(v)
        assert w.run_tx(tx).ok
        results = []
        for i in range(10):
            tx = w.begin_tx()
            tx.create_edge(vids[i % 12], vids[(i + 1) % 12])
            w.submit_tx(tx, results.append)
        w.settle(30e-3)
        assert all(r.ok for r in results)
        # stamp between windows: issued now, before the next wave
        at = w.gatekeepers[0]._tick()
        ga_before = SnapshotEngine(w).snapshot(at)
        r_before, _ = F.run_local(w, "traverse", [(vids[0], {"depth": 0})],
                                  at, use_frontier=True)
        # ---- later windows commit ----
        results2 = []
        for i in range(14):
            tx = w.begin_tx()
            tx.create_edge(vids[(i + 5) % 12], vids[(i + 9) % 12])
            w.submit_tx(tx, results2.append)
        w.settle(30e-3)
        assert all(r.ok for r in results2)
        # identical reads at `at` across all three paths, post-commit
        r_f, _ = F.run_local(w, "traverse", [(vids[0], {"depth": 0})], at,
                             use_frontier=True)
        r_s, _ = F.run_local(w, "traverse", [(vids[0], {"depth": 0})], at,
                             use_frontier=False)
        ga = SnapshotEngine(w).snapshot(at)
        lv = np.asarray(A.bfs_levels_ga(ga, [ga.index[vids[0]]]))
        r_a = sorted(ga.vids[i] for i in np.nonzero(lv < A.INF)[0])
        assert r_f == r_s == r_a == r_before
        assert int(ga.edge_src.size) == int(ga_before.edge_src.size), \
            "later batches leaked into the earlier stamp"

    def test_logical_abort_is_per_tx_within_batch(self):
        """One bad tx aborts alone; the rest of its window commits."""
        w = make_weaver(seed=4, write_group_commit=0.5e-3,
                        write_group_max=16)
        tx = w.begin_tx()
        tx.create_vertex("a")
        assert w.run_tx(tx).ok
        results = []
        specs = [("create", "b"), ("create", "a"),    # dup -> abort
                 ("edge", "a", "b"), ("create", "c")]
        for spec in specs:
            tx = w.begin_tx()
            if spec[0] == "create":
                tx.create_vertex(spec[1])
            else:
                tx.create_edge(spec[1], spec[2])
            w.submit_tx(tx, results.append, gatekeeper=0)  # one window
        w.settle(30e-3)
        assert [r.ok for r in results] == [True, False, True, True]
        assert "exists" in results[1].error
        c = w.counters()
        assert c["tx_aborted"] == 1
        assert c["tx_batches"] >= 1

    def test_stale_window_timer_does_not_shorten_next_window(self):
        """A timer armed for a window that a max-count trigger already
        flushed must not fire into the NEXT window (it would cut every
        later window short under load)."""
        w = make_weaver(seed=8, write_group_commit=10e-3,
                        write_group_max=4)
        tx = w.begin_tx()
        tx.create_vertex("r")
        assert w.run_tx(tx).ok          # own (max-1-sized) windows
        base = w.counters()["tx_batches"]
        results = []
        for i in range(4):              # fills group_max -> instant flush
            tx = w.begin_tx()
            tx.set_vertex_prop("r", "a", i)
            w.submit_tx(tx, results.append, gatekeeper=0)
        w.settle(2e-3)
        assert w.counters()["tx_batches"] == base + 1
        for i in range(2):              # new window, deadline now+10ms
            tx = w.begin_tx()
            tx.set_vertex_prop("r", "b", i)
            w.submit_tx(tx, results.append, gatekeeper=0)
        w.settle(7e-3)                  # ~9ms: stale timer would have fired
        assert w.counters()["tx_batches"] == base + 1, \
            "second window flushed early (stale timer)"
        w.settle(8e-3)                  # past the real ~12ms deadline
        assert w.counters()["tx_batches"] == base + 2
        assert len(results) == 6 and all(r.ok for r in results)

    def test_batch_prefix_stops_at_pending_program_stamp(self):
        """A WriteBatch item merely CONCURRENT with a gated program's
        stamp must not apply inside the bulk prefix: per-tx execution
        re-checks runnable programs between items, and the item may yet
        be oracle-ordered after the program (a re-create would destroy
        history the program still needs).  The prefix stops; the
        remainder is requeued as the new head."""
        from repro.core.writepath import WriteBatch
        w = make_weaver(seed=10, n_shards=1)
        sh = w.shards[0]
        sh.partition.create_vertex("v", Stamp(0, (1, 0), 0, 1))
        sh.partition.set_vertex_prop("v", "k", "old", Stamp(0, (2, 0), 0, 2))
        # gated program, concurrent with the batch's 2nd/3rd items
        p_stamp = Stamp(0, (3, 5), 1, 5)

        class _Coord:
            def report(self, *a, **k):
                pass
        sh.deliver_prog(1, ("t", 1), "get_node", p_stamp, [("v", None)],
                        _Coord())
        wb = WriteBatch([
            (Stamp(0, (3, 0), 0, 3),
             [{"op": "set_vertex_prop", "vid": "v", "key": "k",
               "value": "mid"}]),
            (Stamp(0, (4, 0), 0, 4), [{"op": "delete_vertex", "vid": "v"}]),
            (Stamp(0, (5, 0), 0, 5), [{"op": "create_vertex", "vid": "v"}]),
        ])
        sh.enqueue(0, 1, wb.stamp, "txbatch", wb)
        # other queue: dominating NOP head (would allow the WHOLE batch
        # if only queue heads bounded the prefix)
        sh.enqueue(1, 1, Stamp(0, (9, 9), 1, 9), "nop", None)
        head = sh.queues[0][0]
        assert head.kind == "txbatch" and len(head.payload) == 2, \
            "prefix overtook a concurrent pending program"
        assert head.stamp.key() == (0, (4, 0), 0)
        v = sh.partition.vertices["v"]
        assert v.delete_ts is None, "delete applied ahead of the program"
        assert len(v.props["k"]) == 2, "history lost ahead of the program"

    def test_retry_abort_after_max(self):
        """_retry_or_abort gives up after MAX_RETRIES (shared bound of
        the per-tx and group paths)."""
        from repro.core.gatekeeper import MAX_RETRIES
        w = make_weaver(seed=5, write_group_commit=0.5e-3)
        gk = w.gatekeepers[0]
        got = []
        stamp = gk._tick()
        gk._retry_or_abort((None, [], stamp,
                            lambda ok, err, s: got.append((ok, err)),
                            MAX_RETRIES, 0.0, None))
        w.settle(5e-3)
        assert got == [(False, "too many retries")]
        assert w.counters()["tx_aborted"] == 1
        assert w.counters()["tx_retried"] == 1


# ---------------------------------------------------------------------------
# bulk column appends
# ---------------------------------------------------------------------------

class TestBulkColumns:
    @pytest.mark.parametrize("seed", [0, 4])
    def test_apply_batch_columns_equal_per_op(self, seed):
        """MVGraphPartition.apply_batch (buffered column appends, one
        extend per table) must leave byte-identical columns to per-op
        application of the same (stamp, op) stream."""
        from repro.core.mvgraph import MVGraphPartition

        def gen(rng, n_rounds=25):
            vids, eids, rounds, nxt = [], {}, [], [0]
            for r in range(n_rounds):
                ops = []
                for _ in range(int(rng.integers(1, 10))):
                    c = rng.random()
                    if c < 0.3 or not vids:
                        vid = f"v{len(vids)}"
                        vids.append(vid)
                        ops.append({"op": "create_vertex", "vid": vid})
                    elif c < 0.55 and len(vids) >= 2:
                        s, d = rng.choice(len(vids), 2)
                        nxt[0] += 1
                        eids.setdefault(f"v{s}", []).append(nxt[0])
                        ops.append({"op": "create_edge", "src": f"v{s}",
                                    "dst": f"v{d}", "eid": nxt[0]})
                    elif c < 0.7:
                        s = rng.integers(len(vids))
                        es = eids.get(f"v{s}")
                        if es:
                            ops.append({"op": "delete_edge",
                                        "src": f"v{s}", "eid": es.pop()})
                    elif c < 0.9:
                        s = rng.integers(len(vids))
                        ops.append({"op": "set_vertex_prop",
                                    "vid": f"v{s}", "key": "k",
                                    "value": float(rng.random())})
                    else:
                        s = rng.integers(len(vids))
                        es = eids.get(f"v{s}")
                        if es:
                            ops.append({"op": "set_edge_prop",
                                        "src": f"v{s}", "eid": es[-1],
                                        "key": "w", "value": 1.0})
                rounds.append(ops)
            return rounds

        def run(mode):
            p = MVGraphPartition(3)
            sg = _Stamps(3)
            for ops in gen(np.random.default_rng(seed)):
                items = [(sg.next(), [op]) for op in ops]
                if mode == "batch":
                    p.apply_batch(items)
                else:
                    for ts, opl in items:
                        for op in opl:
                            p.apply_op(op, ts)
            return p.columns

        ca, cb = run("per-op"), run("batch")
        for name in ("v_gid", "v_create", "v_delete", "e_src", "e_dst",
                     "e_create", "e_delete"):
            assert np.array_equal(getattr(ca, name).view(),
                                  getattr(cb, name).view()), name
        assert sorted(ca.v_patch) == sorted(cb.v_patch)
        assert sorted(ca.e_patch) == sorted(cb.e_patch)
        assert ca.v_slot == cb.v_slot and ca.e_slot == cb.e_slot
        for t in ("v_props", "e_props"):
            pa, pb = getattr(ca, t), getattr(cb, t)
            for name in ("owner", "key", "val", "num", "stamp"):
                assert np.array_equal(getattr(pa, name).view(),
                                      getattr(pb, name).view()), (t, name)
            assert sorted(pa.patch) == sorted(pb.patch)
            assert pa.by_owner == pb.by_owner


# ---------------------------------------------------------------------------
# plan LRU
# ---------------------------------------------------------------------------

class TestPlanLRU:
    def _loaded_shard(self, entries=4):
        w = make_weaver(seed=6, n_shards=1, plan_cache_entries=entries)
        sh = w.shards[0]
        sg = _Stamps(2)
        for i in range(6):
            sh.partition.create_vertex(f"p{i}", sg.next())
        for i in range(5):
            sh.partition.create_edge(f"p{i}", f"p{i+1}", sg.next())
        return w, sh, sg

    def test_concurrent_stamps_keep_separate_plans(self):
        w, sh, sg = self._loaded_shard()
        base = list(sg.clock)
        sa = Stamp(0, (base[0] + 5, base[1] + 1), 0, base[0] + 5)
        sb = Stamp(0, (base[0] + 1, base[1] + 5), 1, base[1] + 5)
        assert compare(sa, sb) is Order.CONCURRENT
        pa = sh._frontier_plan(sa)
        pb = sh._frontier_plan(sb)
        assert pa is not pb
        c0 = w.sim.counters.plan_cold_builds
        # alternating queries now hit their own cached plans: no thrash
        assert sh._frontier_plan(sa) is pa
        assert sh._frontier_plan(sb) is pb
        assert sh._frontier_plan(sa) is pa
        assert w.sim.counters.plan_cold_builds == c0
        assert w.sim.counters.plan_cache_evictions == 0

    def test_budget_evicts_lru(self):
        w, sh, sg = self._loaded_shard(entries=1)
        base = list(sg.clock)
        sa = Stamp(0, (base[0] + 5, base[1] + 1), 0, base[0] + 5)
        sb = Stamp(0, (base[0] + 1, base[1] + 5), 1, base[1] + 5)
        pa = sh._frontier_plan(sa)
        sh._frontier_plan(sb)                  # evicts pa (budget 1)
        assert w.sim.counters.plan_cache_evictions == 1
        assert sh._frontier_plan(sa) is not pa  # cold again: thrash mode
        assert w.sim.counters.plan_cold_builds >= 3

    def test_dominating_stamp_still_reuses(self):
        """The PR 3 settled-reuse contract survives the LRU."""
        w, sh, sg = self._loaded_shard()
        s1 = sg.query()
        p1 = sh._frontier_plan(s1)
        assert p1.settled
        assert sh._frontier_plan(sg.query()) is p1


# ---------------------------------------------------------------------------
# scalar delivery coalescing
# ---------------------------------------------------------------------------

class TestScalarCoalescing:
    @staticmethod
    def _build(coalesce):
        w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=4, gc_period=0,
                                seed=9, frontier_coalesce=coalesce))
        vids = [f"c{i}" for i in range(16)]
        tx = w.begin_tx()
        for v in vids:
            tx.create_vertex(v)
        assert w.run_tx(tx).ok
        eids = {}
        tx = w.begin_tx()
        for i in range(16):
            for j in (1, 2, 3):
                eids[(i, j)] = tx.create_edge(vids[i], vids[(i + j) % 16])
        assert w.run_tx(tx).ok
        # unhashable edge-filter constant -> scalar path with emits
        tx = w.begin_tx()
        for handle in eids.values():
            tx.set_edge_prop(handle, "tag", [1])
        assert w.run_tx(tx).ok
        return w, vids

    def test_merges_and_matches_uncoalesced(self):
        results = {}
        for coalesce in (True, False):
            w, vids = self._build(coalesce)
            params = {"depth": 0, "edge_property": ("tag", [1])}
            res, _, _ = w.run_program("traverse", [(vids[0], params)])
            c = w.counters()
            assert c["frontier_batches"] == 0, "filter should force scalar"
            results[coalesce] = (sorted(res), c["scalar_coalesced"])
        assert results[True][0] == results[False][0]
        assert results[True][1] > 0, "no scalar deliveries merged"
        assert results[False][1] == 0


# ---------------------------------------------------------------------------
# shard reorder buffer (cross-gatekeeper batch merging)
# ---------------------------------------------------------------------------

class TestReorderBuffer:
    def test_merges_foreign_runnable_prefix(self):
        """Synthetic interleaving a1 ≺ b1 ≺ a2 ≺ b2 ≺ a3 across two
        gatekeeper batches: executing gk0's batch must pull b1/b2 into
        the same bulk apply, stop at a3 (gk1's stream exhausts with no
        next-item bound), and requeue the leftover."""
        from repro.core.writepath import WriteBatch

        w = make_weaver(seed=0, n_gk=2, n_shards=1)
        sh = w.shards[0]
        a = [Stamp(0, (1, 0), 0, 1), Stamp(0, (2, 1), 0, 2),
             Stamp(0, (3, 2), 0, 3)]
        b = [Stamp(0, (1, 1), 1, 1), Stamp(0, (2, 2), 1, 2)]
        mk = lambda i: [{"op": "create_vertex", "vid": f"x{i}"}]
        sh.enqueue(0, 1, a[0], "txbatch",
                   WriteBatch([(a[0], mk(0)), (a[1], mk(1)),
                               (a[2], mk(2))]))
        # gk1 queue was empty -> nothing ran yet
        assert w.counters()["crossgk_batch_merges"] == 0
        sh.enqueue(1, 1, b[0], "txbatch",
                   WriteBatch([(b[0], mk(3)), (b[1], mk(4))]))
        c = w.counters()
        assert c["crossgk_batch_merges"] == 1
        assert c["crossgk_merged_txs"] == 2
        # a1, b1, a2, b2 applied; a3 requeued as the gk0 leftover
        assert set(sh.partition.vertices) == {"x0", "x1", "x3", "x4"}
        assert len(sh.queues[0]) == 1
        assert sh.queues[0][0].kind == "txbatch"
        assert [s for s, _ in sh.queues[0][0].payload.items] == [a[2]]
        assert not sh.queues[1]
        # the merged items acked to their ORIGIN gatekeepers' stamps
        for s in (a[0], a[1], b[0], b[1]):
            assert s.key() in sh._applied

    def test_concurrent_foreign_head_not_merged(self):
        """A foreign batch whose head is vector-concurrent with the
        executing batch's items must NOT be pulled in (ordering it
        would need the oracle — the buffer is refinement-free)."""
        from repro.core.writepath import WriteBatch

        w = make_weaver(seed=0, n_gk=2, n_shards=1)
        sh = w.shards[0]
        a = [Stamp(0, (1, 0), 0, 1), Stamp(0, (2, 0), 0, 2)]
        b = [Stamp(0, (0, 1), 1, 1)]          # concurrent with both
        mk = lambda i: [{"op": "create_vertex", "vid": f"y{i}"}]
        sh.enqueue(0, 1, a[0], "txbatch",
                   WriteBatch([(a[0], mk(0)), (a[1], mk(1))]))
        sh.enqueue(1, 1, b[0], "txbatch", WriteBatch([(b[0], mk(2))]))
        c = w.counters()
        assert c["crossgk_batch_merges"] == 0
        assert c["crossgk_merged_txs"] == 0

    def test_end_to_end_merge_and_state_equivalence(self):
        """Staggered interleaved cross-gk submissions with fast
        vector-clock announcements: merges fire in the real pipeline and
        the final state matches the per-tx (window=0) oracle.  One shard
        so timing is independent of the per-process vid hash seed."""
        modes = {}
        for window in (0.0, 0.3e-3):
            rng = np.random.default_rng(0)
            w = make_weaver(seed=2, n_shards=1, write_group_commit=window,
                            write_group_max=32, tau=0.05e-3,
                            tau_nop=0.05e-3)
            vids = [f"n{i}" for i in range(20)]
            tx = w.begin_tx()
            for v in vids:
                tx.create_vertex(v)
            assert w.run_tx(tx).ok
            w.settle(5e-3)
            res = []

            def submit(g):
                tx = w.begin_tx()
                u = vids[int(rng.integers(len(vids)))]
                v = vids[int(rng.integers(len(vids)))]
                tx.create_edge(u, v)
                w.submit_tx(tx, res.append, gatekeeper=g)

            t = 0.0
            for i in range(80):
                g = i % 2
                # gk1's arrivals lag half a window so its flushed batch
                # interleaves (vector-ordered) with gk0's next batch
                w.sim.schedule(t + (0.15e-3 if g else 0.0), submit, g)
                t += 0.15e-3
            w.settle(100e-3)
            assert len(res) == 80 and all(r.ok for r in res)
            modes[window] = (_fingerprint(w), w.counters())
        (f1, c1), (f2, c2) = modes[0.0], modes[0.3e-3]
        assert f1 == f2, "final committed state diverged"
        assert c2["crossgk_batch_merges"] >= 1
        assert c2["crossgk_merged_txs"] >= c2["crossgk_batch_merges"]
        assert c1["crossgk_batch_merges"] == 0   # no batches, no merges
