"""Unit + property tests for refinable timestamps and the timeline oracle."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.clock import (Order, Stamp, compare, merge, pack, pack_many,
                              visibility_mask_np, zero)
from repro.core.oracle import (KIND_PROG, KIND_TX, CycleError, TimelineOracle)


def S(epoch, clock, gk=0):
    return Stamp(epoch, tuple(clock), gk, clock[gk])


class TestVectorClock:
    def test_basic_orders(self):
        assert compare(S(0, [1, 1, 0]), S(0, [3, 4, 2])) is Order.BEFORE
        assert compare(S(0, [3, 4, 2]), S(0, [1, 1, 0])) is Order.AFTER
        # the paper's Fig. 5 concurrent pair
        assert compare(S(0, [3, 4, 2], 1), S(0, [3, 1, 5], 2)) is Order.CONCURRENT

    def test_epoch_dominates(self):
        assert compare(S(0, [100, 100]), S(1, [0, 1], 1)) is Order.BEFORE

    def test_equal(self):
        a = S(0, [2, 3], 0)
        assert compare(a, a) is Order.EQUAL

    def test_merge(self):
        assert merge((1, 5, 2), (3, 1, 2)) == (3, 5, 2)

    @given(st.lists(st.tuples(st.integers(0, 20), st.integers(0, 20),
                              st.integers(0, 20)), min_size=2, max_size=8))
    @settings(max_examples=100, deadline=None)
    def test_compare_antisymmetric_transitive(self, clocks):
        stamps = [S(0, list(c), 0) for c in clocks]
        for a in stamps:
            for b in stamps:
                oa, ob = compare(a, b), compare(b, a)
                if oa is Order.BEFORE:
                    assert ob is Order.AFTER
                if oa is Order.CONCURRENT:
                    assert ob is Order.CONCURRENT
        # transitivity of BEFORE
        for a in stamps:
            for b in stamps:
                for c in stamps:
                    if (compare(a, b) is Order.BEFORE
                            and compare(b, c) is Order.BEFORE):
                        assert compare(a, c) is Order.BEFORE

    @given(st.integers(1, 6), st.data())
    @settings(max_examples=50, deadline=None)
    def test_visibility_mask_matches_scalar(self, g, data):
        n = data.draw(st.integers(1, 10))
        creates, deletes, q = [], [], S(
            0, [data.draw(st.integers(0, 9)) for _ in range(g)], 0)
        for _ in range(n):
            creates.append(S(0, [data.draw(st.integers(0, 9))
                                 for _ in range(g)], 0))
            if data.draw(st.booleans()):
                deletes.append(S(0, [data.draw(st.integers(0, 9))
                                     for _ in range(g)], 0))
            else:
                deletes.append(None)
        mask = visibility_mask_np(pack_many(creates, g),
                                  pack_many(deletes, g), pack(q, g))
        for i in range(n):
            vis = compare(creates[i], q) is Order.BEFORE
            if deletes[i] is not None and compare(deletes[i], q) is Order.BEFORE:
                vis = False
            assert bool(mask[i]) == vis


class TestOracle:
    def test_assert_and_query(self):
        o = TimelineOracle()
        a = o.create_event(S(0, [1, 0], 0))
        b = o.create_event(S(0, [0, 1], 1))
        assert o.query_order(a, b) is None
        o.assert_order(a, b)
        assert o.query_order(a, b) is Order.BEFORE
        assert o.query_order(b, a) is Order.AFTER

    def test_cycle_refused(self):
        o = TimelineOracle()
        a = o.create_event(S(0, [1, 0], 0))
        b = o.create_event(S(0, [0, 1], 1))
        o.assert_order(a, b)
        with pytest.raises(CycleError):
            o.assert_order(b, a)

    def test_transitive_through_explicit_edges(self):
        # paper §4.2: S0: T3 ≺ T5, S1: T4 ≺ T3  =>  T4 ≺ T5
        o = TimelineOracle()
        t3 = o.create_event(S(0, [3, 0, 0], 0))
        t4 = o.create_event(S(0, [0, 3, 0], 1))
        t5 = o.create_event(S(0, [0, 0, 3], 2))
        o.assert_order(t3, t5)
        o.assert_order(t4, t3)
        assert o.query_order(t4, t5) is Order.BEFORE

    def test_vclock_implied_transitivity(self):
        # paper §4.2: oracle orders <0,1> ≺ <1,0>; then <0,1> ≺ <2,0>
        # follows because <1,0> ≺ <2,0> by vector clocks.
        o = TimelineOracle()
        a = o.create_event(S(0, [0, 1], 1))
        b = o.create_event(S(0, [1, 0], 0))
        c = o.create_event(S(0, [2, 0], 0))
        o.assert_order(a, b)
        assert o.query_order(a, c) is Order.BEFORE

    def test_order_events_respects_kinds(self):
        # unordered (tx, prog) pair -> tx first (wall-clock rule §4.2)
        o = TimelineOracle()
        prog = S(0, [1, 0], 0)
        tx = S(0, [0, 1], 1)
        chain = o.order_events([prog, tx], [KIND_PROG, KIND_TX])
        assert chain == [tx.key(), prog.key()]

    def test_order_events_total_and_consistent(self):
        o = TimelineOracle()
        stamps = [S(0, [3, 4, 2], 1), S(0, [3, 1, 5], 2), S(0, [4, 4, 1], 0)]
        chain = o.order_events(stamps, [KIND_TX] * 3)
        assert len(chain) == 3
        # re-query: same total order, now committed
        for i in range(3):
            for j in range(i + 1, 3):
                assert o.query_order(chain[i], chain[j]) is Order.BEFORE

    def test_decisions_monotonic(self):
        o = TimelineOracle()
        a, b = S(0, [1, 0], 0), S(0, [0, 1], 1)
        first = o.order_events([a, b], [KIND_TX, KIND_TX])
        for _ in range(5):
            assert o.order_events([a, b], [KIND_TX, KIND_TX]) == first

    def test_gc_drops_old_events(self):
        o = TimelineOracle()
        a = o.create_event(S(0, [1, 1], 0))
        b = o.create_event(S(0, [9, 9], 0))
        horizon = S(0, [5, 5], 0)
        dropped = o.collect(horizon)
        assert dropped == 1
        assert a not in o.events and b in o.events

    @given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5)),
                    min_size=2, max_size=7, unique=True))
    @settings(max_examples=60, deadline=None)
    def test_order_events_never_cycles(self, clocks):
        o = TimelineOracle()
        stamps = [S(0, list(c), i % 2) for i, c in enumerate(clocks)]
        chain = o.order_events(stamps, [KIND_TX] * len(stamps))
        # every adjacent pair committed; verify global consistency
        pos = {k: i for i, k in enumerate(chain)}
        for x in chain:
            for y in chain:
                if pos[x] < pos[y]:
                    assert o.query_order(y, x) is not Order.BEFORE
