#!/usr/bin/env python
"""Trace-export gate (wired into scripts/ci.sh).

Either validates an existing Chrome trace-event JSON file, or — with
no argument — runs a small traced serving workload, exports the
trace, and gates on it.  Checks, without external deps:

* the document passes ``repro.core.obs.validate_trace_events``
  (Chrome trace-event schema: ``traceEvents`` list, ``ph``/``ts``/
  ``dur``/``pid``/``tid`` fields, non-negative µs durations);
* it contains at least one complete (``ph: "X"``) event;
* when generating the trace itself, the critical-path analyzer's
  per-request stage sums match the measured end-to-end latency within
  ``--eps`` (default 1%), and the trace-driven invariant checkers
  (completeness, exactly-once apply, stamp monotonicity) all pass.

Exit non-zero with a findings list on any failure.

Usage:
    scripts/check_trace.py [trace.json] [--eps 0.01]
"""

from __future__ import annotations

import json
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(ROOT, "src"))


def check_doc(doc: dict, errs: list) -> None:
    from repro.core.obs import validate_trace_events
    errs.extend(validate_trace_events(doc))
    evs = doc.get("traceEvents", [])
    if not any(isinstance(e, dict) and e.get("ph") == "X" for e in evs):
        errs.append("no complete ('X') events in trace")


def run_and_check(eps: float, errs: list) -> str:
    """Traced smoke workload -> export -> attribution + invariants."""
    from repro.core import Weaver, WeaverConfig
    from repro.core.obs import (attribution_table, export_trace,
                                format_stage_table, run_invariant_checks)
    cfg = WeaverConfig(trace_sample_rate=1.0, write_group_commit=1e-3,
                       read_group_commit=1e-3, adaptive_admission=True,
                       seed=17)
    w = Weaver(cfg)
    for i in range(16):
        tx = w.begin_tx()
        tx.create_vertex(f"c{i}")
        if i:
            tx.create_edge(f"c{i - 1}", f"c{i}")
        r = w.run_tx(tx)
        if not r.ok:
            errs.append(f"smoke tx {i} failed: {r.error}")
    for i in range(8):
        res = w.run_program("count_edges", [(f"c{i}", None)])
        if res[0] is None:
            errs.append(f"smoke program {i} returned None")
    w.settle()

    tr = w.sim.tracer
    attr = attribution_table(tr)
    rows = [r for r in attr["requests"] if "e2e" in r]
    if not rows:
        errs.append("no complete traces to attribute")
    if attr["max_rel_err"] >= eps:
        errs.append(f"stage sums diverge from e2e: max_rel_err "
                    f"{attr['max_rel_err']:.2e} >= {eps}")
    for name, findings in run_invariant_checks(tr).items():
        for f in findings[:5]:
            errs.append(f"invariant {name}: {f}")

    out = os.path.join(ROOT, "trace_smoke.json")
    doc = export_trace(tr, out)
    check_doc(doc, errs)
    print(format_stage_table(attr))
    print(f"trace: {len(tr.traces())} traces, {len(tr.spans)} spans, "
          f"{len(doc['traceEvents'])} events -> {out}")
    return out


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    eps = 0.01
    if "--eps" in argv:
        i = argv.index("--eps")
        eps = float(argv[i + 1])
        argv = argv[:i] + argv[i + 2:]
    errs: list = []
    if argv:
        path = argv[0]
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError) as e:
            print(f"TRACE CHECK FAILED: cannot read {path}: {e}",
                  file=sys.stderr)
            return 1
        check_doc(doc, errs)
        n = len(doc.get("traceEvents", []))
        if not errs:
            print(f"trace check OK ({path}: {n} events)")
    else:
        run_and_check(eps, errs)
        if not errs:
            print("trace check OK (generated smoke trace)")
    if errs:
        print("TRACE CHECK FAILED:", file=sys.stderr)
        for e in errs:
            print(f"  - {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
