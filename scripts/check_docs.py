#!/usr/bin/env python
"""Docs consistency check (wired into scripts/ci.sh).

Verifies, without external deps:

* ``README.md``, ``docs/ARCHITECTURE.md``, ``docs/CONFIG.md`` exist;
* every intra-repo markdown link in them resolves to a real file;
* every ``repro.*`` dotted module reference resolves under ``src/``
  (attribute tails after a module file are not checked);
* every referenced ``WeaverConfig.<knob>`` / ``Counters.<field>`` is a
  real dataclass field, and ``docs/CONFIG.md`` documents EVERY field of
  both dataclasses;
* the README results table between ``<!-- BENCH:START -->`` /
  ``<!-- BENCH:END -->`` matches the checked-in ``BENCH_*.json``
  artifacts exactly (``--write`` regenerates it in place).

Exit non-zero with a findings list on any failure.
"""

from __future__ import annotations

import json
import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DOCS = ["README.md", "docs/ARCHITECTURE.md", "docs/CONFIG.md",
        "docs/OBSERVABILITY.md"]
START, END = "<!-- BENCH:START -->", "<!-- BENCH:END -->"

sys.path.insert(0, os.path.join(ROOT, "src"))


def _read(rel: str) -> str:
    with open(os.path.join(ROOT, rel)) as f:
        return f.read()


def _bench(rel: str) -> dict:
    return json.load(open(os.path.join(ROOT, rel)))


def render_bench_table() -> str:
    """The README results table, derived ONLY from the BENCH files."""
    sn = _bench("BENCH_snapshot.json")
    npg = _bench("BENCH_nodeprog.json")
    wp = _bench("BENCH_writepath.json")
    rc = _bench("BENCH_recovery.json")
    sv = _bench("BENCH_serving.json")
    rp = _bench("BENCH_replication.json")
    x = lambda v: f"{v:.1f}x"
    rows = [
        ("Snapshot engine", "cold columnar build vs seed per-object path",
         x(sn["speedup"]["cold_vs_python"])),
        ("Snapshot engine", "delta refresh vs cold (~0.25% churn)",
         x(sn["speedup"]["delta_vs_cold"])),
        ("Snapshot engine", "no-op refresh vs cold",
         x(sn["speedup"]["noop_vs_cold"])),
        ("Node programs", "multi-hop traverse, frontier vs scalar",
         x(npg["speedup"]["traverse_multi_hop"])),
        ("Node programs", "reachability, frontier vs scalar",
         x(npg["speedup"]["reachable"])),
        ("Node programs", "weighted sssp, frontier vs scalar",
         x(npg["speedup"]["sssp"])),
        ("Node programs",
         f"get_edges stream ({npg['ragged']['get_edges_stream']['n_roots']}"
         " roots, ragged replies, warm plans)",
         x(npg["speedup"]["get_edges_stream"])),
        ("Node programs",
         f"clustering batch ({npg['ragged']['clustering_batch']['n_roots']}"
         " roots, 3-phase wedge closing, warm plans)",
         x(npg["speedup"]["clustering_batch"])),
        ("Node programs", "plan maintenance under write churn vs forced "
         "cold rebuilds (traverse)",
         x(npg["write_churn"]["traverse_multi_hop"]["plan_speedup"])),
        ("Write path",
         f"group commit vs per-tx throughput (mean batch "
         f"{wp['mean_batch']:.1f}, message reduction "
         f"{wp['message_reduction']:.2f}x)",
         x(wp["speedup"])),
        ("Recovery",
         f"store-walk vs WAL-replay shard MTTR "
         f"({rc['mttr'][-1]['n_users']} users, "
         f"{rc['mttr'][-1]['replayed_ops']} replayed ops; shard failover "
         f"{rc['goodput']['recovery_ms']:.0f} ms, 0 lost acks)",
         x(rc["mttr"][-1]["walk_over_wal"])),
        ("Serving",
         f"windowed vs per-program read admission at saturation "
         f"(mean window "
         f"{sv['saturation']['windowed']['mean_batch']:.0f}, low-load p99 "
         f"ratio {sv['sweep']['low_load_p99_ratio']:.2f}, goodput past "
         f"saturation {sv['sweep']['goodput_flat']:.2f} of peak)",
         x(sv["saturation"]["speedup"])),
        ("Replication",
         f"read throughput with {rp['read_scaling']['rows'][-1]['n_replicas']}"
         f" change-feed replicas/shard vs none (bit-identical results; "
         f"in-pod reads "
         f"{rp['pod_latency']['in_pod_speedup_p50']:.1f}x faster than "
         f"cross-pod; primary kill -> promotion in "
         f"{rp['promotion']['recovery_ms']:.0f} ms)",
         x(rp["read_scaling"]["rows"][-1]["throughput_per_s"]
           / rp["read_scaling"]["rows"][0]["throughput_per_s"])),
    ]
    eq = all([sn["equivalent"], npg["equivalent"], wp["equivalent"],
              rc["equivalent"], sv["equivalence"]["equivalent"],
              rp["equivalent"]])
    out = ["| Benchmark | Headline metric | Speedup |", "|---|---|---|"]
    out += [f"| {a} | {b} | **{c}** |" for a, b, c in rows]
    out.append("")
    out.append(f"Equivalence bits: snapshot={int(sn['equivalent'])} "
               f"nodeprog={int(npg['equivalent'])} "
               f"writepath={int(wp['equivalent'])} "
               f"recovery={int(rc['equivalent'])} "
               f"serving={int(sv['equivalence']['equivalent'])} "
               f"replication={int(rp['equivalent'])} "
               f"({'all identical to the scalar oracle' if eq else 'DIVERGED'}).")
    return "\n".join(out)


def check_links(rel: str, text: str, errs: list) -> None:
    base = os.path.dirname(os.path.join(ROOT, rel))
    for m in re.finditer(r"\[[^\]]*\]\(([^)\s]+)\)", text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        path = os.path.normpath(os.path.join(base, target.split("#")[0]))
        if not os.path.exists(path):
            errs.append(f"{rel}: broken link -> {target}")


def check_modules(rel: str, text: str, errs: list) -> None:
    for m in re.finditer(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+", text):
        parts = m.group(0).split(".")
        path = os.path.join(ROOT, "src")
        for i, part in enumerate(parts):
            if os.path.isdir(os.path.join(path, part)):
                path = os.path.join(path, part)
            elif os.path.isfile(os.path.join(path, part + ".py")):
                break                      # rest are attributes
            else:
                errs.append(f"{rel}: unresolved module {m.group(0)}")
                break


def check_fields(rel: str, text: str, errs: list) -> None:
    import dataclasses
    from repro.core.simulation import Counters
    from repro.core.weaver import WeaverConfig
    fields = {
        "WeaverConfig": {f.name for f in dataclasses.fields(WeaverConfig)},
        "Counters": {f.name for f in dataclasses.fields(Counters)},
    }
    for cls, names in fields.items():
        for m in re.finditer(rf"\b{cls}\.([a-z_][a-z0-9_]*)", text):
            if m.group(1) not in names:
                errs.append(f"{rel}: unknown {cls} field {m.group(1)}")
    if rel.endswith("CONFIG.md"):
        for cls, names in fields.items():
            missing = [n for n in sorted(names)
                       if not re.search(rf"`{n}`", text)]
            if missing:
                errs.append(f"{rel}: {cls} fields undocumented: "
                            + ", ".join(missing))


def check_bench_table(text: str, errs: list, write: bool) -> None:
    if START not in text or END not in text:
        errs.append("README.md: missing BENCH table markers")
        return
    want = render_bench_table()
    head, rest = text.split(START, 1)
    inside, tail = rest.split(END, 1)
    if write:
        nu = head + START + "\n" + want + "\n" + END + tail
        with open(os.path.join(ROOT, "README.md"), "w") as f:
            f.write(nu)
        print("README.md bench table regenerated")
        return
    if inside.strip() != want.strip():
        errs.append("README.md: bench table out of date with BENCH_*.json "
                    "(run: python scripts/check_docs.py --write)")


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    write = "--write" in argv
    errs: list = []
    for rel in DOCS:
        if not os.path.isfile(os.path.join(ROOT, rel)):
            errs.append(f"missing doc: {rel}")
    if errs:
        print("\n".join(errs), file=sys.stderr)
        return 1
    if write:
        check_bench_table(_read("README.md"), errs, write=True)
    for rel in DOCS:
        text = _read(rel)
        check_links(rel, text, errs)
        check_modules(rel, text, errs)
        check_fields(rel, text, errs)
    check_bench_table(_read("README.md"), errs, write=False)
    if errs:
        print("DOCS CHECK FAILED:", file=sys.stderr)
        for e in errs:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print(f"docs check OK ({len(DOCS)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
