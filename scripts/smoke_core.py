"""Quick manual smoke of the Weaver core (not a pytest test)."""
import sys
sys.path.insert(0, "src")

from repro.core import Weaver, WeaverConfig

w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=3, seed=1))

# build a small graph transactionally
tx = w.begin_tx()
a = tx.create_vertex("a")
b = tx.create_vertex("b")
c = tx.create_vertex("c")
e1 = tx.create_edge("a", "b")
tx.set_edge_prop(e1, "color", "red")
e2 = tx.create_edge("b", "c")
r = w.run_tx(tx)
print("tx1:", r)
assert r.ok, r.error

tx2 = w.begin_tx()
tx2.create_edge("a", "c")
r2 = w.run_tx(tx2)
print("tx2:", r2)
assert r2.ok

# read
print("read a:", w.read_vertex("a"))

# node programs
res, stamp, lat = w.run_program("traverse", [("a", {"depth": 0})])
print("traverse from a:", res, "latency", lat)
assert res == ["a", "b", "c"], res

res, _, _ = w.run_program("reachable", [("c", {"target": "a"})])
print("reachable c->a:", res)
assert res is False

res, _, _ = w.run_program("count_edges", [("a", None)])
print("count_edges(a):", res)
assert res == 2

# delete both edges into c and re-check reachability
tx3 = w.begin_tx()
tx3.delete_edge("b", e2.eid)
r3 = w.run_tx(tx3)
assert r3.ok
res, _, _ = w.run_program("traverse", [("a", {"depth": 0})])
print("traverse after delete b->c:", res)
assert res == ["a", "b", "c"], res   # still reachable via a->c (tx2)
a_edges = w.read_vertex("a")["edges"]
eid_ac = [eid for eid, dst in a_edges.items() if dst == "c"][0]
tx4 = w.begin_tx()
tx4.delete_edge("a", eid_ac)
assert w.run_tx(tx4).ok
res, _, _ = w.run_program("traverse", [("a", {"depth": 0})])
print("traverse after delete a->c:", res)
assert res == ["a", "b"], res

print("counters:", {k: v for k, v in w.counters().items() if v})
print("OK")
