#!/usr/bin/env bash
# CI entry point: tier-1 test suite, then the benchmark harness in smoke
# mode (snapshot + nodeprog + writepath + coordination + recovery +
# serving — coordination covers the tau sweep's aggressive-concurrency
# corner, the historical oracle CycleError; nodeprog's smoke includes
# the ragged get_edges/clustering section; serving asserts the windowed
# read-admission equivalence bit, exercises the shed/retry sweep at
# smoke sizes, and exports a causal trace from its obs section), then
# the trace gate (Chrome trace-event schema on the exported smoke
# trace, plus a generated traced run asserting critical-path stage
# sums tile each request's e2e latency within 1% and the trace-driven
# protocol invariants hold), then the replication hardening stages
# (the replica test battery under three distinct PYTHONHASHSEED values
# — bit-identity must not hinge on dict iteration order — and a forced
# two-pod replication smoke), then the docs consistency check
# (README/docs exist, links + WeaverConfig/Counters/module references
# resolve, README results table matches the checked-in BENCH files).
# Exits non-zero on ANY failure (pytest failure, benchmark exception,
# equivalence-bit regression, or docs drift — benchmarks/run.py already
# exits 1 if any module raises).
#
# Usage: scripts/ci.sh            # from anywhere; cd's to the repo root
# Deps:  requirements-dev.txt (pinned); jax/numpy come with the image.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 pytest ==="
python -m pytest -x -q

echo "=== multi-device (8 forced host devices) ==="
# re-runs the tests that self-skip under a single device: collective
# costing inside scans and the device-sharded column-plane equivalence
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -x -q tests/test_hlo_cost.py tests/test_device_shard.py

echo "=== benchmarks (smoke) ==="
python -m benchmarks.run --smoke

echo "=== trace check ==="
# schema-validate the trace the serving smoke run exported, then run
# the generated-trace gate (attribution tiling + invariant checkers)
python scripts/check_trace.py trace_serving_smoke.json
python scripts/check_trace.py
rm -f trace_serving_smoke.json trace_smoke.json

echo "=== replication tests x3 hash seeds ==="
# replica reads must be bit-identical to the primary regardless of
# Python's per-process hash randomization (dict/set iteration order)
for hs in 0 1 2; do
    echo "--- PYTHONHASHSEED=$hs ---"
    PYTHONHASHSEED=$hs python -m pytest -q tests/test_replica.py
done

echo "=== forced multi-pod smoke ==="
# two-pod deployment: in-pod replica routing must beat cross-pod reads
REPRO_FORCE_PODS=1 REPRO_BENCH_SMOKE=1 python -m benchmarks.replication

echo "=== docs check ==="
python scripts/check_docs.py

echo "=== CI OK ==="
