#!/usr/bin/env bash
# CI entry point: tier-1 test suite, then the benchmark harness in smoke
# mode (snapshot + nodeprog + writepath + coordination — the last one
# covers the tau sweep's aggressive-concurrency corner, the historical
# oracle CycleError).  Exits non-zero on ANY failure (pytest failure,
# benchmark exception, or equivalence-bit regression — benchmarks/run.py
# already exits 1 if any module raises).
#
# Usage: scripts/ci.sh            # from anywhere; cd's to the repo root
# Deps:  requirements-dev.txt (pinned); jax/numpy come with the image.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 pytest ==="
python -m pytest -x -q

echo "=== benchmarks (smoke) ==="
python -m benchmarks.run --smoke

echo "=== CI OK ==="
