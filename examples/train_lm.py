"""End-to-end LM training driver: a small dense LM for a few hundred
steps on CPU with checkpoint/restart through the refinable-timestamp
multi-version checkpoint store.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import sys
sys.path.insert(0, "src")

import argparse
import shutil

import jax
import numpy as np

from repro.models import transformer
from repro.models.transformer import LMConfig
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig
from repro.data import synth

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

cfg = LMConfig(name="tiny-lm", n_layers=2, d_model=64, n_heads=4, n_kv=2,
               d_head=16, d_ff=192, vocab=256, dtype="float32",
               loss_chunks=0)
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
n_params = sum(int(np.prod(p.shape))
               for p in jax.tree_util.tree_leaves(params))
print(f"model: {n_params/1e6:.2f}M params")

rng = np.random.default_rng(0)
gen = synth.token_batches(rng, cfg.vocab, batch=8, seq=32)

ckpt_dir = "/tmp/repro_example_lm_ckpt"
shutil.rmtree(ckpt_dir, ignore_errors=True)
trainer = Trainer(lambda p, b: transformer.lm_loss(p, b, cfg), params,
                  AdamWConfig(lr=3e-3, warmup_steps=10,
                              total_steps=args.steps),
                  TrainerConfig(total_steps=args.steps, ckpt_every=50,
                                ckpt_dir=ckpt_dir, log_every=25))
hist = trainer.fit(gen, until=args.steps // 2)
print(f"-- simulating failure at step {trainer.step}; resuming from the "
      f"stamped checkpoint --")
trainer.on_failure()
hist = trainer.fit(gen)
first, last = trainer.history[0]["loss"], trainer.history[-1]["loss"]
print(f"loss {first:.3f} -> {last:.3f} over {trainer.step} steps "
      f"(epoch after failure: {trainer.store.epoch})")
assert last < first, "loss should decrease"
