"""CoinGraph (paper §5.1): a Bitcoin blockchain explorer on Weaver.

Ingests a synthetic chain transactionally (blocks arrive as atomic
transactions — forks/reorgs would replace a block's graph atomically),
then serves block-render queries as node programs.

    PYTHONPATH=src python examples/coingraph.py
"""
import os
import sys
sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.block_query import build_chain_in_weaver
from repro.configs import PAPER_DEPLOYMENT
from repro.core import Weaver
from repro.data import synth

rng = np.random.default_rng(7)
chain = synth.blockchain(rng, n_blocks=16)
w = Weaver(PAPER_DEPLOYMENT)
build_chain_in_weaver(w, chain)
print(f"ingested {len(chain)} blocks, "
      f"{sum(len(b['txs']) for b in chain)} transactions")

for h in (1, 8, 15):
    block = chain[h]
    res, stamp, lat = w.run_program("block_render",
                                    [(block["id"], {"hop": 0})])
    total = sum(r["value"] for r in res)
    print(f"block {h:3d}: {len(res):3d} txs, total value {total:8.2f} BTC, "
          f"{lat*1e3:6.2f} ms ({lat/max(len(res),1)*1e3:.3f} ms/tx)")

# a reorg: atomically replace the tip block's transaction set
tip = chain[-1]
tx = w.begin_tx()
for t in tip["txs"]:
    edges = w.read_vertex(tip["id"])["edges"]
eids = list(w.read_vertex(tip["id"])["edges"])
for eid in eids:
    tx.delete_edge(tip["id"], eid)
replacement = tx.create_vertex("tx_reorg_0")
tx.create_edge(tip["id"], replacement)
print("reorg commit:", w.run_tx(tx).ok)
res, _, _ = w.run_program("get_edges", [(tip["id"], None)])
print(f"tip now has {len(res)} edge(s) — swapped atomically")
