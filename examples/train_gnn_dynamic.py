"""Train a GIN on a LIVE Weaver graph: writers mutate the graph while the
trainer pulls snapshot-consistent minibatches at refinable timestamps —
the paper's long-read/concurrent-write isolation as a training feature.

    PYTHONPATH=src python examples/train_gnn_dynamic.py
"""
import sys
sys.path.insert(0, "src")

import dataclasses

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import Weaver, WeaverConfig
from repro.data.pipeline import DynamicGraphPipeline
from repro.models import gnn, mp
from repro.optim import AdamWConfig, adamw, make_train_step

# pipeline batches are CSC-sorted (dst-major): claim sorted segment ids
# in every scatter of the jitted model
mp.set_sorted_indices(True)

# boot a store and seed a graph
w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=3, seed=3))
tx = w.begin_tx()
for i in range(24):
    tx.create_vertex(f"v{i}")
rng = np.random.default_rng(0)
for _ in range(60):
    a, b = rng.integers(0, 24, 2)
    if a != b:
        tx.create_edge(f"v{a}", f"v{b}")
assert w.run_tx(tx).ok

cfg = dataclasses.replace(get_arch("gin-tu").config, n_layers=2,
                          d_hidden=16, d_feat=8, n_classes=3)
pipe = DynamicGraphPipeline(w, d_feat=8, n_classes=3, pad_nodes=64,
                            pad_edges=256)

counter = {"i": 24}
def writer(wv):
    """Concurrent mutator: adds a vertex + edge between batches."""
    tx = wv.begin_tx()
    vid = tx.create_vertex(f"v{counter['i']}")
    tx.create_edge(vid, f"v{counter['i'] % 24}")
    counter["i"] += 1
    assert wv.run_tx(tx).ok

params = gnn.init_params(jax.random.PRNGKey(0), cfg)
step = jax.jit(make_train_step(lambda p, b: gnn.gnn_loss(p, b, cfg),
                               AdamWConfig(lr=1e-2, warmup_steps=2,
                                           total_steps=30)))
opt = adamw.init(params)
batches = pipe.batches(mutate_between=writer)
for i in range(30):
    b = next(batches)
    ng = b.pop("n_graphs")
    b = {k: np.asarray(v) for k, v in b.items()}
    b["n_graphs"] = ng
    params, opt, m = step(params, opt, b)
    if (i + 1) % 10 == 0:
        live = int(b["label_mask"].sum())
        print(f"step {i+1:3d}  loss {float(m['loss']):.4f}  "
              f"(snapshot had {live} live nodes)")
print("done — every batch was a consistent snapshot while 30 writers "
      "committed concurrently")
