"""End-to-end serving driver (the paper's kind of system): boot a Weaver
deployment, bulk-load a social graph, serve the TAO read/write mix with
batched concurrent requests — and keep serving through a shard failure.

    PYTHONPATH=src python examples/social_serve.py
"""
import os
import sys
sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import ClosedLoopDriver, load_weaver_graph, stats
from repro.configs import PAPER_DEPLOYMENT
from repro.core import Weaver
from repro.data import synth

rng = np.random.default_rng(0)
w = Weaver(PAPER_DEPLOYMENT)
edges = synth.social_graph(rng, n_users=300, avg_degree=6)
vertices = load_weaver_graph(w, edges)
print(f"loaded {len(vertices)} users, {len(edges)} follows")

ops = synth.tao_workload(rng, 3000, read_frac=0.998, vertices=vertices)
kill_at = 1500
resubmits = {"n": 0}


def issue(cid, idx, done):
    if idx == kill_at:                      # mid-serve shard failure
        w.kill("shard2")
        print(f"!! killed shard2 at request {idx} "
              f"(epoch bumps; backup recovers from the backing store; "
              f"in-flight programs are RESUBMITTED by the client, §4.3)")
    op = ops[idx % len(ops)]
    t0 = w.sim.now
    state = {"done": False}

    def _done(*_):
        if not state["done"]:
            state["done"] = True
            done(w.sim.now - t0)

    def attempt():
        if state["done"]:
            return
        if op["type"] in ("get_edges", "count_edges", "get_node"):
            w.submit_program(op["type"], [(op["v"], None)],
                            lambda r, s, l: _done())
        else:
            tx = w.begin_tx()
            if op["type"] == "create_edge":
                tx.create_edge(op["v"], op["u"])
            else:
                v = w.read_vertex(op["v"])
                if v and v["edges"]:
                    tx.delete_edge(op["v"], next(iter(v["edges"])))
                else:
                    tx.set_vertex_prop(op["v"], "touch", idx)
            w.submit_tx(tx, lambda r: _done())
        # client-side timeout + resubmission with a fresh timestamp
        def retry():
            if not state["done"]:
                resubmits["n"] += 1
                attempt()
        w.sim.schedule(0.08, retry)

    attempt()

drv = ClosedLoopDriver(w.sim, n_clients=48, n_requests=3000, issue=issue)
res = drv.run(timeout=120.0)
print(f"served {res['completed']} requests at "
      f"{res['throughput_per_s']:,.0f} req/s (simulated)")
print(f"latency p50={res['p50_ms']:.2f}ms p99={res['p99_ms']:.2f}ms")
print(f"epoch after failure: {w.manager.epoch} "
      f"(failures handled: {w.manager.failures_handled}, "
      f"client resubmissions: {resubmits['n']})")
c = w.counters()
print(f"oracle calls {c['oracle_calls']}, announces "
      f"{c['announce_messages']}, committed {c['tx_committed']}")
