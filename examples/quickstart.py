"""Quickstart: a Weaver graph store in 40 lines — transactions, node
programs, snapshot isolation.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import Weaver, WeaverConfig

w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=3, seed=42))

# 1. strictly serializable transactions (paper Fig. 2 style)
tx = w.begin_tx()
user = tx.create_vertex("user")
photo = tx.create_vertex("photo")
own = tx.create_edge(user, photo)
tx.set_edge_prop(own, "rel", "OWNS")
for friend in ("ana", "bob"):
    tx.create_vertex(friend)
    e = tx.create_edge(photo, friend)
    tx.set_edge_prop(e, "rel", "VISIBLE")
result = w.run_tx(tx)
print(f"commit ok={result.ok} stamp={result.stamp}")

# 2. node programs: traversal on a consistent snapshot
reachable, stamp, latency = w.run_program("traverse",
                                          [("user", {"depth": 0})])
print(f"reachable from user: {reachable}  ({latency*1e3:.2f} ms simulated)")

# 3. snapshot isolation: a concurrent delete does not tear the read
tx2 = w.begin_tx()
tx2.delete_edge(own)
boxes = []
w.submit_tx(tx2, boxes.append)
w.submit_program("traverse", [("user", {"depth": 0})],
                 lambda r, s, l: boxes.append(r))
w.sim.run(until=w.sim.now + 0.1)
print(f"after concurrent delete: tx ok={boxes[0].ok}, "
      f"traversal saw {boxes[1]} (all-or-nothing, never a torn path)")
print("counters:", {k: v for k, v in w.counters().items() if v})
