"""Group-commit write engine vs the per-tx commit path (ISSUE 4).

A write-heavy closed-loop workload (create_edge / set_vertex_prop mix,
pre-generated so both modes execute the IDENTICAL op stream) runs twice:

* ``per_tx``  — ``write_group_commit = 0``: one gatekeeper serve round,
  one store round trip, one shard queue item per transaction (the
  semantic oracle);
* ``grouped`` — admission windows batch stamping, ONE vectorized
  ``LastUpdateTable`` validation per window, ONE store round trip
  (group durability point) and ONE packed ``WriteBatch`` per
  destination shard per window.

Reported: simulated write throughput for both modes, the speedup, the
group-commit counters (windows, mean batch size, conflict rows checked)
and an ``equivalent`` bit: both modes must converge to the same graph —
live-edge multiset and property-version multisets per vertex, plus
identical ``traverse`` / ``count_edges`` node-program results at final
quiescence (stamps differ between modes by construction, so the
comparison is over committed state, not raw stamps).

Full mode writes ``BENCH_writepath.json`` at the repo root; smoke mode
(``REPRO_BENCH_SMOKE``) shrinks sizes and never touches repo-root BENCH
files.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.configs import PAPER_DEPLOYMENT
from repro.core import Weaver
from repro.data import synth

from .common import ClosedLoopDriver, load_weaver_graph, save_result

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
N_USERS = 300 if SMOKE else 1500
N_REQUESTS = 600 if SMOKE else 8000
N_CLIENTS = 64 if SMOKE else 256
GROUP_WINDOW = 0.2e-3
GROUP_MAX = 32 if SMOKE else 48


def _gen_ops(rng: np.random.Generator, vertices: List[str],
             n: int) -> List[Tuple]:
    """Pre-generated op specs so both modes run the identical stream."""
    out = []
    for i in range(n):
        v = vertices[int(rng.integers(len(vertices)))]
        if rng.random() < 0.8:
            u = vertices[int(rng.integers(len(vertices)))]
            out.append(("edge", v, u))
        else:
            out.append(("prop", v, float(np.round(rng.random(), 6))))
    return out


def _fingerprint(w: Weaver) -> Dict:
    """Mode-invariant committed state: live-edge multiset and property
    version multisets per vertex (eids and stamps legitimately differ
    between modes — retries re-stamp — so neither participates)."""
    edges: Dict[str, List[str]] = {}
    props: Dict[str, List[Tuple[str, object]]] = {}
    for vid, v in sorted(w.store.vertices.items()):
        if v.delete_ts is not None:
            continue
        edges[vid] = sorted(dst for dst, _, dts in v.edges.values()
                            if dts is None)
        pv = []
        for key, versions in sorted(v.props.items()):
            pv.extend((key, val) for val, _ in versions)
        props[vid] = sorted(pv)
    return {"edges": edges, "props": props}


def run_mode(window: float, ops: List[Tuple], seed: int) -> Tuple[Dict, Dict]:
    cfg = dataclasses.replace(
        PAPER_DEPLOYMENT, n_gatekeepers=2, n_shards=4, seed=seed,
        write_group_commit=window, write_group_max=GROUP_MAX)
    w = Weaver(cfg)
    rng = np.random.default_rng(seed)
    edges = synth.social_graph(rng, N_USERS, avg_degree=3)
    vertices = load_weaver_graph(w, edges)
    base = w.counters()
    t_wall = time.time()

    def issue(cid, idx, done):
        kind, v, x = ops[idx]
        tx = w.begin_tx()
        if kind == "edge":
            tx.create_edge(v, x)
        else:
            tx.set_vertex_prop(v, "score", x)
        w.submit_tx(tx, lambda r: done(r.latency),
                    gatekeeper=cid % cfg.n_gatekeepers)

    drv = ClosedLoopDriver(w.sim, N_CLIENTS, len(ops), issue)
    res = drv.run(timeout=600.0)
    w.settle(20e-3)
    res["wall_s"] = time.time() - t_wall
    c = w.counters()
    res["counters"] = {k: c[k] - base[k] for k in (
        "tx_committed", "tx_retried", "tx_aborted", "tx_batches",
        "tx_batch_size_sum", "conflict_rows_checked", "oracle_calls",
        "messages_sent")}
    # read-side equivalence probes at final quiescence
    root = vertices[0]
    trav, _, _ = w.run_program("traverse", [(root, {"depth": 2})])
    cnt, _, _ = w.run_program("count_edges", [(root, None)])
    reads = {"traverse": sorted(trav), "count_edges": cnt}
    return {**res, "reads": reads}, _fingerprint(w)


def run(seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed + 1)
    # same graph both modes (run_mode re-derives it from the same seed)
    edges0 = synth.social_graph(np.random.default_rng(seed), N_USERS,
                                avg_degree=3)
    vertices = sorted({v for e in edges0 for v in e})
    ops = _gen_ops(rng, vertices, N_REQUESTS)
    per_tx, fp_tx = run_mode(0.0, ops, seed)
    grouped, fp_gc = run_mode(GROUP_WINDOW, ops, seed)
    speedup = grouped["throughput_per_s"] / max(per_tx["throughput_per_s"],
                                                1e-9)
    equivalent = (fp_tx == fp_gc
                  and per_tx["reads"] == grouped["reads"]
                  and per_tx["completed"] == grouped["completed"])
    gcc = grouped["counters"]
    out = {
        "n_users": N_USERS, "n_requests": N_REQUESTS,
        "n_clients": N_CLIENTS,
        "group_window_ms": GROUP_WINDOW * 1e3, "group_max": GROUP_MAX,
        "per_tx": per_tx, "grouped": grouped,
        "speedup": speedup,
        "mean_batch": (gcc["tx_batch_size_sum"] / gcc["tx_batches"]
                       if gcc["tx_batches"] else 0.0),
        "conflict_rows_checked": gcc["conflict_rows_checked"],
        "message_reduction": (per_tx["counters"]["messages_sent"]
                              / max(gcc["messages_sent"], 1)),
        "equivalent": bool(equivalent),
        "paper_claim": "group commit amortizes admission, validation, "
                       "durability and shard apply across a window; "
                       "semantics unchanged (batched == per-tx)",
    }
    return out


def main() -> None:
    out = run()
    print(f"writepath,per_tx_throughput,{out['per_tx']['throughput_per_s']:.0f}")
    print(f"writepath,grouped_throughput,{out['grouped']['throughput_per_s']:.0f}")
    print(f"writepath,speedup,{out['speedup']:.2f}")
    print(f"writepath,mean_batch,{out['mean_batch']:.1f}")
    print(f"writepath,message_reduction,{out['message_reduction']:.2f}")
    print(f"writepath,equivalent,{int(out['equivalent'])}")
    assert out["equivalent"], "group-commit state diverged from per-tx"
    if SMOKE:
        save_result("writepath_smoke", out)
        return
    assert out["speedup"] >= 3.0, \
        f"group-commit speedup {out['speedup']:.2f}x below the 3x bar"
    with open(os.path.join(REPO_ROOT, "BENCH_writepath.json"), "w") as f:
        json.dump(out, f, indent=1)
    save_result("writepath", out)


if __name__ == "__main__":
    main()
