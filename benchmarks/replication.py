"""Change-feed read replicas and multi-pod deployment (ISSUE 10).

Three measurements:

* **Read-throughput scaling** — a closed-loop read-heavy workload over
  growing replica counts.  The identical request sequence runs against
  every replica count; the per-request result lists must be IDENTICAL
  across configurations (the ``equivalent`` bit) — replicas add read
  capacity for settled-stamp windows, they never change an answer.

* **In-pod vs cross-pod read latency** — the same read workload on a
  two-pod deployment, once with a replica co-located with the
  gatekeepers (reads dodge the cross-pod hop) and once with every data
  server in the far pod (every read pays the pod surcharge both ways).

* **Goodput through primary kill + promotion** — a closed-loop mixed
  workload with a primary shard killed mid-run: the most caught-up
  replica is promoted (partition adopted, WAL top-up only for its lag),
  clients retry through the epoch barrier, and the goodput dip +
  time-to-new-epoch are reported.

Full mode writes ``BENCH_replication.json`` at the repo root; smoke
mode (``REPRO_BENCH_SMOKE``) shrinks sizes and never touches repo-root
BENCH files.  ``REPRO_FORCE_PODS`` forces the pod-latency measurement
even in smoke (the ci.sh multi-pod stage).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

import numpy as np

from repro.configs import PAPER_DEPLOYMENT
from repro.core import Weaver
from repro.data import synth

from .common import ClosedLoopDriver, load_weaver_graph, save_result

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
FORCE_PODS = bool(os.environ.get("REPRO_FORCE_PODS"))
REPLICA_COUNTS = [0, 1, 2] if SMOKE else [0, 1, 2, 4]
N_USERS = 200 if SMOKE else 600
N_READS = 300 if SMOKE else 1200
N_CLIENTS = 96
MULTIGET = 32   # entries per read request (TAO-style multiget): per-
#                 window shard service scales with entries, so read
#                 capacity — not admission cadence — is the bottleneck
#                 replicas relieve.  The pod-latency measurement uses
#                 single-entry reads instead (network-dominated).
N_MIX = 300 if SMOKE else 1500
BUCKET_S = 5e-3


def _cfg(**kw):
    kw.setdefault("n_gatekeepers", 2)
    kw.setdefault("n_shards", 4)
    kw.setdefault("read_group_commit", 0.5e-3)
    kw.setdefault("read_window_alias", True)
    return dataclasses.replace(PAPER_DEPLOYMENT, **kw)


def _loaded(cfg, seed: int):
    w = Weaver(cfg)
    rng = np.random.default_rng(seed)
    edges = synth.social_graph(rng, N_USERS, avg_degree=4)
    vertices = load_weaver_graph(w, edges)
    w.settle(50e-3)            # replicas cold-sync the loaded graph
    return w, vertices


def _read_run(cfg, seed: int, n_reads: int, k: int = MULTIGET,
              n_clients: int = N_CLIENTS) -> Dict:
    """One closed-loop read workload (``k``-entry multigets); returns
    throughput/latency plus the ordered per-request results (the
    cross-config equivalence evidence)."""
    w, vertices = _loaded(cfg, seed)
    rng = np.random.default_rng(seed + 1)
    picks = [[vertices[int(rng.integers(len(vertices)))]
              for _ in range(k)] for _ in range(n_reads)]
    results: List[object] = [None] * n_reads

    def issue(cid, idx, done):
        def cb(r, s, l, idx=idx):
            results[idx] = r
            done(l)
        w.submit_program("count_edges", [(v, None) for v in picks[idx]],
                         cb, gatekeeper=cid % cfg.n_gatekeepers)

    drv = ClosedLoopDriver(w.sim, n_clients, n_reads, issue)
    res = drv.run(timeout=600.0)
    w.settle(20e-3)
    c = w.sim.counters
    return {
        "completed": res["completed"],
        "throughput_per_s": res["throughput_per_s"],
        "p50_ms": res["p50_ms"],
        "p99_ms": res["p99_ms"],
        "replica_reads_served": c.replica_reads_served,
        "stamps_settled": c.stamps_settled,
        "cold_resyncs": c.replica_cold_resyncs,
        "cross_pod_msgs": c.cross_pod_msgs,
        "results": results,
    }


def read_scaling(seed: int = 0) -> Dict:
    rows = []
    result_sets = []
    for n_rep in REPLICA_COUNTS:
        r = _read_run(_cfg(n_replicas=n_rep, seed=seed), seed, N_READS)
        result_sets.append(r.pop("results"))
        rows.append({"n_replicas": n_rep, **r})
    equivalent = all(rs == result_sets[0] for rs in result_sets[1:])
    return {"rows": rows, "equivalent": bool(equivalent)}


def pod_latency(seed: int = 3) -> Dict:
    """Two-pod read latency: replicas in the gatekeeper pod vs every
    data server one cross-pod hop away."""
    n_sh = 4
    near = {"gk0": 0, "gk1": 0}
    far = {"gk0": 0, "gk1": 0}
    for s in range(n_sh):
        near[f"shard{s}"] = 1
        near[f"shard{s}r0"] = 0       # co-located replica serves in-pod
        far[f"shard{s}"] = 1
        far[f"shard{s}r0"] = 1        # everything across the pod gap
    out = {}
    for name, pm in (("in_pod", near), ("cross_pod", far)):
        cfg = _cfg(n_replicas=1, pods=2, pod_map=pm, seed=seed)
        # single-entry reads, few clients: latency-dominated (not
        # queue-dominated), so the pod surcharge is what's measured
        r = _read_run(cfg, seed, N_READS, k=1, n_clients=16)
        r.pop("results")
        out[name] = r
    out["in_pod_speedup_p50"] = (out["cross_pod"]["p50_ms"]
                                 / max(out["in_pod"]["p50_ms"], 1e-9))
    return out


def promotion_goodput(seed: int = 5) -> Dict:
    """Closed-loop mixed reads+writes with a primary killed mid-run;
    the most caught-up replica is promoted."""
    cfg = _cfg(n_replicas=2, seed=seed, read_your_writes=True,
               read_retry_timeout=8e-3, write_group_commit=0.5e-3)
    w, vertices = _loaded(cfg, seed)
    rng = np.random.default_rng(seed + 1)
    done_at: List[float] = []
    unresolved = [0]
    epoch0 = w.manager.epoch
    rec = {"t_kill": None, "t_epoch": None}
    kill_after = (2 * N_MIX) // 5

    def _probe():
        if w.manager.epoch > epoch0:
            rec["t_epoch"] = w.sim.now
        else:
            w.sim.schedule(1e-3, _probe)

    def _tick(ok):
        done_at.append(w.sim.now)
        if not ok:
            unresolved[0] += 1
        if len(done_at) == kill_after:
            rec["t_kill"] = w.sim.now
            w.kill("shard1")
            _probe()

    def issue(cid, idx, done):
        v = vertices[int(rng.integers(len(vertices)))]
        if idx % 5 == 0:
            u = vertices[int(rng.integers(len(vertices)))]
            tx = w.begin_tx()
            tx.create_edge(v, u)

            def cbw(r):
                _tick(r.ok)
                done(r.latency)
            w.submit_tx(tx, cbw, gatekeeper=cid % cfg.n_gatekeepers)
        else:
            def cbr(r, s, l):
                _tick(r is not None)
                done(l)
            w.submit_program("count_edges", [(v, None)], cbr,
                             gatekeeper=cid % cfg.n_gatekeepers)

    drv = ClosedLoopDriver(w.sim, N_CLIENTS, N_MIX, issue)
    res = drv.run(timeout=600.0)
    w.settle(50e-3)
    t0 = done_at[0]
    buckets = np.bincount(((np.asarray(done_at) - t0)
                           / BUCKET_S).astype(int))
    rate = buckets / BUCKET_S
    kill_b = int((rec["t_kill"] - t0) / BUCKET_S)
    baseline = float(rate[:max(kill_b, 1)].mean())
    dip = (float(rate[kill_b:kill_b + 8].min())
           if kill_b < len(rate) else 0.0)
    c = w.sim.counters
    return {
        "completed": res["completed"],
        "n_requests": N_MIX,
        "throughput_per_s": res["throughput_per_s"],
        "goodput_baseline_per_s": baseline,
        "goodput_dip_per_s": dip,
        "dip_fraction": dip / max(baseline, 1e-9),
        "recovery_ms": (rec["t_epoch"] - rec["t_kill"]) * 1e3
        if rec["t_epoch"] else None,
        "replica_promotions": c.replica_promotions,
        "promotion_topup_ops": c.wal_replay_ops,
        "replica_reads_served": c.replica_reads_served,
        "unresolved": unresolved[0],
        "client_gaveup": c.client_gaveup,
        "p99_ms": res["p99_ms"],
    }


def run(seed: int = 0) -> Dict:
    scaling = read_scaling(seed)
    pods = pod_latency(seed + 3)
    promo = promotion_goodput(seed + 5)
    base = scaling["rows"][0]["throughput_per_s"]
    best = max(r["throughput_per_s"] for r in scaling["rows"][1:])
    equivalent = (scaling["equivalent"]
                  and all(r["completed"] == N_READS
                          for r in scaling["rows"])
                  and best > base
                  and promo["completed"] == promo["n_requests"]
                  and promo["replica_promotions"] == 1
                  and promo["recovery_ms"] is not None)
    return {
        "read_scaling": scaling,
        "pod_latency": pods,
        "promotion": promo,
        "equivalent": bool(equivalent),
        "paper_claim": "read replicas subscribe to the refinable-"
                       "timestamp change feed and serve settled-stamp "
                       "reads bit-identically to the primary; a failed "
                       "primary is replaced by promoting the most "
                       "caught-up replica (§4.3 failover + §3.3 "
                       "timeline reuse)",
    }


def main() -> None:
    if FORCE_PODS:
        # ci.sh multi-pod stage: just the two-pod latency measurement,
        # with its routing/ordering invariants asserted
        p = pod_latency()
        print(f"replication,in_pod_p50_ms,{p['in_pod']['p50_ms']:.3f}")
        print(f"replication,cross_pod_p50_ms,"
              f"{p['cross_pod']['p50_ms']:.3f}")
        print(f"replication,in_pod_speedup_p50,"
              f"{p['in_pod_speedup_p50']:.2f}")
        assert p["in_pod"]["replica_reads_served"] > 0
        assert p["in_pod"]["completed"] == N_READS
        assert p["cross_pod"]["completed"] == N_READS
        assert p["cross_pod"]["cross_pod_msgs"] > 0
        assert p["in_pod"]["p50_ms"] < p["cross_pod"]["p50_ms"], p
        save_result("replication_pods", p)
        return
    out = run()
    for r in out["read_scaling"]["rows"]:
        n = r["n_replicas"]
        print(f"replication,read_throughput_per_s[{n}],"
              f"{r['throughput_per_s']:.0f}")
        print(f"replication,replica_reads_served[{n}],"
              f"{r['replica_reads_served']}")
    p = out["pod_latency"]
    print(f"replication,in_pod_p50_ms,{p['in_pod']['p50_ms']:.3f}")
    print(f"replication,cross_pod_p50_ms,{p['cross_pod']['p50_ms']:.3f}")
    print(f"replication,in_pod_speedup_p50,{p['in_pod_speedup_p50']:.2f}")
    g = out["promotion"]
    print(f"replication,goodput_baseline_per_s,"
          f"{g['goodput_baseline_per_s']:.0f}")
    print(f"replication,goodput_dip_per_s,{g['goodput_dip_per_s']:.0f}")
    print(f"replication,recovery_ms,{g['recovery_ms']:.1f}")
    print(f"replication,replica_promotions,{g['replica_promotions']}")
    print(f"replication,equivalent,{int(out['equivalent'])}")
    assert out["equivalent"], \
        "replica reads diverged, scaling flat, or promotion failed"
    assert p["in_pod"]["replica_reads_served"] > 0
    assert p["in_pod"]["p50_ms"] < p["cross_pod"]["p50_ms"], p
    if SMOKE:
        save_result("replication_smoke", out)
        return
    with open(os.path.join(REPO_ROOT, "BENCH_replication.json"), "w") as f:
        json.dump(out, f, indent=1)
    save_result("replication", out)


if __name__ == "__main__":
    main()
