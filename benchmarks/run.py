"""Benchmark harness entry point: one module per paper table/figure.

Prints ``name,metric,value`` CSV lines (simulated time; deterministic).

  snapshot       — snapshot materialization: columnar cold/delta vs seed
  nodeprog       — frontier-batched vs per-vertex node programs
  writepath      — group-commit write engine vs per-tx commits
  recovery       — WAL-replay vs store-walk MTTR; goodput dip on failure
  replication    — change-feed read replicas: read-throughput scaling
                   with replica count (bit-identical results), in-pod vs
                   cross-pod read latency, goodput through primary kill
                   with replica promotion
  serving        — windowed read admission vs per-program; offered-load
                   sweep past saturation with backpressure; SLO curves
  block_query    — Fig. 7 / Table 2 (CoinGraph vs relational explorer)
  social         — Fig. 9 / Fig. 10 (TAO mix, Weaver vs 2PL)
  traversal      — Fig. 11 (node programs vs BSP sync/async)
  scalability    — Fig. 12 / Fig. 13 (gatekeeper & shard scaling)
  coordination   — Fig. 14 (tau sweep: announce vs oracle)
  scaling        — forced host-device sweep: sharded columnar snapshot
                   equivalence + modeled device scaling; columnar BSP
                   vs interpreted; Weaver vs columnar BSP (Fig. 11
                   at the columnar baseline)
  roofline       — §Roofline summary from the dry-run artifacts

A benchmark that raises is reported, the remaining modules still run,
and the harness exits non-zero at the end — failures are loud, never
silently skipped.

``--smoke`` (used by ``scripts/ci.sh``) sets ``REPRO_BENCH_SMOKE=1``
(modules shrink their graph sizes / iteration counts) and runs only the
snapshot + nodeprog + writepath + recovery + replication + serving +
coordination + scaling modules — a
minutes-scale end-to-end check that the data-plane benchmarks still
build, run, and meet their equivalence bits (coordination rides along
so the tau sweep's aggressive-concurrency corner — the historical
oracle ``CycleError`` — stays covered in CI; scaling asserts the
sharded-vs-host bit-identity through real forced-multi-device
``shard_map`` launches).
"""

from __future__ import annotations

import os
import sys
import time


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    if smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    from . import (block_query, coordination, nodeprog, recovery,
                   replication, roofline, scalability, scaling, serving,
                   snapshot, social, traversal, writepath)

    modules = [("snapshot", snapshot), ("nodeprog", nodeprog),
               ("writepath", writepath), ("recovery", recovery),
               ("replication", replication),
               ("serving", serving), ("block_query", block_query),
               ("social", social), ("traversal", traversal),
               ("scalability", scalability),
               ("coordination", coordination), ("scaling", scaling),
               ("roofline", roofline)]
    if smoke:
        modules = [("snapshot", snapshot), ("nodeprog", nodeprog),
                   ("writepath", writepath), ("recovery", recovery),
                   ("replication", replication),
                   ("serving", serving), ("coordination", coordination),
                   ("scaling", scaling)]
    t00 = time.time()
    failures = []
    for name, mod in modules:
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            mod.main()
        except Exception as e:  # keep the harness going, fail at the end
            failures.append((name, f"{type(e).__name__}: {e}"))
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            import traceback
            traceback.print_exc(limit=3)
        print(f"# {name} took {time.time()-t0:.1f}s wall", flush=True)
    print(f"# total {time.time()-t00:.1f}s wall")
    if failures:
        for name, err in failures:
            print(f"# FAILED {name}: {err}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
