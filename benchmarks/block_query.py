"""Fig. 7 / Table 2: Bitcoin block-query latency — CoinGraph (Weaver
node programs) vs. a normalized-relational explorer (Blockchain.info's
MySQL backend modeled on the same simulator).

The paper's observation: both systems scale linearly in transactions per
block, but the graph store pays ~0.6-0.8 ms/tx (in-memory adjacency
traversal) while the join-based explorer pays 5-8 ms/tx (B-tree row
fetches per join row).  We reproduce the *marginal cost per transaction*
gap with an explicit relational cost model: each block query does one
index lookup plus one row fetch per Bitcoin transaction and per output
(B-tree page touch, storage-era service time), matching §5.1's
diagnosis ("expensive MySQL join queries").
"""

from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from repro.configs import PAPER_DEPLOYMENT
from repro.core import Weaver
from repro.core.simulation import Simulator
from repro.data import synth

from .common import load_weaver_graph, save_result


class RelationalExplorer:
    """Blockchain.info stand-in: normalized schema + joins per query.

    A block render joins blocks -> transactions -> {inputs, outputs,
    addresses}: per Bitcoin transaction, N_JOINS secondary-index
    traversals plus the joined row fetches (spinning-disk-era MySQL page
    costs).  This is a *conservative* model — the paper's measured
    5-8 ms/tx additionally includes WAN and concurrent client load.
    """

    ROW_FETCH = 250e-6      # B-tree row fetch incl. page touch (disk era)
    INDEX_LOOKUP = 400e-6
    N_JOINS = 3             # inputs, outputs, addresses

    def __init__(self, sim: Simulator, chain: List[dict]):
        self.sim = sim
        sim.register(self)
        self.blocks = {b["id"]: b for b in chain}

    def query_block(self, block_id: str, on_done: Callable) -> None:
        t0 = self.sim.now
        b = self.blocks[block_id]
        service = self.INDEX_LOOKUP          # block row
        for tx in b["txs"]:
            rows = 1 + len(tx["outputs"])    # tx row + joined rows
            service += self.N_JOINS * self.INDEX_LOOKUP \
                + rows * self.ROW_FETCH
        self.sim.schedule(service,
                          lambda: on_done(self.sim.now - t0))


def build_chain_in_weaver(w: Weaver, chain: List[dict]) -> None:
    for block in chain:
        tx = w.begin_tx()
        tx.create_vertex(block["id"])
        for t in block["txs"]:
            tx.create_vertex(t["id"])
            e = tx.create_edge(block["id"], t["id"])
            tx.set_edge_prop(e, "type", "contains")
            tx.set_vertex_prop(t["id"], "value", t["value"])
        r = w.run_tx(tx)
        assert r.ok, r.error
        # output addresses in a separate transaction (like real ingest)
        tx2 = w.begin_tx()
        staged = set()
        for t in block["txs"]:
            for a in t["outputs"]:
                if a not in staged and w.read_vertex(a) is None:
                    tx2.create_vertex(a)
                    staged.add(a)
                tx2.create_edge(t["id"], a)
        r2 = w.run_tx(tx2)
        assert r2.ok, r2.error


def run(n_blocks: int = 24, repeats: int = 5, seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    chain = synth.blockchain(rng, n_blocks)

    # --- CoinGraph / Weaver ------------------------------------------------
    w = Weaver(PAPER_DEPLOYMENT)
    build_chain_in_weaver(w, chain)
    weaver_rows = []
    for block in chain:
        lats = []
        for _ in range(repeats):
            res, _, lat = w.run_program("block_render",
                                        [(block["id"], {"hop": 0})])
            assert len(res) == len(block["txs"]), (len(res),
                                                   len(block["txs"]))
            lats.append(lat)
        weaver_rows.append({"block": block["id"],
                            "n_tx": len(block["txs"]),
                            "latency_s": float(np.mean(lats))})

    # --- Relational baseline -------------------------------------------------
    sim2 = Simulator(seed=seed)
    rel = RelationalExplorer(sim2, chain)
    rel_rows = []
    for block in chain:
        box = []
        rel.query_block(block["id"], box.append)
        sim2.run()
        rel_rows.append({"block": block["id"], "n_tx": len(block["txs"]),
                         "latency_s": box[0]})

    # marginal cost per transaction (paper: 0.6-0.8ms vs 5-8ms)
    def per_tx(rows):
        big = [r for r in rows if r["n_tx"] >= 5]
        if not big:
            big = rows
        return float(np.mean([r["latency_s"] / max(r["n_tx"], 1)
                              for r in big]))

    w_per_tx = per_tx(weaver_rows)
    r_per_tx = per_tx(rel_rows)
    biggest = max(weaver_rows, key=lambda r: r["n_tx"])
    biggest_rel = next(r for r in rel_rows
                       if r["block"] == biggest["block"])
    out = {
        "weaver_rows": weaver_rows,
        "relational_rows": rel_rows,
        "weaver_ms_per_tx": w_per_tx * 1e3,
        "relational_ms_per_tx": r_per_tx * 1e3,
        "speedup_per_tx": r_per_tx / w_per_tx,
        "biggest_block": {"n_tx": biggest["n_tx"],
                          "weaver_s": biggest["latency_s"],
                          "relational_s": biggest_rel["latency_s"],
                          "speedup": biggest_rel["latency_s"]
                          / biggest["latency_s"]},
        "paper_claim": "8x faster on block 350k; 0.6-0.8ms vs 5-8ms per tx",
    }
    save_result("block_query", out)
    return out


def main() -> None:
    out = run()
    print(f"block_query,weaver_ms_per_tx,{out['weaver_ms_per_tx']:.3f}")
    print(f"block_query,relational_ms_per_tx,"
          f"{out['relational_ms_per_tx']:.3f}")
    print(f"block_query,speedup_per_tx,{out['speedup_per_tx']:.2f}")
    print(f"block_query,biggest_block_speedup,"
          f"{out['biggest_block']['speedup']:.2f}")


if __name__ == "__main__":
    main()
