"""§Roofline: aggregate the dry-run artifacts into the per-cell roofline
table (compute / memory / collective terms, dominant bottleneck, useful-
flops ratio).  Reads results/dryrun/*.json produced by
``python -m repro.launch.dryrun``.
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, List

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def load_cells(variant: str = "baseline") -> List[dict]:
    cells = []
    if not DRYRUN_DIR.is_dir():
        return cells
    for fn in sorted(DRYRUN_DIR.glob(f"*__{variant}.json")):
        cells.append(json.loads(fn.read_text()))
    return cells


def table(cells: List[dict]) -> List[dict]:
    rows = []
    for c in cells:
        if c.get("status") == "skipped":
            rows.append({"cell": f"{c['arch']}/{c['shape']}/{c['mesh']}",
                         "status": "SKIP", "why": c.get("skip_reason", "")})
            continue
        if c.get("status") != "ok":
            rows.append({"cell": f"{c['arch']}/{c['shape']}/{c['mesh']}",
                         "status": "FAIL", "why": c.get("error", "")})
            continue
        r = c["roofline"]
        rows.append({
            "cell": f"{c['arch']}/{c['shape']}/{c['mesh']}",
            "status": "ok",
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "dominant": r["dominant"],
            "bound_s": r["step_s_lower_bound"],
            "useful_frac": c.get("useful_flops_fraction", 0.0),
            "mem_GiB": c["memory"]["peak_estimate_bytes"] / 2**30,
        })
    return rows


def main() -> None:
    cells = load_cells()
    rows = table(cells)
    ok = [r for r in rows if r["status"] == "ok"]
    print("cell,dominant,compute_ms,memory_ms,collective_ms,bound_ms,"
          "useful_frac,mem_GiB")
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['cell']},{r['status']},,,,,,")
            continue
        print(f"{r['cell']},{r['dominant']},{r['compute_s']*1e3:.3f},"
              f"{r['memory_s']*1e3:.3f},{r['collective_s']*1e3:.3f},"
              f"{r['bound_s']*1e3:.3f},{r['useful_frac']:.3f},"
              f"{r['mem_GiB']:.2f}")
    if ok:
        from collections import Counter
        doms = Counter(r["dominant"] for r in ok)
        print(f"roofline,cells_ok,{len(ok)}")
        for d, n in sorted(doms.items()):
            print(f"roofline,dominant_{d},{n}")
        worst = min((r for r in ok if r["useful_frac"] > 0),
                    key=lambda r: r["useful_frac"], default=None)
        if worst:
            print(f"roofline,worst_useful_frac,{worst['useful_frac']:.3f}"
                  f" ({worst['cell']})")


if __name__ == "__main__":
    main()
