"""Fig. 9 + Fig. 10: social-network workload throughput and latency —
Weaver (refinable timestamps) vs. the 2PL/Titan baseline, on the same
simulator, cost model and graph.

Table 1 mix at 99.8% / 75% / 25% reads.  Expected shape (paper):
Weaver throughput falls as writes grow but stays well above the 2PL
engine, whose lock-everything protocol keeps throughput roughly flat
across mixes (12x / 6.4x / 2.8x in the paper's absolute setup).
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs import PAPER_DEPLOYMENT
from repro.core import Weaver
from repro.core.twopl import TwoPLStore
from repro.data import synth

from .common import ClosedLoopDriver, load_weaver_graph, save_result, stats


def _workload(rng, n, read_frac, vertices):
    return synth.tao_workload(rng, n, read_frac, vertices)


def run_weaver(read_frac: float, n_users: int, n_requests: int,
               n_clients: int, seed: int) -> Dict:
    rng = np.random.default_rng(seed)
    w = Weaver(PAPER_DEPLOYMENT)
    edges = synth.social_graph(rng, n_users, avg_degree=6)
    vertices = load_weaver_graph(w, edges)
    ops = _workload(rng, n_requests, read_frac, vertices)
    read_lat, write_lat = [], []

    def issue(cid, idx, done):
        op = ops[idx % len(ops)]
        kind = op["type"]
        if kind in ("get_edges", "count_edges", "get_node"):
            t0 = w.sim.now
            w.submit_program(kind, [(op["v"], None)],
                             lambda r, s, l: (read_lat.append(l),
                                              done(w.sim.now - t0))[1])
        elif kind == "create_edge":
            tx = w.begin_tx()
            tx.create_edge(op["v"], op["u"])
            w.submit_tx(tx, lambda r: (write_lat.append(r.latency),
                                       done(r.latency))[1])
        else:  # delete_edge
            v = w.read_vertex(op["v"])
            if v and v["edges"]:
                tx = w.begin_tx()
                tx.delete_edge(op["v"], next(iter(v["edges"])))
                w.submit_tx(tx, lambda r: (write_lat.append(r.latency),
                                           done(r.latency))[1])
            else:  # nothing to delete: substitute a read
                t0 = w.sim.now
                w.submit_program("get_node", [(op["v"], None)],
                                 lambda r, s, l: done(w.sim.now - t0))

    drv = ClosedLoopDriver(w.sim, n_clients, n_requests, issue)
    res = drv.run()
    res["read_latency"] = stats(read_lat)
    res["write_latency"] = stats(write_lat)
    res["counters"] = {k: v for k, v in w.counters().items() if v}
    return res


def run_twopl(read_frac: float, n_users: int, n_requests: int,
              n_clients: int, seed: int) -> Dict:
    rng = np.random.default_rng(seed)
    store = TwoPLStore(n_shards=PAPER_DEPLOYMENT.n_shards, seed=seed)
    edges = synth.social_graph(rng, n_users, avg_degree=6)
    store.load_graph(edges)
    vertices = sorted({v for e in edges for v in e})
    ops = _workload(rng, n_requests, read_frac, vertices)
    read_lat, write_lat = [], []

    def issue(cid, idx, done):
        op = ops[idx % len(ops)]
        kind = op["type"]
        if kind in ("get_edges", "count_edges", "get_node"):
            store.submit([{"op": "get_vertex", "vid": op["v"]}],
                         lambda r: (read_lat.append(r["latency"]),
                                    done(r["latency"]))[1])
        elif kind == "create_edge":
            store.submit([{"op": "create_edge", "src": op["v"],
                           "dst": op["u"], "eid": store.fresh_eid()}],
                         lambda r: (write_lat.append(r["latency"]),
                                    done(r["latency"]))[1])
        else:
            store.submit([{"op": "get_vertex", "vid": op["v"]},
                          {"op": "set_vertex_prop", "vid": op["v"],
                           "key": "touch", "value": idx}],
                         lambda r: (write_lat.append(r["latency"]),
                                    done(r["latency"]))[1])

    drv = ClosedLoopDriver(store.sim, n_clients, n_requests, issue)
    res = drv.run()
    res["read_latency"] = stats(read_lat)
    res["write_latency"] = stats(write_lat)
    return res


def run(n_users: int = 400, n_requests: int = 2000, n_clients: int = 64,
        seed: int = 0) -> Dict:
    out = {}
    for frac, label in [(0.998, "99.8"), (0.75, "75"), (0.25, "25")]:
        wv = run_weaver(frac, n_users, n_requests, n_clients, seed)
        pl = run_twopl(frac, n_users, n_requests, n_clients, seed)
        out[label] = {
            "weaver": wv, "twopl": pl,
            "speedup": wv["throughput_per_s"]
            / max(pl["throughput_per_s"], 1e-9),
        }
    out["paper_claim"] = ("12x @99.8% reads, 6.4x @75%, 2.8x @25%; "
                          "2PL flat ~2000 tx/s across mixes")
    save_result("social", out)
    return out


def main() -> None:
    out = run()
    for label in ("99.8", "75", "25"):
        r = out[label]
        print(f"social,weaver_tput_{label},"
              f"{r['weaver']['throughput_per_s']:.0f}")
        print(f"social,twopl_tput_{label},{r['twopl']['throughput_per_s']:.0f}")
        print(f"social,speedup_{label},{r['speedup']:.2f}")


if __name__ == "__main__":
    main()
