"""Device-scaling sweep: sharded columnar data plane + columnar BSP.

Three questions, one benchmark:

1. **Does the device-sharded snapshot path scale?**  Subprocess children
   run under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (jax
   locks the device count at first init, hence one process per N), build
   the SAME graph into a device-sharded and a host-global Weaver, assert
   the snapshot arrays are *bit-identical* through real multi-device
   ``shard_map`` launches (cold AND delta after churn), and time the
   columnar build.

   All forced host devices share one physical CPU, so wall-clock cannot
   show parallel speedup here.  Children therefore report **modeled**
   scaling, decomposed from measured times: per-shard device-resident
   work (visibility masks, concurrent-residue scan, visible-row gather,
   vid interning, local edge-key sort) is timed per shard, the serial
   merge residue is ``t_cold - sum(per_shard)``, and
   ``modeled_cold(N) = t_serial + max_over_devices(assigned shard
   time)`` with shards round-robined onto devices.  A child computes the
   model at N'=1 and at its own N from the SAME measurements, so the
   reported speedup is noise-cancelling and honestly labeled modeled.

2. **Do snapshot analytics scale?**  PageRank's per-iteration scatter is
   sliced into N contiguous edge ranges; modeled iteration time is
   ``max(slice) + combine`` over a measured full pass (at N=1 the model
   reproduces the measurement).  CC is reported as measured.

3. **Is the columnar BSP baseline fair and is Weaver still ahead?**
   In-process: interpreted ``BSPEngine`` vs ``ColumnarBSPEngine`` on one
   bench-scale graph — identical simulated results required, wall-clock
   speedup reported (the interpreter overhead the rewrite removes) —
   then the Fig. 11 comparison at the *columnar* baseline: Weaver
   ``reachable`` node programs must keep their simulated-latency
   advantage over columnar BSP-sync (barriers), per the paper's claim.

Full mode writes ``BENCH_scaling.json`` at the repo root; smoke saves
``results/bench/scaling_smoke.json`` and skips the expensive sizes.
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time
from typing import Dict, List

import numpy as np

from repro.configs import PAPER_DEPLOYMENT
from repro.core import Weaver
from repro.core.bsp import BSPEngine, ColumnarBSPEngine
from repro.data import synth

from .common import load_weaver_graph, save_result, stats

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

# ---------------------------------------------------------------------------
# Child process: one forced device count per process.
# ---------------------------------------------------------------------------

CHILD_SRC = r'''
import json, os, sys, time
DEVICES = int(sys.argv[1])
N_USERS = int(sys.argv[2])
DEG = int(sys.argv[3])
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=%d" % DEVICES)
import numpy as np
import jax
assert len(jax.devices()) == DEVICES, jax.devices()

from repro.core import Weaver, WeaverConfig, clock
from repro.core.analytics import (SnapshotEngine, connected_components_ga,
                                  pagerank_ga)
from repro.core.clock import Stamp
from repro.data import synth
from repro.launch.mesh import make_columns_mesh

N_SHARDS = 8          # divisible onto 1/2/4 devices
N_GK = 2


def med(f, iters=5):
    return float(np.median([f() for _ in range(iters)]))


class SG:
    def __init__(self):
        self.clock = [0] * N_GK
        self.i = 0

    def next(self):
        g = self.i % N_GK
        self.i += 1
        self.clock[g] += 1
        return Stamp(0, tuple(self.clock), g, self.clock[g])

    def query(self):
        self.i += 1
        self.clock = [c + 1 for c in self.clock]
        return Stamp(0, tuple(self.clock), 0, self.clock[0])


rng = np.random.default_rng(0)
edges = synth.social_graph(rng, N_USERS, avg_degree=DEG)
verts = sorted({v for e in edges for v in e})


def build(flag):
    w = Weaver(WeaverConfig(n_gatekeepers=N_GK, n_shards=N_SHARDS,
                            gc_period=0, seed=0,
                            device_shard_columns=flag))
    sg = SG()
    part = lambda v: w.shards[w.store.place(v)].partition
    for v in verts:
        part(v).create_vertex(v, sg.next())
    for s, d in edges:
        part(s).create_edge(s, d, sg.next())
    return w, sg, part


w_dev, sg_dev, part_dev = build(True)
w_host, sg_host, part_host = build(False)
assert make_columns_mesh().devices.size == DEVICES


def assert_same(got, want):
    assert got.vids[:got.n_nodes] == want.vids[:want.n_nodes]
    assert np.array_equal(got.edge_src, want.edge_src)
    assert np.array_equal(got.edge_dst, want.edge_dst)


# --- equivalence through the real N-device shard_map: cold then delta ---
eng_dev, eng_host = SnapshotEngine(w_dev), SnapshotEngine(w_host)
g_d = eng_dev.snapshot(sg_dev.query())
g_h = eng_host.snapshot(sg_host.query())
assert_same(g_d, g_h)
assert w_dev.device_plane.stats["launches"] > 0

# identical churn streams (create + delete edges), then delta refresh.
# Round one warms the plane's row-scatter path (jit compile); round two
# is the timed O(changed) refresh.
def churn(w, sg, part, seed):
    r = np.random.default_rng(seed)
    for _ in range(150):
        s = verts[int(r.integers(len(verts)))]
        d = verts[int(r.integers(len(verts)))]
        part(s).create_edge(s, d, sg.next())
    for _ in range(100):
        s = verts[int(r.integers(len(verts)))]
        e = part(s).vertices[s].out_edges.get(1)
        if e is not None and e.delete_ts is None:
            part(s).delete_edge(s, 1, sg.next())


t_delta_sharded = t_delta_host = None
for round_seed in (7, 11):
    churn(w_dev, sg_dev, part_dev, round_seed)
    churn(w_host, sg_host, part_host, round_seed)
    assert sg_dev.clock == sg_host.clock
    at_d, at_h = sg_dev.query(), sg_host.query()
    t0 = time.perf_counter()
    g_d = eng_dev.snapshot(at_d)
    t_delta_sharded = time.perf_counter() - t0
    t0 = time.perf_counter()
    g_h = eng_host.snapshot(at_h)
    t_delta_host = time.perf_counter() - t0
    assert_same(g_d, g_h)
assert eng_dev.stats["delta"] >= 2, eng_dev.stats
assert w_dev.device_plane.stats["row_updates"] > 0

# --- cold timings: sharded launch path and host oracle path -------------
def cold(w, sg):
    at = sg.query()
    t0 = time.perf_counter()
    SnapshotEngine(w).snapshot(at)
    return time.perf_counter() - t0


t_cold_shard = med(lambda: cold(w_dev, sg_dev), 3)
t_cold_host = med(lambda: cold(w_host, sg_host), 3)

# --- modeled device-parallel decomposition of the host-path cold build --
q = clock.pack(sg_host.query(), N_GK)
iv = w_host.intern.vids
per_shard = []
for sh in w_host.shards:
    cols = sh.partition.columns
    if cols is None or (cols.n_v == 0 and cols.n_e == 0):
        per_shard.append(0.0)
        continue

    def work(cols=cols):
        t0 = time.perf_counter()
        cv, dv = cols.v_create.view(), cols.v_delete.view()
        ce, de = cols.e_create.view(), cols.e_delete.view()
        vcb, vdb = clock._np_before(cv, q), clock._np_before(dv, q)
        ecb, edb = clock._np_before(ce, q), clock._np_before(de, q)
        clock.concurrent_mask_np(cv, q)
        clock.concurrent_mask_np(ce, q)
        vvis, evis = vcb & ~vdb, ecb & ~edb
        gids = cols.v_gid.view()[vvis]
        vids = [iv[g] for g in gids.tolist()]
        # cold layout is shard-contiguous: each device builds its own
        # vid->index sub-dict over a known offset range
        _ = {vid: i for i, vid in enumerate(vids)}
        src = cols.e_src.view()[evis].astype(np.int64)
        dst = cols.e_dst.view()[evis].astype(np.int64)
        np.sort((src << 32) | dst)
        return time.perf_counter() - t0

    per_shard.append(med(work, 5))

p_sum = float(sum(per_shard))
t_serial = max(t_cold_host - p_sum, 0.0)


def modeled_cold(n_dev):
    dev_t = [0.0] * n_dev
    for i, t in enumerate(per_shard):
        dev_t[i % n_dev] += t
    return t_serial + max(dev_t)


# --- analytics: pagerank slice model, cc measured -----------------------
ga = SnapshotEngine(w_host).snapshot(sg_host.query())
pr = pagerank_ga(ga)
jax.block_until_ready(pr)
t_pr = med(lambda: (lambda t0: (jax.block_until_ready(pagerank_ga(ga)),
                                time.perf_counter() - t0)[1])(
    time.perf_counter()), 3)
cc = connected_components_ga(ga)
jax.block_until_ready(cc)
t_cc = med(lambda: (lambda t0: (jax.block_until_ready(
    connected_components_ga(ga)), time.perf_counter() - t0)[1])(
    time.perf_counter()), 3)

# PageRank device model: dst-range partitioning.  The CSC orientation
# is dst-sorted, so device d owns vertices [vlo, vhi) and exactly the
# contiguous edge range targeting them; its per-iteration work is a
# gather over its edges, a segment-sum into its vertex range and
# V/N-sized vector ops — timed on the real jitted kernel restricted to
# that slice.  Combine = the per-iteration allgather of the pr pieces.
from repro.core.analytics import pagerank
csrc = np.asarray(ga.csc_src)
cdst = np.asarray(ga.csc_dst)
n_nodes = ga.n_nodes


def slice_wall(vlo, vhi):
    elo = int(np.searchsorted(cdst, vlo))
    ehi = int(np.searchsorted(cdst, vhi))
    s = np.asarray(csrc[elo:ehi])
    d = (cdst[elo:ehi] - vlo).astype(np.int32)

    def one():
        t0 = time.perf_counter()
        jax.block_until_ready(pagerank(s, d, int(vhi - vlo), 20, 0.85,
                                       False, True))
        return time.perf_counter() - t0

    one()        # compile this slice shape
    return med(one, 3)


def modeled_pr(n_dev):
    bounds = [n_nodes * k // n_dev for k in range(n_dev + 1)]
    slices = [slice_wall(a, b) for a, b in zip(bounds[:-1], bounds[1:])]
    if n_dev == 1:
        return max(slices)
    pieces = [np.zeros(b - a, np.float32)
              for a, b in zip(bounds[:-1], bounds[1:])]
    t0 = time.perf_counter()
    for _ in range(20):
        np.concatenate(pieces)
    t_comb = time.perf_counter() - t0
    return max(slices) + t_comb


modeled_pr_n = modeled_pr(DEVICES)
out = {
    "devices": DEVICES,
    "n_nodes": int(g_d.n_nodes),
    "n_edges": int(g_d.edge_src.size),
    "cold_host_s": t_cold_host,
    "cold_sharded_s": t_cold_shard,
    # host delta shows the O(changed) refresh; the sharded number adds
    # the plane's per-sync scatter-launch overhead (CPU-backend jnp
    # dispatch, amortized across queries on a real accelerator)
    "delta_host_s": t_delta_host,
    "delta_sharded_s": t_delta_sharded,
    "delta_speedup_vs_cold": t_cold_host / max(t_delta_host, 1e-12),
    "serial_residue_s": t_serial,
    "per_shard_sum_s": p_sum,
    "modeled_cold_1dev_s": modeled_cold(1),
    "modeled_cold_Ndev_s": modeled_cold(DEVICES),
    "speedup_cold_modeled": modeled_cold(1) / max(modeled_cold(DEVICES),
                                                  1e-12),
    "pagerank_s": t_pr,
    "modeled_pagerank_Ndev_s": modeled_pr_n,
    "speedup_pagerank_modeled": t_pr / max(modeled_pr_n, 1e-12),
    "cc_s": t_cc,
    "plane_stats": w_dev.device_plane.stats,
}
print("RESULT " + json.dumps(out))
'''


def run_child(devices: int, n_users: int, deg: int) -> Dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-c", CHILD_SRC, str(devices), str(n_users),
         str(deg)],
        capture_output=True, text=True, timeout=900, env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"scaling child (devices={devices}) failed:\n"
            f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line from child:\n{proc.stdout}")


# ---------------------------------------------------------------------------
# In-process: columnar BSP vs interpreted, then Weaver vs columnar BSP.
# ---------------------------------------------------------------------------

def bsp_wallclock(n_users: int, deg: int, n_queries: int,
                  seed: int = 0) -> Dict:
    """Interpreted vs columnar engine wall-clock at equal simulated
    results — what the columnar rewrite buys is pure interpreter
    overhead, so results (reached/visited/levels) must be identical."""
    rng = np.random.default_rng(seed)
    edges = synth.social_graph(rng, n_users, avg_degree=deg)
    vertices = sorted({v for e in edges for v in e})
    pairs = [(vertices[rng.integers(len(vertices))],
              vertices[rng.integers(len(vertices))])
             for _ in range(n_queries)]

    walls, results = {}, {}
    for name, cls in (("interpreted", BSPEngine),
                      ("columnar", ColumnarBSPEngine)):
        eng = cls(n_workers=PAPER_DEPLOYMENT.n_shards, seed=seed)
        eng.load_graph(edges)
        t0 = time.perf_counter()
        res = []
        for s, t in pairs:
            box: List[dict] = []
            eng.bfs_sync(s, t, box.append)
            eng.sim.run(until=eng.sim.now + 600.0)
            assert box, "bfs did not finish"
            res.append((box[0]["reached"], box[0]["visited"],
                        box[0]["latency"]))
        walls[name] = time.perf_counter() - t0
        results[name] = res
    for a, b in zip(results["interpreted"], results["columnar"]):
        assert a[0] == b[0] and a[1] == b[1], "columnar result mismatch"
    return {
        "n_nodes": len(vertices), "n_edges": len(edges),
        "n_queries": n_queries,
        "interpreted_wall_s": walls["interpreted"],
        "columnar_wall_s": walls["columnar"],
        "wall_speedup": walls["interpreted"] / max(walls["columnar"],
                                                   1e-12),
        "results_equal": True,
    }


def weaver_vs_columnar_bsp(n_users: int, n_queries: int,
                           seed: int = 0) -> Dict:
    """Fig. 11 at the columnar baseline: Weaver node programs must keep
    their simulated-latency advantage once BSP interpreter overhead is
    gone (barriers/locks are what remains charged)."""
    rng = np.random.default_rng(seed)
    edges = synth.social_graph(rng, n_users, avg_degree=10)
    vertices = sorted({v for e in edges for v in e})
    pairs = [(vertices[rng.integers(len(vertices))],
              vertices[rng.integers(len(vertices))])
             for _ in range(n_queries)]

    deployment = dataclasses.replace(PAPER_DEPLOYMENT, tau=0.05e-3,
                                     tau_nop=0.05e-3)
    w = Weaver(deployment)
    load_weaver_graph(w, edges)
    weaver_lat, weaver_reached = [], []
    for s, t in pairs:
        res, _, lat = w.run_program("reachable", [(s, {"target": t})],
                                    timeout=60.0)
        weaver_lat.append(lat)
        weaver_reached.append(bool(res))

    sync_lat, async_lat, sync_reached = [], [], []
    for variant, sink in (("sync", sync_lat), ("async", async_lat)):
        eng = ColumnarBSPEngine(n_workers=PAPER_DEPLOYMENT.n_shards,
                                seed=seed)
        eng.load_graph(edges)
        for s, t in pairs:
            box: List[dict] = []
            if variant == "sync":
                eng.bfs_sync(s, t, box.append)
            else:
                eng.bfs_async(s, t, box.append)
            eng.sim.run(until=eng.sim.now + 120.0)
            assert box, f"{variant} bfs did not finish"
            sink.append(box[0]["latency"])
            if variant == "sync":
                sync_reached.append(bool(box[0]["reached"]))

    agree = float(np.mean([a == b for a, b
                           in zip(weaver_reached, sync_reached)]))
    return {
        "weaver": stats(weaver_lat),
        "columnar_bsp_sync": stats(sync_lat),
        "columnar_bsp_async": stats(async_lat),
        "speedup_vs_sync": float(np.mean(sync_lat) / np.mean(weaver_lat)),
        "speedup_vs_async": float(np.mean(async_lat)
                                  / np.mean(weaver_lat)),
        "reachability_agreement": agree,
    }


def run(device_counts: List[int] = None) -> Dict:
    if device_counts is None:
        device_counts = [1, 2] if SMOKE else [1, 2, 4]
    n_users, deg = (4000, 5) if SMOKE else (12000, 5)

    sweep = []
    for n in device_counts:
        r = run_child(n, n_users, deg)
        sweep.append(r)
        print(f"scaling,devices,{n}")
        print(f"scaling,cold_host_ms_{n}dev,{r['cold_host_s']*1e3:.2f}")
        print(f"scaling,speedup_cold_modeled_{n}dev,"
              f"{r['speedup_cold_modeled']:.2f}")
        print(f"scaling,speedup_pagerank_modeled_{n}dev,"
              f"{r['speedup_pagerank_modeled']:.2f}")

    top = sweep[-1]
    if SMOKE:
        assert top["speedup_cold_modeled"] >= 1.1, top
        assert top["speedup_pagerank_modeled"] >= 1.1, top
    else:
        assert top["devices"] == 4
        assert top["speedup_cold_modeled"] >= 1.6, top
        assert top["speedup_pagerank_modeled"] >= 1.6, top

    bsp = bsp_wallclock(*((6000, 6, 2) if SMOKE else (40000, 10, 3)))
    print(f"scaling,bsp_wall_speedup,{bsp['wall_speedup']:.2f}")
    if not SMOKE:
        assert bsp["wall_speedup"] >= 5.0, bsp

    fig11 = weaver_vs_columnar_bsp(*((400, 5) if SMOKE else (1500, 12)))
    print(f"scaling,weaver_mean_ms,{fig11['weaver']['mean_ms']:.2f}")
    print(f"scaling,columnar_bsp_sync_mean_ms,"
          f"{fig11['columnar_bsp_sync']['mean_ms']:.2f}")
    print(f"scaling,weaver_speedup_vs_columnar_sync,"
          f"{fig11['speedup_vs_sync']:.2f}")
    if not SMOKE:
        assert fig11["speedup_vs_sync"] > 1.0, fig11

    out = {
        "smoke": SMOKE,
        "graph": {"n_users": n_users, "avg_degree": deg},
        "device_sweep": sweep,
        "columnar_bsp_wallclock": bsp,
        "weaver_vs_columnar_bsp": fig11,
        "notes": "forced host devices share one CPU; device speedups are "
                 "MODELED from measured per-shard/per-slice times "
                 "(see module docstring); equivalence and BSP result "
                 "equality are asserted on real outputs",
    }
    if SMOKE:
        save_result("scaling_smoke", out)
    else:
        save_result("scaling", out)
        with open(os.path.join(REPO_ROOT, "BENCH_scaling.json"),
                  "w") as f:
            json.dump(out, f, indent=1)
    return out


def main() -> None:
    run()


if __name__ == "__main__":
    main()
