"""Snapshot-materialization microbenchmark (columnar engine PR).

Times three ways of materializing a timestamp-consistent snapshot of a
~100k-edge synthetic social graph (wall-clock, not simulated time — this
measures the data-plane bridge itself):

* ``python``  — the seed per-object path (`snapshot_arrays_python`):
  per-vertex/per-edge dict iteration with per-stamp ``compare`` calls;
* ``cold``    — columnar cold build: concatenate shard columns, one
  batched visibility pass, vectorized CSR compaction;
* ``delta``   — cached delta refresh after mutating <1% of stamps
  (O(changed) re-evaluation + sorted-merge patch of the CSR arrays);
* ``noop``    — cached refresh with nothing changed.

Writes ``BENCH_snapshot.json`` at the repo root (plus the usual
results/bench copy) with median seconds and speedups, so the perf
trajectory of the snapshot path is tracked across PRs.

Writes are applied directly to the shard partitions with synthetic
totally-ordered stamps: the transaction pipeline is not under test here.
"""

from __future__ import annotations

import json
import os
import time
from typing import List

import numpy as np

from repro.core import Weaver, WeaverConfig
from repro.core import analytics as A
from repro.core.analytics import SnapshotEngine
from repro.core.clock import Stamp

from .common import save_result

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
N_USERS = 4_000 if SMOKE else 20_000
AVG_DEG = 5


class _StampGen:
    """Totally-ordered synthetic stamps (round-robin gatekeepers)."""

    def __init__(self, n_gk: int):
        self.n_gk = n_gk
        self.clock = [0] * n_gk
        self.i = 0

    def next(self) -> Stamp:
        g = self.i % self.n_gk
        self.i += 1
        self.clock[g] += 1
        return Stamp(0, tuple(self.clock), g, self.clock[g])

    def query(self) -> Stamp:
        """A stamp after everything issued so far (program-like)."""
        g = self.i % self.n_gk
        self.i += 1
        self.clock = [c + 1 for c in self.clock]
        return Stamp(0, tuple(self.clock), g, self.clock[g])


def _build(seed: int = 0):
    rng = np.random.default_rng(seed)
    from repro.data import synth
    edges = synth.social_graph(rng, N_USERS, AVG_DEG)
    w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=4, gc_period=0,
                            seed=seed))
    sg = _StampGen(w.cfg.n_gatekeepers)
    part_of = lambda vid: w.shards[w.store.place(vid)].partition
    vertices = sorted({v for e in edges for v in e})
    for v in vertices:
        part_of(v).create_vertex(v, sg.next())
    handles = []
    for s, d in edges:
        handles.append((s, part_of(s).create_edge(s, d, sg.next()).eid))
    return w, sg, vertices, handles, len(edges)


def _canon(ga) -> tuple:
    vids = ga.vids[:ga.n_nodes]
    pairs = sorted(zip((vids[i] for i in ga.edge_src.tolist()),
                       (vids[i] for i in ga.edge_dst.tolist())))
    return sorted(vids), pairs


def _median(f, iters: int) -> float:
    ts: List[float] = []
    for _ in range(iters):
        t0 = time.perf_counter()
        f()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def main() -> None:
    w, sg, vertices, handles, n_edges = _build()
    at = sg.query()

    # seed python path (O(V+E) interpreter work per query)
    t_python = _median(lambda: A.snapshot_arrays_python(w, at), 2)

    # columnar cold build (fresh engine each run)
    t_cold = _median(lambda: SnapshotEngine(w).snapshot(at), 3)

    # equivalence spot-check while we are here
    eng = SnapshotEngine(w)
    ok = _canon(eng.snapshot(at)) == _canon(A.snapshot_arrays_python(w, at))

    # delta refresh: mutate <1% of stamps, re-snapshot on a warm engine
    rng = np.random.default_rng(1)
    frac = max(1, n_edges // 400)        # 0.25% of edges
    part_of = lambda vid: w.shards[w.store.place(vid)].partition
    delta_ts: List[float] = []
    at_i = at
    for round_i in range(14):
        for s, d in zip(rng.choice(vertices, frac // 2),
                        rng.choice(vertices, frac // 2)):
            part_of(s).create_edge(s, d, sg.next())
        kill = rng.integers(0, len(handles), frac // 2)
        for i in kill:
            s, eid = handles[int(i)]
            e = part_of(s).vertices[s].out_edges[eid]
            if e.delete_ts is None:
                part_of(s).delete_edge(s, eid, sg.next())
        at_i = sg.query()
        t0 = time.perf_counter()
        eng.snapshot(at_i)
        if round_i >= 4:             # first rounds warm the grow buffers
            delta_ts.append(time.perf_counter() - t0)
    t_delta = float(np.median(delta_ts))

    # no-change refresh at a fresh later stamp
    at_n = sg.query()
    t_noop = _median(lambda: eng.snapshot(at_n), 5)

    # delta result still equivalent after the mutation stream
    ok = ok and (_canon(eng.snapshot(at_n))
                 == _canon(A.snapshot_arrays_python(w, at_n)))

    payload = {
        "graph": {"n_vertices": len(vertices), "n_edges": n_edges},
        "seconds": {"python": t_python, "cold": t_cold,
                    "delta": t_delta, "noop": t_noop},
        "speedup": {"cold_vs_python": t_python / t_cold,
                    "delta_vs_cold": t_cold / t_delta,
                    "noop_vs_cold": t_cold / t_noop},
        "changed_per_delta": frac,
        "engine_stats": eng.stats,
        "equivalent": bool(ok),
    }
    for k, v in payload["seconds"].items():
        print(f"snapshot,seconds_{k},{v:.6f}")
    for k, v in payload["speedup"].items():
        print(f"snapshot,{k},{v:.2f}")
    print(f"snapshot,equivalent,{int(ok)}")
    if SMOKE:        # CI: equivalence/asserts ran; keep the full-run
        save_result("snapshot_smoke", payload)   # numbers at repo root
        return
    with open(os.path.join(REPO_ROOT, "BENCH_snapshot.json"), "w") as f:
        json.dump(payload, f, indent=1)
    save_result("snapshot", payload)


if __name__ == "__main__":
    main()
