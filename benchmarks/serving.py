"""Closed-loop SLO serving benchmark: windowed read admission vs the
per-program oracle, offered-load sweep past saturation with gatekeeper
backpressure, and the batched==per-program equivalence bit.

Four sections (all simulated seconds; deterministic for a given seed):

  saturation   — closed-loop client fleet driving pure reads to the
                 gatekeeper saturation point, per-program admission vs
                 windowed+adaptive.  Full mode enforces the >=3x
                 throughput bar (one shared stamp + vectorized routing
                 amortizes the per-request gatekeeper CPU).
  sweep        — open-loop Poisson arrivals swept past the service
                 capacity with bounded admission queues + read retry
                 sessions: low-load p99 must stay within 1.5x of
                 per-program admission, and goodput must stay flat
                 (not collapse) as offered load exceeds capacity.
                 Gatekeeper service times are scaled up for this
                 section so saturation is reachable with thousands
                 (not millions) of simulated requests.
  equivalence  — identical write history, then identical quiescent
                 reads under both admission modes; results must be
                 bit-identical (windowed stamps differ, visibility of
                 settled data must not).
  mixed        — TAO read/write mix through GraphQueryServer with
                 ``read_your_writes=True``: tx acks wait for shard
                 apply (acks_deferred > 0) and every request completes.
  obs          — tracing overhead + purity (ISSUE 9): the same seeded
                 mixed workload untraced twice (run-to-run noise
                 floor) and fully traced; results and non-obs
                 counters must be bit-identical, and the traced
                 run's critical-path stage attribution must tile
                 every request's e2e latency.  Smoke mode exports
                 the trace to trace_serving_smoke.json for
                 scripts/check_trace.py.

Full mode writes BENCH_serving.json and BENCH_obs.json at the repo
root.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.configs import PAPER_DEPLOYMENT
from repro.core import Weaver
from repro.core.gatekeeper import CostModel
from repro.core.obs import (OBS_COUNTER_FIELDS, attribution_table,
                            export_trace, format_stage_table,
                            run_invariant_checks)
from repro.data import synth
from repro.runtime.server import GraphQueryServer

from .common import load_weaver_graph, save_result, stats

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"

# windowed-admission config deltas shared by every section
WINDOWED = dict(read_group_commit=200e-6, read_group_max=128,
                adaptive_admission=True)


def _deploy(seed: int, n_users: int, **over) -> Tuple[Weaver, List[str]]:
    cfg = dataclasses.replace(PAPER_DEPLOYMENT, seed=seed, **over)
    w = Weaver(cfg)
    rng = np.random.default_rng(seed)
    edges = synth.social_graph(rng, n_users, avg_degree=6)
    vertices = load_weaver_graph(w, edges)
    w.settle()
    return w, vertices


# ---- section 1: closed-loop saturation ---------------------------------


def saturation(seed: int) -> Dict:
    # ~1.6ms base read latency (network + NOP visibility gating) means a
    # closed loop needs throughput*latency clients merely to reach the
    # per-program gatekeeper capacity (~200k/s at 4 GKs) — size the
    # fleet well past that so both modes run saturated, not latency-bound
    n_users = 60 if SMOKE else 200
    n_clients = 512 if SMOKE else 2048
    n_requests = 2000 if SMOKE else 10000
    out = {}
    for label, over in [("per_program", {}), ("windowed", WINDOWED)]:
        w, vertices = _deploy(seed, n_users, **over)
        srv = GraphQueryServer(w)
        rng = np.random.default_rng(seed + 1)
        picks = rng.integers(0, len(vertices), size=n_requests)

        def make(i, picks=picks, vertices=vertices):
            return "prog", ("get_node", [(vertices[int(picks[i])], None)])

        res = srv.run_closed_loop(n_clients, n_requests, make)
        assert res["completed"] == n_requests, res
        c = w.counters()
        res["latency"] = stats(res.pop("latencies_s"))
        res["mean_batch"] = (c["prog_batch_size_sum"] / c["prog_batches"]
                             if c["prog_batches"] else 1.0)
        res["counters"] = {k: v for k, v in c.items() if v}
        out[label] = res
    out["speedup"] = (out["windowed"]["throughput_per_s"]
                      / out["per_program"]["throughput_per_s"])
    return out


# ---- section 2: open-loop offered-load sweep ---------------------------


def sweep(seed: int) -> Dict:
    """Offered load vs goodput/latency with throttling on.

    Service times are inflated (gk_stamp 200us, gk_batch_prog 50us) so
    the 2-gatekeeper capacity lands near 40k reads/s and the sweep can
    cross it with a few thousand requests per point.
    """
    cost = CostModel(gk_stamp=200e-6, gk_batch_prog=50e-6)
    base = dict(n_gatekeepers=2, n_shards=4, cost=cost,
                admission_queue_limit=64, read_retry_timeout=4e-3,
                **WINDOWED)
    n_users = 40 if SMOKE else 80
    duration = 0.02 if SMOKE else 0.05
    rates = [10e3, 40e3] if SMOKE else [10e3, 20e3, 40e3, 60e3, 80e3]
    points = []
    for rate in rates:
        w, vertices = _deploy(seed, n_users, **base)
        srv = GraphQueryServer(w)
        n_requests = int(rate * duration)
        rng = np.random.default_rng(seed + 2)
        picks = rng.integers(0, len(vertices), size=n_requests)

        def make(i, picks=picks, vertices=vertices):
            return "prog", ("get_node", [(vertices[int(picks[i])], None)])

        res = srv.run_open_loop(rate, n_requests, make, seed=seed + 3,
                                timeout=20.0)
        c = w.counters()
        res["latency"] = stats(res.pop("latencies_s"))
        res["shed"] = c["progs_shed"]
        res["retries"] = c["prog_retries"]
        res["gaveup"] = c["prog_gaveup"]
        points.append(res)
    # per-program oracle at the lowest rate, for the low-load p99 bar
    w, vertices = _deploy(seed, n_users, n_gatekeepers=2, n_shards=4,
                          cost=cost, read_retry_timeout=4e-3)
    srv = GraphQueryServer(w)
    n_requests = int(rates[0] * duration)
    rng = np.random.default_rng(seed + 2)
    picks = rng.integers(0, len(vertices), size=n_requests)
    res = srv.run_open_loop(
        rates[0], n_requests,
        lambda i: ("prog", ("get_node", [(vertices[int(picks[i])], None)])),
        seed=seed + 3, timeout=20.0)
    oracle = stats(res.pop("latencies_s"))
    goodputs = [p["goodput_per_s"] for p in points]
    return {
        "points": points,
        "per_program_low_load": oracle,
        "low_load_p99_ratio": points[0]["latency"]["p99_ms"]
        / max(oracle["p99_ms"], 1e-9),
        "goodput_flat": goodputs[-1] / max(max(goodputs), 1e-9),
    }


# ---- section 3: batched == per-program equivalence ---------------------


def equivalence(seed: int) -> Dict:
    """Same writes, same quiescent reads, both admission modes —
    results (not stamps: windows share one) must be bit-identical."""
    n_users = 40 if SMOKE else 120
    n_reads = 200 if SMOKE else 800
    results = {}
    for label, over in [("per_program", {}), ("windowed", WINDOWED)]:
        w, vertices = _deploy(seed, n_users, **over)
        rng = np.random.default_rng(seed + 4)
        # write churn: edge creates/deletes, then settle to quiescence
        for _ in range(40 if SMOKE else 160):
            a = vertices[int(rng.integers(0, len(vertices)))]
            b = vertices[int(rng.integers(0, len(vertices)))]
            tx = w.begin_tx()
            tx.create_edge(a, b)
            w.submit_tx(tx, lambda r: None)
        w.settle(50e-3)
        picks = rng.integers(0, len(vertices), size=n_reads)
        got: List[Tuple[int, str]] = []
        for i in range(n_reads):
            v = vertices[int(picks[i])]
            name = ("get_edges", "count_edges", "get_node")[i % 3]
            w.submit_program(name, [(v, None)],
                             lambda r, s, l, i=i: got.append((i, repr(r))))
        w.settle(50e-3)
        assert len(got) == n_reads, (label, len(got))
        results[label] = sorted(got)
    return {"equivalent": int(results["per_program"] == results["windowed"]),
            "n_reads": n_reads}


# ---- section 4: mixed TAO workload with read-your-writes ---------------


def mixed(seed: int) -> Dict:
    n_users = 50 if SMOKE else 150
    n_requests = 400 if SMOKE else 2000
    w, vertices = _deploy(seed, n_users, read_your_writes=True, **WINDOWED)
    srv = GraphQueryServer(w)
    rng = np.random.default_rng(seed + 5)
    ops = synth.tao_workload(rng, n_requests, 0.9, vertices)

    def make(i):
        op = ops[i]
        kind = op["type"]
        if kind in ("get_edges", "count_edges", "get_node"):
            return "prog", (kind, [(op["v"], None)])
        tx = w.begin_tx()
        if kind == "create_edge":
            tx.create_edge(op["v"], op["u"])
        else:                      # delete_edge: best-effort on a live edge
            v = w.read_vertex(op["v"])
            if v and v["edges"]:
                tx.delete_edge(op["v"], next(iter(v["edges"])))
            else:
                tx.create_edge(op["v"], op["v"] + "_x")
        return "tx", tx

    res = srv.run_closed_loop(64 if SMOKE else 192, n_requests, make)
    c = w.counters()
    assert res["completed"] == n_requests, res
    # racing deletes may abort (application-level conflict, not a serving
    # failure); sessions must never give up though
    assert res["ok"] >= 0.98 * n_requests, res
    assert c["client_gaveup"] == 0 and c["prog_gaveup"] == 0, c
    assert c["acks_deferred"] > 0, "read_your_writes never deferred an ack"
    res["latency"] = stats(res.pop("latencies_s"))
    res["acks_deferred"] = c["acks_deferred"]
    return res


# ---- section 5: observability overhead + purity ------------------------


def obs(seed: int) -> Dict:
    """Tracing is pure observation: the traced run must be
    bit-identical to the untraced one, and near-free in wall clock.
    Two untraced runs bound the run-to-run timing noise the overhead
    ratio is judged against."""
    n_users = 40 if SMOKE else 120
    n_requests = 600 if SMOKE else 4000
    n_clients = 64 if SMOKE else 192

    def run_once(rate: float):
        w, vertices = _deploy(seed, n_users, trace_sample_rate=rate,
                              **WINDOWED)
        srv = GraphQueryServer(w)
        rng = np.random.default_rng(seed + 6)
        picks = rng.integers(0, len(vertices), size=n_requests)

        def make(i, picks=picks, vertices=vertices):
            return "prog", ("get_node", [(vertices[int(picks[i])], None)])

        t0 = time.perf_counter()
        res = srv.run_closed_loop(n_clients, n_requests, make)
        wall = time.perf_counter() - t0
        assert res["completed"] == n_requests, res
        c = w.counters()
        for f in OBS_COUNTER_FIELDS:
            c.pop(f, None)
        lat = tuple(np.round(res["latencies_s"], 12).tolist())
        return w, wall, lat, c

    _, wall_a, lat_a, c_a = run_once(0.0)
    _, wall_b, lat_b, c_b = run_once(0.0)
    # armed-but-idle: the tracer exists (every hook runs its guard +
    # sampling stride) but records ~nothing — the disabled-overhead bar
    _, wall_i, lat_i, c_i = run_once(1e-9)
    w_t, wall_t, lat_t, c_t = run_once(1.0)

    assert lat_a == lat_b == lat_i == lat_t, \
        "tracing changed request latencies"
    assert c_a == c_b == c_i == c_t, "tracing changed simulator counters"

    tr = w_t.sim.tracer
    attr = attribution_table(tr)
    checks = run_invariant_checks(tr)
    base = max(min(wall_a, wall_b), 1e-9)
    noise = abs(wall_a - wall_b) / base
    out = {
        "n_requests": n_requests,
        "identical": 1,
        "wall_untraced_s": [wall_a, wall_b],
        "wall_idle_tracer_s": wall_i,
        "wall_traced_s": wall_t,
        "noise_floor": noise,
        "idle_overhead": wall_i / base - 1.0,
        "traced_overhead": wall_t / base - 1.0,
        "n_traces": len(tr.traces()),
        "n_spans": len(tr.spans),
        "attribution_max_rel_err": attr["max_rel_err"],
        "stages_ms": attr["stages"],
        "invariants_ok": int(all(not v for v in checks.values())),
    }
    assert attr["max_rel_err"] < 0.01, attr["max_rel_err"]
    assert out["invariants_ok"], checks
    print(format_stage_table(attr))
    if SMOKE:
        root = os.path.join(os.path.dirname(__file__), "..")
        doc = export_trace(w_t.sim.tracer,
                           os.path.join(root, "trace_serving_smoke.json"))
        out["trace_events"] = len(doc["traceEvents"])
    return out


def main(seed: int = 0) -> None:
    out = {
        "saturation": saturation(seed),
        "sweep": sweep(seed),
        "equivalence": equivalence(seed),
        "mixed": mixed(seed),
        "obs": obs(seed),
    }
    sat = out["saturation"]
    swp = out["sweep"]
    print(f"serving,per_program_reads_per_s,"
          f"{sat['per_program']['throughput_per_s']:.0f}")
    print(f"serving,windowed_reads_per_s,"
          f"{sat['windowed']['throughput_per_s']:.0f}")
    print(f"serving,windowed_speedup,{sat['speedup']:.2f}")
    print(f"serving,mean_window_batch,{sat['windowed']['mean_batch']:.1f}")
    print(f"serving,low_load_p99_ratio,{swp['low_load_p99_ratio']:.2f}")
    print(f"serving,goodput_flat_past_saturation,{swp['goodput_flat']:.2f}")
    print(f"serving,equivalent,{out['equivalence']['equivalent']}")
    print(f"serving,mixed_p99_ms,{out['mixed']['latency']['p99_ms']:.2f}")
    ob = out["obs"]
    print(f"serving,obs_identical,{ob['identical']}")
    print(f"serving,obs_idle_overhead,{ob['idle_overhead']:.3f}")
    print(f"serving,obs_traced_overhead,{ob['traced_overhead']:.3f}")
    print(f"serving,obs_max_rel_err,{ob['attribution_max_rel_err']:.2e}")

    assert out["equivalence"]["equivalent"] == 1, \
        "windowed reads diverged from the per-program oracle"
    if not SMOKE:
        assert sat["speedup"] >= 3.0, \
            f"windowed speedup {sat['speedup']:.2f} < 3x bar"
        assert swp["low_load_p99_ratio"] <= 1.5, \
            f"low-load p99 ratio {swp['low_load_p99_ratio']:.2f} > 1.5x bar"
        assert swp["goodput_flat"] >= 0.8, \
            f"goodput collapsed past saturation ({swp['goodput_flat']:.2f})"
        # the 3% bar applies to tracing *disabled*; the armed-but-idle
        # run is its measurable proxy, judged against the run-to-run
        # noise floor of the two untraced runs
        assert ob["idle_overhead"] <= 0.03 + 2 * ob["noise_floor"], ob
        save_result("serving", out)
        root = os.path.join(os.path.dirname(__file__), "..")
        with open(os.path.join(root, "BENCH_serving.json"), "w") as f:
            json.dump(out, f, indent=1, default=str)
        with open(os.path.join(root, "BENCH_obs.json"), "w") as f:
            json.dump(ob, f, indent=1, default=str)
    else:
        save_result("serving_smoke", out)


if __name__ == "__main__":
    main()
