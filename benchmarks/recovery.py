"""Crash recovery: WAL replay vs store-walk MTTR, goodput dip (ISSUE 6).

Three measurements:

* **MTTR vs partition size** — for growing graphs, wall-clock of the
  two recovery paths for every shard: redo-WAL replay
  (``BackingStore.recover_shard``) vs the ``vertices``-walk oracle
  (``recover_shard_walk``), each driven through the same
  ``MVGraphPartition`` rebuild a promoted backup performs.  The paths
  must produce bit-identical multi-version state (the ``equivalent``
  bit) — the WAL is a faster route to the SAME partition, not a
  different one.

* **Goodput dip** — a closed-loop write workload with a shard killed
  mid-run: the epoch barrier pauses admission, the backup replays, and
  the dip depth + time-to-new-epoch are reported.  Every client request
  still completes (bounded retry; no acked write is lost).

* **Exactly-once under the dip** — the run asserts zero client
  give-ups and zero re-execution aborts.

Full mode writes ``BENCH_recovery.json`` at the repo root; smoke mode
(``REPRO_BENCH_SMOKE``) shrinks sizes and never touches repo-root BENCH
files.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.configs import PAPER_DEPLOYMENT
from repro.core import Weaver
from repro.core.mvgraph import MVGraphPartition
from repro.core.obs import (attribution_table, format_stage_table,
                            run_invariant_checks)
from repro.data import synth

from .common import ClosedLoopDriver, load_weaver_graph, save_result

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
MTTR_SIZES = [200] if SMOKE else [300, 1000, 3000]
N_CHURN = 150 if SMOKE else 600
N_USERS = 300 if SMOKE else 1200
N_REQUESTS = 400 if SMOKE else 4000
N_CLIENTS = 32 if SMOKE else 128
BUCKET_S = 5e-3


def _fingerprint(p: MVGraphPartition) -> Dict:
    """Canonical multi-version state (mirrors tests/test_recovery.py)."""
    out = {}
    for vid, v in p.vertices.items():
        edges = tuple(sorted(
            (eid, e.dst, e.create_ts.key(),
             None if e.delete_ts is None else e.delete_ts.key(),
             tuple(sorted((k, tuple((x.value, x.ts.key()) for x in vers))
                          for k, vers in e.props.items())))
            for eid, e in v.out_edges.items()))
        props = tuple(sorted((k, tuple((x.value, x.ts.key()) for x in vers))
                             for k, vers in v.props.items()))
        out[vid] = (v.create_ts.key(),
                    None if v.delete_ts is None else v.delete_ts.key(),
                    edges, props)
    return out


def _loaded_weaver(n_users: int, seed: int) -> Weaver:
    cfg = dataclasses.replace(PAPER_DEPLOYMENT, n_gatekeepers=2, n_shards=4,
                              seed=seed)
    w = Weaver(cfg)
    rng = np.random.default_rng(seed)
    edges = synth.social_graph(rng, n_users, avg_degree=3)
    vertices = load_weaver_graph(w, edges)
    for i in range(N_CHURN):           # prop churn deepens the redo log
        tx = w.begin_tx()
        tx.set_vertex_prop(vertices[int(rng.integers(len(vertices)))],
                           "score", float(i))
        assert w.run_tx(tx).ok
    w.settle(20e-3)
    return w


def _rebuild(w: Weaver, ops: List[dict]) -> MVGraphPartition:
    p = MVGraphPartition(w.cfg.n_gatekeepers, intern=w.intern)
    for op in ops:
        p.apply_op(op, op["ts"])
    return p


def mttr_sweep(seed: int = 0) -> List[Dict]:
    """Per-size wall-clock of both recovery paths, all shards."""
    rows = []
    for n_users in MTTR_SIZES:
        w = _loaded_weaver(n_users, seed)
        wal_s = walk_s = 0.0
        n_ops = 0
        equivalent = True
        for sid in range(w.cfg.n_shards):
            t0 = time.perf_counter()
            ops = w.store.recover_shard(sid, use_wal=True)
            p_wal = _rebuild(w, ops)
            wal_s += time.perf_counter() - t0
            n_ops += len(ops)
            t0 = time.perf_counter()
            p_walk = _rebuild(w, w.store.recover_shard_walk(sid))
            walk_s += time.perf_counter() - t0
            equivalent &= _fingerprint(p_wal) == _fingerprint(p_walk)
        rows.append({
            "n_users": n_users,
            "replayed_ops": n_ops,
            "mttr_wal_ms": wal_s * 1e3,
            "mttr_walk_ms": walk_s * 1e3,
            "walk_over_wal": walk_s / max(wal_s, 1e-9),
            "equivalent": bool(equivalent),
        })
    return rows


def goodput_dip(seed: int = 1) -> Dict:
    """Closed-loop writes with a shard killed mid-run.

    Runs with ``read_your_writes=True``: tx acks wait for shard apply,
    so writes in flight to the dying shard surface in the goodput curve
    as delayed acks (recovered by retry), not as silent ack-then-lose —
    the dip this benchmark measures is the client-visible one.

    The run is fully traced (pure observation — the goodput numbers
    are unchanged); the recorded spans feed the trace-driven
    invariant checkers (completeness, exactly-once apply across the
    shard failover, stamp monotonicity) and a latency-stage
    attribution table covering the dip."""
    cfg = dataclasses.replace(PAPER_DEPLOYMENT, n_gatekeepers=2, n_shards=4,
                              seed=seed, read_your_writes=True,
                              trace_sample_rate=1.0)
    w = Weaver(cfg)
    rng = np.random.default_rng(seed)
    edges = synth.social_graph(rng, N_USERS, avg_degree=3)
    vertices = load_weaver_graph(w, edges)
    done_at: List[float] = []
    errors: List[str] = []
    epoch0 = w.manager.epoch
    rec = {"t_kill": None, "t_epoch": None}

    kill_after = (2 * N_REQUESTS) // 5   # fail mid-run, workload-scaled

    def _probe():
        if w.manager.epoch > epoch0:
            rec["t_epoch"] = w.sim.now
        else:
            w.sim.schedule(1e-3, _probe)

    def issue(cid, idx, done):
        v = vertices[int(rng.integers(len(vertices)))]
        u = vertices[int(rng.integers(len(vertices)))]
        tx = w.begin_tx()
        if idx % 4:
            tx.create_edge(v, u)
        else:
            tx.set_vertex_prop(v, "score", float(idx))

        def cb(r):
            done_at.append(w.sim.now)
            if not r.ok:
                errors.append(r.error or "")
            if len(done_at) == kill_after:
                rec["t_kill"] = w.sim.now
                w.kill("shard1")
                _probe()
            done(r.latency)
        w.submit_tx(tx, cb, gatekeeper=cid % cfg.n_gatekeepers)
    drv = ClosedLoopDriver(w.sim, N_CLIENTS, N_REQUESTS, issue)
    res = drv.run(timeout=600.0)
    w.settle(50e-3)

    t0 = done_at[0]
    buckets = np.bincount(((np.asarray(done_at) - t0) / BUCKET_S).astype(int))
    rate = buckets / BUCKET_S
    kill_b = int((rec["t_kill"] - t0) / BUCKET_S)
    baseline = float(rate[:max(kill_b, 1)].mean())
    dip = float(rate[kill_b:kill_b + 8].min()) if kill_b < len(rate) else 0.0
    tr = w.sim.tracer
    attr = attribution_table(tr)
    checks = run_invariant_checks(tr)
    print(format_stage_table(attr))
    c = w.sim.counters
    return {
        "completed": res["completed"],
        "trace": {
            "n_traces": len(tr.traces()),
            "n_spans": len(tr.spans),
            "attribution_max_rel_err": attr["max_rel_err"],
            "stages_ms": attr["stages"],
            "invariants": {k: len(v) for k, v in checks.items()},
            "invariants_ok": int(all(not v for v in checks.values())),
        },
        "n_requests": N_REQUESTS,
        "throughput_per_s": res["throughput_per_s"],
        "goodput_baseline_per_s": baseline,
        "goodput_dip_per_s": dip,
        "dip_fraction": dip / max(baseline, 1e-9),
        "recovery_ms": (rec["t_epoch"] - rec["t_kill"]) * 1e3
        if rec["t_epoch"] else None,
        "wal_replay_ops": c.wal_replay_ops,
        "client_retries": c.client_retries,
        "client_gaveup": c.client_gaveup,
        "reexec_aborts": sum("exists" in e for e in errors),
        "p99_ms": res["p99_ms"],
    }


def run(seed: int = 0) -> Dict:
    mttr = mttr_sweep(seed)
    dip = goodput_dip(seed + 1)
    equivalent = (all(r["equivalent"] for r in mttr)
                  and dip["completed"] == dip["n_requests"]
                  and dip["client_gaveup"] == 0
                  and dip["reexec_aborts"] == 0
                  and dip["recovery_ms"] is not None)
    return {
        "mttr": mttr,
        "goodput": dip,
        "equivalent": bool(equivalent),
        "paper_claim": "a failed shard is replaced by a backup replaying "
                       "the redo WAL to the stable point; acked "
                       "transactions survive, clients retry through the "
                       "epoch barrier exactly-once (§4.3)",
    }


def main() -> None:
    out = run()
    for r in out["mttr"]:
        print(f"recovery,mttr_wal_ms[{r['n_users']}],{r['mttr_wal_ms']:.1f}")
        print(f"recovery,mttr_walk_ms[{r['n_users']}],{r['mttr_walk_ms']:.1f}")
    g = out["goodput"]
    print(f"recovery,goodput_baseline_per_s,{g['goodput_baseline_per_s']:.0f}")
    print(f"recovery,goodput_dip_per_s,{g['goodput_dip_per_s']:.0f}")
    print(f"recovery,recovery_ms,{g['recovery_ms']:.1f}")
    print(f"recovery,client_gaveup,{g['client_gaveup']}")
    print(f"recovery,equivalent,{int(out['equivalent'])}")
    print(f"recovery,trace_invariants_ok,{g['trace']['invariants_ok']}")
    print(f"recovery,trace_max_rel_err,"
          f"{g['trace']['attribution_max_rel_err']:.2e}")
    assert out["equivalent"], "recovery paths diverged or a client lost a tx"
    assert g["trace"]["invariants_ok"], g["trace"]["invariants"]
    assert g["trace"]["attribution_max_rel_err"] < 0.01, g["trace"]
    if SMOKE:
        save_result("recovery_smoke", out)
        return
    with open(os.path.join(REPO_ROOT, "BENCH_recovery.json"), "w") as f:
        json.dump(out, f, indent=1)
    save_result("recovery", out)


if __name__ == "__main__":
    main()
