"""Fig. 11: traversal (reachability/BFS) latency — Weaver node programs
vs. GraphLab-style sync (barrier) and async (neighbour-locking) engines.

Sequential single-client queries (matching the paper's methodology of
matching GraphLab's execution model).  Expected shape: Weaver 4-9x lower
mean latency; higher variance than point reads because work per query
varies wildly.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs import PAPER_DEPLOYMENT
from repro.core import Weaver
from repro.core.bsp import BSPEngine
from repro.data import synth

from .common import load_weaver_graph, save_result, stats


def run(n_users: int = 1500, n_queries: int = 15, seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    edges = synth.social_graph(rng, n_users, avg_degree=10)
    vertices = sorted({v for e in edges for v in e})
    pairs = [(vertices[rng.integers(len(vertices))],
              vertices[rng.integers(len(vertices))])
             for _ in range(n_queries)]

    # --- Weaver node programs (sequential client) ---------------------------
    # latency-tuned deployment: the paper (§3.5) adapts tau to the
    # workload; a read-dominated traversal service runs with tight
    # announce/NOP cadence so the per-hop comparability wait is small
    import dataclasses as _dc
    deployment = _dc.replace(PAPER_DEPLOYMENT, tau=0.05e-3, tau_nop=0.05e-3)
    w = Weaver(deployment)
    load_weaver_graph(w, edges)
    weaver_lat: List[float] = []
    weaver_reached: List[bool] = []
    for s, t in pairs:
        res, _, lat = w.run_program("reachable", [(s, {"target": t})],
                                    timeout=60.0)
        weaver_lat.append(lat)
        weaver_reached.append(bool(res))

    # --- BSP engines ----------------------------------------------------------
    sync_lat, async_lat = [], []
    sync_reached = []
    for variant, sink in (("sync", sync_lat), ("async", async_lat)):
        eng = BSPEngine(n_workers=PAPER_DEPLOYMENT.n_shards, seed=seed)
        eng.load_graph(edges)
        for s, t in pairs:
            box = []
            if variant == "sync":
                eng.bfs_sync(s, t, box.append)
            else:
                eng.bfs_async(s, t, box.append)
            eng.sim.run(until=eng.sim.now + 120.0)
            assert box, f"{variant} bfs did not finish"
            sink.append(box[0]["latency"])
            if variant == "sync":
                sync_reached.append(bool(box[0]["reached"]))

    # correctness cross-check: Weaver agrees with BSP-sync reachability
    agree = float(np.mean([a == b for a, b
                           in zip(weaver_reached, sync_reached)]))

    out = {
        "weaver": stats(weaver_lat),
        "bsp_sync": stats(sync_lat),
        "bsp_async": stats(async_lat),
        "speedup_vs_sync": float(np.mean(sync_lat) / np.mean(weaver_lat)),
        "speedup_vs_async": float(np.mean(async_lat) / np.mean(weaver_lat)),
        "reachability_agreement": agree,
        "paper_claim": "4.3x-9.4x lower latency than GraphLab",
    }
    save_result("traversal", out)
    return out


def main() -> None:
    out = run()
    print(f"traversal,weaver_mean_ms,{out['weaver']['mean_ms']:.2f}")
    print(f"traversal,bsp_sync_mean_ms,{out['bsp_sync']['mean_ms']:.2f}")
    print(f"traversal,bsp_async_mean_ms,{out['bsp_async']['mean_ms']:.2f}")
    print(f"traversal,speedup_vs_sync,{out['speedup_vs_sync']:.2f}")
    print(f"traversal,speedup_vs_async,{out['speedup_vs_async']:.2f}")
    print(f"traversal,agreement,{out['reachability_agreement']:.2f}")


if __name__ == "__main__":
    main()
