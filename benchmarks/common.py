"""Shared benchmark machinery: graph loading, closed-loop drivers,
latency stats.  All numbers are *simulated* seconds (deterministic)."""

from __future__ import annotations

import json
import os
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "bench")


def save_result(name: str, payload: dict) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def load_weaver_graph(w, edges: List[Tuple[str, str]], chunk: int = 128):
    vertices = sorted({v for e in edges for v in e})
    for i in range(0, len(vertices), chunk):
        tx = w.begin_tx()
        for v in vertices[i:i + chunk]:
            tx.create_vertex(v)
        r = w.run_tx(tx)
        assert r.ok, r.error
    for i in range(0, len(edges), chunk):
        tx = w.begin_tx()
        for s, d in edges[i:i + chunk]:
            tx.create_edge(s, d)
        r = w.run_tx(tx)
        assert r.ok, r.error
    return vertices


def stats(latencies: List[float]) -> Dict[str, float]:
    if not latencies:
        return {"n": 0}
    a = np.asarray(latencies)
    return {
        "n": int(a.size),
        "mean_ms": float(a.mean() * 1e3),
        "p50_ms": float(np.percentile(a, 50) * 1e3),
        "p90_ms": float(np.percentile(a, 90) * 1e3),
        "p99_ms": float(np.percentile(a, 99) * 1e3),
        "max_ms": float(a.max() * 1e3),
    }


class ClosedLoopDriver:
    """N concurrent clients; each issues the next request on completion.

    ``issue(client_id, req_index, on_done)`` must submit one request and
    arrange for ``on_done(latency)`` to fire at completion.
    """

    def __init__(self, sim, n_clients: int, n_requests: int,
                 issue: Callable[[int, int, Callable], None]):
        self.sim = sim
        self.n_clients = n_clients
        self.n_requests = n_requests
        self.issue = issue
        self.completed = 0
        self.issued = 0
        self.latencies: List[float] = []
        self.t_start: Optional[float] = None
        self.t_end: Optional[float] = None

    def run(self, timeout: float = 300.0) -> Dict:
        self.t_start = self.sim.now

        def next_req(cid: int) -> None:
            if self.issued >= self.n_requests:
                return
            idx = self.issued
            self.issued += 1

            def done(latency: float) -> None:
                self.completed += 1
                self.latencies.append(latency)
                if self.completed >= self.n_requests:
                    self.t_end = self.sim.now
                    return
                next_req(cid)

            self.issue(cid, idx, done)

        for c in range(self.n_clients):
            next_req(c)
        deadline = self.sim.now + timeout
        while (self.completed < self.n_requests and self.sim.pending()
               and self.sim.now < deadline):
            self.sim.run(until=min(deadline, self.sim.now + 50e-3))
        if self.t_end is None:
            self.t_end = self.sim.now
        dt = max(self.t_end - self.t_start, 1e-9)
        return {
            "completed": self.completed,
            "duration_s": dt,
            "throughput_per_s": self.completed / dt,
            **stats(self.latencies),
        }
