"""Frontier-batched node-program microbenchmark (nodeprog runtime PR).

Wall-clock times of the two node-program execution paths at identical
stamps on a ~100k-edge synthetic social graph:

* ``scalar``   — the per-vertex interpreter (seed semantics): one Python
  callback + NodeView/EdgeView materialization per delivered vertex, one
  (dst, params) entry per emitted vertex;
* ``frontier`` — the batched path: per-shard sorted-CSR plans over the
  stamped columns, one vectorized step per hop per shard, one packed
  frontier message per destination shard per hop.

Queries: multi-hop ``traverse`` (full BFS from a seed user), bounded
``traverse`` (3 hops), ``reachable`` pairs, and weighted ``sssp`` —
driven synchronously (``frontier.run_local``) so both paths execute at
the SAME stamp and results can be compared bit-for-bit.  A **ragged**
section covers the last two ex-scalar programs: a multi-root
``get_edges`` stream (ragged per-entry edge lists + property columns,
one ``RaggedReply`` per shard step) and a ``clustering`` batch (3-phase
wedge-closing protocol), bar ≥3x over the scalar path each.  A second
section runs ``traverse`` through the full simulator (two Weaver
deployments, ``frontier_progs`` on/off) to report the simulated-time
and message/entry counters.

A third section measures **write churn**: with ~0.5% of edges mutated
between program hops (stamps after ``T_prog`` — invisible by snapshot
isolation), the delta-refreshed plans must keep the batched path fast
where forced cold rebuilds collapse it: plan maintenance ≥5x faster at
equal stamps with bit-identical results (``write_churn.*`` in the
payload), and the simulator section asserts same-(prog, stamp) delivery
coalescing keeps per-hop executions O(active shards).

Writes ``BENCH_nodeprog.json`` at the repo root (plus the usual
results/bench copy) with median seconds, speedups, entry/message
reductions, and the equivalence bit.  The acceptance bars are
``speedup.traverse_multi_hop >= 3`` (PR 2) and
``write_churn.*.plan_speedup >= 5`` with churn-run results identical to
the forced-cold baseline (PR 3).

``REPRO_BENCH_SMOKE=1`` (or ``benchmarks.run --smoke``) shrinks the
graph and iteration counts for CI.
"""

from __future__ import annotations

import json
import os
import time
from typing import List

import numpy as np

from repro.core import Weaver, WeaverConfig
from repro.core import frontier as F
from repro.core.clock import Stamp

from .common import save_result

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
N_USERS = 4_000 if SMOKE else 20_000
AVG_DEG = 5
CHURN_FRAC = 0.005        # ≤1% of edges mutated between hops


class _StampGen:
    """Totally-ordered synthetic stamps (round-robin gatekeepers)."""

    def __init__(self, n_gk: int):
        self.n_gk = n_gk
        self.clock = [0] * n_gk
        self.i = 0

    def next(self) -> Stamp:
        g = self.i % self.n_gk
        self.i += 1
        self.clock[g] += 1
        return Stamp(0, tuple(self.clock), g, self.clock[g])

    def query(self) -> Stamp:
        g = self.i % self.n_gk
        self.i += 1
        self.clock = [c + 1 for c in self.clock]
        return Stamp(0, tuple(self.clock), g, self.clock[g])


def _build(seed: int = 0):
    rng = np.random.default_rng(seed)
    from repro.data import synth
    edges = synth.social_graph(rng, N_USERS, AVG_DEG)
    w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=4, gc_period=0,
                            seed=seed))
    sg = _StampGen(w.cfg.n_gatekeepers)
    part_of = lambda vid: w.shards[w.store.place(vid)].partition
    vertices = sorted({v for e in edges for v in e})
    for v in vertices:
        part_of(v).create_vertex(v, sg.next())
    made = []
    for s, d in edges:
        e = part_of(s).create_edge(s, d, sg.next())
        # deterministic 1..4 weight so sssp exercises the prop columns
        part_of(s).set_edge_prop(s, e.eid, "weight",
                                 float(1 + (e.eid % 4)), sg.next())
        made.append((s, e.eid))
    return w, sg, vertices, made


def _median(f, iters: int) -> float:
    ts: List[float] = []
    for _ in range(iters):
        t0 = time.perf_counter()
        f()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _churner(w, sg, vertices, live_edges, frac, seed=11):
    """Per-hop mutator: deletes/creates ~frac of the edge set with
    stamps AFTER the query stamp (invisible at T_prog, but every
    mutation bumps the owning shard's column version)."""
    rng = np.random.default_rng(seed)
    part_of = lambda vid: w.shards[w.store.place(vid)].partition
    k = max(2, int(len(live_edges) * frac))

    def churn(hop):
        for _ in range(k // 2):
            s, eid = live_edges[int(rng.integers(0, len(live_edges)))]
            e = part_of(s).vertices[s].out_edges.get(eid)
            if e is not None and e.delete_ts is None:
                part_of(s).delete_edge(s, eid, sg.next())
        for _ in range(k // 2):
            a, b = rng.integers(0, len(vertices), 2)
            if a == b:
                continue
            s, d = str(vertices[a]), str(vertices[b])
            e = part_of(s).create_edge(s, d, sg.next())
            part_of(s).set_edge_prop(s, e.eid, "weight",
                                     float(1 + (e.eid % 4)), sg.next())
            live_edges.append((s, e.eid))

    return churn


def main() -> None:
    w, sg, vertices, live_edges = _build()
    n_edges = len(live_edges)
    place = lambda vid: w.store.place(vid)
    rng = np.random.default_rng(1)
    seeds = [str(v) for v in rng.choice(vertices, 8, replace=False)]
    at = sg.query()

    queries = {
        "traverse_multi_hop": ("traverse", [(seeds[0], {"depth": 0})]),
        "traverse_3hop": ("traverse",
                          [(seeds[1], {"depth": 0, "max_depth": 3})]),
        "reachable": ("reachable", [(seeds[2], {"target": seeds[3]})]),
        "sssp": ("sssp", [(seeds[4], {"target": seeds[5],
                                      "max_depth": 32})]),
    }

    seconds: dict = {"scalar": {}, "frontier": {}}
    msgstats: dict = {"scalar": {}, "frontier": {}}
    equivalent = True
    for qname, (prog, entries) in queries.items():
        results = {}
        for mode, flag in (("frontier", True), ("scalar", False)):
            run = lambda: F.run_local(w, prog, entries, at,
                                      use_frontier=flag, shard_of=place,
                                      persistent_plans=False)
            r, st = run()
            results[mode] = r
            msgstats[mode][qname] = st
            # scalar multi-hop BFS over 100k edges is slow: time it once,
            # batched path gets proper medians
            seconds[mode][qname] = _median(run, 3 if flag else 1)
        equivalent &= results["frontier"] == results["scalar"]

    speedup = {q: seconds["scalar"][q] / seconds["frontier"][q]
               for q in queries}
    entry_reduction = {
        q: msgstats["scalar"][q]["entries"]
        / max(1, msgstats["frontier"][q]["entries"])
        for q in queries}

    # ---- ragged programs: the last two ex-scalar node programs ----------
    # get_edges returns ragged per-entry edge lists (one RaggedReply per
    # shard step, CSR gather + property columns), clustering runs the
    # 3-phase wedge-closing protocol (packed neighbour lists + vectorized
    # sorted intersection).  Multi-root streams — the TAO read mix is
    # 59% get_edges — with order-insensitive reductions so the
    # equivalence bit compares the FULL result multiset, not just the
    # first-completed root.
    from repro.core.nodeprog import REGISTRY, _edge_lists
    ge_roots = [str(v) for v in rng.choice(vertices, 500 if SMOKE else 3000,
                                           replace=False)]
    cl_roots = [str(v) for v in rng.choice(vertices, 300 if SMOKE else 2000,
                                           replace=False)]
    ragged_queries = {
        "get_edges_stream": (
            "get_edges", [(v, {"props": ("weight",)}) for v in ge_roots],
            lambda xs: sorted(map(sorted, _edge_lists(xs)))),
        "clustering_batch": (
            "clustering", [(v, {"phase": 0}) for v in cl_roots],
            lambda xs: sorted(xs)),
    }
    ragged: dict = {}
    for qname, (prog, entries, canon) in ragged_queries.items():
        old_reduce = REGISTRY[prog].reduce
        REGISTRY[prog].reduce = canon
        try:
            r_s, st_s = F.run_local(w, prog, entries, at,
                                    use_frontier=False, shard_of=place)
            msgstats["scalar"][qname] = st_s
            sec_scalar = _median(
                lambda: F.run_local(w, prog, entries, at,
                                    use_frontier=False, shard_of=place), 1)
            # cold: every call pays the per-shard plan builds
            r_f, st_f = F.run_local(w, prog, entries, at,
                                    use_frontier=True, shard_of=place,
                                    persistent_plans=False)
            msgstats["frontier"][qname] = st_f
            sec_cold = _median(
                lambda: F.run_local(w, prog, entries, at,
                                    use_frontier=True, shard_of=place,
                                    persistent_plans=False), 3)
            # warm: the deployed hot path — the shard's stamp-keyed plan
            # LRU keeps settled plans alive across queries, so a read
            # STREAM reuses them (plan_cold == 0 per call after warmup)
            shared: dict = {}
            F.run_local(w, prog, entries, at, use_frontier=True,
                        shard_of=place, plans=shared)
            sec_warm = _median(
                lambda: F.run_local(w, prog, entries, at,
                                    use_frontier=True, shard_of=place,
                                    plans=shared), 3)
            _, st_warm = F.run_local(w, prog, entries, at,
                                     use_frontier=True, shard_of=place,
                                     plans=shared)
            assert st_warm["plan_cold"] == 0, "warm stream rebuilt plans"
        finally:
            REGISTRY[prog].reduce = old_reduce
        identical = r_f == r_s
        equivalent &= identical
        seconds["scalar"][qname] = sec_scalar
        seconds["frontier"][qname] = sec_warm
        speedup[qname] = sec_scalar / sec_warm
        entry_reduction[qname] = (
            st_s["entries"] / max(1, st_f["entries"]))
        ragged[qname] = {
            "n_roots": len(entries),
            "seconds": {"scalar": sec_scalar, "frontier_cold": sec_cold,
                        "frontier_warm": sec_warm},
            "speedup": speedup[qname],
            "speedup_cold": sec_scalar / sec_cold,
            "plan_seconds_cold": st_f["plan_seconds"],
            "entry_reduction": entry_reduction[qname],
            "identical": bool(identical),
        }

    # ---- write churn: delta-refreshed plans vs forced cold rebuilds ------
    # ~0.5% of edges mutated between EVERY hop (stamps after the query
    # stamp), so each hop finds every shard's columns.version moved.
    # plan_delta=True patches the plans in place; plan_delta=False is
    # PR 2's behaviour — a cold rebuild per shard per hop.  Results must
    # be bit-identical (snapshot isolation at the fixed query stamp).
    # both rooted at seeds[0] — verified multi-hop by the section above
    # (other seeds may have 0 out-degree on the smoke-sized graph)
    churn_queries = {
        "traverse_multi_hop": ("traverse", [(seeds[0], {"depth": 0})]),
        "sssp": ("sssp", [(seeds[0], {"target": seeds[3],
                                      "max_depth": 32})]),
    }
    at2 = sg.query()
    write_churn: dict = {"frac": CHURN_FRAC}
    churn_ok = True
    iters = 2 if SMOKE else 3
    for qname, (prog, entries) in churn_queries.items():
        acc = {m: {"walls": [], "plans": [], "steady": [], "last": None}
               for m in ("delta", "cold")}
        # modes INTERLEAVED (order alternating per iteration): every
        # run's churn permanently grows the graph, so running one mode's
        # iterations first would hand the other a larger edge set and
        # bias the ratio
        for it in range(iters):
            order = [("delta", True), ("cold", False)]
            if it % 2:
                order.reverse()
            for mode, delta in order:
                churn = _churner(w, sg, vertices, live_edges, CHURN_FRAC,
                                 seed=17 * (it + 1) + (0 if delta else 7))
                t0 = time.perf_counter()
                r, st = F.run_local(w, prog, entries, at2,
                                    use_frontier=True, shard_of=place,
                                    on_hop=churn, plan_delta=delta,
                                    persistent_plans=False)
                a = acc[mode]
                a["walls"].append(time.perf_counter() - t0)
                a["plans"].append(st["plan_seconds"])
                # hop 1 = the initial per-shard builds, identical work
                # in both modes; hops 2+ isolate refresh-vs-rebuild
                a["steady"].append(sum(st["plan_seconds_by_hop"][1:]))
                a["last"] = (r, st)
        res = {}
        for mode, a in acc.items():
            r, st = a["last"]
            res[mode] = {
                "seconds": float(np.median(a["walls"])),
                "plan_seconds": float(np.median(a["plans"])),
                "plan_seconds_steady": float(np.median(a["steady"])),
                "plan_cold": st["plan_cold"],
                "plan_delta": st["plan_delta"],
                "plan_rows": st["plan_rows"],
                "hops": st["hops"],
                "result": r,
            }
        identical = res["delta"]["result"] == res["cold"]["result"]
        churn_ok &= identical
        # the patch-consumption counter proves refreshes were delta
        churn_ok &= res["delta"]["plan_delta"] > 0
        churn_ok &= res["delta"]["plan_cold"] <= len(w.shards)
        plan_speedup = (res["cold"]["plan_seconds_steady"]
                        / max(res["delta"]["plan_seconds_steady"], 1e-9))
        query_speedup = (res["cold"]["seconds"]
                         / max(res["delta"]["seconds"], 1e-9))
        for mode in res:
            res[mode].pop("result")
        write_churn[qname] = {
            **res,
            "plan_speedup": plan_speedup,
            "query_speedup": query_speedup,
            "identical": bool(identical),
        }

    # ---- through the simulator: counters + simulated latency ------------
    def sim_side(frontier_on: bool, n_shards: int = 4, n: int = 400,
                 m: int = 2400, coalesce: bool = True):
        ww = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=n_shards,
                                 seed=3, frontier_progs=frontier_on,
                                 frontier_coalesce=coalesce))
        rng2 = np.random.default_rng(7)
        tx = ww.begin_tx()
        for i in range(n):
            tx.create_vertex(f"s{i}")
        seen = set()
        for _ in range(m):
            a, b = rng2.integers(0, n, 2)
            if a != b and (a, b) not in seen:
                seen.add((a, b))
                tx.create_edge(f"s{a}", f"s{b}")
        assert ww.run_tx(tx).ok
        t0 = time.perf_counter()
        res, _, lat = ww.run_program("traverse", [("s0", {"depth": 0})],
                                     timeout=120.0)
        wall = time.perf_counter() - t0
        c = ww.counters()
        return {
            "result_size": len(res),
            "sim_latency_ms": lat * 1e3,
            "wall_s": wall,
            "frontier_batches": c["frontier_batches"],
            "frontier_coalesced": c["frontier_coalesced"],
            "scalar_deliveries": c["scalar_deliveries"],
            "entries_delivered": c["prog_entries_delivered"],
            "shard_hops": c["shard_hops"],
            "plan_cold_builds": c["plan_cold_builds"],
        }

    sim_frontier = sim_side(True)
    sim_scalar = sim_side(False)
    equivalent &= sim_frontier["result_size"] == sim_scalar["result_size"]

    # ---- coalescing: many source shards per hop, executions O(shards) ---
    co_shards = 8
    sim_co_on = sim_side(True, n_shards=co_shards, coalesce=True)
    sim_co_off = sim_side(True, n_shards=co_shards, coalesce=False)
    coalesce_ok = (sim_co_on["result_size"] == sim_co_off["result_size"]
                   and sim_co_on["frontier_coalesced"] > 0
                   and sim_co_on["frontier_batches"]
                   < sim_co_off["frontier_batches"])

    payload = {
        "graph": {"n_vertices": len(vertices), "n_edges": n_edges},
        "seconds": seconds,
        "speedup": speedup,
        "entry_reduction": entry_reduction,
        "ragged": ragged,
        "messages": msgstats,
        "write_churn": write_churn,
        "simulator": {"frontier": sim_frontier, "scalar": sim_scalar,
                      "coalesce_on": sim_co_on, "coalesce_off": sim_co_off},
        "equivalent": bool(equivalent),
        "churn_identical": bool(churn_ok),
        "coalesce_ok": bool(coalesce_ok),
        "smoke": SMOKE,
    }
    for q, s in speedup.items():
        print(f"nodeprog,speedup_{q},{s:.2f}")
    for q, r in entry_reduction.items():
        print(f"nodeprog,entry_reduction_{q},{r:.2f}")
    for q in churn_queries:
        print(f"nodeprog,churn_plan_speedup_{q},"
              f"{write_churn[q]['plan_speedup']:.2f}")
        print(f"nodeprog,churn_query_speedup_{q},"
              f"{write_churn[q]['query_speedup']:.2f}")
    print(f"nodeprog,sim_entries_frontier,"
          f"{sim_frontier['entries_delivered']}")
    print(f"nodeprog,sim_entries_scalar,{sim_scalar['entries_delivered']}")
    print(f"nodeprog,coalesced_executions_saved,"
          f"{sim_co_off['frontier_batches'] - sim_co_on['frontier_batches']}")
    print(f"nodeprog,equivalent,{int(equivalent)}")
    if SMOKE:        # CI: keep the full-run numbers at the repo root
        save_result("nodeprog_smoke", payload)
    else:
        with open(os.path.join(REPO_ROOT, "BENCH_nodeprog.json"), "w") as f:
            json.dump(payload, f, indent=1)
        save_result("nodeprog", payload)
    if not equivalent:
        raise AssertionError("frontier/scalar results diverged")
    if not churn_ok:
        raise AssertionError("write-churn delta/cold results diverged "
                             "or plans did not delta-refresh")
    min_plan_speedup = min(write_churn[q]["plan_speedup"]
                           for q in churn_queries)
    if not SMOKE and min_plan_speedup < 5.0:
        raise AssertionError(
            f"plan delta refresh only {min_plan_speedup:.1f}x over forced "
            "cold rebuild (bar: 5x)")
    min_ragged = min(r["speedup"] for r in ragged.values())
    if not SMOKE and min_ragged < 3.0:
        raise AssertionError(
            f"ragged program speedup only {min_ragged:.1f}x over the "
            "scalar path (bar: 3x for get_edges/clustering)")
    if not coalesce_ok:
        raise AssertionError("frontier coalescing ineffective")


if __name__ == "__main__":
    main()
