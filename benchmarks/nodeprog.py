"""Frontier-batched node-program microbenchmark (nodeprog runtime PR).

Wall-clock times of the two node-program execution paths at identical
stamps on a ~100k-edge synthetic social graph:

* ``scalar``   — the per-vertex interpreter (seed semantics): one Python
  callback + NodeView/EdgeView materialization per delivered vertex, one
  (dst, params) entry per emitted vertex;
* ``frontier`` — the batched path: per-shard sorted-CSR plans over the
  stamped columns, one vectorized step per hop per shard, one packed
  frontier message per destination shard per hop.

Queries: multi-hop ``traverse`` (full BFS from a seed user), bounded
``traverse`` (3 hops), ``reachable`` pairs, and weighted ``sssp`` —
driven synchronously (``frontier.run_local``) so both paths execute at
the SAME stamp and results can be compared bit-for-bit.  A second
section runs ``traverse`` through the full simulator (two Weaver
deployments, ``frontier_progs`` on/off) to report the simulated-time
and message/entry counters.

Writes ``BENCH_nodeprog.json`` at the repo root (plus the usual
results/bench copy) with median seconds, speedups, entry/message
reductions, and the equivalence bit.  The acceptance bar for this PR is
``speedup.traverse_multi_hop >= 3``.
"""

from __future__ import annotations

import json
import os
import time
from typing import List

import numpy as np

from repro.core import Weaver, WeaverConfig
from repro.core import frontier as F
from repro.core.clock import Stamp

from .common import save_result

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

N_USERS = 20_000
AVG_DEG = 5


class _StampGen:
    """Totally-ordered synthetic stamps (round-robin gatekeepers)."""

    def __init__(self, n_gk: int):
        self.n_gk = n_gk
        self.clock = [0] * n_gk
        self.i = 0

    def next(self) -> Stamp:
        g = self.i % self.n_gk
        self.i += 1
        self.clock[g] += 1
        return Stamp(0, tuple(self.clock), g, self.clock[g])

    def query(self) -> Stamp:
        g = self.i % self.n_gk
        self.i += 1
        self.clock = [c + 1 for c in self.clock]
        return Stamp(0, tuple(self.clock), g, self.clock[g])


def _build(seed: int = 0):
    rng = np.random.default_rng(seed)
    from repro.data import synth
    edges = synth.social_graph(rng, N_USERS, AVG_DEG)
    w = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=4, gc_period=0,
                            seed=seed))
    sg = _StampGen(w.cfg.n_gatekeepers)
    part_of = lambda vid: w.shards[w.store.place(vid)].partition
    vertices = sorted({v for e in edges for v in e})
    for v in vertices:
        part_of(v).create_vertex(v, sg.next())
    for s, d in edges:
        e = part_of(s).create_edge(s, d, sg.next())
        # deterministic 1..4 weight so sssp exercises the prop columns
        part_of(s).set_edge_prop(s, e.eid, "weight",
                                 float(1 + (e.eid % 4)), sg.next())
    return w, sg, vertices, len(edges)


def _median(f, iters: int) -> float:
    ts: List[float] = []
    for _ in range(iters):
        t0 = time.perf_counter()
        f()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def main() -> None:
    w, sg, vertices, n_edges = _build()
    place = lambda vid: w.store.place(vid)
    rng = np.random.default_rng(1)
    seeds = [str(v) for v in rng.choice(vertices, 8, replace=False)]
    at = sg.query()

    queries = {
        "traverse_multi_hop": ("traverse", [(seeds[0], {"depth": 0})]),
        "traverse_3hop": ("traverse",
                          [(seeds[1], {"depth": 0, "max_depth": 3})]),
        "reachable": ("reachable", [(seeds[2], {"target": seeds[3]})]),
        "sssp": ("sssp", [(seeds[4], {"target": seeds[5],
                                      "max_depth": 32})]),
    }

    seconds: dict = {"scalar": {}, "frontier": {}}
    msgstats: dict = {"scalar": {}, "frontier": {}}
    equivalent = True
    for qname, (prog, entries) in queries.items():
        results = {}
        for mode, flag in (("frontier", True), ("scalar", False)):
            run = lambda: F.run_local(w, prog, entries, at,
                                      use_frontier=flag, shard_of=place)
            r, st = run()
            results[mode] = r
            msgstats[mode][qname] = st
            # scalar multi-hop BFS over 100k edges is slow: time it once,
            # batched path gets proper medians
            seconds[mode][qname] = _median(run, 3 if flag else 1)
        equivalent &= results["frontier"] == results["scalar"]

    speedup = {q: seconds["scalar"][q] / seconds["frontier"][q]
               for q in queries}
    entry_reduction = {
        q: msgstats["scalar"][q]["entries"]
        / max(1, msgstats["frontier"][q]["entries"])
        for q in queries}

    # ---- through the simulator: counters + simulated latency ------------
    def sim_side(frontier_on: bool):
        ww = Weaver(WeaverConfig(n_gatekeepers=2, n_shards=4, seed=3,
                                 frontier_progs=frontier_on))
        rng2 = np.random.default_rng(7)
        tx = ww.begin_tx()
        for i in range(400):
            tx.create_vertex(f"s{i}")
        seen = set()
        for _ in range(2400):
            a, b = rng2.integers(0, 400, 2)
            if a != b and (a, b) not in seen:
                seen.add((a, b))
                tx.create_edge(f"s{a}", f"s{b}")
        assert ww.run_tx(tx).ok
        t0 = time.perf_counter()
        res, _, lat = ww.run_program("traverse", [("s0", {"depth": 0})],
                                     timeout=120.0)
        wall = time.perf_counter() - t0
        c = ww.counters()
        return {
            "result_size": len(res),
            "sim_latency_ms": lat * 1e3,
            "wall_s": wall,
            "frontier_batches": c["frontier_batches"],
            "scalar_deliveries": c["scalar_deliveries"],
            "entries_delivered": c["prog_entries_delivered"],
            "shard_hops": c["shard_hops"],
        }

    sim_frontier = sim_side(True)
    sim_scalar = sim_side(False)
    equivalent &= sim_frontier["result_size"] == sim_scalar["result_size"]

    payload = {
        "graph": {"n_vertices": len(vertices), "n_edges": n_edges},
        "seconds": seconds,
        "speedup": speedup,
        "entry_reduction": entry_reduction,
        "messages": msgstats,
        "simulator": {"frontier": sim_frontier, "scalar": sim_scalar},
        "equivalent": bool(equivalent),
    }
    for q, s in speedup.items():
        print(f"nodeprog,speedup_{q},{s:.2f}")
    for q, r in entry_reduction.items():
        print(f"nodeprog,entry_reduction_{q},{r:.2f}")
    print(f"nodeprog,sim_entries_frontier,"
          f"{sim_frontier['entries_delivered']}")
    print(f"nodeprog,sim_entries_scalar,{sim_scalar['entries_delivered']}")
    print(f"nodeprog,equivalent,{int(equivalent)}")
    with open(os.path.join(REPO_ROOT, "BENCH_nodeprog.json"), "w") as f:
        json.dump(payload, f, indent=1)
    save_result("nodeprog", payload)
    if not equivalent:
        raise AssertionError("frontier/scalar results diverged")


if __name__ == "__main__":
    main()
