"""Fig. 14: coordination overhead vs. the clock-sync period tau.

Counts gatekeeper announce messages and timeline-oracle calls, normalized
per query, across a tau sweep on a fixed concurrent write workload.
Expected U-shape: small tau -> announce cost dominates; large tau ->
concurrent stamps inflate oracle calls; the sweet spot sits between.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict

import numpy as np

from repro.configs import PAPER_DEPLOYMENT
from repro.core import Weaver
from repro.data import synth

from .common import ClosedLoopDriver, load_weaver_graph, save_result

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))


def run_one(tau: float, n_users: int, n_requests: int, n_clients: int,
            seed: int) -> Dict:
    cfg = dataclasses.replace(PAPER_DEPLOYMENT, tau=tau,
                              n_gatekeepers=4, n_shards=4, seed=seed)
    w = Weaver(cfg)
    rng = np.random.default_rng(seed)
    edges = synth.social_graph(rng, n_users, avg_degree=5)
    vertices = load_weaver_graph(w, edges)
    base = w.counters()

    def issue(cid, idx, done):
        # write-heavy mix to stress ordering (50/50)
        v = vertices[int(rng.integers(len(vertices)))]
        if idx % 2 == 0:
            u = vertices[int(rng.integers(len(vertices)))]
            tx = w.begin_tx()
            tx.create_edge(v, u)
            w.submit_tx(tx, lambda r: done(r.latency),
                        gatekeeper=cid % cfg.n_gatekeepers)
        else:
            t0 = w.sim.now
            w.submit_program("get_node", [(v, None)],
                             lambda r, s, l: done(w.sim.now - t0),
                             gatekeeper=cid % cfg.n_gatekeepers)

    drv = ClosedLoopDriver(w.sim, n_clients, n_requests, issue)
    res = drv.run(timeout=600.0)
    c = w.counters()
    announce = c["announce_messages"] - base["announce_messages"]
    oracle = c["oracle_calls"] - base["oracle_calls"]
    return {
        "tau_ms": tau * 1e3,
        "completed": res["completed"],
        "n_requests": n_requests,
        "announce_per_query": announce / max(res["completed"], 1),
        "oracle_per_query": oracle / max(res["completed"], 1),
        "total_coord_per_query": (announce + oracle)
        / max(res["completed"], 1),
        "throughput": res["throughput_per_s"],
    }


def run(n_users: int = 150, n_requests: int = 800, n_clients: int = 24,
        seed: int = 0) -> Dict:
    taus = [0.05e-3, 0.2e-3, 1e-3, 5e-3, 20e-3, 100e-3]
    if SMOKE:
        # keep both extremes — the large-tau corner is the historical
        # order_events CycleError regression (heavy same-epoch
        # concurrency) — but shrink the load to CI scale
        taus = [0.05e-3, 1e-3, 100e-3]
        n_users, n_requests, n_clients = 80, 240, 12
    rows = [run_one(t, n_users, n_requests, n_clients, seed)
            for t in taus]
    # U-shape check: total coordination cost at extremes > at the best mid
    best = min(rows, key=lambda r: r["total_coord_per_query"])
    out = {
        "rows": rows,
        "best_tau_ms": best["tau_ms"],
        "ushape": (rows[0]["total_coord_per_query"]
                   > best["total_coord_per_query"]
                   and rows[-1]["total_coord_per_query"]
                   > best["total_coord_per_query"]),
        "announce_monotone_down": all(
            rows[i]["announce_per_query"] >= rows[i + 1]["announce_per_query"]
            - 1e-9 for i in range(len(rows) - 1)),
        "oracle_monotone_up": rows[-1]["oracle_per_query"]
        >= rows[0]["oracle_per_query"],
        "paper_claim": "announce cost falls with tau, oracle cost rises; "
                       "intermediate tau is the sweet spot (Fig. 14)",
    }
    save_result("coordination_smoke" if SMOKE else "coordination", out)
    return out


def main() -> None:
    out = run()
    for r in out["rows"]:
        print(f"coordination,tau{r['tau_ms']:g}ms_announce,"
              f"{r['announce_per_query']:.3f}")
        print(f"coordination,tau{r['tau_ms']:g}ms_oracle,"
              f"{r['oracle_per_query']:.3f}")
    print(f"coordination,best_tau_ms,{out['best_tau_ms']:g}")
    print(f"coordination,ushape,{int(out['ushape'])}")
    # the enforced regression bit (CI smoke): every tau corner — the
    # aggressive large-tau one included (historical oracle CycleError) —
    # must drain its whole closed loop, not merely avoid crashing
    # (the U-shape itself is scale-dependent and stays report-only)
    for r in out["rows"]:
        assert r["completed"] == r["n_requests"], \
            f"tau={r['tau_ms']}ms stalled at {r['completed']}/{r['n_requests']}"


if __name__ == "__main__":
    main()
