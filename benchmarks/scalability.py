"""Fig. 12 + Fig. 13: throughput scaling with gatekeepers and shards.

Fig. 12: vertex-local reads (get_node) bottleneck on gatekeepers ->
throughput should scale ~linearly in #gatekeepers at fixed shards.
Fig. 13: local-clustering-coefficient node programs bottleneck on shard
work -> throughput should scale ~linearly in #shards at fixed
gatekeepers.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.configs import PAPER_DEPLOYMENT
from repro.core import Weaver
from repro.data import synth

from .common import ClosedLoopDriver, load_weaver_graph, save_result


def _boot(n_gk: int, n_shards: int, n_users: int, seed: int,
          avg_degree: int = 5, dense_users: int = 0):
    cfg = dataclasses.replace(PAPER_DEPLOYMENT, n_gatekeepers=n_gk,
                              n_shards=n_shards, seed=seed)
    w = Weaver(cfg)
    rng = np.random.default_rng(seed)
    edges = synth.social_graph(rng, dense_users or n_users,
                               avg_degree=avg_degree)
    vertices = load_weaver_graph(w, edges)
    return w, vertices, rng


def _throughput(w, vertices, rng, prog: str, n_requests: int,
                n_clients: int) -> float:
    def issue(cid, idx, done):
        v = vertices[int(rng.integers(len(vertices)))]
        t0 = w.sim.now
        w.submit_program(prog, [(v, {"phase": 0} if prog == "clustering"
                                 else None)],
                         lambda r, s, l: done(w.sim.now - t0))

    drv = ClosedLoopDriver(w.sim, n_clients, n_requests, issue)
    res = drv.run(timeout=600.0)
    return res["throughput_per_s"]


def run(n_users: int = 200, n_requests: int = 2500, n_clients: int = 256,
        seed: int = 0) -> Dict:
    # Fig. 12: vertex-local reads, gatekeeper-CPU-bound (many clients)
    gk_scaling = []
    for n_gk in (1, 2, 4, 6):
        w, vertices, rng = _boot(n_gk, 4, n_users, seed)
        tput = _throughput(w, vertices, rng, "get_node", n_requests,
                           n_clients)
        gk_scaling.append({"n_gatekeepers": n_gk, "throughput": tput})

    # Fig. 13: 1-hop clustering coefficient, shard-CPU-bound (denser graph)
    shard_scaling = []
    for n_sh in (2, 4, 8):
        w, vertices, rng = _boot(3, n_sh, n_users, seed,
                                 avg_degree=20, dense_users=500)
        tput = _throughput(w, vertices, rng, "clustering",
                           n_requests // 3, n_clients)
        shard_scaling.append({"n_shards": n_sh, "throughput": tput})

    def ratio(rows, key):
        return rows[-1]["throughput"] / max(rows[0]["throughput"], 1e-9)

    out = {
        "gatekeeper_scaling": gk_scaling,
        "shard_scaling": shard_scaling,
        "gk_speedup_1_to_6": ratio(gk_scaling, "n_gatekeepers"),
        "shard_speedup_2_to_8": ratio(shard_scaling, "n_shards"),
        "paper_claim": "linear scaling in both dimensions (Figs 12-13)",
    }
    save_result("scalability", out)
    return out


def main() -> None:
    out = run()
    for row in out["gatekeeper_scaling"]:
        print(f"scalability,gk{row['n_gatekeepers']}_tput,"
              f"{row['throughput']:.0f}")
    for row in out["shard_scaling"]:
        print(f"scalability,shard{row['n_shards']}_tput,"
              f"{row['throughput']:.0f}")
    print(f"scalability,gk_speedup_1to6,{out['gk_speedup_1_to_6']:.2f}")
    print(f"scalability,shard_speedup_2to8,"
          f"{out['shard_speedup_2_to_8']:.2f}")


if __name__ == "__main__":
    main()
