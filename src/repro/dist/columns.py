"""Device-sharded columnar data plane: per-shard stamp blocks resident
per mesh device, visibility evaluated with ONE ``shard_map`` launch.

The host-global engines (``core.analytics.SnapshotEngine``,
``core.frontier.ShardPlan``) funnel every stamp comparison through
``clock._np_before`` on concatenated host arrays — one host's memory
bandwidth bounds snapshot assembly and plan builds.  This plane keeps a
*committed* copy of every partition's packed stamp tables on a fixed
mesh device and answers "row ≺ q" for ALL shards with a single sharded
kernel launch; only the boolean masks travel back to the host, and the
concurrent residue still takes the engines' existing single batched
oracle trip (refinement patches the host masks in place — broadcast,
not gathered).

Layout invariants (docs/ARCHITECTURE.md "Device-sharded columnar data
plane"):

* each shard owns one block ``(TABLES=4, B, C)`` — v_create, v_delete,
  e_create, e_delete stacked under ONE uniform capacity ``B`` (next
  pow2 of the largest table + slack) so the whole deployment is a
  dense ``(S_pad, 4, B, C)`` array and the launch needs no raggedness;
* devices own contiguous block ranges (device ``d`` holds blocks
  ``[d*spd, (d+1)*spd)``), matching ``NamedSharding(mesh, P("data"))``
  over axis 0;
* unused rows/blocks are ``NO_STAMP`` — ``_before`` maps them to False,
  so padding never flips a mask;
* maintenance follows the partitions' ``cursor()``/``CompactionEvent``
  contract: appends and in-place stamp patches become per-device row
  scatters (O(changed) per device, scatter index vectors padded to a
  pow2 bucket by repeating the last (idx, row) pair — duplicate
  scatters of an identical value are deterministic and bucketing bounds
  XLA specializations); a compaction remap re-uploads that shard's
  block (same O(live) cost class as the host engines' remap).

CPU vs accelerator: the block kernel is ``clock._jnp_before`` (pure
int32 jnp, bit-identical to ``_np_before``) on CPU and the Pallas
``before`` kernel (``kernels.mv_visibility``) off-CPU.  The host-global
path stays the equivalence oracle — see ``WeaverConfig.device_shard_columns``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

TABLES = 4            # v_create, v_delete, e_create, e_delete
_MIN_BLOCK = 64


def _pow2(n: int) -> int:
    return 1 << max(0, int(n - 1)).bit_length() if n > 1 else 1


class DeviceColumnPlane:
    """Device-resident stamp blocks + one sharded visibility launch.

    One plane per deployment (``Weaver.device_plane``); both the
    snapshot engine and per-shard plan builds feed from it.  Blocks are
    keyed by column-table identity (``id(cols)`` with a strong ref), so
    dead shards, promoted backups and engine rebuilds never confuse
    block assignment.
    """

    def __init__(self, n_gk: int, mesh=None, min_block: int = _MIN_BLOCK):
        import jax

        self.n_gk = n_gk
        self.c = n_gk + 1
        if mesh is None:
            from ..launch.mesh import make_columns_mesh
            mesh = make_columns_mesh()
        self.mesh = mesh
        self.n_dev = int(np.prod(list(mesh.shape.values())))
        self._devices = list(mesh.devices.flat)
        self.min_block = min_block
        self._idx: Dict[int, int] = {}     # id(cols) -> block index
        self._cols: List[object] = []      # block index -> cols (strong ref)
        self._consumed: List[Optional[List[int]]] = []  # per-block cursor
        self._cap = 0                      # B: rows per (table, block)
        self._spd = 0                      # blocks (shards) per device
        self._dev: List[object] = []       # per-device (spd, 4, B, C) arrays
        self._masks: Optional[np.ndarray] = None   # (S_pad, 4, B) bool
        self._masks_q: Optional[bytes] = None
        self._launch = None
        self.stats = {"rebuilds": 0, "row_updates": 0, "block_uploads": 0,
                      "launches": 0}

    # ---- residency maintenance -------------------------------------------

    def sync(self, shard_cols: Sequence) -> None:
        """Bring resident blocks up to date with each partition's change
        feed.  O(changed) per device for appends/patches; a compaction
        (or an unseen partition / capacity overflow) re-uploads or
        rebuilds."""
        live = [c for c in shard_cols if c is not None]
        need = self._cap
        fresh = not self._dev
        for cols in live:
            bi = self._idx.get(id(cols))
            if bi is None or self._cols[bi] is not cols:
                fresh = True
            need = max(need, cols.n_v, cols.n_e)
        if fresh or need > self._cap:
            for cols in live:
                bi = self._idx.get(id(cols))
                if bi is None or self._cols[bi] is not cols:
                    self._idx[id(cols)] = len(self._cols)
                    self._cols.append(cols)
                    self._consumed.append(None)
            self._rebuild(need)
            return
        for cols in live:
            self._sync_one(self._idx[id(cols)], cols)

    def _rebuild(self, need_rows: int) -> None:
        import jax

        s = len(self._cols)
        self._spd = max(1, -(-s // self.n_dev))
        self._cap = _pow2(max(self.min_block, need_rows + need_rows // 4))
        self._dev = []
        for d in range(self.n_dev):
            host = np.full((self._spd, TABLES, self._cap, self.c),
                           np.iinfo(np.int32).max, np.int32)
            for j in range(self._spd):
                bi = d * self._spd + j
                if bi < s:
                    self._fill_block(host[j], self._cols[bi])
            self._dev.append(jax.device_put(host, self._devices[d]))
        for bi, cols in enumerate(self._cols):
            self._consumed[bi] = cols.cursor()
        self._masks = None
        self._launch = None                 # shapes changed
        self.stats["rebuilds"] += 1

    @staticmethod
    def _fill_block(block: np.ndarray, cols) -> None:
        nv, ne = cols.n_v, cols.n_e
        if nv:
            block[0, :nv] = cols.v_create.view()
            block[1, :nv] = cols.v_delete.view()
        if ne:
            block[2, :ne] = cols.e_create.view()
            block[3, :ne] = cols.e_delete.view()

    def _sync_one(self, bi: int, cols) -> None:
        cur = self._consumed[bi]
        tgt = cols.cursor()
        if cur == tgt:
            return
        if cur is None or tgt[4] != cur[4]:
            # compaction remap (or never-synced block): the slot space
            # changed wholesale — re-upload this shard's live rows
            self._upload_block(bi, cols)
            self._consumed[bi] = tgt
            return
        nv0, ne0, lv0, le0, _ = cur
        ups: List[Tuple[int, np.ndarray, np.ndarray]] = []
        for t0, n0, n1, patch, p0, cview, dview in (
                (0, nv0, cols.n_v, cols.v_patch, lv0,
                 cols.v_create.view(), cols.v_delete.view()),
                (2, ne0, cols.n_e, cols.e_patch, le0,
                 cols.e_create.view(), cols.e_delete.view())):
            slots = {s for s in patch[p0:] if s < n0}
            if n1 > n0:
                slots.update(range(n0, n1))
            if not slots:
                continue
            idx = np.fromiter(sorted(slots), np.int64, len(slots))
            ups.append((t0, idx, np.ascontiguousarray(cview[idx])))
            ups.append((t0 + 1, idx, np.ascontiguousarray(dview[idx])))
        if ups:
            self._scatter(bi, ups)
        self._consumed[bi] = tgt
        self._masks = None

    def _scatter(self, bi: int,
                 ups: List[Tuple[int, np.ndarray, np.ndarray]]) -> None:
        import jax.numpy as jnp

        d, j = divmod(bi, self._spd)
        arr = self._dev[d]
        for t, idx, rows in ups:
            m = idx.size
            mp = _pow2(m)
            if mp != m:          # pad to a pow2 bucket (dup-scatter safe)
                idx = np.concatenate([idx, np.full(mp - m, idx[-1],
                                                   np.int64)])
                rows = np.concatenate(
                    [rows, np.repeat(rows[-1:], mp - m, axis=0)])
            arr = arr.at[j, t, jnp.asarray(idx), :].set(jnp.asarray(rows))
            self.stats["row_updates"] += m
        self._dev[d] = arr

    def _upload_block(self, bi: int, cols) -> None:
        import jax.numpy as jnp

        d, j = divmod(bi, self._spd)
        host = np.full((TABLES, self._cap, self.c),
                       np.iinfo(np.int32).max, np.int32)
        self._fill_block(host, cols)
        self._dev[d] = self._dev[d].at[j].set(jnp.asarray(host))
        self._masks = None
        self.stats["block_uploads"] += 1

    # ---- the sharded launch ----------------------------------------------

    def _get_launch(self):
        if self._launch is not None:
            return self._launch
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from .. import dist
        from ..core import clock

        use_pallas = jax.default_backend() != "cpu"

        def block_fn(blk, q):
            # blk (spd, 4, B, C) — this device's blocks; q (C,) replicated
            rows = blk.reshape(-1, blk.shape[-1])
            if use_pallas:
                from ..kernels.mv_visibility import ops
                m = ops.before_mask(rows, q)
            else:
                m = clock._jnp_before(rows, q)
            return m.reshape(blk.shape[:-1])

        f = dist.shard_map(block_fn, mesh=self.mesh,
                           in_specs=(P("data"), P()), out_specs=P("data"),
                           check_vma=False)
        self._launch = jax.jit(f)
        return self._launch

    def before_all(self, q: np.ndarray) -> None:
        """ONE sharded launch answering ``row ≺ q`` for every resident
        block; host-side masks cached until the next mutation or query
        change.  Call after :meth:`sync`."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        q = np.asarray(q, np.int32)
        if self._masks is not None and self._masks_q == q.tobytes():
            return
        shape = (self.n_dev * self._spd, TABLES, self._cap, self.c)
        sharding = NamedSharding(self.mesh, P("data"))
        garr = jax.make_array_from_single_device_arrays(
            shape, sharding, list(self._dev))
        out = self._get_launch()(garr, jnp.asarray(q))
        self._masks = np.asarray(out)
        self._masks_q = q.tobytes()
        self.stats["launches"] += 1

    def masks_for(self, cols) -> Tuple[np.ndarray, np.ndarray,
                                       np.ndarray, np.ndarray]:
        """(v_create≺q, v_delete≺q, e_create≺q, e_delete≺q) boolean masks
        for one partition, trimmed to its synced row counts."""
        bi = self._idx[id(cols)]
        cur = self._consumed[bi]
        m = self._masks[bi]
        nv, ne = cur[0], cur[1]
        return m[0, :nv], m[1, :nv], m[2, :ne], m[3, :ne]

    def has(self, cols) -> bool:
        bi = self._idx.get(id(cols))
        return (bi is not None and self._cols[bi] is cols
                and self._masks is not None)
