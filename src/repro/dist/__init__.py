"""Distributed utilities: mesh registry + sharding-constraint helpers.

The models reference a process-global mesh so the same forward functions
run unmodified on a single CPU device (mesh unset -> every helper is an
identity / trivial answer) and under `jax.jit` on a production mesh
(`launch.dryrun` calls :func:`set_mesh` before lowering).

Spec arguments to :func:`constrain` are FUNCTIONS of the mesh (e.g.
``lambda m: P(dp_axes(m), "model")``) so model code never has to know
which axes exist in the current deployment.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence, Union

from . import collectives, sharding

_MESH = None


def set_mesh(mesh) -> None:
    """Install the process-global mesh (None to clear)."""
    global _MESH
    _MESH = mesh


def get_mesh():
    """The installed mesh, or None (single-device / smoke-test mode)."""
    return _MESH


def axis_size(mesh, axes: Union[None, str, Sequence[str]]) -> int:
    """Product of the named mesh axis sizes (1 when unset/empty)."""
    if mesh is None or axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= int(mesh.shape[a])
    return size


def constrain(x, spec_fn: Callable):
    """Apply ``with_sharding_constraint(x, spec_fn(mesh))`` under the
    global mesh; identity when no mesh is installed."""
    mesh = _MESH
    if mesh is None:
        return x
    import jax
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_fn(mesh)))


def shard_map(f, **kw):
    """Version-portable shard_map: the top-level ``jax.shard_map`` alias
    (and its ``check_vma`` kwarg) landed after 0.4.x; fall back to
    ``jax.experimental.shard_map`` with ``check_rep`` there."""
    import jax

    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _sm

    if "check_vma" in kw:
        kw["check_rep"] = kw.pop("check_vma")
    return _sm(f, **kw)
