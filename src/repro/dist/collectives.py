"""Explicit shard_map collectives (MoE all-to-all, hierarchical grad
sync, cross-pod allreduce).

Overflow semantics of the MoE all-to-all (parity with the jit-level
scatter path)
-------------------------------------------------------------------
The jit-level scatter path (``models/moe.moe_block``) drops (token,
slot) pairs per **global expert** once the expert's capacity ``C`` is
full, in global flattened ``(T, k)`` order.  The all-to-all dispatch
only sees device-local tokens, so a naive local capacity check drops a
*different* set of pairs under overflow.  ``moe_alltoall_block`` now
reproduces the scatter semantics exactly (``overflow="global"``, the
default): each device computes its tokens' **global** position inside
their expert with one extra all-gather of the per-expert local counts
(an ``(n_devices, E)`` int32 exchange — negligible next to the
activation all-to-alls) and applies the same ``pos < capacity`` cut.
The wire buffer ``c_dev`` is then clamped to the static bound
``min(t_loc*k, e_loc*capacity)`` so no kept pair can secondarily
overflow a (source device, destination shard) slab.  The legacy
per-(source device, destination shard) drop rule survives behind
``overflow="local"`` for callers that prefer a smaller wire buffer over
drop parity; any other value, or ``overflow="global"`` without a
``capacity``, is an explicit config error raised at trace time.

Two-phase exact sizing
----------------------
The static bound is safe but often far larger than what any (source
device, destination shard) slab actually carries — the all-to-all then
ships mostly zeros.  :func:`moe_alltoall_exact_c_dev` is a cheap
phase-1 counting pass over the *logits only* (same routing math, same
keep mask — factored into ``_route_keep`` so the two cannot drift)
that returns the exact max kept-pairs-per-slab, rounded up to a
multiple of 8 for lane alignment.  It must run OUTSIDE jit — the count
becomes a static wire-buffer shape — and raises on tracer input.
Passing its result with ``exact_c_dev=True`` skips the static clamp;
outputs are elementwise identical for ANY ``c_dev`` >= the true max.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _route_keep(logits_l, k, e, e_loc, n_model, tok_axes, mesh,
                n_tok_dev, capacity, overflow):
    """Per-device routing shared by the dispatch body and the phase-1
    sizing pass: normalized router weights, flat expert ids, destination
    shards, per-expert positions, and the ``overflow="global"`` keep
    mask (all-ones for ``"local"`` — its cut is per-destination and
    applied by the caller)."""
    probs = jax.nn.softmax(logits_l.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, k)              # (t_loc, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    flat_e = idx.reshape(-1)                            # (N = t_loc*k,)
    n = flat_e.shape[0]
    dest = flat_e // e_loc
    # local position of each pair inside its expert (stable sort:
    # ties keep local flattened (token, slot) order)
    order_e = jnp.argsort(flat_e)
    e_sorted = flat_e[order_e]
    starts_e = jnp.searchsorted(
        e_sorted, jnp.arange(e, dtype=e_sorted.dtype))
    pos_e_sorted = jnp.arange(n, dtype=jnp.int32) - starts_e[e_sorted]
    pos_e = jnp.zeros((n,), jnp.int32).at[order_e].set(pos_e_sorted)

    if overflow == "global":
        # exclusive prefix of per-expert counts over all devices in
        # global token order: device rank = row-major index over the
        # token sharding axes, matching the (dp..., model) layout of
        # the global token array
        counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
        all_counts = jax.lax.all_gather(counts, tok_axes)
        all_counts = all_counts.reshape(n_tok_dev, e)
        my = jnp.int32(0)
        for a in tok_axes:
            my = my * int(mesh.shape[a]) + jax.lax.axis_index(a)
        mask = (jnp.arange(n_tok_dev, dtype=jnp.int32)
                < my)[:, None].astype(jnp.int32)
        prefix = jnp.sum(all_counts * mask, axis=0)     # (e,)
        keep = prefix[flat_e] + pos_e < capacity        # == scatter path
    else:
        keep = jnp.ones((n,), bool)                     # cut per-dest later
    return weights, flat_e, dest, pos_e, keep


def moe_alltoall_block(xf, logits, w_gate, w_up, w_down, mesh, top_k,
                      c_dev, local_capacity_factor=2.0, capacity=None,
                      overflow="global", exact_c_dev=False):
    """Expert-parallel MoE dispatch via explicit all-to-all.

    Tokens are sharded over (dp axes, 'model'); the expert axis of the
    weight tensors is sharded over 'model' (replicated across dp).  Each
    device routes its local (token, slot) pairs to the model shard that
    owns the chosen expert with a sort-based dispatch (same MegaBlocks
    trick as the jit-level scatter path), exchanges (n_model, c_dev, d)
    buffers with ONE all_to_all each way, and combines locally with its
    own router weights — so only token activations cross the wire.

    Capacity semantics (see module docstring): ``overflow="global"``
    (default) drops per global expert at ``capacity`` exactly like the
    scatter path — elementwise-equal outputs in and out of the overflow
    regime; ``overflow="local"`` keeps the legacy per-(source device,
    destination shard) drop at ``max(c_dev, ceil(t_loc*k*
    local_capacity_factor/n_model))``, which agrees with the scatter
    path only when capacity is ample.

    ``exact_c_dev=True`` trusts the caller's ``c_dev`` instead of
    clamping it up to the static bound — pass the result of the
    phase-1 :func:`moe_alltoall_exact_c_dev` counting pass, which
    guarantees every kept pair fits its slab.
    """
    import math

    from jax.sharding import PartitionSpec as P

    from . import shard_map
    from .sharding import dp_axes

    n_model = int(mesh.shape["model"])
    e = w_gate.shape[0]
    assert e % n_model == 0, (e, n_model)
    e_loc = e // n_model
    dp_names = dp_axes(mesh)
    tok_axes = tuple(dp_names) + ("model",)
    n_dp = int(math.prod(int(mesh.shape[a]) for a in dp_names)) \
        if dp_names else 1
    t_loc = int(xf.shape[0]) // (n_dp * n_model)
    if overflow == "global":
        if capacity is None:
            raise ValueError(
                "moe_alltoall_block(overflow='global') needs the global "
                "per-expert `capacity` used by the scatter path; pass it, "
                "or opt into the divergent overflow='local' semantics")
        if exact_c_dev:
            # phase-1 counted the true max kept per slab; a larger
            # buffer only ships more zeros, never changes the output
            c_dev = min(int(c_dev),
                        min(t_loc * int(top_k), e_loc * int(capacity)))
        else:
            # every kept pair must fit its (source device, dest shard)
            # slab: a device keeps at most min(its local pairs,
            # e_loc*capacity) pairs for one destination shard — a STATIC
            # bound, so parity needs no runtime assertion
            c_dev = max(int(c_dev),
                        min(t_loc * int(top_k), e_loc * int(capacity)))
    elif overflow == "local":
        c_dev = max(int(c_dev),
                    math.ceil(t_loc * int(top_k)
                              * float(local_capacity_factor) / n_model))
    else:
        raise ValueError(f"unknown overflow mode {overflow!r} "
                         "(expected 'global' or 'local')")
    n_tok_dev = n_dp * n_model

    def body(xf_l, logits_l, wg, wu, wd):
        t_loc, d = xf_l.shape
        k = top_k
        weights, flat_e, dest, pos_e, keep = _route_keep(
            logits_l, k, e, e_loc, n_model, tok_axes, mesh, n_tok_dev,
            capacity, overflow)
        n = flat_e.shape[0]

        # position among the KEPT pairs of each destination shard
        d2 = jnp.where(keep, dest, n_model)               # dropped -> tail
        order_d = jnp.argsort(d2)
        d2s = d2[order_d]
        starts_d = jnp.searchsorted(
            d2s, jnp.arange(n_model, dtype=d2s.dtype))
        pos_d_sorted = (jnp.arange(n, dtype=jnp.int32)
                        - starts_d[jnp.minimum(d2s, n_model - 1)])
        pos = jnp.zeros((n,), jnp.int32).at[order_d].set(pos_d_sorted)
        if overflow == "local":
            keep = pos < c_dev
        pos_c = jnp.where(keep, pos, c_dev)             # overflow slot
        token_of = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), k)

        send_x = jnp.zeros((n_model, c_dev + 1, d), xf_l.dtype)
        send_x = send_x.at[dest, pos_c].set(
            xf_l[token_of] * keep[:, None].astype(xf_l.dtype))
        send_e = jnp.zeros((n_model, c_dev + 1), jnp.int32)
        send_e = send_e.at[dest, pos_c].set(flat_e % e_loc)

        recv_x = jax.lax.all_to_all(send_x[:, :c_dev], "model", 0, 0)
        recv_e = jax.lax.all_to_all(send_e[:, :c_dev], "model", 0, 0)

        rx = recv_x.reshape(n_model * c_dev, d)
        re = recv_e.reshape(n_model * c_dev)
        g = jnp.einsum("nd,ndf->nf", rx, wg[re])
        u = jnp.einsum("nd,ndf->nf", rx, wu[re])
        out = jnp.einsum("nf,nfd->nd", jax.nn.silu(g) * u, wd[re])

        back = jax.lax.all_to_all(
            out.reshape(n_model, c_dev, d), "model", 0, 0)
        back_flat = jnp.concatenate(
            [back.reshape(n_model * c_dev, d),
             jnp.zeros((1, d), back.dtype)], axis=0)
        slot = jnp.where(keep, dest * c_dev + pos_c, n_model * c_dev)
        per_slot = back_flat[slot]                      # (N, d)
        w_comb = (weights.reshape(-1).astype(xf_l.dtype)
                  * keep.astype(xf_l.dtype))
        return jnp.sum((per_slot * w_comb[:, None]).reshape(t_loc, k, d),
                       axis=1)

    spec_tok = P(tok_axes, None)
    spec_w = P("model", None, None)
    return shard_map(body, mesh=mesh,
                     in_specs=(spec_tok, spec_tok, spec_w, spec_w, spec_w),
                     out_specs=spec_tok, check_vma=False)(
        xf, logits, w_gate, w_up, w_down)


def moe_alltoall_exact_c_dev(logits, mesh, top_k, capacity=None,
                             overflow="global",
                             local_capacity_factor=2.0):
    """Phase-1 of two-phase wire-buffer sizing: the exact max number of
    kept (token, slot) pairs any (source device, destination shard)
    slab carries, rounded up to a multiple of 8 (lane alignment) with a
    floor of 8, never above the static safety bound.

    Runs the SAME routing + keep math as :func:`moe_alltoall_block`
    (``_route_keep``) over the logits only — no activations move — and
    reduces the per-(device, dest) kept counts to one host integer.
    The result is a static shape, so this MUST run outside jit: call it
    on concrete logits (e.g. the previous step's, or a profiling
    batch), then pass the result as ``c_dev`` with ``exact_c_dev=True``.
    Outputs are elementwise identical for any ``c_dev`` >= the true
    max, so resizing between steps never changes numerics.

    ``overflow="local"`` already sizes its buffer from its own drop
    rule — the legacy formula IS exact there and is returned directly.
    """
    import math

    from jax.sharding import PartitionSpec as P

    from . import shard_map
    from .sharding import dp_axes

    if isinstance(logits, jax.core.Tracer):
        raise ValueError(
            "moe_alltoall_exact_c_dev must run outside jit: its result "
            "becomes a static wire-buffer shape (size on concrete "
            "logits, then pass c_dev into the jitted step)")
    n_model = int(mesh.shape["model"])
    e = int(logits.shape[-1])
    assert e % n_model == 0, (e, n_model)
    e_loc = e // n_model
    dp_names = dp_axes(mesh)
    tok_axes = tuple(dp_names) + ("model",)
    n_dp = int(math.prod(int(mesh.shape[a]) for a in dp_names)) \
        if dp_names else 1
    n_tok_dev = n_dp * n_model
    t_loc = int(logits.shape[0]) // n_tok_dev
    k = int(top_k)
    if overflow == "local":
        return math.ceil(t_loc * k * float(local_capacity_factor) / n_model)
    if overflow != "global":
        raise ValueError(f"unknown overflow mode {overflow!r} "
                         "(expected 'global' or 'local')")
    if capacity is None:
        raise ValueError(
            "moe_alltoall_exact_c_dev(overflow='global') needs the "
            "global per-expert `capacity` used by the scatter path")
    bound = min(t_loc * k, e_loc * int(capacity))

    def count(logits_l):
        _, _, dest, _, keep = _route_keep(
            logits_l, k, e, e_loc, n_model, tok_axes, mesh, n_tok_dev,
            capacity, "global")
        kept = jnp.zeros((n_model,), jnp.int32).at[dest].add(
            keep.astype(jnp.int32))
        return kept[None, :]                 # (1, n_model) per device

    spec_tok = P(tok_axes, None)
    counts = shard_map(count, mesh=mesh, in_specs=(spec_tok,),
                       out_specs=spec_tok, check_vma=False)(logits)
    max_kept = int(jax.device_get(counts).max())
    return min(bound, max(8, -(-max_kept // 8) * 8))


def _pod_mean(x32, compress: bool):
    """fp32 mean over the 'pod' axis, optionally int8-compressed for the
    slow DCN link — the shared cross-pod hop of ``grad_sync`` and
    ``cross_pod_allreduce``."""
    if compress:
        from repro.optim.compress import dequantize_int8, quantize_int8
        q, s = quantize_int8(x32)
        return jax.lax.pmean(dequantize_int8(q, s), "pod")
    return jax.lax.pmean(x32, "pod")


def grad_sync(mesh, grads, int8_cross_pod: bool = False):
    """Hierarchical gradient mean over the data-parallel axes.

    In-pod (``data``) reduction runs in fp32; the cross-pod hop (the slow
    DCN link) optionally quantizes its summand to int8 with per-tensor
    scales (``optim.compress``) before reducing — via the same
    :func:`_pod_mean` body that backs :func:`cross_pod_allreduce`.
    Tensor-parallel (``model``) gradients are already replicated and
    untouched.
    """
    if mesh is None or all(int(s) == 1 for s in mesh.shape.values()):
        return grads
    from jax.sharding import PartitionSpec as P

    from . import shard_map
    from .sharding import dp_axes

    dp = dp_axes(mesh)
    if not dp:
        return grads
    in_pod = tuple(a for a in dp if a != "pod")

    def body(g):
        def one(x):
            x32 = x.astype(jnp.float32)
            if in_pod:
                x32 = jax.lax.pmean(x32, in_pod)
            if "pod" in dp:
                x32 = _pod_mean(x32, int8_cross_pod)
            return x32.astype(x.dtype)

        return jax.tree_util.tree_map(one, g)

    return shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P())(grads)


def cross_pod_allreduce(mesh, x, compress: bool = False, in_spec=None):
    """Mean-allreduce of one tensor over the 'pod' axis.

    The standalone form of ``grad_sync``'s cross-pod hop (same
    :func:`_pod_mean` body, so the two cannot drift): use it to average
    metrics, EMA shadows, or other per-pod state that does not ride the
    gradient pytree.  ``in_spec`` is the tensor's PartitionSpec (default
    replicated); the output keeps the same spec, with the value averaged
    across pods.  Identity on meshes without a 'pod' axis (or pod=1).
    """
    if mesh is None or "pod" not in mesh.axis_names \
            or int(mesh.shape["pod"]) == 1:
        return x
    from jax.sharding import PartitionSpec as P

    from . import shard_map

    spec = in_spec if in_spec is not None else P()

    def body(xl):
        return _pod_mean(xl.astype(jnp.float32), compress).astype(x.dtype)

    return shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                     check_vma=False)(x)
