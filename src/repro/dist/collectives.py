"""Explicit shard_map collectives (MoE all-to-all, hierarchical grad
sync, cross-pod allreduce).

Only the single-device-correct entry points are provided here; the
multi-device shard_map bodies are gated until the distributed runtime
lands (tracked in ROADMAP "Open items").  Callers already guard on
``dist.get_mesh() is not None`` plus config flags, so the default smoke
and tier-1 paths never reach the gated branches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_GATE_MSG = ("repro.dist.collectives.{name} requires the multi-device "
             "shard_map runtime, which is not wired up in this build; "
             "run with the jit-level variant (default config) instead")


def moe_alltoall_block(xf, logits, w_gate, w_up, w_down, mesh, top_k,
                      c_dev, local_capacity_factor=2.0):
    """Expert-parallel MoE dispatch via explicit all-to-all.

    Tokens are sharded over (dp axes, 'model'); the expert axis of the
    weight tensors is sharded over 'model' (replicated across dp).  Each
    device routes its local (token, slot) pairs to the model shard that
    owns the chosen expert with a sort-based dispatch (same MegaBlocks
    trick as the jit-level scatter path), exchanges (n_model, c_dev, d)
    buffers with ONE all_to_all each way, and combines locally with its
    own router weights — so only token activations cross the wire.

    Capacity semantics: drops are per (source device, destination shard)
    at ``max(c_dev, ceil(t_loc*k*local_capacity_factor/n_model))``, vs
    the scatter path's per-global-expert capacity; with ample capacity
    (no drops) both paths agree elementwise.
    """
    import math

    from jax.sharding import PartitionSpec as P

    from . import shard_map
    from .sharding import dp_axes

    n_model = int(mesh.shape["model"])
    e = w_gate.shape[0]
    assert e % n_model == 0, (e, n_model)
    e_loc = e // n_model
    dp_names = dp_axes(mesh)
    tok_axes = tuple(dp_names) + ("model",)
    n_dp = int(math.prod(int(mesh.shape[a]) for a in dp_names)) \
        if dp_names else 1
    t_loc = int(xf.shape[0]) // (n_dp * n_model)
    c_dev = max(int(c_dev),
                math.ceil(t_loc * int(top_k)
                          * float(local_capacity_factor) / n_model))

    def body(xf_l, logits_l, wg, wu, wd):
        t_loc, d = xf_l.shape
        k = top_k
        probs = jax.nn.softmax(logits_l.astype(jnp.float32), axis=-1)
        weights, idx = jax.lax.top_k(probs, k)          # (t_loc, k)
        weights = weights / jnp.maximum(
            jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
        flat_e = idx.reshape(-1)                        # (N = t_loc*k,)
        n = flat_e.shape[0]
        dest = flat_e // e_loc
        order = jnp.argsort(dest)
        dest_sorted = dest[order]
        starts = jnp.searchsorted(
            dest_sorted, jnp.arange(n_model, dtype=dest_sorted.dtype))
        pos_sorted = jnp.arange(n, dtype=jnp.int32) - starts[dest_sorted]
        pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
        keep = pos < c_dev
        pos_c = jnp.where(keep, pos, c_dev)             # overflow slot
        token_of = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), k)

        send_x = jnp.zeros((n_model, c_dev + 1, d), xf_l.dtype)
        send_x = send_x.at[dest, pos_c].set(
            xf_l[token_of] * keep[:, None].astype(xf_l.dtype))
        send_e = jnp.zeros((n_model, c_dev + 1), jnp.int32)
        send_e = send_e.at[dest, pos_c].set(flat_e % e_loc)

        recv_x = jax.lax.all_to_all(send_x[:, :c_dev], "model", 0, 0)
        recv_e = jax.lax.all_to_all(send_e[:, :c_dev], "model", 0, 0)

        rx = recv_x.reshape(n_model * c_dev, d)
        re = recv_e.reshape(n_model * c_dev)
        g = jnp.einsum("nd,ndf->nf", rx, wg[re])
        u = jnp.einsum("nd,ndf->nf", rx, wu[re])
        out = jnp.einsum("nf,nfd->nd", jax.nn.silu(g) * u, wd[re])

        back = jax.lax.all_to_all(
            out.reshape(n_model, c_dev, d), "model", 0, 0)
        back_flat = jnp.concatenate(
            [back.reshape(n_model * c_dev, d),
             jnp.zeros((1, d), back.dtype)], axis=0)
        slot = jnp.where(keep, dest * c_dev + pos_c, n_model * c_dev)
        per_slot = back_flat[slot]                      # (N, d)
        w_comb = (weights.reshape(-1).astype(xf_l.dtype)
                  * keep.astype(xf_l.dtype))
        return jnp.sum((per_slot * w_comb[:, None]).reshape(t_loc, k, d),
                       axis=1)

    spec_tok = P(tok_axes, None)
    spec_w = P("model", None, None)
    return shard_map(body, mesh=mesh,
                     in_specs=(spec_tok, spec_tok, spec_w, spec_w, spec_w),
                     out_specs=spec_tok, check_vma=False)(
        xf, logits, w_gate, w_up, w_down)


def grad_sync(mesh, grads, int8_cross_pod: bool = False):
    """Hierarchical gradient mean over the data-parallel axes.

    In-pod (``data``) reduction runs in fp32; the cross-pod hop (the slow
    DCN link) optionally quantizes its summand to int8 with per-tensor
    scales (``optim.compress``) before reducing.  Tensor-parallel
    (``model``) gradients are already replicated and untouched.
    """
    if mesh is None or all(int(s) == 1 for s in mesh.shape.values()):
        return grads
    from jax.sharding import PartitionSpec as P

    from . import shard_map
    from .sharding import dp_axes

    dp = dp_axes(mesh)
    if not dp:
        return grads
    in_pod = tuple(a for a in dp if a != "pod")

    def body(g):
        def one(x):
            x32 = x.astype(jnp.float32)
            if in_pod:
                x32 = jax.lax.pmean(x32, in_pod)
            if "pod" in dp:
                if int8_cross_pod:
                    from repro.optim.compress import (dequantize_int8,
                                                      quantize_int8)
                    q, s = quantize_int8(x32)
                    x32 = jax.lax.pmean(dequantize_int8(q, s), "pod")
                else:
                    x32 = jax.lax.pmean(x32, "pod")
            return x32.astype(x.dtype)

        return jax.tree_util.tree_map(one, g)

    return shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P())(grads)


def cross_pod_allreduce(mesh, x, compress: bool = False):
    """Mean-allreduce over the 'pod' axis (gated off single-device)."""
    if mesh is None or "pod" not in mesh.axis_names \
            or int(mesh.shape["pod"]) == 1:
        return x
    raise NotImplementedError(_GATE_MSG.format(name="cross_pod_allreduce"))
