"""Mesh-axis naming conventions and name-based parameter partition
rules shared by the models and launchers.

Axis vocabulary (see ``launch.mesh``): ``model`` is tensor parallelism;
``data`` and (multi-pod) ``pod`` are pure data parallelism.

Parameter rules are *conservative shape heuristics*: any returned spec
is a valid placement (GSPMD inserts reshards as needed around the
activation constraints the models emit), so correctness never depends on
them — only the dry-run memory profile does.
"""

from __future__ import annotations

import math
from typing import Callable, Tuple

#: data-parallel axis names, outermost first
DP_AXIS_ORDER = ("pod", "data")

#: don't bother sharding axes smaller than this
MIN_SHARD_DIM = 128


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes present in ``mesh`` (outermost first)."""
    if mesh is None:
        return ()
    return tuple(a for a in DP_AXIS_ORDER if a in mesh.axis_names)


def model_axis(mesh) -> str:
    return "model"


def _heuristic_rule(mesh, fsdp: bool) -> Callable:
    from jax.sharding import PartitionSpec as P

    dp = dp_axes(mesh)
    n_model = int(mesh.shape["model"]) if "model" in mesh.axis_names else 1
    n_dp = int(math.prod(int(mesh.shape[a]) for a in dp)) if dp else 1

    def rule(path, leaf) -> P:
        shape = getattr(leaf, "shape", ())
        spec = [None] * len(shape)
        if n_model > 1:
            # tensor parallelism: last large divisible axis over 'model'
            for ax in reversed(range(len(shape))):
                if shape[ax] >= MIN_SHARD_DIM and shape[ax] % n_model == 0:
                    spec[ax] = "model"
                    break
        if fsdp and n_dp > 1:
            # fully-sharded storage: first remaining divisible axis
            for ax in range(len(shape)):
                if (spec[ax] is None and shape[ax] >= MIN_SHARD_DIM
                        and shape[ax] % n_dp == 0):
                    spec[ax] = dp if len(dp) > 1 else dp[0]
                    break
        return P(*spec)

    return rule


def lm_param_rule(mesh, fsdp: bool = True) -> Callable:
    return _heuristic_rule(mesh, fsdp)


def gnn_param_rule(mesh) -> Callable:
    return _heuristic_rule(mesh, fsdp=False)


def recsys_param_rule(mesh) -> Callable:
    return _heuristic_rule(mesh, fsdp=False)


def shardings_for_tree(tree, rule: Callable, mesh):
    """Apply a (path, leaf) -> PartitionSpec rule over a param pytree."""
    import jax
    from jax.sharding import NamedSharding

    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, rule(p, l)), tree)


def gnn_batch_spec(mesh, full_graph: bool = False) -> dict:
    """Per-key overrides for GNN batch arrays; callers default any key
    not listed here to first-dim sharding over all mesh axes."""
    return {}
