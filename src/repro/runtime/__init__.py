from .server import GraphQueryServer, LMServer, RecServer
from .trainer import HeartbeatMonitor, Trainer, TrainerConfig

__all__ = ["GraphQueryServer", "LMServer", "RecServer", "HeartbeatMonitor",
           "Trainer", "TrainerConfig"]
