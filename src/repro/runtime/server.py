"""Batched serving loops.

* :class:`GraphQueryServer` — the paper-native server: batched node
  programs against the Weaver store (the end-to-end serving driver of
  examples/social_serve.py runs this under the TAO workload).
* :class:`LMServer` — LM decode serving with a continuous batch of
  sessions over a shared KV cache (prefill + decode_step).
* :class:`RecServer` — SASRec scoring (catalog or candidate mode).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import sasrec, transformer


class GraphQueryServer:
    """Serve node programs / transactions against a Weaver deployment."""

    def __init__(self, weaver):
        self.weaver = weaver
        self.inflight = 0
        self.completed: List[dict] = []

    def submit(self, kind: str, payload, on_done: Optional[Callable] = None):
        self.inflight += 1

        def _done(*args):
            self.inflight -= 1
            rec = {"kind": kind, "result": args}
            self.completed.append(rec)
            if on_done:
                on_done(*args)

        if kind == "tx":
            self.weaver.submit_tx(payload, _done)
        else:
            name, entries = payload
            self.weaver.submit_program(name, entries,
                                       lambda r, s, l: _done(r, s, l))

    def drain(self, timeout: float = 5.0) -> None:
        sim = self.weaver.sim
        deadline = sim.now + timeout
        while self.inflight > 0 and sim.now < deadline and sim.pending():
            sim.run(until=min(deadline, sim.now + 10e-3))


@dataclasses.dataclass
class LMSession:
    sid: int
    prompt: np.ndarray
    generated: List[int] = dataclasses.field(default_factory=list)


class LMServer:
    """Continuous-batch decode server (greedy sampling)."""

    def __init__(self, params, cfg: transformer.LMConfig, batch: int,
                 max_len: int):
        self.params = params
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.cache = transformer.init_cache(cfg, batch, max_len)
        self._prefill = jax.jit(
            lambda p, t: transformer.prefill(p, t, cfg,
                                             max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t: transformer.decode_step(p, c, t, cfg))

    def prefill_batch(self, prompts: np.ndarray):
        logits, self.cache = self._prefill(self.params,
                                           jnp.asarray(prompts))
        return np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))

    def decode(self, tokens: np.ndarray, steps: int) -> np.ndarray:
        out = []
        cur = jnp.asarray(tokens)[:, None]
        for _ in range(steps):
            logits, self.cache = self._decode(self.params, self.cache, cur)
            cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
            out.append(np.asarray(cur[:, 0]))
        return np.stack(out, axis=1)


class RecServer:
    def __init__(self, params, cfg: sasrec.SASRecConfig):
        self.params = params
        self.cfg = cfg
        self._catalog = jax.jit(
            lambda p, h: sasrec.score_catalog(p, h, cfg))
        self._cands = jax.jit(
            lambda p, h, c: sasrec.score_candidates(p, h, c, cfg))

    def top_k(self, hist: np.ndarray, k: int = 10) -> np.ndarray:
        scores = self._catalog(self.params, jnp.asarray(hist))
        _, idx = jax.lax.top_k(scores, k)
        return np.asarray(idx)

    def score(self, hist: np.ndarray, candidates: np.ndarray) -> np.ndarray:
        return np.asarray(self._cands(self.params, jnp.asarray(hist),
                                      jnp.asarray(candidates)))
