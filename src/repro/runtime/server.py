"""Batched serving loops.

* :class:`GraphQueryServer` — the paper-native server: batched node
  programs against the Weaver store (the end-to-end serving driver of
  examples/social_serve.py runs this under the TAO workload).
* :class:`LMServer` — LM decode serving with a continuous batch of
  sessions over a shared KV cache (prefill + decode_step).
* :class:`RecServer` — SASRec scoring (catalog or candidate mode).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import sasrec, transformer


class GraphQueryServer:
    """Serve node programs / transactions against a Weaver deployment.

    Beyond fire-and-drain :meth:`submit`, this drives closed- and
    open-loop client fleets entirely inside the discrete-event
    simulation: :meth:`run_closed_loop` models N clients that each keep
    exactly one request outstanding (throughput finds the system's
    saturation point), and :meth:`run_open_loop` models a Poisson
    arrival process at a fixed offered rate regardless of completions
    (latency and goodput degrade visibly past saturation — the
    serving-benchmark regime where admission windows and backpressure
    matter).  Both return per-request latencies plus failure counts so
    callers can compute percentile/goodput curves.
    """

    def __init__(self, weaver):
        self.weaver = weaver
        self.inflight = 0
        self.completed: List[dict] = []

    def submit(self, kind: str, payload, on_done: Optional[Callable] = None):
        self.inflight += 1

        def _done(*args):
            self.inflight -= 1
            rec = {"kind": kind, "result": args}
            self.completed.append(rec)
            if on_done:
                on_done(*args)

        if kind == "tx":
            self.weaver.submit_tx(payload, _done)
        else:
            name, entries = payload
            self.weaver.submit_program(name, entries,
                                       lambda r, s, l: _done(r, s, l))

    def drain(self, timeout: float = 5.0) -> None:
        sim = self.weaver.sim
        deadline = sim.now + timeout
        while self.inflight > 0 and sim.now < deadline and sim.pending():
            sim.run(until=min(deadline, sim.now + 10e-3))

    # ---- client fleets -------------------------------------------------

    def _issue(self, kind: str, payload, on_done: Callable) -> None:
        """Issue one request; ``on_done(ok: bool, latency: float)``.

        A program completing with result ``None`` (retry budget
        exhausted / shed without a session) counts as a failure; a tx
        reply of ``(None, None)`` (client session gave up) likewise.
        """
        if kind == "tx":
            self.weaver.submit_tx(
                payload, lambda r: on_done(r.ok, r.latency))
        else:
            name, entries = payload
            self.weaver.submit_program(
                name, entries, lambda r, s, lat: on_done(r is not None, lat))

    def run_closed_loop(self, n_clients: int, n_requests: int,
                        make_request: Callable[[int], Tuple[str, object]],
                        timeout: float = 120.0) -> dict:
        """N clients, one outstanding request each, until ``n_requests``
        have been *issued*; returns latencies of everything completed."""
        sim = self.weaver.sim
        state = {"issued": 0, "done": 0, "ok": 0, "t_end": sim.now}
        lat: List[float] = []
        t0 = sim.now

        def next_req() -> None:
            if state["issued"] >= n_requests:
                return
            i = state["issued"]
            state["issued"] += 1
            kind, payload = make_request(i)

            def _done(ok: bool, latency: float) -> None:
                state["done"] += 1
                state["t_end"] = sim.now
                if ok:
                    state["ok"] += 1
                    lat.append(latency)
                next_req()

            self._issue(kind, payload, _done)

        for _ in range(min(n_clients, n_requests)):
            next_req()
        deadline = t0 + timeout
        while state["done"] < state["issued"] and sim.now < deadline \
                and sim.pending():
            sim.run(until=min(deadline, sim.now + 10e-3))
        dur = max(state["t_end"] - t0, 1e-9)
        return {"issued": state["issued"], "completed": state["done"],
                "ok": state["ok"], "duration_s": dur,
                "throughput_per_s": state["done"] / dur,
                "goodput_per_s": state["ok"] / dur,
                "latencies_s": lat}

    def run_open_loop(self, rate: float, n_requests: int,
                      make_request: Callable[[int], Tuple[str, object]],
                      seed: int = 0, timeout: float = 120.0,
                      request_deadline: Optional[float] = None) -> dict:
        """Poisson arrivals at ``rate``/sec, independent of completions.

        Offered load past the service capacity is exactly the regime
        where bounded admission queues must shed: completions that never
        arrive (no session) would hang the drain, so failure surfacing
        via sessions/give-ups is part of the contract being measured.

        ``request_deadline`` (simulated seconds; defaults to
        ``min(timeout, 10.0)``) arms a watchdog: if any *issued* request
        stays outstanding past the deadline — i.e. the retry machinery
        silently swallowed its completion instead of surfacing a failure
        — the driver raises ``RuntimeError`` with a diagnostic (oldest
        stuck request, its kind and age, progress and shed counters)
        rather than spinning until the wall-clock timeout and returning
        a result that under-reports the bug.
        """
        sim = self.weaver.sim
        rng = np.random.default_rng(seed)
        if request_deadline is None:
            request_deadline = min(timeout, 10.0)
        state = {"done": 0, "ok": 0, "issued": 0, "t_end": sim.now}
        outstanding: Dict[int, Tuple[float, str]] = {}
        lat: List[float] = []
        t0 = sim.now

        def _diagnose(reason: str) -> RuntimeError:
            c = sim.counters
            if outstanding:
                oldest = min(outstanding, key=lambda i: outstanding[i][0])
                issue_t, kind = outstanding[oldest]
                stuck = (f"oldest stuck: req#{oldest} kind={kind} "
                         f"age={sim.now - issue_t:.3f}s")
            else:
                stuck = "no requests outstanding"
            return RuntimeError(
                f"open-loop watchdog: {reason}; "
                f"issued={state['issued']}/{n_requests} "
                f"completed={state['done']} ok={state['ok']}; {stuck}; "
                f"counters: progs_shed={c.progs_shed} "
                f"txs_shed={c.txs_shed} prog_gaveup={c.prog_gaveup} "
                f"client_gaveup={c.client_gaveup}")

        def arrive(i: int) -> None:
            state["issued"] += 1
            kind, payload = make_request(i)
            outstanding[i] = (sim.now, kind)

            def _done(ok: bool, latency: float) -> None:
                outstanding.pop(i, None)
                state["done"] += 1
                state["t_end"] = sim.now
                if ok:
                    state["ok"] += 1
                    lat.append(latency)

            self._issue(kind, payload, _done)

        # pre-schedule the whole arrival process (deterministic given seed)
        t = 0.0
        for i in range(n_requests):
            t += float(rng.exponential(1.0 / rate))
            sim.schedule(t, arrive, i)
        deadline = t0 + timeout
        while state["done"] < n_requests and sim.now < deadline \
                and sim.pending():
            sim.run(until=min(deadline, sim.now + 10e-3))
            if outstanding:
                oldest_t = min(it for it, _ in outstanding.values())
                if sim.now - oldest_t > request_deadline:
                    raise _diagnose(
                        f"request exceeded deadline "
                        f"({request_deadline:.3f}s simulated)")
        # a hang can also surface as the event queue running dry with
        # issued requests still outstanding: nothing can complete them
        if outstanding and not sim.pending():
            raise _diagnose("event queue drained with requests outstanding")
        dur = max(state["t_end"] - t0, 1e-9)
        return {"offered_per_s": rate, "issued": n_requests,
                "completed": state["done"], "ok": state["ok"],
                "duration_s": dur,
                "throughput_per_s": state["done"] / dur,
                "goodput_per_s": state["ok"] / dur,
                "latencies_s": lat}


@dataclasses.dataclass
class LMSession:
    sid: int
    prompt: np.ndarray
    generated: List[int] = dataclasses.field(default_factory=list)


class LMServer:
    """Continuous-batch decode server (greedy sampling)."""

    def __init__(self, params, cfg: transformer.LMConfig, batch: int,
                 max_len: int):
        self.params = params
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.cache = transformer.init_cache(cfg, batch, max_len)
        self._prefill = jax.jit(
            lambda p, t: transformer.prefill(p, t, cfg,
                                             max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t: transformer.decode_step(p, c, t, cfg))

    def prefill_batch(self, prompts: np.ndarray):
        logits, self.cache = self._prefill(self.params,
                                           jnp.asarray(prompts))
        return np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))

    def decode(self, tokens: np.ndarray, steps: int) -> np.ndarray:
        out = []
        cur = jnp.asarray(tokens)[:, None]
        for _ in range(steps):
            logits, self.cache = self._decode(self.params, self.cache, cur)
            cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
            out.append(np.asarray(cur[:, 0]))
        return np.stack(out, axis=1)


class RecServer:
    def __init__(self, params, cfg: sasrec.SASRecConfig):
        self.params = params
        self.cfg = cfg
        self._catalog = jax.jit(
            lambda p, h: sasrec.score_catalog(p, h, cfg))
        self._cands = jax.jit(
            lambda p, h, c: sasrec.score_candidates(p, h, c, cfg))

    def top_k(self, hist: np.ndarray, k: int = 10) -> np.ndarray:
        scores = self._catalog(self.params, jnp.asarray(hist))
        _, idx = jax.lax.top_k(scores, k)
        return np.asarray(idx)

    def score(self, hist: np.ndarray, candidates: np.ndarray) -> np.ndarray:
        return np.asarray(self._cands(self.params, jnp.asarray(hist),
                                      jnp.asarray(candidates)))
