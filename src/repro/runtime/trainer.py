"""Fault-tolerant training loop.

Production posture (1000+ nodes, DESIGN.md §5):
* checkpoint/restart through the refinable-timestamp multi-version store
  (resume picks the max complete stamp; epoch bumps on failure);
* straggler detection via NOP-heartbeats — the paper's NOP-transaction
  mechanism repurposed: every worker posts a heartbeat per step, the
  monitor flags workers whose heartbeat age exceeds k x median step time
  (on real clusters the flagged host is ejected and the run resumes
  elastically from the last stamp; the single-process simulation hook
  records the decision);
* elastic resume: the checkpoint stores unsharded leaves, so a restart
  may use a different mesh (device count) than the run that saved.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint import MVCheckpointStore
from repro.optim import AdamWConfig, adamw, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    n_writers: int = 1
    writer_id: int = 0


class HeartbeatMonitor:
    """NOP-heartbeat straggler detection (paper §4.1 mechanism)."""

    def __init__(self, n_workers: int, factor: float = 3.0):
        self.n_workers = n_workers
        self.factor = factor
        self.last_beat = np.zeros(n_workers)
        self.step_times: List[float] = []
        self.flagged: List[int] = []

    def beat(self, worker: int, now: float) -> None:
        if self.last_beat[worker] > 0:
            self.step_times.append(now - self.last_beat[worker])
        self.last_beat[worker] = now

    def check(self, now: float) -> List[int]:
        if len(self.step_times) < 4:
            return []
        med = float(np.median(self.step_times[-64:]))
        out = [w for w in range(self.n_workers)
               if self.last_beat[w] > 0
               and now - self.last_beat[w] > self.factor * med]
        for w in out:
            if w not in self.flagged:
                self.flagged.append(w)
        return out


class Trainer:
    def __init__(self, loss_fn: Callable, params, opt_cfg: AdamWConfig,
                 cfg: TrainerConfig, mesh=None, param_shardings=None):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.mesh = mesh
        self.step_fn = jax.jit(make_train_step(loss_fn, opt_cfg))
        self.params = params
        self.opt_state = adamw.init(params)
        self.step = 0
        self.store = MVCheckpointStore(cfg.ckpt_dir,
                                       n_writers=cfg.n_writers,
                                       writer_id=cfg.writer_id,
                                       keep=cfg.keep)
        self.monitor = HeartbeatMonitor(n_workers=1,
                                        factor=cfg.straggler_factor)
        self.history: List[Dict] = []
        self.param_shardings = param_shardings

    # ---- restart -------------------------------------------------------
    def try_resume(self) -> bool:
        info = self.store.latest()
        if info is None:
            return False
        state_like = {"params": self.params, "opt": self.opt_state}
        state, info = self.store.restore(state_like)
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step = info.step
        return True

    def on_failure(self) -> None:
        """Simulated node failure: epoch bump + resume from last stamp."""
        self.store.bump_epoch()
        assert self.try_resume(), "no checkpoint to resume from"

    # ---- loop -----------------------------------------------------------
    def fit(self, batches: Iterator[dict],
            until: Optional[int] = None) -> List[Dict]:
        target = until if until is not None else self.cfg.total_steps
        while self.step < target:
            batch = next(batches)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step += 1
            self.monitor.beat(0, time.perf_counter())
            self.monitor.check(time.perf_counter())
            rec = {"step": self.step, "loss": loss, "time_s": dt}
            self.history.append(rec)
            if self.step % self.cfg.log_every == 0:
                print(f"step {self.step:5d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
            if self.step % self.cfg.ckpt_every == 0 or self.step == target:
                self.store.save({"params": self.params,
                                 "opt": self.opt_state}, self.step)
        return self.history
