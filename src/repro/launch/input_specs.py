"""ShapeDtypeStruct stand-ins + step functions for every (arch x shape)
cell — nothing here allocates device memory; dims that jit's sharding
check requires to divide the mesh are padded exactly like the data
pipeline pads real batches.

``build(arch_id, shape_name, mesh, variant)`` returns a
:class:`Lowerable` with the function to jit, abstract args, input
shardings, and roofline metadata (MODEL_FLOPS per step).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.dist import sharding as sh
from repro.models import gnn, sasrec, transformer
from repro.optim import AdamWConfig, adamw, make_train_step


@dataclasses.dataclass
class Lowerable:
    fn: Callable
    args: tuple
    in_shardings: tuple
    meta: dict


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def _pad_to(x: int, m: int) -> int:
    return int(-(-x // m) * m)


def _mesh_total(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))


def _dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in sh.dp_axes(mesh)]))


def _safe(axes, dim, mesh) -> Optional[object]:
    """Return axes if dim divides the mesh extent of axes, else None."""
    if axes is None:
        return None
    tup = axes if isinstance(axes, tuple) else (axes,)
    ext = int(np.prod([mesh.shape[a] for a in tup]))
    return axes if dim % ext == 0 else None


OPT = AdamWConfig(lr=3e-4, warmup_steps=100, total_steps=10_000)


# ------------------------------------------------------------------ LM cells
def _lm_train(spec, dims, mesh, variant):
    cfg = spec.config
    if variant == "moe_a2a" and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, moe_impl="a2a"))
    if variant == "no_remat":
        cfg = dataclasses.replace(cfg, remat="none")
    batch, seq = dims["batch"], dims["seq"]
    params = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(lambda: adamw.init(params))
    bspec = {"tokens": _sds((batch, seq), jnp.int32),
             "labels": _sds((batch, seq), jnp.int32)}
    step = make_train_step(
        lambda p, b: transformer.lm_loss(p, b, cfg), OPT)

    rule = sh.lm_param_rule(mesh, fsdp=(variant != "no_fsdp"))
    pshard = sh.shardings_for_tree(params, rule, mesh)
    oshard = adamw.AdamWState(
        step=NamedSharding(mesh, P()),
        mu=sh.shardings_for_tree(params, rule, mesh),
        nu=sh.shardings_for_tree(params, rule, mesh))
    dp = sh.dp_axes(mesh)
    bshard = {"tokens": NamedSharding(mesh, P(_safe(dp, batch, mesh), None)),
              "labels": NamedSharding(mesh, P(_safe(dp, batch, mesh), None))}
    tokens = batch * seq
    meta = {
        "model_flops": 6.0 * cfg.n_active_params() * tokens,
        "model_flops_note": "6*N_active*D (train fwd+bwd)",
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
        "tokens_per_step": tokens,
    }
    return Lowerable(step, (params, opt, bspec), (pshard, oshard, bshard),
                     meta)


def _lm_prefill(spec, dims, mesh, variant):
    cfg = spec.config
    batch, seq = dims["batch"], dims["seq"]
    params = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    fn = lambda p, t: transformer.prefill(p, t, cfg)
    rule = sh.lm_param_rule(mesh, fsdp=(variant != "no_fsdp"))
    pshard = sh.shardings_for_tree(params, rule, mesh)
    dp = sh.dp_axes(mesh)
    # SP: batch over DP, sequence over 'model'
    tspec = P(_safe(dp, batch, mesh), _safe("model", seq, mesh))
    tshard = NamedSharding(mesh, tspec)
    tokens = batch * seq
    meta = {
        "model_flops": 2.0 * cfg.n_active_params() * tokens,
        "model_flops_note": "2*N_active*D (prefill fwd)",
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
        "tokens_per_step": tokens,
    }
    return Lowerable(fn, (params, _sds((batch, seq), jnp.int32)),
                     (pshard, tshard), meta)


def _lm_decode(spec, dims, mesh, variant):
    cfg = spec.config
    if variant == "decode_splitk":
        cfg = dataclasses.replace(cfg, decode_attn="splitk")
    batch, seq = dims["batch"], dims["seq"]
    params = jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    window_bounded = variant == "window_cache"
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, batch, seq,
                                       window_bounded=window_bounded))
    fn = lambda p, c, t: transformer.decode_step(p, c, t, cfg)
    rule = sh.lm_param_rule(mesh, fsdp=(variant != "no_fsdp"))
    pshard = sh.shardings_for_tree(params, rule, mesh)
    dp = sh.dp_axes(mesh)
    bax = _safe(dp, batch, mesh)
    s_len = cache["k"].shape[2]
    sax = _safe("model", s_len, mesh)
    cshard = {"k": NamedSharding(mesh, P(None, bax, sax, None, None)),
              "v": NamedSharding(mesh, P(None, bax, sax, None, None)),
              "len": NamedSharding(mesh, P(bax))}
    tshard = NamedSharding(mesh, P(bax, None))
    meta = {
        "model_flops": 2.0 * cfg.n_active_params() * batch,
        "model_flops_note": "2*N_active per token (decode, B tokens)",
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
        "tokens_per_step": batch,
        "kv_bytes": float(np.prod(cache["k"].shape)) * 2 * 2,
    }
    return Lowerable(fn, (params, cache, _sds((batch, 1), jnp.int32)),
                     (pshard, cshard, tshard), meta)


# ----------------------------------------------------------------- GNN cells
def _gnn_batch_sds(cfg, dims, mesh, kind):
    total = _mesh_total(mesh)
    pad = lambda x: _pad_to(x, 512 if total <= 512 else total)
    if kind == "gnn_mol":
        n = pad(dims["batch"] * dims["n_nodes"])
        e = pad(dims["batch"] * dims["n_edges"])
        n_graphs = dims["batch"]
    else:
        n = pad(dims["n_nodes"])
        e = pad(dims["n_edges"])
        n_graphs = 1
    d_feat = dims["d_feat"]
    b = {
        "edge_src": _sds((e,), jnp.int32),
        "edge_dst": _sds((e,), jnp.int32),
        "graph_ids": _sds((n,), jnp.int32),
    }
    if cfg.kind == "dimenet":
        b["species"] = _sds((n,), jnp.int32)
        b["pos"] = _sds((n, 3), jnp.float32)
        t = pad(e * 8)                        # cutoff-capped triplet fan-in
        b["trip_in"] = _sds((t,), jnp.int32)
        b["trip_out"] = _sds((t,), jnp.int32)
        b["labels"] = _sds((n_graphs,), jnp.float32)
    else:
        b["x"] = _sds((n, d_feat), jnp.float32)
        if cfg.task == "graph":
            b["labels"] = _sds((n_graphs,), jnp.int32)
        else:
            b["labels"] = _sds((n,), jnp.int32)
            b["label_mask"] = _sds((n,), jnp.float32)
    return b, n, e, n_graphs


def _gnn_flops(cfg, n, e, t=0):
    """Analytic model flops for the GNN families (fwd+bwd = 3x fwd)."""
    h = cfg.d_hidden
    if cfg.kind == "gin":
        f = cfg.n_layers * (2 * n * h * h * 2 + 2 * e * h)
    elif cfg.kind == "pna":
        f = cfg.n_layers * (2 * e * (2 * h) * h + 4 * e * h
                            + 2 * n * (13 * h) * h)
    elif cfg.kind == "gat":
        f = cfg.n_layers * (2 * n * cfg.n_heads * h * h + 6 * e * h)
    else:  # dimenet
        f = cfg.n_layers * (2 * t * cfg.n_bilinear * h * h + 2 * e * h * h * 3)
    return 3.0 * f


def _gnn_train(spec, dims, mesh, variant, kind):
    cfg = spec.config
    cfg = dataclasses.replace(cfg, d_feat=dims["d_feat"],
                              n_classes=dims.get("n_classes",
                                                 cfg.n_classes),
                              constrain_acts={"gnn_constrained": "all",
                                              "gnn_nodes": "nodes"}.get(
                                                  variant, ""))
    if kind == "gnn_mol" and cfg.kind != "dimenet":
        cfg = dataclasses.replace(cfg, task="graph")
    bsds, n, e, n_graphs = _gnn_batch_sds(cfg, dims, mesh, kind)
    params = jax.eval_shape(
        lambda: gnn.init_params(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(lambda: adamw.init(params))

    def loss_fn(p, b):
        return gnn.gnn_loss(p, {**b, "n_graphs": n_graphs}, cfg)

    step = make_train_step(loss_fn, OPT)
    rule = sh.gnn_param_rule(mesh)
    pshard = sh.shardings_for_tree(params, rule, mesh)
    oshard = adamw.AdamWState(
        step=NamedSharding(mesh, P()),
        mu=sh.shardings_for_tree(params, rule, mesh),
        nu=sh.shardings_for_tree(params, rule, mesh))
    axes = tuple(mesh.axis_names)
    bspec = sh.gnn_batch_spec(mesh, full_graph=True)
    bshard = {}
    for k, v in bsds.items():
        spec_k = bspec.get(k, P(axes))
        if v.shape[0] == n_graphs and n_graphs % _mesh_total(mesh) != 0:
            spec_k = P(*([None] * len(v.shape)))      # tiny: replicate
        bshard[k] = NamedSharding(mesh, spec_k)
    t = bsds.get("trip_in")
    meta = {
        "model_flops": _gnn_flops(cfg, n, e,
                                  t.shape[0] if t is not None else 0),
        "model_flops_note": "analytic per-family (fwd+bwd=3x fwd)",
        "n_nodes": n, "n_edges": e,
    }
    return Lowerable(step, (params, opt, bsds), (pshard, oshard, bshard),
                     meta)


# -------------------------------------------------------------- recsys cells
def _rec_train(spec, dims, mesh, variant):
    cfg = spec.config
    batch, seq = dims["batch"], cfg.seq_len
    params = jax.eval_shape(
        lambda: sasrec.init_params(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(lambda: adamw.init(params))
    bsds = {k: _sds((batch, seq), jnp.int32) for k in ("hist", "pos", "neg")}
    step = make_train_step(lambda p, b: sasrec.bce_loss(p, b, cfg), OPT)
    rule = sh.recsys_param_rule(mesh)
    pshard = sh.shardings_for_tree(params, rule, mesh)
    oshard = adamw.AdamWState(
        step=NamedSharding(mesh, P()),
        mu=sh.shardings_for_tree(params, rule, mesh),
        nu=sh.shardings_for_tree(params, rule, mesh))
    dp = sh.dp_axes(mesh)
    bshard = {k: NamedSharding(mesh, P(_safe(dp, batch, mesh), None))
              for k in bsds}
    d = cfg.d_embed
    attn_f = cfg.n_blocks * (4 * batch * seq * d * d + 2 * batch * seq * seq * d)
    emb_f = 2 * batch * seq * d * 3
    meta = {"model_flops": 3.0 * (attn_f + emb_f),
            "model_flops_note": "analytic fwd+bwd",
            "tokens_per_step": batch * seq}
    return Lowerable(step, (params, opt, bsds), (pshard, oshard, bshard),
                     meta)


def _rec_serve(spec, dims, mesh, variant):
    cfg = spec.config
    batch, seq = dims["batch"], cfg.seq_len
    params = jax.eval_shape(
        lambda: sasrec.init_params(jax.random.PRNGKey(0), cfg))

    from repro import dist as _dist
    from jax.sharding import PartitionSpec as _P
    dp = sh.dp_axes(mesh)
    vshard = mesh.shape["model"]

    def fn(p, hist):
        scores = sasrec.score_catalog(p, hist, cfg)
        # (B, V): batch over DP, catalog over 'model'
        scores = _dist.constrain(
            scores, lambda m: _P(sh.dp_axes(m), "model"))

        # Distributed top-k.  XLA's sort partitioning REPLICATES the
        # operand (976 GiB/device for the bulk cell, measured), so stage 1
        # runs as an explicit shard_map: local top-100 per catalog shard
        # with globally-offset indices, then a cheap merge top-100 over
        # the (B, shards*100) gathered candidates.
        def local_topk(sc):                      # (B/dp, V/vshard)
            v_loc, i_loc = jax.lax.top_k(sc, 100)
            off = jax.lax.axis_index("model") * sc.shape[-1]
            return v_loc, (i_loc + off).astype(jnp.int32)

        v_loc, i_loc = _dist.shard_map(
            local_topk, mesh=mesh,
            in_specs=_P(dp, "model"),
            out_specs=(_P(dp, "model"), _P(dp, "model")))(scores)
        # (B, vshard*100) candidates, batch-sharded; merge is tiny
        v_top, pos = jax.lax.top_k(v_loc, 100)
        idx = jnp.take_along_axis(i_loc, pos, axis=1)
        return v_top, idx

    rule = sh.recsys_param_rule(mesh)
    pshard = sh.shardings_for_tree(params, rule, mesh)
    dp = sh.dp_axes(mesh)
    hshard = NamedSharding(mesh, P(_safe(dp, batch, mesh), None))
    rows = sasrec.table_rows(cfg)
    d = cfg.d_embed
    f = (cfg.n_blocks * (4 * batch * seq * d * d + 2 * batch * seq * seq * d)
         + 2 * batch * rows * d)
    meta = {"model_flops": float(f),
            "model_flops_note": "encode + full-catalog dot",
            "catalog_rows": rows}
    return Lowerable(fn, (params, _sds((batch, seq), jnp.int32)),
                     (pshard, hshard), meta)


def _rec_retrieval(spec, dims, mesh, variant):
    cfg = spec.config
    batch, seq = dims["batch"], cfg.seq_len
    n_cand = dims["n_candidates"]
    params = jax.eval_shape(
        lambda: sasrec.init_params(jax.random.PRNGKey(0), cfg))
    fn = lambda p, h, c: sasrec.score_candidates(p, h, c, cfg)
    rule = sh.recsys_param_rule(mesh)
    pshard = sh.shardings_for_tree(params, rule, mesh)
    hshard = NamedSharding(mesh, P(None, None))
    cshard = NamedSharding(mesh, P(None, _safe("model", n_cand, mesh)))
    d = cfg.d_embed
    f = cfg.n_blocks * (4 * batch * seq * d * d
                        + 2 * batch * seq * seq * d) + 2 * batch * n_cand * d
    meta = {"model_flops": float(f),
            "model_flops_note": "encode + 1M-candidate batched dot"}
    return Lowerable(fn, (params, _sds((batch, seq), jnp.int32),
                          _sds((batch, n_cand), jnp.int32)),
                     (pshard, hshard, cshard), meta)


# ------------------------------------------------------------------ dispatch
def build(arch_id: str, shape_name: str, mesh: Mesh,
          variant: str = "baseline") -> Lowerable:
    spec = get_arch(arch_id)
    shape = spec.shapes[shape_name]
    if shape.skip:
        raise ValueError(f"cell {arch_id}/{shape_name} skipped: {shape.skip}")
    kind = shape.kind
    if kind == "lm_train":
        return _lm_train(spec, shape.dims, mesh, variant)
    if kind == "lm_prefill":
        return _lm_prefill(spec, shape.dims, mesh, variant)
    if kind == "lm_decode":
        return _lm_decode(spec, shape.dims, mesh, variant)
    if kind in ("gnn_full", "gnn_mini", "gnn_mol"):
        return _gnn_train(spec, shape.dims, mesh, variant, kind)
    if kind == "rec_train":
        return _rec_train(spec, shape.dims, mesh, variant)
    if kind == "rec_serve":
        return _rec_serve(spec, shape.dims, mesh, variant)
    if kind == "rec_retrieval":
        return _rec_retrieval(spec, shape.dims, mesh, variant)
    raise ValueError(f"unknown shape kind {kind}")
