"""Production mesh factory.

Defined as a FUNCTION so importing this module never touches jax device
state.  Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is pure
data parallelism whose gradient all-reduce crosses DCN once per step.
"""

from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} "
            f"present — run under XLA_FLAGS=--xla_force_host_platform_"
            f"device_count=512 (see launch/dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """1-device mesh for CPU smoke tests of the sharded step functions."""
    import jax

    return jax.make_mesh(shape, axes, devices=jax.devices()[:1])


def make_columns_mesh(n_devices=None):
    """1-D ``("data",)`` mesh over (up to) all local devices for the
    device-sharded columnar data plane (``repro.dist.columns``).

    Deliberately NOT registered through ``repro.dist.set_mesh`` — the
    plane passes it to ``shard_map`` explicitly, so the process-global
    mesh that the model helpers consult stays whatever the deployment
    installed (usually unset on CPU).
    """
    import jax

    devices = jax.devices()
    n = len(devices) if n_devices is None else min(int(n_devices),
                                                   len(devices))
    return jax.make_mesh((n,), ("data",), devices=devices[:n])
