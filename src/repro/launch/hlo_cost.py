"""Loop-aware static cost analysis over post-SPMD HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, but a
scan-over-layers transformer executes it ``n_layers`` times — so flops,
bytes and collective volumes of scanned models are undercounted by
1-2 orders of magnitude (verified against an unrolled compile; see
tests/test_hlo_cost.py).  This module re-derives the three roofline
inputs from the HLO text with while-loop trip-count multipliers:

* parse computations and their ops (shapes, operands);
* find ``while`` ops, extract the trip count from the loop-condition
  computation's comparison constant;
* propagate multipliers: multiplier(body) = multiplier(parent) * trips;
* flops:   2 * prod(output dims) * contraction-size for every ``dot``
  (counted inside fusion bodies too, times the enclosing multiplier);
* bytes:   output + operand bytes of top-level ops (fusion bodies count
  as one op — their internals stay in registers/VMEM);
* collectives: output bytes of all-reduce / all-gather / reduce-scatter /
  all-to-all / collective-permute, times multiplier.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|"
    r"pred|c64|c128|token)\[([0-9,]*)\](?:\{[^}]*\})?")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
# NOTE: tuple types may contain /*index=N*/ comments (with '='), so the
# tuple branch lazily matches anything up to the ") op(" anchor.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\(.*?\))|(?:[\w\[\],{}]+))\s+"
    r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _dims(dim_str: str) -> List[int]:
    return [int(d) for d in dim_str.split(",") if d] if dim_str else []


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    return _dims(m.group(2)) if m else []


@dataclass
class Op:
    name: str
    kind: str
    shape_str: str
    line: str
    operands: List[str]


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # op -> shape str


def parse_computations(hlo: str) -> Tuple[Dict[str, "Computation"],
                                          Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") or line.lstrip().startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                # record parameters' shapes from the header (nested tuple
                # types are skipped — their reads go through
                # get-tuple-element, which we do not charge anyway)
                if "->" in line:
                    hdr = line[: line.rindex("->")]
                    for pm in re.finditer(
                            r"([\w.\-]+):\s*((?:f|s|u|pred|bf|c)[\w]*"
                            r"\[[0-9,]*\](?:\{[^}]*\})?)", hdr):
                        cur.shapes[pm.group(1)] = pm.group(2)
                continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            name, shape_str, kind = m.group(1), m.group(2), m.group(3)
            args = line[m.end():]
            # operands: %refs before the closing paren of the op call
            depth = 1
            end = 0
            for i, ch in enumerate(args):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operands = _OPERAND_RE.findall(args[:end])
            cur.ops.append(Op(name, kind, shape_str, line, operands))
            cur.shapes[name] = shape_str
    return comps, entry


def _while_info(op: Op, line: str) -> Tuple[Optional[str], Optional[str]]:
    body = re.search(r"body=%?([\w.\-]+)", line)
    cond = re.search(r"condition=%?([\w.\-]+)", line)
    return (body.group(1) if body else None,
            cond.group(1) if cond else None)


def _trip_count(cond: Computation) -> int:
    """Loop bound from the condition computation: the comparison constant
    (plus 1 for direction=LE).  Falls back to 1 when unparseable."""
    consts: List[int] = []
    le = False
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                consts.append(int(m.group(1)))
        if op.kind == "compare" and "direction=LE" in op.line:
            le = True
    if not consts:
        return 1
    t = max(consts)
    if le:
        t += 1
    return max(t, 1)


def _dot_flops(op: Op, comp: Computation) -> float:
    out_dims = _first_shape_dims(op.shape_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # contraction size from lhs shape + dnums
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    lhs_name = op.operands[0] if op.operands else None
    k = 1
    if m and lhs_name and lhs_name in comp.shapes:
        lhs_dims = _first_shape_dims(comp.shapes[lhs_name])
        for idx in _dims(m.group(1)):
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
    return 2.0 * out_elems * k


@dataclass
class CostReport:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)
    while_trips: Dict[str, int] = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(hlo: str, entry_hint: str = "main") -> CostReport:
    comps, entry = parse_computations(hlo)
    rep = CostReport(collective_bytes={c: 0.0 for c in COLLECTIVES},
                     collective_counts={c: 0.0 for c in COLLECTIVES})

    # ---- multiplier propagation -------------------------------------------
    if entry is None:
        # fallback: exact-prefix "main", else the never-referenced one
        referenced = set()
        for comp in comps.values():
            for op in comp.ops:
                for r in re.findall(
                        r"(?:body|condition|to_apply|branch_computations|"
                        r"calls)=\{?%?([\w.\-]+)", op.line):
                    referenced.add(r)
        for name in comps:
            if name == entry_hint or name.startswith(entry_hint + "."):
                entry = name
                break
        if entry is None:
            cands = [n for n in comps if n not in referenced]
            entry = cands[-1] if cands else next(iter(comps))

    mult: Dict[str, float] = {}

    def visit(name: str, m: float) -> None:
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        comp = comps[name]
        for op in comp.ops:
            if op.kind == "while":
                body, cond = _while_info(op, op.line)
                trips = _trip_count(comps[cond]) if cond in comps else 1
                rep.while_trips[body or op.name] = trips
                if body:
                    visit(body, m * trips)
                if cond:
                    visit(cond, m * (trips + 1))
            elif op.kind in ("fusion", "call", "custom-call", "map",
                             "reduce", "reduce-window", "scatter", "sort",
                             "conditional", "select-and-scatter",
                             "all-reduce", "reduce-scatter"):
                for r in re.findall(
                        r"(?:to_apply|calls)=%?([\w.\-]+)", op.line):
                    visit(r, m)
                for r in re.findall(r"branch_computations=\{([^}]*)\}",
                                    op.line):
                    for b in _OPERAND_RE.findall(r) or \
                            [x.strip().lstrip("%") for x in r.split(",")]:
                        visit(b, m)

    visit(entry, 1.0)

    # fusion-body computations (flops counted, bytes not)
    fusion_bodies = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "fusion":
                for r in re.findall(r"calls=%?([\w.\-]+)", op.line):
                    fusion_bodies.add(r)
                    mult.setdefault(r, mult.get(comp.name, 1.0))
                    if mult.get(r, 0.0) == 0.0:
                        mult[r] = mult.get(comp.name, 1.0)

    # propagate multipliers into fusion bodies from their callers
    for comp in comps.values():
        cm = mult.get(comp.name, 0.0)
        if cm == 0.0:
            continue
        for op in comp.ops:
            if op.kind == "fusion":
                for r in re.findall(r"calls=%?([\w.\-]+)", op.line):
                    mult[r] = max(mult.get(r, 0.0), cm)

    # ---- cost accumulation ----------------------------------------------------
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        in_fusion_body = comp.name in fusion_bodies
        for op in comp.ops:
            if op.kind in ("dot", "convolution"):
                rep.flops += m * _dot_flops(op, comp)
            if in_fusion_body:
                continue                      # bytes stay on-chip
            if op.kind in ("parameter", "constant", "get-tuple-element",
                           "tuple", "bitcast", "copy", "copy-start",
                           "copy-done"):
                # copies are loop-carry aliasing artifacts of the CPU
                # backend; on TPU buffer donation elides them
                continue
            out_b = _shape_bytes(op.shape_str)
            if op.kind == "fusion":
                # a fusion whose root is a dynamic-update-slice is an
                # in-place slice write on TPU: charge 2x the update size,
                # not the full carried buffer
                called = re.findall(r"calls=%?([\w.\-]+)", op.line)
                root = None
                if called and called[0] in comps and comps[called[0]].ops:
                    inner_c = comps[called[0]]
                    by_name = {o.name: o for o in inner_c.ops}
                    root = inner_c.ops[-1]
                    # walk through wrapper ops to the real producer
                    for _ in range(6):
                        if root.kind in ("bitcast", "convert", "reshape",
                                         "transpose", "copy") \
                                and root.operands \
                                and root.operands[0] in by_name:
                            root = by_name[root.operands[0]]
                        else:
                            break
                if root is not None and root.kind == "dynamic-update-slice":
                    inner = comps[called[0]]
                    upd_b = (_shape_bytes(inner.shapes[root.operands[1]])
                             if len(root.operands) > 1
                             and root.operands[1] in inner.shapes
                             else _shape_bytes(root.shape_str))
                    rep.bytes_accessed += m * 2 * min(upd_b, out_b)
                    continue
                in_b = sum(_shape_bytes(comp.shapes[o])
                           for o in op.operands if o in comp.shapes)
                rep.bytes_accessed += m * (out_b + in_b)
                base = op.kind
                continue
            if op.kind in ("gather", "dynamic-slice"):
                # reads only the gathered/sliced rows, not the operand
                in_b = out_b
            elif op.kind in ("scatter", "dynamic-update-slice"):
                # touches only the update region (in-place on TPU)
                upd = (_shape_bytes(comp.shapes[op.operands[1]])
                       if len(op.operands) > 1
                       and op.operands[1] in comp.shapes else out_b)
                rep.bytes_accessed += m * 2 * min(upd, out_b)
                continue
            else:
                in_b = sum(_shape_bytes(comp.shapes[o])
                           for o in op.operands if o in comp.shapes)
            rep.bytes_accessed += m * (out_b + in_b)
            base = op.kind.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and not op.kind.endswith("-done"):
                rep.collective_bytes[base] += m * out_b
                rep.collective_counts[base] += m
    return rep
