"""Serving launcher: ``python -m repro.launch.serve --mode <graph|lm|rec>``.

graph: boot a Weaver deployment, load a synthetic social graph, serve the
TAO read/write mix (the paper's native serving workload).
lm / rec: batched model serving on reduced configs (CPU container).
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def serve_graph(args) -> int:
    from repro.configs import PAPER_DEPLOYMENT
    from repro.core import Weaver
    from repro.data import synth
    from repro.runtime import GraphQueryServer

    w = Weaver(PAPER_DEPLOYMENT)
    rng = np.random.default_rng(args.seed)
    edges = synth.social_graph(rng, n_users=args.users, avg_degree=8)
    vertices = sorted({v for e in edges for v in e})
    # bulk load in chunks of transactions
    for i in range(0, len(vertices), 64):
        tx = w.begin_tx()
        for v in vertices[i:i + 64]:
            tx.create_vertex(v)
        assert w.run_tx(tx).ok
    for i in range(0, len(edges), 64):
        tx = w.begin_tx()
        for s, d in edges[i:i + 64]:
            tx.create_edge(s, d)
        assert w.run_tx(tx).ok

    server = GraphQueryServer(w)
    ops = synth.tao_workload(rng, args.requests, read_frac=0.998,
                             vertices=vertices)
    t0 = w.sim.now
    for op in ops:
        if op["type"] in ("get_edges", "count_edges", "get_node"):
            server.submit("prog", (op["type"], [(op["v"], None)]))
        elif op["type"] == "create_edge":
            tx = w.begin_tx()
            tx.create_edge(op["v"], op["u"])
            server.submit("tx", tx)
        else:
            ed = w.read_vertex(op["v"])
            if ed and ed["edges"]:
                tx = w.begin_tx()
                tx.delete_edge(op["v"], next(iter(ed["edges"])))
                server.submit("tx", tx)
    server.drain(timeout=30.0)
    dt = w.sim.now - t0
    done = len(server.completed)
    print(f"served {done}/{len(ops)} requests in {dt:.3f}s simulated "
          f"-> {done / max(dt, 1e-9):,.0f} req/s")
    c = w.counters()
    print(f"oracle calls: {c['oracle_calls']}, announce msgs: "
          f"{c['announce_messages']}, committed tx: {c['tx_committed']}")
    return 0


def serve_lm(args) -> int:
    import jax
    from repro.configs import get_arch
    from repro.launch.train import reduced_config
    from repro.models import transformer
    from repro.runtime import LMServer

    spec = get_arch(args.arch or "gemma3-1b")
    cfg = reduced_config(spec)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(args.seed)
    srv = LMServer(params, cfg, batch=args.batch, max_len=64)
    prompts = rng.integers(0, cfg.vocab, (args.batch, 16))
    first = srv.prefill_batch(prompts)
    toks = srv.decode(first, steps=16)
    print(f"decoded {toks.shape} tokens for {args.batch} sessions")
    return 0


def serve_rec(args) -> int:
    import jax
    from repro.configs import get_arch
    from repro.launch.train import reduced_config
    from repro.models import sasrec
    from repro.runtime import RecServer

    cfg = reduced_config(get_arch("sasrec"))
    params = sasrec.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(args.seed)
    srv = RecServer(params, cfg)
    hist = rng.integers(1, cfg.n_items + 1, (args.batch, cfg.seq_len))
    top = srv.top_k(hist, k=10)
    print(f"top-10 recommendations for {args.batch} users: {top.shape}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["graph", "lm", "rec"],
                    default="graph")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--users", type=int, default=200)
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return {"graph": serve_graph, "lm": serve_lm,
            "rec": serve_rec}[args.mode](args)


if __name__ == "__main__":
    sys.exit(main())
