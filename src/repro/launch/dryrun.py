import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: ``.lower().compile()`` every (arch x shape x mesh)
cell, print ``memory_analysis()`` + ``cost_analysis()``, parse collective
bytes out of the post-SPMD HLO, and emit roofline terms per cell.

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count on first init) — and is deliberately NOT set in conftest.py
or pyproject: smoke tests and benches see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
      --shape long_500k --mesh single --variant window_cache
Results accumulate under results/dryrun/<arch>__<shape>__<mesh>__<variant>.json
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import numpy as np

# TPU v5e hardware constants (targets; this container is CPU)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per-chip usable, 1 link)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.MULTILINE)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-tensor bytes of every collective op in post-SPMD HLO.

    The per-device transfer volume of ring all-gather/all-reduce is
    ~(n-1)/n x tensor bytes; we record raw tensor bytes (upper bound) and
    per-op counts so §Roofline can reason about both.
    """
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        out[op] += _shape_bytes(shape_str)
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": float(sum(out.values()))}


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> dict:
    tc = flops_per_dev / PEAK_FLOPS
    tm = bytes_per_dev / HBM_BW
    tn = coll_bytes_per_dev / ICI_BW
    dom = max((tc, "compute"), (tm, "memory"), (tn, "collective"))[1]
    return {"compute_s": tc, "memory_s": tm, "collective_s": tn,
            "dominant": dom,
            "step_s_lower_bound": max(tc, tm, tn)}


def run_cell(arch: str, shape: str, mesh_kind: str, variant: str,
             outdir: Path, hlo_dir=None) -> dict:
    import jax
    from repro.configs import get_arch
    from repro.launch import input_specs
    from repro.launch.mesh import make_production_mesh

    spec = get_arch(arch)
    sh = spec.shapes[shape]
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "variant": variant, "status": "ok"}
    if sh.skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = sh.skip
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    from repro import dist
    dist.set_mesh(mesh)
    low = input_specs.build(arch, shape, mesh, variant)
    with mesh:
        jitted = jax.jit(low.fn, in_shardings=low.in_shardings)
        lowered = jitted.lower(*low.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    if hlo_dir is not None:
        Path(hlo_dir).mkdir(parents=True, exist_ok=True)
        (Path(hlo_dir) / f"{arch}__{shape}__{mesh_kind}__{variant}.hlo.txt"
         ).write_text(hlo[:50_000_000])

    # loop-aware static costing (XLA's cost_analysis counts while bodies
    # ONCE — a scan-over-layers model is undercounted ~n_layers x; see
    # repro/launch/hlo_cost.py and tests/test_hlo_cost.py)
    from repro.launch import hlo_cost
    rep = hlo_cost.analyze(hlo)
    flops = rep.flops                            # per-device, post-SPMD
    bytes_acc = rep.bytes_accessed
    coll_per_dev = rep.total_collective_bytes
    coll = {"bytes": rep.collective_bytes,
            "counts": rep.collective_counts,
            "total_bytes": coll_per_dev,
            "while_trips": rep.while_trips}
    xla_raw = {"flops": float(cost.get("flops", 0.0)),
               "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    terms = roofline_terms(flops, bytes_acc, coll_per_dev)
    model_flops = low.meta.get("model_flops", 0.0)
    useful = model_flops / max(flops * n_chips, 1.0)

    rec.update({
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost": {"flops_per_device": flops,
                 "bytes_accessed_per_device": bytes_acc,
                 "xla_raw_uncorrected": xla_raw},
        "collectives": coll,
        "roofline": terms,
        "model_flops": model_flops,
        "useful_flops_fraction": useful,
        "meta": low.meta,
    })
    outdir.mkdir(parents=True, exist_ok=True)
    fn = outdir / f"{arch}__{shape}__{mesh_kind}__{variant}.json"
    fn.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--hlo-dir", default=None,
                    help="dump post-SPMD HLO text per cell")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCHS

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    outdir = Path(args.outdir)
    cells = []
    for aid, spec in ARCHS.items():
        if args.arch and aid != args.arch:
            continue
        for sname in spec.shapes:
            if args.shape and sname != args.shape:
                continue
            for mk in meshes:
                cells.append((aid, sname, mk))

    failures = 0
    for aid, sname, mk in cells:
        tag = f"{aid}/{sname}/{mk}/{args.variant}"
        fn = outdir / f"{aid}__{sname}__{mk}__{args.variant}.json"
        if args.skip_existing and fn.exists():
            prev = json.loads(fn.read_text())
            if prev.get("status") in ("ok", "skipped"):
                print(f"[skip-existing] {tag}")
                continue
        try:
            rec = run_cell(aid, sname, mk, args.variant, outdir,
                           args.hlo_dir)
            if rec["status"] == "skipped":
                print(f"[SKIP] {tag}: {rec['skip_reason']}")
                outdir.mkdir(parents=True, exist_ok=True)
                fn.write_text(json.dumps(rec, indent=1))
            else:
                r = rec["roofline"]
                print(f"[ok] {tag}: compile {rec['compile_s']}s "
                      f"flops/dev {rec['cost']['flops_per_device']:.3e} "
                      f"dom={r['dominant']} "
                      f"peak_mem {rec['memory']['peak_estimate_bytes']/2**30:.2f} GiB")
        except Exception as e:
            failures += 1
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
            traceback.print_exc(limit=4)
            outdir.mkdir(parents=True, exist_ok=True)
            fn.write_text(json.dumps(
                {"arch": aid, "shape": sname, "mesh": mk,
                 "variant": args.variant, "status": "fail",
                 "error": f"{type(e).__name__}: {e}"}, indent=1))
        sys.stdout.flush()
    print(f"done: {len(cells)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
