"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

On this CPU container it runs REDUCED configs (same code path as
production; the full configs lower via dryrun.py).  On a real cluster the
same entry point runs under ``jax.distributed.initialize()`` with the
production mesh.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys

import numpy as np


def reduced_config(spec):
    from repro.models import gnn, sasrec, transformer
    cfg = spec.config
    if spec.family == "lm":
        moe = cfg.moe
        if moe is not None:
            moe = dataclasses.replace(moe, n_experts=4,
                                      top_k=min(moe.top_k, 2), d_expert=32)
        return dataclasses.replace(
            cfg, n_layers=2, d_model=64, n_heads=4,
            n_kv=max(1, cfg.n_kv * 4 // cfg.n_heads), d_head=16, d_ff=128,
            vocab=512, moe=moe, dtype="float32")
    if spec.family == "gnn":
        return dataclasses.replace(cfg, d_hidden=32, d_feat=16, n_classes=4)
    return dataclasses.replace(cfg, n_items=1000, seq_len=16, d_embed=32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    from repro.configs import get_arch
    from repro.data import graphs as G, synth
    from repro.models import gnn, sasrec, transformer
    from repro.optim import AdamWConfig
    from repro.runtime import Trainer, TrainerConfig

    spec = get_arch(args.arch)
    cfg = reduced_config(spec)
    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)

    if spec.family == "lm":
        params = transformer.init_params(key, cfg)
        loss_fn = lambda p, b: transformer.lm_loss(p, b, cfg)
        def batches():
            while True:
                yield synth.lm_batch(rng, cfg.vocab, args.batch, args.seq)
    elif spec.family == "gnn":
        n, e = 256, 1024
        src, dst = G.random_graph(rng, n, e)
        if cfg.kind == "dimenet":
            tin, tout = G.build_triplets(src, dst, max_per_edge=4)
            base = {"species": rng.integers(0, 8, n).astype(np.int32),
                    "pos": rng.normal(size=(n, 3)).astype(np.float32),
                    "edge_src": src, "edge_dst": dst,
                    "trip_in": tin, "trip_out": tout,
                    "graph_ids": np.zeros(n, np.int32), "n_graphs": 1,
                    "labels": np.asarray([0.5], np.float32)}
        else:
            base = {"x": rng.normal(size=(n, cfg.d_feat)).astype(np.float32),
                    "edge_src": src, "edge_dst": dst,
                    "graph_ids": np.zeros(n, np.int32), "n_graphs": 1,
                    "labels": rng.integers(0, cfg.n_classes,
                                           n).astype(np.int32)}
        params = gnn.init_params(key, cfg)
        loss_fn = lambda p, b: gnn.gnn_loss(p, b, cfg)
        def batches():
            while True:
                yield base
    else:
        params = sasrec.init_params(key, cfg)
        loss_fn = lambda p, b: sasrec.bce_loss(p, b, cfg)
        def batches():
            while True:
                yield synth.sasrec_batch(rng, cfg.n_items, args.batch,
                                         cfg.seq_len)

    trainer = Trainer(
        loss_fn, params,
        AdamWConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps),
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=10))
    if args.resume and trainer.try_resume():
        print(f"resumed at step {trainer.step}")
    hist = trainer.fit(batches())
    print(f"final loss {hist[-1]['loss']:.4f} after {trainer.step} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
