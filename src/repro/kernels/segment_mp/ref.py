"""Pure-jnp oracle for fused message passing:

    y[v] = sum_{e : dst[e] = v} x[src[e]] @ W

the SpMM-regime hot op behind GIN/PNA aggregation and traversal node
programs (frontier expansion is this op with W = I and boolean x).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_matmul_reduce_ref(x: jnp.ndarray, w: jnp.ndarray,
                              edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                              n_nodes: int) -> jnp.ndarray:
    msgs = x[edge_src] @ w
    return jax.ops.segment_sum(msgs, edge_dst, num_segments=n_nodes)
