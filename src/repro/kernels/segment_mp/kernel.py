"""Pallas TPU kernel: fused (gather -> matmul -> segment-reduce).

TPU adaptation of the FusedMM/GE-SpMM GPU pattern (DESIGN.md §3):
instead of warp-per-row scatter with atomics (no TPU analogue), edges are
**pre-sorted by destination** and packed into a block-ELL layout so that

* each grid row ``i`` owns a contiguous destination-node block
  ``[i*BN, (i+1)*BN)`` and the edge tiles that target it,
* the inner grid dim ``j`` streams that block's edge tiles; the gathered
  source features ``xs`` arrive as ``(BE, D)`` VMEM tiles,
* the segment reduction is a **one-hot matmul on the MXU**:
  ``out += onehot(dst_local) @ (xs @ W)`` — a (BN, BE) x (BE, F) product,
  which is the TPU-idiomatic replacement for scatter-add,
* the output block lives in VMEM across all ``j`` iterations (its
  BlockSpec index ignores ``j``) and accumulates.

Padding edges carry ``dst = -1`` and never match a one-hot row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 128     # destination nodes per block
DEFAULT_BLOCK_E = 256     # edges per tile


def _mp_kernel(xs_ref, dst_ref, w_ref, out_ref, acc_ref, *,
               block_n: int):
    j = pl.program_id(1)
    n_tiles = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xs = xs_ref[...]                   # (BE, D) gathered source features
    dst = dst_ref[...]                 # (1, BE) global dst ids (-1 = pad)
    w = w_ref[...]                     # (D, F)
    h = jnp.dot(xs, w, preferred_element_type=jnp.float32)   # (BE, F)
    i = pl.program_id(0)
    row_base = i * block_n
    rows = row_base + jax.lax.broadcasted_iota(jnp.int32, (block_n, 1), 0)
    onehot = (dst == rows).astype(h.dtype)                   # (BN, BE)
    # fp32 accumulation across edge tiles (better than the bf16 ref)
    acc_ref[...] += jnp.dot(onehot, h,
                            preferred_element_type=jnp.float32)

    @pl.when(j == n_tiles - 1)
    def _store():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("n_nodes", "block_n", "block_e",
                                    "interpret"))
def segment_mp_pallas(xs_packed: jnp.ndarray, dst_packed: jnp.ndarray,
                      w: jnp.ndarray, n_nodes: int,
                      block_n: int = DEFAULT_BLOCK_N,
                      block_e: int = DEFAULT_BLOCK_E,
                      interpret: bool = True) -> jnp.ndarray:
    """xs_packed (n_blocks*max_tiles*BE, D) gathered + padded features,
    dst_packed (n_blocks*max_tiles*BE,) global dst ids (-1 pad),
    w (D, F) -> y (n_nodes_padded, F) with n_nodes_padded = n_blocks*BN.
    """
    d = xs_packed.shape[1]
    f = w.shape[1]
    n_blocks = n_nodes // block_n
    assert n_nodes % block_n == 0
    total_e = xs_packed.shape[0]
    max_tiles = total_e // (n_blocks * block_e)
    assert max_tiles * n_blocks * block_e == total_e, \
        (total_e, n_blocks, block_e)

    kernel = functools.partial(_mp_kernel, block_n=block_n)
    out = pl.pallas_call(
        kernel,
        grid=(n_blocks, max_tiles),
        in_specs=[
            pl.BlockSpec((block_e, d), lambda i, j: (i * max_tiles + j, 0)),
            pl.BlockSpec((1, block_e), lambda i, j: (0, i * max_tiles + j)),
            pl.BlockSpec((d, f), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, f), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_nodes, f), w.dtype),
        scratch_shapes=[pltpu.VMEM((block_n, f), jnp.float32)],
        interpret=interpret,
    )(xs_packed, dst_packed[None, :], w)
    return out
