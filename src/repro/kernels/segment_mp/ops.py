"""Layout preparation + public wrappers for the segment_mp kernel.

``pack_edges`` converts a dst-sorted edge list into the block-ELL layout
the kernel wants: for each destination-node block, its edges padded to a
whole number of ``block_e`` tiles; every block padded to the max tile
count (regular grid).  Pad slots carry src=0 / dst=-1.

``segment_reduce_sorted`` is the scalar sibling used by the
frontier-batched node-program runtime (``repro.core.frontier``) for
per-hop neighbour aggregation: it reduces values over *pre-sorted*
segment keys, returning the compressed ``(unique_keys, reduced)`` form a
frontier exchange wants (the next hop's packed frontier IS the unique
key set).  On CPU it is a ``reduceat`` over the sorted runs; off-CPU it
routes through ``jax.ops.segment_*`` with ``indices_are_sorted=True`` —
the same sortedness contract the block-ELL kernel exploits.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import (DEFAULT_BLOCK_E, DEFAULT_BLOCK_N, segment_mp_pallas)
from .ref import segment_matmul_reduce_ref


def pack_edges(edge_src: np.ndarray, edge_dst: np.ndarray, n_nodes: int,
               block_n: int = DEFAULT_BLOCK_N,
               block_e: int = DEFAULT_BLOCK_E
               ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Returns (src_packed, dst_packed, n_nodes_padded); edges need not be
    pre-sorted."""
    edge_src = np.asarray(edge_src)
    edge_dst = np.asarray(edge_dst)
    order = np.argsort(edge_dst, kind="stable")
    src = edge_src[order]
    dst = edge_dst[order]
    n_pad = -(-n_nodes // block_n) * block_n
    n_blocks = n_pad // block_n
    # edges per block
    blk = dst // block_n
    counts = np.bincount(blk, minlength=n_blocks)
    max_tiles = max(1, int(-(-counts.max() // block_e))) if counts.size \
        else 1
    cap = max_tiles * block_e
    src_packed = np.zeros((n_blocks * cap,), np.int32)
    dst_packed = np.full((n_blocks * cap,), -1, np.int32)
    starts = np.zeros(n_blocks + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    for b in range(n_blocks):
        lo, hi = starts[b], starts[b + 1]
        m = hi - lo
        src_packed[b * cap: b * cap + m] = src[lo:hi]
        dst_packed[b * cap: b * cap + m] = dst[lo:hi]
    return src_packed, dst_packed, n_pad


_REDUCERS = {
    "min": (np.minimum, "segment_min"),
    "max": (np.maximum, "segment_max"),
    "sum": (np.add, "segment_sum"),
}


def segment_starts(keys: np.ndarray) -> np.ndarray:
    """Run starts of a sorted key array: positions where a new segment
    begins (``keys`` must be non-decreasing)."""
    if keys.size == 0:
        return np.zeros(0, np.int64)
    return np.flatnonzero(np.r_[True, keys[1:] != keys[:-1]])


def segment_reduce_sorted(values: np.ndarray, keys: np.ndarray,
                          op: str = "min",
                          use_jax: Optional[bool] = None):
    """Reduce ``values`` over equal runs of the SORTED ``keys``.

    Returns ``(unique_keys, reduced)`` — compressed form, one entry per
    distinct key in ascending order.  ``use_jax=None`` picks the jax
    segment op (``indices_are_sorted=True``) off-CPU and the numpy
    ``ufunc.reduceat`` fast path on CPU; pass True/False to force.
    """
    ufunc, seg_name = _REDUCERS[op]
    values = np.asarray(values)
    keys = np.asarray(keys)
    if keys.size == 0:
        return keys, values[:0]
    if use_jax is None:
        use_jax = jax.default_backend() != "cpu"
    starts = segment_starts(keys)
    uniq = keys[starts]
    if not use_jax:
        return uniq, ufunc.reduceat(values, starts)
    # dense segment ids from the run starts, then the sorted segment op
    seg_ids = np.cumsum(np.r_[False, keys[1:] != keys[:-1]])
    fn = getattr(jax.ops, seg_name)
    out = fn(jnp.asarray(values), jnp.asarray(seg_ids),
             num_segments=int(uniq.size), indices_are_sorted=True)
    return uniq, np.asarray(out)


def segment_count_sorted(keys: np.ndarray):
    """(unique_keys, run_lengths) of a sorted key array."""
    keys = np.asarray(keys)
    if keys.size == 0:
        return keys, np.zeros(0, np.int64)
    starts = segment_starts(keys)
    return keys[starts], np.diff(np.r_[starts, keys.size])


def segment_matmul_reduce(x: jnp.ndarray, w: jnp.ndarray,
                          edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                          n_nodes: int,
                          block_n: int = DEFAULT_BLOCK_N,
                          block_e: int = DEFAULT_BLOCK_E,
                          interpret: bool = True) -> jnp.ndarray:
    """Drop-in equivalent of the jnp reference (repro.models.mp seam)."""
    src_packed, dst_packed, n_pad = pack_edges(
        np.asarray(edge_src), np.asarray(edge_dst), n_nodes,
        block_n, block_e)
    xs = jnp.asarray(x)[src_packed]        # gather (XLA); kernel fuses
    y = segment_mp_pallas(xs, jnp.asarray(dst_packed), jnp.asarray(w),
                          n_pad, block_n=block_n, block_e=block_e,
                          interpret=interpret)
    return y[:n_nodes]
