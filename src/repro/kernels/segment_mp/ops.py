"""Layout preparation + public wrapper for the segment_mp kernel.

``pack_edges`` converts a dst-sorted edge list into the block-ELL layout
the kernel wants: for each destination-node block, its edges padded to a
whole number of ``block_e`` tiles; every block padded to the max tile
count (regular grid).  Pad slots carry src=0 / dst=-1.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import (DEFAULT_BLOCK_E, DEFAULT_BLOCK_N, segment_mp_pallas)
from .ref import segment_matmul_reduce_ref


def pack_edges(edge_src: np.ndarray, edge_dst: np.ndarray, n_nodes: int,
               block_n: int = DEFAULT_BLOCK_N,
               block_e: int = DEFAULT_BLOCK_E
               ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Returns (src_packed, dst_packed, n_nodes_padded); edges need not be
    pre-sorted."""
    edge_src = np.asarray(edge_src)
    edge_dst = np.asarray(edge_dst)
    order = np.argsort(edge_dst, kind="stable")
    src = edge_src[order]
    dst = edge_dst[order]
    n_pad = -(-n_nodes // block_n) * block_n
    n_blocks = n_pad // block_n
    # edges per block
    blk = dst // block_n
    counts = np.bincount(blk, minlength=n_blocks)
    max_tiles = max(1, int(-(-counts.max() // block_e))) if counts.size \
        else 1
    cap = max_tiles * block_e
    src_packed = np.zeros((n_blocks * cap,), np.int32)
    dst_packed = np.full((n_blocks * cap,), -1, np.int32)
    starts = np.zeros(n_blocks + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    for b in range(n_blocks):
        lo, hi = starts[b], starts[b + 1]
        m = hi - lo
        src_packed[b * cap: b * cap + m] = src[lo:hi]
        dst_packed[b * cap: b * cap + m] = dst[lo:hi]
    return src_packed, dst_packed, n_pad


def segment_matmul_reduce(x: jnp.ndarray, w: jnp.ndarray,
                          edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
                          n_nodes: int,
                          block_n: int = DEFAULT_BLOCK_N,
                          block_e: int = DEFAULT_BLOCK_E,
                          interpret: bool = True) -> jnp.ndarray:
    """Drop-in equivalent of the jnp reference (repro.models.mp seam)."""
    src_packed, dst_packed, n_pad = pack_edges(
        np.asarray(edge_src), np.asarray(edge_dst), n_nodes,
        block_n, block_e)
    xs = jnp.asarray(x)[src_packed]        # gather (XLA); kernel fuses
    y = segment_mp_pallas(xs, jnp.asarray(dst_packed), jnp.asarray(w),
                          n_pad, block_n=block_n, block_e=block_e,
                          interpret=interpret)
    return y[:n_nodes]
