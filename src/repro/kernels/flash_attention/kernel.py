"""Pallas TPU kernel: blocked online-softmax attention (FlashAttention
fwd), causal + optional sliding window.

Grid: (batch*heads, q_blocks, kv_blocks) with kv minormost, so the f32
scratch (running max m, normalizer l, accumulator acc) persists across a
q-block's kv sweep in VMEM.  The (BQ, BK) logit tile is produced on the
MXU, the rescale/accumulate path follows the standard two-pass-free
online softmax.  Finalization (acc / l) happens on the last kv step.

Adaptation note (DESIGN.md §3): the CUDA original tunes for SRAM/warp
occupancy; here block sizes are chosen so q/k/v tiles are (8,128)-aligned
for VMEM and the two matmuls per step hit the 128x128 MXU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, sq: int, sk: int,
                  causal: bool, window: Optional[int]):
    i = pl.program_id(1)
    j = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (BQ, D)
    k = k_ref[0]                                   # (BK, D)
    v = v_ref[0]                                   # (BK, D)
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = (i * block_q
             + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
             + (sk - sq))                          # align ends for decode
    k_pos = (j * block_k
             + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1))
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask = k_pos <= q_pos
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (BQ, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # (BQ, BK) f32
    alpha = jnp.exp(m_prev - m_new)                # (BQ, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * alpha
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q",
                                    "block_k", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = True) -> jnp.ndarray:
    """q (BH, Sq, D); k, v (BH, Sk, D) -> (BH, Sq, D)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q,
                                                     block_k)
    grid = (bh, sq // block_q, sk // block_k)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, sq=sq, sk=sk,
        causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
