"""Public wrapper: (B, S, H, D) GQA layout -> kernel layout, with KV
head-group expansion and shape padding."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref


def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
        causal: bool = True, window: Optional[int] = None,
        interpret: bool = True, use_ref: bool = False) -> jnp.ndarray:
    """q (B, Sq, Hq, D); k/v (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=2)
        v = jnp.repeat(v, n_rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hq, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hq, sk, d)
    fn = attention_ref if use_ref else flash_attention_pallas
    if use_ref:
        of = fn(qf, kf, vf, causal=causal, window=window)
    else:
        of = fn(qf, kf, vf, causal=causal, window=window,
                interpret=interpret)
    return of.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
