"""Pure-jnp oracle: causal (optionally sliding-window) attention.

q (BH, Sq, D), k/v (BH, Sk, D) — batch*heads folded into the leading dim
(GQA head-group expansion happens in ops.py).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True,
                  window: Optional[int] = None) -> jnp.ndarray:
    bh, sq, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqd,bkd->bqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)    # align ends (decode)
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = k_pos <= q_pos
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs.astype(q.dtype), v)
