"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package has: kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper, layout prep), ref.py (pure-jnp oracle).
All are validated in interpret=True mode against ref.py across
shape/dtype sweeps (tests/test_kernels.py).

  mv_visibility   — batched refinable-timestamp snapshot masks (the
                    paper's multi-version read path, DESIGN.md §3)
  segment_mp      — fused gather->matmul->segment-reduce message passing
                    (SpMM regime: GIN/PNA/GAT aggregation, node programs)
  flash_attention — blocked online-softmax attention (causal + sliding
                    window), the LM prefill hot-spot
  embedding_bag   — BlockSpec-driven dynamic row gather + bag reduce
                    (recsys embedding lookup)
"""
