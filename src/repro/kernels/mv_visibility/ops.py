"""Public wrapper: accepts the (N, C) row-major layout used by
``repro.core.clock.pack_many``, pads N to the block size, and dispatches
to the Pallas kernel.

Backend selection: ``interpret=None`` (the default) auto-detects —
compiled Pallas on TPU/GPU, interpreter mode on CPU (where no Mosaic
backend exists).  Pass an explicit bool to override (tests force
``interpret=True`` to exercise the kernel body on CPU).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import DEFAULT_BLOCK_N, NO_STAMP, before_pallas, \
    default_interpret, visibility_pallas
from .ref import before_cm, visibility_ref


def visibility_mask(create_rows: jnp.ndarray, delete_rows: jnp.ndarray,
                    q: jnp.ndarray, block_n: int = DEFAULT_BLOCK_N,
                    interpret: Optional[bool] = None,
                    use_ref: bool = False) -> jnp.ndarray:
    """(N, C) stamp rows + (C,) query -> (N,) bool visibility mask."""
    if interpret is None:
        interpret = default_interpret()
    n, c = create_rows.shape
    create_cm = jnp.asarray(create_rows).T
    delete_cm = jnp.asarray(delete_rows).T
    q = jnp.asarray(q)
    if use_ref:
        return visibility_ref(create_cm, delete_cm, q)
    n_pad = -(-n // block_n) * block_n
    if n_pad != n:
        pad = n_pad - n
        create_cm = jnp.pad(create_cm, ((0, 0), (0, pad)),
                            constant_values=NO_STAMP)
        delete_cm = jnp.pad(delete_cm, ((0, 0), (0, pad)),
                            constant_values=NO_STAMP)
    mask = visibility_pallas(create_cm, delete_cm, q, block_n=block_n,
                             interpret=interpret)
    return mask[:n]


def before_mask(rows: jnp.ndarray, q: jnp.ndarray,
                block_n: int = DEFAULT_BLOCK_N,
                interpret: Optional[bool] = None,
                use_ref: bool = False) -> jnp.ndarray:
    """(N, C) stamp rows + (C,) query -> (N,) bool ``row ≺ q`` mask
    (the single-table half of :func:`visibility_mask`)."""
    if interpret is None:
        interpret = default_interpret()
    n, c = rows.shape
    rows_cm = jnp.asarray(rows).T
    q = jnp.asarray(q)
    if use_ref:
        return before_cm(rows_cm, q)
    n_pad = -(-n // block_n) * block_n
    if n_pad != n:
        rows_cm = jnp.pad(rows_cm, ((0, 0), (0, n_pad - n)),
                          constant_values=NO_STAMP)
    return before_pallas(rows_cm, q, block_n=block_n,
                         interpret=interpret)[:n]
