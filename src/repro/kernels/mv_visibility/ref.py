"""Pure-jnp oracle for the multi-version visibility kernel.

Stamp rows are ``[epoch, c_0..c_{G-1}]`` int32; a row of all INT32_MAX
means "no stamp" (never deleted).  Visible at q  <=>  create ≺ q  and
not (delete ≺ q), with ``≺`` the epoch-then-vector-clock happens-before
(see repro.core.clock).  Layout here is component-major ``(C, N)`` —
the TPU-friendly orientation (components on sublanes, objects on lanes).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

NO_STAMP = np.iinfo(np.int32).max


def before_cm(rows_cm: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """rows (C, N) ≺ q (C,) -> (N,) bool."""
    is_no = rows_cm[0] == NO_STAMP
    lower_epoch = rows_cm[0] < q[0]
    same_epoch = rows_cm[0] == q[0]
    le = jnp.all(rows_cm[1:] <= q[1:, None], axis=0)
    eq = jnp.all(rows_cm[1:] == q[1:, None], axis=0)
    return jnp.where(is_no, False, lower_epoch | (same_epoch & le & ~eq))


def visibility_ref(create_cm: jnp.ndarray, delete_cm: jnp.ndarray,
                   q: jnp.ndarray) -> jnp.ndarray:
    """create/delete (C, N) int32, q (C,) int32 -> (N,) bool."""
    return before_cm(create_cm, q) & ~before_cm(delete_cm, q)
