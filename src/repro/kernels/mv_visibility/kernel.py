"""Pallas TPU kernel: batched refinable-timestamp visibility masks.

Layout: stamps component-major ``(C, N)`` so the object axis N rides the
128-wide lanes and the tiny component axis C (epoch + G gatekeeper
counters, typically 2-9) rides sublanes; the all/any reductions are
sublane reductions, and each grid step streams a ``(C, BLOCK_N)`` tile of
creates + deletes through VMEM.  The query stamp is scalar-prefetched
(SMEM) since every tile compares against the same q.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NO_STAMP = np.iinfo(np.int32).max

DEFAULT_BLOCK_N = 1024


def default_interpret() -> bool:
    """Compile on TPU/GPU; interpret only on CPU (no Mosaic backend)."""
    return jax.default_backend() == "cpu"


def _visibility_kernel(q_ref, create_ref, delete_ref, out_ref):
    q = q_ref[...]                      # (C, 1) int32 in SMEM-ish block
    c = create_ref[...]                 # (C, BN)
    d = delete_ref[...]                 # (C, BN)

    def before(rows):
        is_no = rows[0] == NO_STAMP
        lower_epoch = rows[0] < q[0, 0]
        same_epoch = rows[0] == q[0, 0]
        le = jnp.all(rows[1:] <= q[1:], axis=0)
        eq = jnp.all(rows[1:] == q[1:], axis=0)
        return jnp.where(is_no, False, lower_epoch | (same_epoch & le & ~eq))

    out_ref[...] = (before(c) & ~before(d))[None, :]


def _before_kernel(q_ref, rows_ref, out_ref):
    q = q_ref[...]                      # (C, 1)
    rows = rows_ref[...]                # (C, BN)
    is_no = rows[0] == NO_STAMP
    lower_epoch = rows[0] < q[0, 0]
    same_epoch = rows[0] == q[0, 0]
    le = jnp.all(rows[1:] <= q[1:], axis=0)
    eq = jnp.all(rows[1:] == q[1:], axis=0)
    out_ref[...] = jnp.where(is_no, False,
                             lower_epoch | (same_epoch & le & ~eq))[None, :]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def before_pallas(rows_cm: jnp.ndarray, q: jnp.ndarray,
                  block_n: int = DEFAULT_BLOCK_N,
                  interpret: bool = None) -> jnp.ndarray:
    """rows (C, N) int32, q (C,) -> (N,) bool ``row ≺ q``.

    The single-table half of :func:`visibility_pallas` — same (C, N)
    layout, block specs and grid; the device-sharded column plane
    launches it per mesh device where create and delete tables live in
    one stacked block and want independent masks.
    """
    if interpret is None:
        interpret = default_interpret()
    c_dim, n = rows_cm.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    out = pl.pallas_call(
        _before_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c_dim, 1), lambda i: (0, 0)),      # q (broadcast)
            pl.BlockSpec((c_dim, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.bool_),
        interpret=interpret,
    )(q[:, None], rows_cm)
    return out[0]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def visibility_pallas(create_cm: jnp.ndarray, delete_cm: jnp.ndarray,
                      q: jnp.ndarray, block_n: int = DEFAULT_BLOCK_N,
                      interpret: bool = None) -> jnp.ndarray:
    """create/delete (C, N) int32, q (C,) -> (N,) bool.

    N must be a multiple of ``block_n`` (ops.py pads).  ``interpret=None``
    auto-detects the backend (compiled off-CPU) — it is a static arg, so
    the branch resolves at trace time.
    """
    if interpret is None:
        interpret = default_interpret()
    c_dim, n = create_cm.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    out = pl.pallas_call(
        _visibility_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c_dim, 1), lambda i: (0, 0)),      # q (broadcast)
            pl.BlockSpec((c_dim, block_n), lambda i: (0, i)),
            pl.BlockSpec((c_dim, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.bool_),
        interpret=interpret,
    )(q[:, None], create_cm, delete_cm)
    return out[0]
