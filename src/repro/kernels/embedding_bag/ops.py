"""Public EmbeddingBag wrapper (sum/mean, -1 padding, per-sample weights)."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .kernel import embedding_bag_pallas
from .ref import embedding_bag_ref


def embedding_bag(table: jnp.ndarray, idx: jnp.ndarray,
                  weights: Optional[jnp.ndarray] = None, mode: str = "sum",
                  interpret: bool = True,
                  use_ref: bool = False) -> jnp.ndarray:
    if use_ref:
        return embedding_bag_ref(table, idx, weights, mode)
    valid = idx >= 0
    idx_safe = jnp.where(valid, idx, 0).astype(jnp.int32)
    w = jnp.ones_like(idx, dtype=table.dtype) if weights is None \
        else weights.astype(table.dtype)
    w = w * valid.astype(table.dtype)
    out = embedding_bag_pallas(table, idx_safe, w, interpret=interpret)
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)
        out = out / cnt
    return out
