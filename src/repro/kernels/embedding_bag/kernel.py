"""Pallas TPU kernel: EmbeddingBag via BlockSpec-driven dynamic row fetch.

The bag indices are **scalar-prefetched** (SMEM) so the embedding-table
BlockSpec's index_map can point each grid step directly at the row the
step needs — the gather happens in the pipeline's async copies (the same
trick MaxText/MegaBlocks-style TPU kernels use for irregular reads),
never through a big materialized (B, L, D) intermediate.

Grid: (B, L) with L minormost; the (1, D) output block accumulates the
weighted rows of one bag across its L steps.  Padding (idx = -1) is
mapped to row 0 and multiplied by weight 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, w_ref, table_ref, out_ref, *, mean: bool):
    j = pl.program_id(1)
    n_l = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    row = table_ref[...]                    # (1, D): row idx[b, j] (or 0)
    w = w_ref[0, 0]                         # scalar weight (0 for padding)
    out_ref[...] += row.astype(out_ref.dtype) * w.astype(out_ref.dtype)

    if mean:
        @pl.when(j == n_l - 1)
        def _norm():
            pass  # normalization done in ops.py (needs the count)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_pallas(table: jnp.ndarray, idx_safe: jnp.ndarray,
                         weights: jnp.ndarray,
                         interpret: bool = True) -> jnp.ndarray:
    """table (V, D); idx_safe (B, L) int32 with pads already mapped to 0;
    weights (B, L) with pads already zeroed -> (B, D) sums."""
    b, l = idx_safe.shape
    v, d = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, l),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, idx: (i, j)),      # weights
            pl.BlockSpec((1, d), lambda i, j, idx: (idx[i, j], 0)),  # table
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j, idx: (i, 0)),
    )
    kernel = functools.partial(_bag_kernel, mean=False)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(idx_safe, weights, table)
