"""Pure-jnp oracle for EmbeddingBag (sum/mean, per-sample weights).

JAX has no native EmbeddingBag — this gather + segment-reduce IS the
system's implementation (kernel taxonomy §B.6/§B.11); the Pallas kernel
accelerates it.  idx (B, L) int32 with -1 padding; weights (B, L) f32.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def embedding_bag_ref(table: jnp.ndarray, idx: jnp.ndarray,
                      weights: Optional[jnp.ndarray] = None,
                      mode: str = "sum") -> jnp.ndarray:
    b, l = idx.shape
    valid = idx >= 0
    safe = jnp.where(valid, idx, 0)
    rows = table[safe]                          # (B, L, D)
    w = jnp.ones_like(idx, dtype=table.dtype) if weights is None \
        else weights.astype(table.dtype)
    w = w * valid.astype(table.dtype)
    out = jnp.sum(rows * w[..., None], axis=1)  # (B, D)
    if mode == "mean":
        cnt = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)
        out = out / cnt
    return out
