"""Model zoo: decoder LMs (dense/MoE), GNNs, SASRec — pure-functional JAX."""

from . import gnn, layers, moe, mp, sasrec, transformer

__all__ = ["gnn", "layers", "moe", "mp", "sasrec", "transformer"]
