"""Decoder-only LM (dense + MoE) with scan-over-layers, GQA, RoPE
variants, sliding-window/global mixes, KV-cache decode, and train/serve
steps.  Covers the five assigned LM architectures (moonshot, qwen3-moe,
phi4-mini, gemma3, chatglm3) from a single config dataclass.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro import dist
from repro.dist.sharding import dp_axes

from .layers import (AttnConfig, attention, attn_qkv, decode_attention_block,
                     embed, init_attn, init_embedding, init_mlp, mlp_block,
                     rms_norm, self_attention_block, unembed)
from .moe import MoEConfig, init_moe, moe_block


def _logits_spec(mesh):
    """(B, S, V): batch over DP, sequence over 'model' (SP)."""
    return P(dp_axes(mesh), "model", None)


def _flat_spec(mesh):
    """Flattened (B*S, d) token activations: all DP axes + 'model'."""
    axes = tuple(dp_axes(mesh)) + ("model",)
    return P(axes, None)


def _flat_vec_spec(mesh):
    axes = tuple(dp_axes(mesh)) + ("model",)
    return P(axes)


def _act_spec(mesh):
    """Training activations (B, S, d): DP on batch + sequence parallelism
    on 'model' — keeps the per-layer attention score matrix at
    (B/dp, H, S/model, S), which is what fits a 4k x 4k context in HBM.
    XLA inserts the all-gathers of K/V that SP implies."""
    return P(dp_axes(mesh), "model", None)

Param = dict


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    qkv_bias: bool = False
    # cycle of per-layer windows; 0 = global attention.  gemma3 uses
    # (512,)*5 + (0,) i.e. 5 local : 1 global.
    window_pattern: Tuple[int, ...] = (0,)
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = True
    embed_scale: bool = False          # gemma multiplies embeddings by sqrt(d)
    aux_loss_weight: float = 0.01
    remat: str = "layer"               # layer | none (activation ckpt)
    loss_chunks: int = 16              # vocab chunks for fused CE (0=full)
    # decode attention over the S-sharded KV cache:
    # "gather" = let the partitioner all-gather K/V (baseline);
    # "splitk" = shard_map flash-decoding: local partial softmax per KV
    #            shard + tiny (B,H,D) psum combine (§Perf)
    decode_attn: str = "gather"
    dtype: str = "bfloat16"

    @property
    def attn(self) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.n_kv, self.d_head,
                          self.rope_theta, self.rope_fraction, self.qkv_bias)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def layer_windows(self) -> np.ndarray:
        pat = self.window_pattern
        return np.asarray([pat[i % len(pat)] for i in range(self.n_layers)],
                          dtype=np.int32)

    def n_params(self) -> int:
        """Total parameter count (for 6ND model-flops accounting)."""
        a = self.attn
        attn = self.d_model * (a.n_heads + 2 * a.n_kv) * a.d_head \
            + a.n_heads * a.d_head * self.d_model
        if self.moe:
            m = self.moe
            ffn = m.n_experts * 3 * self.d_model * m.d_expert \
                + self.d_model * m.n_experts \
                + (3 * self.d_model * m.n_shared * m.d_expert if m.n_shared
                   else 0)
        else:
            ffn = 3 * self.d_model * self.d_ff
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn + 2 * self.d_model) + emb

    def n_active_params(self) -> int:
        """Active parameters per token (MoE top-k only)."""
        if not self.moe:
            return self.n_params()
        a = self.attn
        attn = self.d_model * (a.n_heads + 2 * a.n_kv) * a.d_head \
            + a.n_heads * a.d_head * self.d_model
        m = self.moe
        ffn = m.top_k * 3 * self.d_model * m.d_expert \
            + self.d_model * m.n_experts \
            + (3 * self.d_model * m.n_shared * m.d_expert if m.n_shared else 0)
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn + 2 * self.d_model) + emb


# --------------------------------------------------------------------- init
def init_layer(key, cfg: LMConfig) -> Param:
    ka, km, k1, k2 = jax.random.split(key, 4)
    dt = cfg.jdtype
    p = {
        "attn": init_attn(ka, cfg.attn, dt),
        "ln_attn": jnp.zeros((cfg.d_model,), dt),
        "ln_mlp": jnp.zeros((cfg.d_model,), dt),
    }
    if cfg.moe:
        p["moe"] = init_moe(km, cfg.d_model, cfg.moe, dt)
    else:
        p["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, gated=True, dtype=dt)
    return p


def init_params(key, cfg: LMConfig) -> Param:
    ke, kl, ko = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    # stacked layer params: every leaf gains a leading (n_layers,) dim
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    p = {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model, cfg.jdtype),
        "layers": layers,
        "ln_f": jnp.zeros((cfg.d_model,), cfg.jdtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = init_embedding(ko, cfg.vocab, cfg.d_model, cfg.jdtype)
    return p


# ------------------------------------------------------------------ forward
def _layer_fwd(cfg: LMConfig, x, positions, lp, window):
    h = rms_norm(x, lp["ln_attn"])
    b, s, _ = h.shape
    q, k, v = attn_qkv(lp["attn"], h, cfg.attn, positions)
    o = _windowed_attention(q, k, v, positions, window)
    x = x + o.reshape(b, s, -1) @ lp["attn"]["wo"]
    h = rms_norm(x, lp["ln_mlp"])
    if cfg.moe:
        y, aux = moe_block(lp["moe"], h, cfg.moe)
    else:
        y, aux = mlp_block(lp["mlp"], h), jnp.float32(0.0)
    return x + y, aux


def _score_spec(mesh):
    """(B, H, Sq, Sk) attention scores: batch over DP, q-seq over 'model'
    (matches the SP activation layout).  Without this pin, the partitioner
    hits a propagation cliff on the 512-chip mesh and materializes the
    full score tensor ("involuntary full rematerialization", 1 TiB/dev
    measured on qwen3 multi-pod)."""
    return P(dp_axes(mesh), None, "model", None)


def _windowed_attention(q, k, v, positions, window):
    """Causal attention with a traced per-layer window (0 = global)."""
    from .layers import _repeat_kv, NEG_INF
    b, sq, hq, d = q.shape
    n_rep = hq // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = dist.constrain(logits, _score_spec)
    dq = positions[:, :, None]
    dk = positions[:, None, :]
    mask = dk <= dq
    win_mask = jnp.logical_or(window <= 0, dq - dk < window)
    mask = jnp.logical_and(mask, win_mask)
    logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def forward_features(params: Param, tokens: jnp.ndarray, cfg: LMConfig):
    """tokens (B, S) -> final hidden states (B, S, d), plus MoE aux."""
    b, s = tokens.shape
    x = embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, layer_in):
        x, aux = carry
        lp, window = layer_in
        x = dist.constrain(x, _act_spec)
        x, a = _layer_fwd(cfg, x, positions, lp, window)
        return (x, aux + a), None

    if cfg.remat == "layer":
        body = jax.checkpoint(body)
    windows = jnp.asarray(cfg.layer_windows())
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                               (params["layers"], windows))
    x = rms_norm(x, params["ln_f"])
    return x, aux


def forward(params: Param, tokens: jnp.ndarray, cfg: LMConfig):
    """tokens (B, S) -> logits (B, S, V) in activation dtype, + MoE aux."""
    x, aux = forward_features(params, tokens, cfg)
    out_emb = params.get("unembed", params["embed"])
    logits = unembed(out_emb, x)
    logits = dist.constrain(logits, _logits_spec)
    return logits, aux


def chunked_ce(x: jnp.ndarray, table: jnp.ndarray, labels: jnp.ndarray,
               n_chunks: int) -> jnp.ndarray:
    """Fused unembed + cross-entropy, streamed over vocab chunks.

    Never materializes the (T, V) logits: each scan step computes one
    (T, V/n_chunks) block, folds it into a running (max, sumexp) pair and
    picks out the label logit.  jax.checkpoint on the chunk body keeps the
    backward at one recomputed block at a time.  x: (T, d); table: (V, d);
    labels: (T,) -> per-token nll (T,) fp32.
    """
    t, d = x.shape
    v = table.shape[0]
    vc = v // n_chunks
    assert vc * n_chunks == v, (v, n_chunks)
    chunks = table.reshape(n_chunks, vc, d)

    def body(carry, inp):
        m, s, ll = carry
        i, tb = inp
        lg = jnp.einsum("td,vd->tv", x, tb,
                        preferred_element_type=jnp.float32)  # (T, Vc)
        cm = jnp.max(lg, axis=-1)
        nm = jnp.maximum(m, cm)
        s = s * jnp.exp(m - nm) + jnp.sum(jnp.exp(lg - nm[:, None]),
                                          axis=-1)
        lo = i * vc
        in_chunk = (labels >= lo) & (labels < lo + vc)
        idx = jnp.clip(labels - lo, 0, vc - 1)
        lbl = jnp.take_along_axis(lg, idx[:, None], axis=1)[:, 0]
        ll = jnp.where(in_chunk, lbl, ll)
        return (nm, s, ll), None

    init = (jnp.full((t,), -jnp.inf, jnp.float32),
            jnp.zeros((t,), jnp.float32), jnp.zeros((t,), jnp.float32))
    (m, s, ll), _ = jax.lax.scan(
        jax.checkpoint(body), init,
        (jnp.arange(n_chunks, dtype=jnp.int32), chunks))
    return m + jnp.log(jnp.maximum(s, 1e-30)) - ll


def lm_loss(params: Param, batch: dict, cfg: LMConfig):
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    if cfg.loss_chunks and cfg.vocab % cfg.loss_chunks == 0:
        x, aux = forward_features(params, batch["tokens"], cfg)
        b, s_, d = x.shape
        out_emb = params.get("unembed", params["embed"])
        xf = dist.constrain(x.reshape(b * s_, d), _flat_spec)
        lf = dist.constrain(labels.reshape(-1), _flat_vec_spec)
        nll = chunked_ce(xf, out_emb["table"], lf, cfg.loss_chunks)
        nll = nll.reshape(b, s_)
    else:
        logits, aux = forward(params, batch["tokens"], cfg)
        lse = jax.scipy.special.logsumexp(
            logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None],
                                 axis=-1)[..., 0].astype(jnp.float32)
        nll = lse - ll
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + cfg.aux_loss_weight * aux, {"loss": loss, "aux": aux}


# ------------------------------------------------------------------- decode
def init_cache(cfg: LMConfig, batch: int, max_len: int,
               window_bounded: bool = False):
    """KV cache pytree.  ``window_bounded=True`` allocates only
    ``window`` slots for sliding-window layers (the §Perf-optimized
    layout); the baseline allocates ``max_len`` for every layer."""
    windows = cfg.layer_windows()
    if window_bounded:
        lens = np.asarray([w if w > 0 else max_len for w in windows])
        s = int(lens.max())   # scan needs uniform shapes; bound by max
    else:
        s = max_len
    shape = (cfg.n_layers, batch, s, cfg.n_kv, cfg.d_head)
    dt = cfg.jdtype
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "len": jnp.zeros((batch,), jnp.int32)}


def prefill(params: Param, tokens: jnp.ndarray, cfg: LMConfig,
            max_len: Optional[int] = None):
    """Run the prompt, return last-position logits + populated cache."""
    b, s = tokens.shape
    max_len = max_len or s
    x = embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def cache_spec(mesh):
        # (B, S, kv, dh) per layer -> batch over DP, seq over 'model'
        return P(dp_axes(mesh), "model", None, None)

    def body(x, layer_in):
        lp, window = layer_in
        x = dist.constrain(x, _act_spec)
        h = rms_norm(x, lp["ln_attn"])
        q, k, v = attn_qkv(lp["attn"], h, cfg.attn, positions)
        o = _windowed_attention(q, k, v, positions, window)
        x = x + o.reshape(b, s, -1) @ lp["attn"]["wo"]
        h = rms_norm(x, lp["ln_mlp"])
        if cfg.moe:
            y, _ = moe_block(lp["moe"], h, cfg.moe)
        else:
            y = mlp_block(lp["mlp"], h)
        x = x + y
        pad = max_len - s
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kc = dist.constrain(kc, cache_spec)
        vc = dist.constrain(vc, cache_spec)
        return x, (kc, vc)

    windows = jnp.asarray(cfg.layer_windows())
    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], windows))
    x = rms_norm(x, params["ln_f"])
    out_emb = params.get("unembed", params["embed"])
    logits = unembed(out_emb, x[:, -1:, :])
    cache = {"k": ks, "v": vs,
             "len": jnp.full((b,), s, jnp.int32)}
    return logits, cache


def decode_step(params: Param, cache: dict, tokens: jnp.ndarray,
                cfg: LMConfig):
    """One decode step: tokens (B, 1) -> logits (B, 1, V), new cache."""
    b = tokens.shape[0]
    x = embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    lens = cache["len"]

    def body(x, layer_in):
        lp, window, kc, vc = layer_in
        h = rms_norm(x, lp["ln_attn"])
        mesh = dist.get_mesh()
        if (cfg.decode_attn == "splitk" and mesh is not None
                and "model" in mesh.axis_names
                and kc.shape[1] % mesh.shape["model"] == 0):
            o, nk, nv = _decode_attn_splitk(lp["attn"], h, cfg, kc, vc,
                                            lens, window, mesh)
        else:
            o, nk, nv = _decode_attn(lp["attn"], h, cfg, kc, vc, lens,
                                     window)
        x = x + o
        h = rms_norm(x, lp["ln_mlp"])
        if cfg.moe:
            y, _ = moe_block(lp["moe"], h, cfg.moe)
        else:
            y = mlp_block(lp["mlp"], h)
        return x + y, (nk, nv)

    windows = jnp.asarray(cfg.layer_windows())
    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], windows, cache["k"], cache["v"]))
    x = rms_norm(x, params["ln_f"])
    out_emb = params.get("unembed", params["embed"])
    logits = unembed(out_emb, x)
    new_cache = {"k": ks, "v": vs, "len": lens + 1}
    return logits, new_cache


def _decode_attn(p, x, cfg: LMConfig, k_cache, v_cache, lens, window):
    from .layers import NEG_INF, _repeat_kv
    b = x.shape[0]
    positions = lens[:, None]
    q, k_new, v_new = attn_qkv(p, x, cfg.attn, positions)
    s_max = k_cache.shape[1]
    write_idx = jnp.minimum(lens, s_max - 1)
    k_cache = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
        c, n, (i, 0, 0)))(k_cache, k_new, write_idx)
    v_cache = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
        c, n, (i, 0, 0)))(v_cache, v_new, write_idx)
    k_pos = jnp.broadcast_to(jnp.arange(s_max, dtype=jnp.int32), (b, s_max))
    valid = k_pos <= lens[:, None]
    n_rep = cfg.n_heads // cfg.n_kv
    kk = _repeat_kv(k_cache, n_rep)
    vv = _repeat_kv(v_cache, n_rep)
    scale = 1.0 / np.sqrt(cfg.d_head)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                        preferred_element_type=jnp.float32) * scale
    win_ok = jnp.logical_or(window <= 0,
                            positions[:, :, None] - k_pos[:, None, :] < window)
    mask = jnp.logical_and(valid[:, None, :], win_ok)
    logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    out = o.reshape(b, 1, -1) @ p["wo"]
    return out, k_cache, v_cache


def _decode_attn_splitk(p, x, cfg: LMConfig, k_cache, v_cache, lens,
                        window, mesh):
    """Flash-decoding over a sequence-sharded KV cache.

    The baseline lets the SPMD partitioner all-gather the full K/V cache
    per layer per token (~16 GB/step for gemma3 long_500k, measured).
    Here each device attends over its LOCAL cache shard with running
    (max, sumexp, acc) statistics and the combine is a psum over
    (B, H, D) — bytes per layer drop from O(S*kv*dh) to O(H*dh).
    The cache write happens only on the shard that owns position
    ``lens`` (no cross-shard traffic).
    """
    from jax.sharding import PartitionSpec as P

    b = x.shape[0]
    positions = lens[:, None]
    q, k_new, v_new = attn_qkv(p, x, cfg.attn, positions)   # (B,1,H,dh)...
    n_rep = cfg.n_heads // cfg.n_kv
    s_max = k_cache.shape[1]
    n_sh = mesh.shape["model"]
    s_loc = s_max // n_sh
    dpx = dp_axes(mesh)
    bax = dpx if b % max(dist.axis_size(mesh, dpx), 1) == 0 else None

    def local(q, k_new, v_new, kc, vc, lens_):
        shard = jax.lax.axis_index("model")
        base = (shard * s_loc).astype(jnp.int32)
        idx = lens_ - base                       # (B,)
        own = (idx >= 0) & (idx < s_loc)
        iw = jnp.clip(idx, 0, s_loc - 1)

        def upd(c, n, i, o):
            # write exactly one row: select between the new KV row and
            # the row already there (a full-cache `where` would rewrite
            # the whole shard every layer — measured 190 GB/step)
            cur = jax.lax.dynamic_slice(c, (i, 0, 0), n.shape)
            val = jnp.where(o, n, cur)
            return jax.lax.dynamic_update_slice(c, val, (i, 0, 0))

        kc = jax.vmap(upd)(kc, k_new, iw, own)
        vc = jax.vmap(upd)(vc, v_new, iw, own)
        k_pos = base + jnp.arange(s_loc, dtype=jnp.int32)   # (s_loc,)
        valid = k_pos[None, :] <= lens_[:, None]            # (B, s_loc)
        win_ok = jnp.logical_or(
            window <= 0, lens_[:, None] - k_pos[None, :] < window)
        mask = valid & win_ok
        kk = _windowed_repeat(kc, n_rep)
        vv = _windowed_repeat(vc, n_rep)
        scale = 1.0 / np.sqrt(cfg.d_head)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                            preferred_element_type=jnp.float32) * scale
        logits = jnp.where(mask[:, None, None, :], logits,
                           jnp.float32(-1e30))
        m_loc = jnp.max(logits, axis=-1)                    # (B,H,1)
        m_glob = jax.lax.pmax(m_loc, "model")
        pexp = jnp.exp(logits - m_glob[..., None])
        l_loc = jnp.sum(pexp, axis=-1)                      # (B,H,1)
        acc = jnp.einsum("bhqk,bkhd->bqhd", pexp.astype(vv.dtype), vv)
        l = jax.lax.psum(l_loc, "model")
        acc = jax.lax.psum(acc.astype(jnp.float32), "model")
        o = (acc / jnp.maximum(
            l.transpose(0, 2, 1)[..., None], 1e-30)).astype(x.dtype)
        return o, kc, vc

    cache_spec = P(bax, "model", None, None)
    small_spec = P(bax, None, None, None)
    o, nk, nv = dist.shard_map(
        local, mesh=mesh,
        in_specs=(small_spec, small_spec, small_spec, cache_spec,
                  cache_spec, P(bax)),
        out_specs=(small_spec, cache_spec, cache_spec),
        check_vma=False,
    )(q, k_new, v_new, k_cache, v_cache, lens)
    out = o.reshape(b, 1, -1) @ p["wo"]
    return out, nk, nv


def _windowed_repeat(k, n_rep):
    from .layers import _repeat_kv
    return _repeat_kv(k, n_rep)
