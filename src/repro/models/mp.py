"""Message-passing primitive seam.

JAX has no native sparse message passing (BCOO only), so the framework's
GNN/message-passing layers all route through these helpers built on
``jax.ops.segment_*`` — per the kernel taxonomy, this IS part of the
system.  ``use_pallas`` switches the hot gather->reduce path to the
fused Pallas kernel (``repro.kernels.segment_mp``) where shapes allow;
the jnp path is the semantic reference.

Sorted segment ids
------------------
Snapshots from the columnar engine arrive CSR/CSC-sorted, and the
dynamic-graph pipeline now feeds batches in CSC (dst-major) orientation
— so the dst-keyed scatters can claim ``indices_are_sorted=True`` and
skip XLA's scatter sort.  Every helper takes ``sorted_ids`` (static at
trace time); :func:`set_sorted_indices` flips the module default for
callers whose call sites are buried in jitted model code (e.g. the
dynamic-pipeline trainer, whose batches are ALWAYS dst-sorted).  The
claim is an optimization contract: passing unsorted ids with the flag
set is undefined behaviour, exactly as in ``jax.ops``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_USE_PALLAS = False
_SORTED_DEFAULT = False


def set_use_pallas(flag: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = flag


def set_sorted_indices(flag: bool) -> None:
    """Module default for ``sorted_ids`` (read at trace time)."""
    global _SORTED_DEFAULT
    _SORTED_DEFAULT = flag


def _sorted(flag: bool) -> bool:
    return bool(flag or _SORTED_DEFAULT)


def gather_src(x: jnp.ndarray, edge_src: jnp.ndarray) -> jnp.ndarray:
    return x[edge_src]


def scatter_sum(messages: jnp.ndarray, edge_dst: jnp.ndarray,
                n_nodes: int, sorted_ids: bool = False) -> jnp.ndarray:
    return jax.ops.segment_sum(messages, edge_dst, num_segments=n_nodes,
                               indices_are_sorted=_sorted(sorted_ids))


def scatter_mean(messages, edge_dst, n_nodes: int, sorted_ids: bool = False):
    s = scatter_sum(messages, edge_dst, n_nodes, sorted_ids)
    cnt = jax.ops.segment_sum(jnp.ones((messages.shape[0],), messages.dtype),
                              edge_dst, num_segments=n_nodes,
                              indices_are_sorted=_sorted(sorted_ids))
    return s / jnp.maximum(cnt, 1.0)[:, None]


def scatter_max(messages, edge_dst, n_nodes: int, sorted_ids: bool = False):
    return jax.ops.segment_max(messages, edge_dst, num_segments=n_nodes,
                               indices_are_sorted=_sorted(sorted_ids))


def scatter_min(messages, edge_dst, n_nodes: int, sorted_ids: bool = False):
    return jax.ops.segment_min(messages, edge_dst, num_segments=n_nodes,
                               indices_are_sorted=_sorted(sorted_ids))


def degree(edge_dst: jnp.ndarray, n_nodes: int,
           sorted_ids: bool = False) -> jnp.ndarray:
    return jax.ops.segment_sum(jnp.ones_like(edge_dst, dtype=jnp.float32),
                               edge_dst, num_segments=n_nodes,
                               indices_are_sorted=_sorted(sorted_ids))


def segment_softmax(logits: jnp.ndarray, segments: jnp.ndarray,
                    n_segments: int, sorted_ids: bool = False) -> jnp.ndarray:
    """Softmax over variable-size groups (GAT edge attention)."""
    srt = _sorted(sorted_ids)
    mx = jax.ops.segment_max(logits, segments, num_segments=n_segments,
                             indices_are_sorted=srt)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(logits - mx[segments])
    den = jax.ops.segment_sum(ex, segments, num_segments=n_segments,
                              indices_are_sorted=srt)
    return ex / jnp.maximum(den[segments], 1e-16)


def propagate_matmul(x: jnp.ndarray, w: jnp.ndarray, edge_src: jnp.ndarray,
                     edge_dst: jnp.ndarray, n_nodes: int,
                     dst_sorted: bool = False) -> jnp.ndarray:
    """Fused gather -> matmul -> scatter-sum: y[v] = sum_{(u,v)} (x[u] @ w).

    This is the SpMM-regime hot path; with ``set_use_pallas(True)`` it runs
    through the blocked Pallas kernel (validated against this jnp path).
    """
    if _USE_PALLAS:
        from repro.kernels.segment_mp import ops as smp_ops
        return smp_ops.segment_matmul_reduce(x, w, edge_src, edge_dst,
                                             n_nodes)
    msgs = gather_src(x, edge_src) @ w
    return scatter_sum(msgs, edge_dst, n_nodes, sorted_ids=dst_sorted)
