"""Message-passing primitive seam.

JAX has no native sparse message passing (BCOO only), so the framework's
GNN/message-passing layers all route through these helpers built on
``jax.ops.segment_*`` — per the kernel taxonomy, this IS part of the
system.  ``use_pallas`` switches the hot gather->reduce path to the
fused Pallas kernel (``repro.kernels.segment_mp``) where shapes allow;
the jnp path is the semantic reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_USE_PALLAS = False


def set_use_pallas(flag: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = flag


def gather_src(x: jnp.ndarray, edge_src: jnp.ndarray) -> jnp.ndarray:
    return x[edge_src]


def scatter_sum(messages: jnp.ndarray, edge_dst: jnp.ndarray,
                n_nodes: int) -> jnp.ndarray:
    return jax.ops.segment_sum(messages, edge_dst, num_segments=n_nodes)


def scatter_mean(messages, edge_dst, n_nodes: int):
    s = scatter_sum(messages, edge_dst, n_nodes)
    cnt = jax.ops.segment_sum(jnp.ones((messages.shape[0],), messages.dtype),
                              edge_dst, num_segments=n_nodes)
    return s / jnp.maximum(cnt, 1.0)[:, None]


def scatter_max(messages, edge_dst, n_nodes: int):
    return jax.ops.segment_max(messages, edge_dst, num_segments=n_nodes)


def scatter_min(messages, edge_dst, n_nodes: int):
    return jax.ops.segment_min(messages, edge_dst, num_segments=n_nodes)


def degree(edge_dst: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    return jax.ops.segment_sum(jnp.ones_like(edge_dst, dtype=jnp.float32),
                               edge_dst, num_segments=n_nodes)


def segment_softmax(logits: jnp.ndarray, segments: jnp.ndarray,
                    n_segments: int) -> jnp.ndarray:
    """Softmax over variable-size groups (GAT edge attention)."""
    mx = jax.ops.segment_max(logits, segments, num_segments=n_segments)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(logits - mx[segments])
    den = jax.ops.segment_sum(ex, segments, num_segments=n_segments)
    return ex / jnp.maximum(den[segments], 1e-16)


def propagate_matmul(x: jnp.ndarray, w: jnp.ndarray, edge_src: jnp.ndarray,
                     edge_dst: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    """Fused gather -> matmul -> scatter-sum: y[v] = sum_{(u,v)} (x[u] @ w).

    This is the SpMM-regime hot path; with ``set_use_pallas(True)`` it runs
    through the blocked Pallas kernel (validated against this jnp path).
    """
    if _USE_PALLAS:
        from repro.kernels.segment_mp import ops as smp_ops
        return smp_ops.segment_matmul_reduce(x, w, edge_src, edge_dst,
                                             n_nodes)
    msgs = gather_src(x, edge_src) @ w
    return scatter_sum(msgs, edge_dst, n_nodes)
