"""Transformer building blocks: norms, RoPE variants, GQA attention
(full / sliding-window / KV-cache decode), gated MLPs.

All layers are pure functions over parameter pytrees (nested dicts of
jnp arrays) so they stack cleanly under ``jax.lax.scan`` and shard under
``pjit`` name-based partition rules (see ``repro.dist.sharding``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Param = dict

NEG_INF = jnp.float32(-1e30)


# --------------------------------------------------------------------- norms
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dtype)


# --------------------------------------------------------------------- RoPE
def rope_freqs(d_rot: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_rot, 2, dtype=np.float64) / d_rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               fraction: float = 1.0) -> jnp.ndarray:
    """Rotary embedding on the leading ``fraction`` of head dims.

    ``fraction=0.5`` gives the ChatGLM-style "2d" partial rotary where only
    half of each head rotates (the other half stays positional-free).
    x: (..., S, H, D); positions: broadcastable to (..., S).
    """
    d = x.shape[-1]
    d_rot = int(d * fraction)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    freqs = jnp.asarray(rope_freqs(d_rot, theta), dtype=jnp.float32)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,d_rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr = x[..., :d_rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rot = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([rot.astype(x.dtype), x[..., d_rot:]], axis=-1)


# ----------------------------------------------------------------- attention
def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)) \
              .reshape(b, s, h * n_rep, d)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              q_positions: jnp.ndarray, k_positions: jnp.ndarray,
              causal: bool = True, window: Optional[int] = None,
              softmax_scale: Optional[float] = None) -> jnp.ndarray:
    """Grouped-query attention with optional sliding window.

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D).  Positions give the absolute
    token index of each query/key (needed for decode and windowing).
    """
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    n_rep = hq // hkv
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((sq, k.shape[1]), dtype=bool)
    dq = q_positions[:, :, None]          # (B, Sq, 1)
    dk = k_positions[:, None, :]          # (B, 1, Sk)
    if causal:
        mask = dk <= dq
    else:
        mask = jnp.broadcast_to(mask, (b, sq, k.shape[1]))
    if window is not None:
        mask = jnp.logical_and(mask, dq - dk < window)
    logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    qkv_bias: bool = False


def init_attn(key, cfg: AttnConfig, dtype=jnp.bfloat16) -> Param:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = float(1.0 / np.sqrt(cfg.d_model))
    p = {
        "wq": jax.random.normal(k1, (cfg.d_model, cfg.n_heads * cfg.d_head),
                                dtype) * s,
        "wk": jax.random.normal(k2, (cfg.d_model, cfg.n_kv * cfg.d_head),
                                dtype) * s,
        "wv": jax.random.normal(k3, (cfg.d_model, cfg.n_kv * cfg.d_head),
                                dtype) * s,
        "wo": jax.random.normal(k4, (cfg.n_heads * cfg.d_head, cfg.d_model),
                                dtype) * s,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * cfg.d_head,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv * cfg.d_head,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv * cfg.d_head,), dtype)
    return p


def attn_qkv(p: Param, x: jnp.ndarray, cfg: AttnConfig,
             positions: jnp.ndarray):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, s, cfg.n_kv, cfg.d_head)
    v = v.reshape(b, s, cfg.n_kv, cfg.d_head)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def self_attention_block(p: Param, x: jnp.ndarray, cfg: AttnConfig,
                         positions: jnp.ndarray,
                         window: Optional[int] = None) -> jnp.ndarray:
    b, s, _ = x.shape
    q, k, v = attn_qkv(p, x, cfg, positions)
    o = attention(q, k, v, positions, positions, causal=True, window=window)
    return o.reshape(b, s, cfg.n_heads * cfg.d_head) @ p["wo"]


def decode_attention_block(p: Param, x: jnp.ndarray, cfg: AttnConfig,
                           k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                           cache_len: jnp.ndarray,
                           window: Optional[int] = None):
    """One-token decode: append to the KV cache, attend over the prefix.

    x: (B, 1, d_model); k_cache/v_cache: (B, S_max, n_kv, d_head);
    cache_len: (B,) current lengths.  Returns (out, new_k, new_v).
    """
    b = x.shape[0]
    positions = cache_len[:, None]                       # (B, 1)
    q, k_new, v_new = attn_qkv(p, x, cfg, positions)
    idx = cache_len                                       # (B,)
    k_cache = jax.vmap(lambda c, kn, i: jax.lax.dynamic_update_slice(
        c, kn, (i, 0, 0)))(k_cache, k_new, idx)
    v_cache = jax.vmap(lambda c, vn, i: jax.lax.dynamic_update_slice(
        c, vn, (i, 0, 0)))(v_cache, v_new, idx)
    s_max = k_cache.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(s_max, dtype=jnp.int32), (b, s_max))
    # mask out unwritten cache slots by pushing their positions past query
    k_pos = jnp.where(k_pos <= idx[:, None], k_pos, jnp.int32(2**30))
    o = attention(q, k_cache, v_cache, positions, k_pos, causal=True,
                  window=window)
    out = o.reshape(b, 1, cfg.n_heads * cfg.d_head) @ p["wo"]
    return out, k_cache, v_cache


# ----------------------------------------------------------------------- MLP
def init_mlp(key, d_model: int, d_ff: int, gated: bool = True,
             dtype=jnp.bfloat16) -> Param:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = float(1.0 / np.sqrt(d_model))
    s_out = float(1.0 / np.sqrt(d_ff))
    p = {
        "w_up": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(k2, (d_ff, d_model), dtype) * s_out,
    }
    if gated:
        p["w_gate"] = jax.random.normal(k3, (d_model, d_ff), dtype) * s_in
    return p


def mlp_block(p: Param, x: jnp.ndarray, act=jax.nn.silu) -> jnp.ndarray:
    up = x @ p["w_up"]
    if "w_gate" in p:
        up = act(x @ p["w_gate"]) * up
    else:
        up = act(up)
    return up @ p["w_down"]


# ----------------------------------------------------------------- embedding
def init_embedding(key, vocab: int, d_model: int, dtype=jnp.bfloat16) -> Param:
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embed(p: Param, tokens: jnp.ndarray) -> jnp.ndarray:
    return p["table"][tokens]


def unembed(p: Param, x: jnp.ndarray) -> jnp.ndarray:
    # keep logits in the activation dtype (bf16): the fp32 loss math is
    # streamed (logsumexp fusion) rather than materialized at (B,S,V)
    return jnp.einsum("bsd,vd->bsv", x, p["table"])
