"""SASRec (Self-Attentive Sequential Recommendation, arXiv:1808.09781).

Item-embedding table (the huge-sparse-table regime) + 2 causal
self-attention blocks over length-50 histories + dot-product scoring.
Training uses the paper's BCE with one sampled negative per position;
serving scores the last hidden state against candidate items (the
``retrieval_cand`` shape scores 1M candidates with a batched dot, routed
through the embedding-bag / matmul path — no loops).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Param = dict


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str
    n_items: int
    seq_len: int = 50
    d_embed: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def table_rows(cfg: SASRecConfig, multiple: int = 32) -> int:
    """Embedding-table rows: n_items + 1 pad row, rounded up so the
    row-sharded table divides the mesh 'model' axis."""
    rows = cfg.n_items + 1
    return -(-rows // multiple) * multiple


def init_params(key, cfg: SASRecConfig) -> Param:
    ks = jax.random.split(key, 2 + 6 * cfg.n_blocks)
    dt = cfg.jdtype
    d = cfg.d_embed
    p = {
        # row 0 is the padding item; tail rows are sharding pad (unused)
        "item_emb": jax.random.normal(ks[0], (table_rows(cfg), d), dt) * 0.02,
        "pos_emb": jax.random.normal(ks[1], (cfg.seq_len, d), dt) * 0.02,
        "blocks": [],
    }
    for b in range(cfg.n_blocks):
        k = ks[2 + 6 * b: 8 + 6 * b]
        p["blocks"].append({
            "wq": jax.random.normal(k[0], (d, d), dt) / np.sqrt(d),
            "wk": jax.random.normal(k[1], (d, d), dt) / np.sqrt(d),
            "wv": jax.random.normal(k[2], (d, d), dt) / np.sqrt(d),
            "ln1": jnp.ones((d,), dt),
            "w1": jax.random.normal(k[3], (d, d), dt) / np.sqrt(d),
            "w2": jax.random.normal(k[4], (d, d), dt) / np.sqrt(d),
            "ln2": jnp.ones((d,), dt),
        })
    return p


def _ln(x, scale, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale


def encode(params: Param, hist: jnp.ndarray, cfg: SASRecConfig):
    """hist (B, S) item ids (0 = pad) -> hidden states (B, S, d)."""
    b, s = hist.shape
    x = params["item_emb"][hist] * np.sqrt(cfg.d_embed)
    x = x + params["pos_emb"][None, :s, :]
    pad_mask = hist > 0                                    # (B, S)
    causal = jnp.tril(jnp.ones((s, s), bool))
    mask = causal[None, :, :] & pad_mask[:, None, :]
    h = cfg.n_heads
    dh = cfg.d_embed // h
    for blk in params["blocks"]:
        xn = _ln(x, blk["ln1"])
        q = (xn @ blk["wq"]).reshape(b, s, h, dh)
        k = (xn @ blk["wk"]).reshape(b, s, h, dh)
        v = (xn @ blk["wv"]).reshape(b, s, h, dh)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(dh)
        logits = jnp.where(mask[:, None, :, :], logits, -1e30)
        att = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, -1)
        x = x + o
        xn = _ln(x, blk["ln2"])
        x = x + jax.nn.relu(xn @ blk["w1"]) @ blk["w2"]
    return x * pad_mask[:, :, None]


def bce_loss(params: Param, batch: dict, cfg: SASRecConfig):
    """batch: hist (B,S), pos (B,S) next-item targets, neg (B,S) sampled
    negatives; 0 = pad."""
    h = encode(params, batch["hist"], cfg)                 # (B, S, d)
    pe = params["item_emb"][batch["pos"]]
    ne = params["item_emb"][batch["neg"]]
    pos_logit = jnp.sum(h * pe, axis=-1)
    neg_logit = jnp.sum(h * ne, axis=-1)
    mask = (batch["pos"] > 0).astype(h.dtype)
    loss = -(jax.nn.log_sigmoid(pos_logit)
             + jax.nn.log_sigmoid(-neg_logit)) * mask
    loss = jnp.sum(loss) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss}


def score_catalog(params: Param, hist: jnp.ndarray, cfg: SASRecConfig):
    """Serve: score every item for each user (B, n_items+1)."""
    h = encode(params, hist, cfg)[:, -1, :]                # (B, d)
    return h @ params["item_emb"].T


def score_candidates(params: Param, hist: jnp.ndarray,
                     candidates: jnp.ndarray, cfg: SASRecConfig):
    """Retrieval: hist (B,S), candidates (B, C) -> scores (B, C)."""
    h = encode(params, hist, cfg)[:, -1, :]                # (B, d)
    ce = params["item_emb"][candidates]                    # (B, C, d)
    return jnp.einsum("bd,bcd->bc", h, ce)
