"""Mixture-of-Experts FFN (GShard/Switch-style capacity dispatch).

Design for expert parallelism under pjit: the per-expert buffers
``(E, C, d)`` carry a sharding constraint on the expert axis (the mesh
"model" axis), token activations stay sharded on the data axis, and the
dispatch scatter / combine gather lower to cross-axis collectives chosen
by SPMD.  The shard_map all-to-all variant lives in
``repro.dist.collectives`` (used as a §Perf hillclimb lever).

Router: softmax top-k with normalized weights + Switch-style load-balance
auxiliary loss.  Shared experts (DeepSeek/Moonlight style) are a fused
dense MLP applied to every token.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import dist

from .layers import init_mlp, mlp_block


def _tok_spec(mesh):
    """(T*k, d) token-major tensors: shard dim0 over every mesh axis."""
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    return P(axes, None)


def _ep_spec(mesh):
    # (E, C, d): experts over 'model'.  (A 2-D variant additionally
    # sharding C over 'data' was measured at 8x WORSE temp memory: XLA
    # partitions the dispatch scatter by replicating the updates.  See
    # EXPERIMENTS.md §Perf, refuted-hypothesis log.)
    return P("model", None, None)


Param = dict


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # "scatter": jit-level capacity dispatch (baseline);
    # "a2a": shard_map expert-parallel all-to-all (§Perf variant)
    moe_impl: str = "scatter"
    # a2a overflow semantics: "global" matches the scatter path's
    # per-global-expert drops exactly (wire buffer clamped to the
    # no-secondary-drop bound — up to ~n_model/local_capacity_factor
    # larger all-to-alls); "local" keeps the smaller per-(source
    # device, dest shard) buffer but diverges from scatter under
    # overflow.  See repro.dist.collectives.moe_alltoall_block.
    a2a_overflow: str = "global"


def init_moe(key, d_model: int, cfg: MoEConfig, dtype=jnp.bfloat16) -> Param:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s_in = float(1.0 / np.sqrt(d_model))
    s_out = float(1.0 / np.sqrt(cfg.d_expert))
    p = {
        "router": jax.random.normal(k1, (d_model, cfg.n_experts),
                                    jnp.float32) * s_in,
        "w_gate": jax.random.normal(k2, (cfg.n_experts, d_model,
                                         cfg.d_expert), dtype) * s_in,
        "w_up": jax.random.normal(k3, (cfg.n_experts, d_model,
                                       cfg.d_expert), dtype) * s_in,
        "w_down": jax.random.normal(k4, (cfg.n_experts, cfg.d_expert,
                                         d_model), dtype) * s_out,
    }
    if cfg.n_shared:
        p["shared"] = init_mlp(k5, d_model, cfg.n_shared * cfg.d_expert,
                               gated=True, dtype=dtype)
    return p


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(np.ceil(n_tokens * cfg.top_k * cfg.capacity_factor
                    / cfg.n_experts))
    return max(8, -(-c // 8) * 8)   # round up to a multiple of 8


def moe_block(p: Param, x: jnp.ndarray, cfg: MoEConfig,
              ep_constraint=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss).

    ``ep_constraint`` optionally applies a sharding constraint to the
    (E, C, d) expert buffers (expert parallelism).
    """
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(t, cfg)

    mesh = dist.get_mesh()
    if cfg.moe_impl == "a2a" and mesh is not None             and "model" in mesh.axis_names:
        return _moe_a2a(p, x, cfg, mesh)

    # ---- router ----------------------------------------------------------
    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, k)                   # (T, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)

    # Switch load-balance loss: E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)                             # (E,)
    onehot_top1 = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=0)
    aux = e * jnp.sum(me * ce)

    # ---- dispatch: position of each (token, slot) inside its expert -------
    # Sort-based (MegaBlocks-style): avoids materializing a (T*k, E)
    # one-hot/cumsum — O(N) int32 arrays + one sort instead.
    expert_flat = idx.reshape(-1)                            # (N = T*k,)
    n = expert_flat.shape[0]
    order = jnp.argsort(expert_flat)                         # (N,)
    sorted_ids = expert_flat[order]
    starts = jnp.searchsorted(sorted_ids,
                              jnp.arange(e, dtype=sorted_ids.dtype))
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_ids]
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < c
    pos_c = jnp.where(keep, pos, c)                          # overflow slot

    token_idx = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    gathered = xf[token_idx]                                 # (T*k, d)
    gathered = gathered * keep[:, None].astype(gathered.dtype)

    buf = jnp.zeros((e, c + 1, d), dtype=x.dtype)
    buf = buf.at[expert_flat, pos_c].add(gathered)
    expert_in = buf[:, :c, :]                                # (E, C, d)
    expert_in = dist.constrain(expert_in, _ep_spec)
    if ep_constraint is not None:
        expert_in = ep_constraint(expert_in)

    # ---- expert FFN (SwiGLU), batched over experts -------------------------
    g = jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    h = jax.nn.silu(g) * u
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # (E, C, d)
    expert_out = dist.constrain(expert_out, _ep_spec)
    if ep_constraint is not None:
        expert_out = ep_constraint(expert_out)

    # ---- combine -------------------------------------------------------------
    padded = jnp.concatenate(
        [expert_out, jnp.zeros((e, 1, d), expert_out.dtype)], axis=1)
    back = padded[expert_flat, pos_c]                        # (T*k, d)
    # combine weights cast to the activation dtype BEFORE the big
    # elementwise product: keeps the (T*k, d) backward cotangents in bf16
    w_comb = (weights.astype(x.dtype).reshape(-1, 1)
              * keep[:, None].astype(x.dtype))
    back = back * w_comb
    y = jnp.sum(back.reshape(t, k, d), axis=1)

    if "shared" in p:
        y = y + mlp_block(p["shared"], xf)
    return y.reshape(b, s, d), aux


def _moe_a2a(p: Param, x: jnp.ndarray, cfg: MoEConfig, mesh
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """shard_map expert parallelism: explicit all-to-all dispatch instead
    of the jit-level scatter whose SPMD partitioning is collective-heavy
    (measured in EXPERIMENTS.md §Perf)."""
    from repro.dist import collectives

    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    xf = dist.constrain(xf, _tok_spec)
    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    # aux loss computed jit-level (cheap, fully sharded)
    probs = jax.nn.softmax(logits, axis=-1)
    _, top1 = jax.lax.top_k(probs, 1)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top1[:, 0], cfg.n_experts,
                                 dtype=jnp.float32), axis=0)
    aux = cfg.n_experts * jnp.sum(me * ce)

    n_model = mesh.shape["model"]
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    t_loc = t // (dp * n_model)
    c_dev = max(8, int(-(-t_loc * cfg.top_k * cfg.capacity_factor
                         // n_model) // 8 * 8 + 8))
    # global per-expert capacity == the scatter path's, so the two
    # implementations drop the SAME (token, slot) pairs under overflow
    # (cfg.a2a_overflow="local" opts back into the smaller wire buffer)
    y = collectives.moe_alltoall_block(
        xf, logits, p["w_gate"], p["w_up"], p["w_down"], mesh,
        cfg.top_k, c_dev, capacity=capacity(t, cfg),
        overflow=cfg.a2a_overflow,
        local_capacity_factor=max(2.0, cfg.capacity_factor))
    if "shared" in p:
        y = y + mlp_block(p["shared"], xf)
    return y.reshape(b, s, d), aux
