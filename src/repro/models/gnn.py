"""GNN architectures: GIN, PNA, GAT, DimeNet.

All message passing routes through ``repro.models.mp`` (segment ops — the
JAX-native sparse layer).  Batched small graphs use a ``graph_ids``
vector; full-batch graphs use ``graph_ids=None`` semantics with
``n_graphs=1``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro import dist

from . import mp

Param = dict


def _node_spec(mesh):
    return P(tuple(mesh.axis_names), None)


def _vec_spec(mesh):
    return P(tuple(mesh.axis_names))


def _c(cfg, x, is_node: bool = False):
    """Optionally constrain a node/edge-major activation."""
    mode = cfg.constrain_acts
    if not mode or (mode == "nodes" and not is_node):
        return x
    if x.ndim == 1:
        return dist.constrain(x, _vec_spec)
    return dist.constrain(x, _node_spec)


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                       # gin | pna | gat | dimenet
    n_layers: int
    d_hidden: int
    d_feat: int
    n_classes: int
    # gin
    eps_learnable: bool = True
    # pna
    aggregators: Tuple[str, ...] = ("mean", "max", "min", "std")
    scalers: Tuple[str, ...] = ("identity", "amplification", "attenuation")
    mean_log_degree: float = 2.0
    # gat
    n_heads: int = 8
    # dimenet
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_species: int = 32
    task: str = "node"              # node | graph | energy
    # mesh sharding constraints on activations (§Perf):
    # "" = none (baseline), "all" = node+edge, "nodes" = per-layer node
    # states only (edge tensors left to the partitioner)
    constrain_acts: str = ""
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": jax.random.normal(k, (a, b), dtype) / np.sqrt(a),
             "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp_apply(layers, x, act=jax.nn.relu, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


# ============================================================== GIN =========
def init_gin(key, cfg: GNNConfig) -> Param:
    dt = cfg.jdtype
    keys = jax.random.split(key, cfg.n_layers + 2)
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        layers.append({
            "mlp": _mlp_init(keys[i], [d_in, cfg.d_hidden, cfg.d_hidden], dt),
            "eps": jnp.zeros((), dt),
        })
        d_in = cfg.d_hidden
    return {
        "layers": layers,
        "readout": _mlp_init(keys[-1], [cfg.d_hidden, cfg.n_classes], dt),
    }


def gin_forward(params: Param, batch: dict, cfg: GNNConfig) -> jnp.ndarray:
    x = batch["x"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = x.shape[0]
    for lp in params["layers"]:
        agg = _c(cfg, mp.scatter_sum(_c(cfg, mp.gather_src(x, src)),
                                     dst, n))
        x = _c(cfg, _mlp_apply(lp["mlp"], (1.0 + lp["eps"]) * x + agg,
                               final_act=True), is_node=True)
    if cfg.task == "graph":
        g = jax.ops.segment_sum(x, batch["graph_ids"],
                                num_segments=batch["n_graphs"])
        return _mlp_apply(params["readout"], g)
    return _mlp_apply(params["readout"], x)


# ============================================================== PNA =========
def init_pna(key, cfg: GNNConfig) -> Param:
    dt = cfg.jdtype
    keys = jax.random.split(key, cfg.n_layers + 2)
    n_agg = len(cfg.aggregators) * len(cfg.scalers)
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(keys[i])
        layers.append({
            "pre": _mlp_init(k1, [2 * d_in, cfg.d_hidden], dt),
            "post": _mlp_init(k2, [d_in + n_agg * cfg.d_hidden,
                                   cfg.d_hidden], dt),
        })
        d_in = cfg.d_hidden
    return {
        "layers": layers,
        "readout": _mlp_init(keys[-1], [cfg.d_hidden, cfg.n_classes], dt),
    }


def pna_forward(params: Param, batch: dict, cfg: GNNConfig) -> jnp.ndarray:
    x = batch["x"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = x.shape[0]
    deg = mp.degree(dst, n)
    logd = jnp.log(deg + 1.0)
    delta = cfg.mean_log_degree
    for lp in params["layers"]:
        m = _c(cfg, _mlp_apply(
            lp["pre"], jnp.concatenate([mp.gather_src(x, src),
                                        x[dst]], axis=-1), final_act=True))
        aggs = []
        mean = mp.scatter_mean(m, dst, n)
        for a in cfg.aggregators:
            if a == "mean":
                aggs.append(mean)
            elif a == "max":
                v = mp.scatter_max(m, dst, n)
                aggs.append(jnp.where(jnp.isfinite(v), v, 0.0))
            elif a == "min":
                v = mp.scatter_min(m, dst, n)
                aggs.append(jnp.where(jnp.isfinite(v), v, 0.0))
            elif a == "std":
                sq = mp.scatter_mean(jnp.square(m), dst, n)
                aggs.append(jnp.sqrt(jnp.maximum(sq - jnp.square(mean),
                                                 1e-8)))
        agg = jnp.concatenate(aggs, axis=-1)              # (N, 4H)
        scaled = []
        for s in cfg.scalers:
            if s == "identity":
                scaled.append(agg)
            elif s == "amplification":
                scaled.append(agg * (logd / delta)[:, None])
            elif s == "attenuation":
                scaled.append(agg * (delta / jnp.maximum(logd, 1e-6))[:, None])
        h = jnp.concatenate([x] + scaled, axis=-1)
        x = _c(cfg, _mlp_apply(lp["post"], h, final_act=True),
               is_node=True)
    if cfg.task == "graph":
        g = jax.ops.segment_sum(x, batch["graph_ids"],
                                num_segments=batch["n_graphs"])
        return _mlp_apply(params["readout"], g)
    return _mlp_apply(params["readout"], x)


# ============================================================== GAT =========
def init_gat(key, cfg: GNNConfig) -> Param:
    dt = cfg.jdtype
    keys = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        d_out = cfg.n_classes if last else cfg.d_hidden
        heads = 1 if last else cfg.n_heads
        k1, k2, k3 = jax.random.split(keys[i], 3)
        layers.append({
            "w": jax.random.normal(k1, (heads, d_in, d_out), dt)
            / np.sqrt(d_in),
            "a_src": jax.random.normal(k2, (heads, d_out), dt) * 0.1,
            "a_dst": jax.random.normal(k3, (heads, d_out), dt) * 0.1,
        })
        d_in = cfg.d_hidden * cfg.n_heads
    return {"layers": layers}


def gat_forward(params: Param, batch: dict, cfg: GNNConfig) -> jnp.ndarray:
    x = batch["x"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = x.shape[0]
    for i, lp in enumerate(params["layers"]):
        last = i == len(params["layers"]) - 1
        heads = lp["w"].shape[0]
        h = jnp.einsum("nd,hdo->nho", x, lp["w"])          # (N, H, O)
        e_src = jnp.einsum("nho,ho->nh", h, lp["a_src"])
        e_dst = jnp.einsum("nho,ho->nh", h, lp["a_dst"])
        logits = jax.nn.leaky_relu(e_src[src] + e_dst[dst],
                                   negative_slope=0.2)     # (E, H)
        alpha = jax.vmap(
            lambda lg: mp.segment_softmax(lg, dst, n), in_axes=1,
            out_axes=1)(logits)                            # (E, H)
        msgs = h[src] * alpha[:, :, None]
        if cfg.constrain_acts == "all":
            msgs = dist.constrain(
                msgs, lambda m: P(tuple(m.axis_names), None, None))
        out = jax.ops.segment_sum(msgs, dst, num_segments=n)  # (N, H, O)
        if cfg.constrain_acts:
            out = dist.constrain(
                out, lambda m: P(tuple(m.axis_names), None, None))

        if last:
            x = jnp.mean(out, axis=1)
        else:
            x = jax.nn.elu(out.reshape(n, -1))
    if cfg.task == "graph":
        num = jax.ops.segment_sum(x, batch["graph_ids"],
                                  num_segments=batch["n_graphs"])
        cnt = jax.ops.segment_sum(jnp.ones((n,), x.dtype),
                                  batch["graph_ids"],
                                  num_segments=batch["n_graphs"])
        return num / jnp.maximum(cnt, 1.0)[:, None]
    return x


# ============================================================ DimeNet =======
def bessel_rbf(d: jnp.ndarray, n_radial: int, cutoff: float) -> jnp.ndarray:
    """Radial Bessel basis sin(n pi d/c)/d with cosine cutoff envelope."""
    dd = jnp.maximum(d, 1e-6)[:, None]
    n = jnp.arange(1, n_radial + 1, dtype=d.dtype)
    env = 0.5 * (jnp.cos(jnp.pi * jnp.minimum(d / cutoff, 1.0)) + 1.0)
    return (jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * dd / cutoff)
            / dd) * env[:, None]


def angular_sbf(d: jnp.ndarray, angle: jnp.ndarray, n_spherical: int,
                n_radial: int, cutoff: float) -> jnp.ndarray:
    """Spherical-basis surrogate: radial sin-basis x cos(l*angle).

    DimeNet's exact basis uses spherical Bessel functions j_l and Legendre
    polynomials; we use the separable sin x cos(l.) surrogate (same rank,
    same locality structure) — noted in DESIGN.md as a TPU-friendly
    simplification that keeps the triplet-gather kernel regime intact.
    """
    rb = bessel_rbf(d, n_radial, cutoff)                   # (T, n_radial)
    l = jnp.arange(n_spherical, dtype=d.dtype)
    ab = jnp.cos(l[None, :] * angle[:, None])              # (T, n_spherical)
    return (rb[:, None, :] * ab[:, :, None]).reshape(
        d.shape[0], n_spherical * n_radial)


def init_dimenet(key, cfg: GNNConfig) -> Param:
    dt = cfg.jdtype
    keys = jax.random.split(key, cfg.n_layers + 4)
    h = cfg.d_hidden
    n_sbf = cfg.n_spherical * cfg.n_radial
    blocks = []
    for i in range(cfg.n_layers):
        k = jax.random.split(keys[i], 6)
        blocks.append({
            "w_sbf": jax.random.normal(k[0], (n_sbf, cfg.n_bilinear), dt)
            / np.sqrt(n_sbf),
            "w_kj": jax.random.normal(k[1], (h, h), dt) / np.sqrt(h),
            "bilinear": jax.random.normal(k[2], (cfg.n_bilinear, h, h), dt)
            / np.sqrt(h * cfg.n_bilinear),
            "mlp": _mlp_init(k[3], [h, h, h], dt),
            "out": _mlp_init(k[4], [h, h], dt),
        })
    return {
        "species": jax.random.normal(keys[-3], (cfg.n_species, h), dt) * 0.1,
        "embed": _mlp_init(keys[-2], [2 * h + cfg.n_radial, h], dt),
        "blocks": blocks,
        "out_rbf": jax.random.normal(keys[-1], (cfg.n_radial, h), dt)
        / np.sqrt(cfg.n_radial),
        "energy": _mlp_init(jax.random.split(keys[-1])[0],
                            [h, h, 1], dt),
    }


def dimenet_forward(params: Param, batch: dict, cfg: GNNConfig) -> jnp.ndarray:
    """batch: species (N,), pos (N,3), edge_src/dst (E,),
    trip_in/trip_out (T,) indices into edges (message k->j feeds j->i),
    graph_ids (N,), n_graphs.  Returns per-graph energy (n_graphs,)."""
    species, pos = batch["species"], batch["pos"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    e = src.shape[0]
    vec = pos[dst] - pos[src]                              # (E, 3)
    dist = jnp.linalg.norm(vec + 1e-9, axis=-1)
    rbf = bessel_rbf(dist, cfg.n_radial, cfg.cutoff)       # (E, n_radial)

    z = params["species"][species]                         # (N, h)
    m = _mlp_apply(params["embed"],
                   jnp.concatenate([z[src], z[dst], rbf], axis=-1),
                   act=jax.nn.silu, final_act=True)        # (E, h)

    ti, to = batch["trip_in"], batch["trip_out"]           # (T,)
    # angle between edge ti (k->j) and edge to (j->i)
    v1 = -vec[ti]
    v2 = vec[to]
    cosang = jnp.sum(v1 * v2, axis=-1) / jnp.maximum(
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1), 1e-9)
    angle = jnp.arccos(jnp.clip(cosang, -1.0, 1.0))
    sbf = angular_sbf(dist[ti], angle, cfg.n_spherical, cfg.n_radial,
                      cfg.cutoff)                          # (T, n_sbf)

    n = species.shape[0]
    node_out = jnp.zeros((n, cfg.d_hidden), m.dtype)
    for blk in params["blocks"]:
        # directional message passing over triplets with bilinear layer
        s_proj = sbf @ blk["w_sbf"]                        # (T, n_bilinear)
        m_kj = (m @ blk["w_kj"])[ti]                       # (T, h)
        inter = jnp.einsum("tb,th,bho->to", s_proj, m_kj,
                           blk["bilinear"])                # (T, h)
        agg = jax.ops.segment_sum(inter, to, num_segments=e)
        m = m + _mlp_apply(blk["mlp"], agg, act=jax.nn.silu, final_act=True)
        # per-block output: edges -> atoms
        contrib = (rbf @ params["out_rbf"]) * _mlp_apply(
            blk["out"], m, act=jax.nn.silu)
        node_out = node_out + jax.ops.segment_sum(contrib, dst,
                                                  num_segments=n)
    atom_e = _mlp_apply(params["energy"], node_out, act=jax.nn.silu)  # (N,1)
    return jax.ops.segment_sum(atom_e[:, 0], batch["graph_ids"],
                               num_segments=batch["n_graphs"])


# ============================================================ dispatch ======
INIT = {"gin": init_gin, "pna": init_pna, "gat": init_gat,
        "dimenet": init_dimenet}
FORWARD = {"gin": gin_forward, "pna": pna_forward, "gat": gat_forward,
           "dimenet": dimenet_forward}


def init_params(key, cfg: GNNConfig) -> Param:
    return INIT[cfg.kind](key, cfg)


def forward(params: Param, batch: dict, cfg: GNNConfig) -> jnp.ndarray:
    return FORWARD[cfg.kind](params, batch, cfg)


def gnn_loss(params: Param, batch: dict, cfg: GNNConfig):
    out = forward(params, batch, cfg)
    if cfg.task == "energy":
        err = out - batch["labels"]
        loss = jnp.mean(jnp.square(err))
        return loss, {"loss": loss}
    logits = out
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("label_mask")
    if mask is not None:
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        loss = jnp.mean(nll)
    return loss, {"loss": loss}
