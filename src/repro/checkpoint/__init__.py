from .mvckpt import CheckpointInfo, MVCheckpointStore

__all__ = ["CheckpointInfo", "MVCheckpointStore"]
