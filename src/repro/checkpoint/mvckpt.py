"""Multi-version checkpoint store tagged with refinable timestamps.

The paper's technique applied to the training substrate (DESIGN.md
§Arch-applicability): checkpoints are *versions* stamped exactly like
Weaver transactions — ``(epoch, vector-of-writer-counters)`` — so

* concurrent async checkpoint writers (one per data-parallel host group)
  order by vector-clock happens-before; truly concurrent saves are
  refined through a timeline oracle, exactly as shard servers refine
  conflicting transactions;
* restart picks the max stamp that is *complete* (all writer shards
  present) — a torn checkpoint is never restored (atomic pointer flip);
* failure bumps the epoch (cluster-manager barrier semantics), so every
  post-restart save orders after every pre-failure save;
* restore supports a different device count (elastic): parameters are
  saved unsharded per leaf and resharded on load.

Storage layout: ``<dir>/v_e<EPOCH>_<CTRS>/<leaf-path>.npy`` + a
``MANIFEST.json`` written last (the commit point).
"""

from __future__ import annotations

import json
import os
import shutil
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.core.clock import Order, Stamp, compare
from repro.core.oracle import KIND_TX, TimelineOracle


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(tree)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts)


@dataclass
class CheckpointInfo:
    stamp: Stamp
    step: int
    path: str
    complete: bool


class MVCheckpointStore:
    def __init__(self, directory: str, n_writers: int = 1,
                 writer_id: int = 0, keep: int = 3):
        self.dir = directory
        self.n_writers = n_writers
        self.writer_id = writer_id
        self.keep = keep
        self.clock = [0] * n_writers
        self.epoch = 0
        self.oracle = TimelineOracle()
        os.makedirs(directory, exist_ok=True)
        # recover clock from existing checkpoints (restart path)
        for info in self.list_checkpoints():
            st = info.stamp
            self.epoch = max(self.epoch, st.epoch)
            for i, c in enumerate(st.clock):
                self.clock[i] = max(self.clock[i], c)

    # ---- stamping --------------------------------------------------------
    def _tick(self) -> Stamp:
        self.clock[self.writer_id] += 1
        return Stamp(self.epoch, tuple(self.clock), self.writer_id,
                     self.clock[self.writer_id])

    def merge_remote_clock(self, clock: Tuple[int, ...]) -> None:
        """Announce handling (writers gossip clocks like gatekeepers)."""
        self.clock = [max(a, b) for a, b in zip(self.clock, clock)]

    def bump_epoch(self) -> None:
        """Failure barrier: all post-failure saves order after all
        pre-failure saves (paper §4.3)."""
        self.epoch += 1
        self.clock = [0] * self.n_writers

    # ---- save (atomic: manifest written last) ------------------------------
    def save(self, params, step: int, extra: Optional[dict] = None) -> Stamp:
        stamp = self._tick()
        tag = f"v_e{stamp.epoch}_" + "_".join(map(str, stamp.clock))
        path = os.path.join(self.dir, tag)
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        leaves, treedef = _flatten(params)
        names = []
        for kp, leaf in leaves:
            name = _path_str(kp)
            arr = np.asarray(leaf)
            if arr.dtype.kind not in "biufc":      # e.g. bfloat16
                arr = arr.astype(np.float32)
            np.save(os.path.join(tmp, name + ".npy"), arr)
            names.append(name)
        manifest = {
            "stamp": {"epoch": stamp.epoch, "clock": list(stamp.clock),
                      "gk": stamp.gk, "ctr": stamp.ctr},
            "step": step,
            "leaves": names,
            "writer": self.writer_id,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, path)          # commit point
        self._gc()
        return stamp

    # ---- list / order -------------------------------------------------------
    def list_checkpoints(self) -> List[CheckpointInfo]:
        out = []
        if not os.path.isdir(self.dir):
            return out
        for tag in sorted(os.listdir(self.dir)):
            if tag.endswith(".tmp"):
                continue
            mf = os.path.join(self.dir, tag, "MANIFEST.json")
            if not os.path.exists(mf):
                continue                          # torn save: ignore
            m = json.load(open(mf))
            st = Stamp(m["stamp"]["epoch"], tuple(m["stamp"]["clock"]),
                       m["stamp"]["gk"], m["stamp"]["ctr"])
            out.append(CheckpointInfo(st, m["step"],
                                      os.path.join(self.dir, tag), True))
        return out

    def latest(self) -> Optional[CheckpointInfo]:
        infos = self.list_checkpoints()
        if not infos:
            return None
        best = infos[0]
        for info in infos[1:]:
            o = compare(best.stamp, info.stamp)
            if o is Order.BEFORE:
                best = info
            elif o is Order.CONCURRENT:
                # refine: identical to Weaver's conflicting-transaction
                # path — commit an order at the oracle, reuse forever
                chain = self.oracle.order_events(
                    [best.stamp, info.stamp], [KIND_TX, KIND_TX])
                if chain[-1] == info.stamp.key():
                    best = info
        return best

    # ---- restore (elastic) ---------------------------------------------------
    def restore(self, like_tree, info: Optional[CheckpointInfo] = None,
                shardings=None):
        info = info or self.latest()
        if info is None:
            raise FileNotFoundError("no checkpoint found")
        leaves, treedef = _flatten(like_tree)
        out = []
        import jax.numpy as jnp
        for kp, leaf in leaves:
            name = _path_str(kp)
            arr = np.load(os.path.join(info.path, name + ".npy"))
            assert arr.shape == tuple(leaf.shape), (name, arr.shape,
                                                    leaf.shape)
            out.append(jnp.asarray(arr).astype(leaf.dtype))
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like_tree), out)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, info

    def _gc(self) -> None:
        infos = self.list_checkpoints()
        if len(infos) <= self.keep:
            return
        # total order (refine where needed) then drop the oldest
        infos.sort(key=lambda i: (i.stamp.epoch, sum(i.stamp.clock)))
        for info in infos[:-self.keep]:
            shutil.rmtree(info.path, ignore_errors=True)
