"""Dynamic-graph training pipeline: Weaver store -> snapshot-consistent
minibatches (the paper technique as a first-class training feature).

Writers apply update transactions to the Weaver store while the trainer
pulls batches; each batch is materialized *at a refinable timestamp* via
``analytics.snapshot_arrays``, so a long epoch of GNN steps sees one
coherent graph version per batch no matter how fast writers mutate the
graph — exactly the long-read/concurrent-write isolation the paper
builds refinable timestamps for.

Batches ride the columnar snapshot engine: the first batch pays a cold
columnar build, every later batch is a **delta refresh** that only
re-evaluates the stamps the writers touched since the previous batch
(O(changed), see ``analytics.SnapshotEngine``).  Edges are emitted in
the snapshot's **CSC (dst-major) orientation** with the padding
sentinel (``pad_nodes - 1``, the maximum index) appended last, so
``edge_dst`` is globally non-decreasing and every dst-keyed segment
reduction downstream can claim ``indices_are_sorted=True`` — flip it on
for the whole model with ``repro.models.mp.set_sorted_indices(True)``
when training exclusively from this pipeline.  ``snapshot_stats()``
exposes the engine's cold/delta counters for monitoring the hit rate
under a write workload.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import analytics
from repro.core.clock import Stamp
from repro.core.weaver import Weaver


@dataclass
class SnapshotBatch:
    x: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    labels: np.ndarray
    label_mask: np.ndarray
    graph_ids: np.ndarray
    n_graphs: int
    stamp: Stamp
    n_real_nodes: int


class DynamicGraphPipeline:
    def __init__(self, weaver: Weaver, d_feat: int, n_classes: int,
                 pad_nodes: int, pad_edges: int, seed: int = 0,
                 feature_fn: Optional[Callable] = None):
        self.weaver = weaver
        self.d_feat = d_feat
        self.n_classes = n_classes
        self.pad_nodes = pad_nodes
        self.pad_edges = pad_edges
        self.rng = np.random.default_rng(seed)
        self.feature_fn = feature_fn
        self._feat_cache: dict = {}

    def _features(self, vid: str) -> np.ndarray:
        if self.feature_fn is not None:
            return self.feature_fn(vid)
        f = self._feat_cache.get(vid)
        if f is None:
            h = abs(hash(vid)) % (2 ** 31)
            f = np.random.default_rng(h).normal(
                size=(self.d_feat,)).astype(np.float32)
            self._feat_cache[vid] = f
        return f

    def snapshot_stats(self) -> dict:
        """Cold/delta/noop counters of the weaver's snapshot engine."""
        eng = getattr(self.weaver, "_snapshot_engine", None)
        return dict(eng.stats) if eng is not None else {}

    def snapshot_batch(self) -> SnapshotBatch:
        """One snapshot-consistent full-graph batch at a fresh stamp."""
        # take a fresh stamp by running a trivially small node program:
        # its stamp is the snapshot point (ordered after all committed
        # writes, §4.2)
        vids = list(self.weaver.store.vertices.keys())
        probe = vids[0] if vids else None
        if probe is None:
            raise RuntimeError("empty graph")
        _, stamp, _ = self.weaver.run_program("count_edges", [(probe, None)])
        ga = analytics.snapshot_arrays(self.weaver, stamp)
        n = ga.n_nodes
        assert n <= self.pad_nodes and len(ga.edge_src) <= self.pad_edges, \
            (n, len(ga.edge_src), self.pad_nodes, self.pad_edges)
        x = np.zeros((self.pad_nodes, self.d_feat), np.float32)
        for i, vid in enumerate(ga.vids):
            x[i] = self._features(vid)
        labels = np.zeros((self.pad_nodes,), np.int32)
        for i, vid in enumerate(ga.vids):
            labels[i] = abs(hash(vid + "|y")) % self.n_classes
        mask = np.zeros((self.pad_nodes,), np.float32)
        mask[:n] = 1.0
        pe = self.pad_edges - len(ga.edge_src)
        dead = self.pad_nodes - 1
        # CSC orientation + max-index padding tail => dst is sorted
        src = np.concatenate([ga.csc_src,
                              np.full(pe, dead, np.int32)])
        dst = np.concatenate([ga.csc_dst,
                              np.full(pe, dead, np.int32)])
        return SnapshotBatch(
            x=x, edge_src=src, edge_dst=dst, labels=labels,
            label_mask=mask, graph_ids=np.zeros((self.pad_nodes,), np.int32),
            n_graphs=1, stamp=stamp, n_real_nodes=n)

    def batches(self, mutate_between: Optional[Callable] = None
                ) -> Iterator[dict]:
        while True:
            if mutate_between is not None:
                mutate_between(self.weaver)
            sb = self.snapshot_batch()
            yield {
                "x": sb.x, "edge_src": sb.edge_src, "edge_dst": sb.edge_dst,
                "labels": sb.labels, "label_mask": sb.label_mask,
                "graph_ids": sb.graph_ids, "n_graphs": sb.n_graphs,
            }
