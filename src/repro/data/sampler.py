"""Layer-wise neighbour sampler (GraphSAGE-style, fanout e.g. 15-10).

``minibatch_lg`` requires a real sampler: given seed nodes, sample up to
``fanout[0]`` neighbours per seed, then ``fanout[1]`` per first-hop node,
and emit a compact subgraph (relabelled ids) whose edges point hop->seed
(message flow toward the seeds), padded to static shapes for jit.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .graphs import CSRGraph


@dataclass
class SampledSubgraph:
    node_ids: np.ndarray         # (N_sub,) original ids (padded with -1)
    edge_src: np.ndarray         # (E_sub,) compact ids
    edge_dst: np.ndarray
    seeds: np.ndarray            # compact ids of the seed nodes
    n_real_nodes: int
    n_real_edges: int


class NeighborSampler:
    def __init__(self, graph: CSRGraph, fanouts: Sequence[int],
                 seed: int = 0):
        self.graph = graph
        self.fanouts = list(fanouts)
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray,
               pad_to: Optional[Tuple[int, int]] = None) -> SampledSubgraph:
        g = self.graph
        node_ids: List[int] = list(map(int, seeds))
        index = {v: i for i, v in enumerate(node_ids)}
        e_src: List[int] = []
        e_dst: List[int] = []
        frontier = list(map(int, seeds))
        for fanout in self.fanouts:
            nxt: List[int] = []
            for v in frontier:
                nbrs = g.neighbors(v)
                if len(nbrs) == 0:
                    continue
                if len(nbrs) > fanout:
                    pick = self.rng.choice(nbrs, size=fanout, replace=False)
                else:
                    pick = nbrs
                for u in map(int, pick):
                    if u not in index:
                        index[u] = len(node_ids)
                        node_ids.append(u)
                        nxt.append(u)
                    # message direction: sampled neighbour -> target
                    e_src.append(index[u])
                    e_dst.append(index[v])
            frontier = nxt
        n_real_nodes = len(node_ids)
        n_real_edges = len(e_src)
        nid = np.asarray(node_ids, dtype=np.int64)
        es = np.asarray(e_src, dtype=np.int32)
        ed = np.asarray(e_dst, dtype=np.int32)
        if pad_to is not None:
            max_n, max_e = pad_to
            assert n_real_nodes <= max_n and n_real_edges <= max_e, \
                (n_real_nodes, n_real_edges, pad_to)
            nid = np.concatenate([nid, np.full(max_n - n_real_nodes, -1,
                                               np.int64)])
            # padding edges self-loop on a dedicated dead node (last slot)
            pad_e = max_e - n_real_edges
            es = np.concatenate([es, np.full(pad_e, max_n - 1, np.int32)])
            ed = np.concatenate([ed, np.full(pad_e, max_n - 1, np.int32)])
        return SampledSubgraph(
            node_ids=nid, edge_src=es, edge_dst=ed,
            seeds=np.arange(len(seeds), dtype=np.int32),
            n_real_nodes=n_real_nodes, n_real_edges=n_real_edges)

    @staticmethod
    def max_sizes(n_seeds: int, fanouts: Sequence[int]) -> Tuple[int, int]:
        """Static worst-case (nodes, edges) for jit padding."""
        nodes = n_seeds
        layer = n_seeds
        edges = 0
        for f in fanouts:
            layer = layer * f
            nodes += layer
            edges += layer
        return nodes, edges
