from . import graphs, sampler, synth

__all__ = ["graphs", "sampler", "synth"]
