"""Synthetic data generators: token streams, recsys interactions, and the
social-network / blockchain graphs used by the paper's benchmarks."""

from __future__ import annotations

from typing import Iterator, List, Optional, Tuple

import numpy as np


def token_batches(rng: np.random.Generator, vocab: int, batch: int,
                  seq: int) -> Iterator[dict]:
    """Zipfian token stream with next-token labels."""
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs)
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}


def lm_batch(rng: np.random.Generator, vocab: int, batch: int,
             seq: int) -> dict:
    toks = rng.integers(0, vocab, size=(batch, seq + 1))
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def sasrec_batch(rng: np.random.Generator, n_items: int, batch: int,
                 seq: int) -> dict:
    hist = rng.integers(1, n_items + 1, size=(batch, seq)).astype(np.int32)
    pos = rng.integers(1, n_items + 1, size=(batch, seq)).astype(np.int32)
    neg = rng.integers(1, n_items + 1, size=(batch, seq)).astype(np.int32)
    return {"hist": hist, "pos": pos, "neg": neg}


# ---------------------------------------------------------------- paper data
def social_graph(rng: np.random.Generator, n_users: int,
                 avg_degree: int) -> List[Tuple[str, str]]:
    """Power-law follower graph (LiveJournal-flavoured)."""
    n_edges = n_users * avg_degree
    w = 1.0 / np.arange(1, n_users + 1) ** 0.8
    w /= w.sum()
    src = rng.choice(n_users, size=n_edges, p=w)
    dst = rng.choice(n_users, size=n_edges, p=w)
    return [(f"u{s}", f"u{d}") for s, d in zip(src, dst) if s != d]


def blockchain(rng: np.random.Generator, n_blocks: int,
               tx_per_block_fn=None) -> List[dict]:
    """Synthetic Bitcoin-like chain: block vertices pointing at their
    transactions, transactions pointing at output addresses.

    tx counts per block grow with height like the real chain (Fig. 7)."""
    chain = []
    addr_pool = [f"addr{i}" for i in range(max(64, n_blocks * 4))]
    for h in range(n_blocks):
        if tx_per_block_fn is not None:
            n_tx = max(1, int(tx_per_block_fn(h)))
        else:
            n_tx = max(1, int((h + 1) ** 1.2 / 2) + int(rng.integers(0, 3)))
        txs = []
        for t in range(n_tx):
            n_out = int(rng.integers(1, 4))
            outs = list(rng.choice(addr_pool, size=n_out, replace=False))
            txs.append({"id": f"tx_{h}_{t}",
                        "value": float(rng.random() * 10),
                        "outputs": outs})
        chain.append({"height": h, "id": f"block_{h}", "txs": txs})
    return chain


def tao_workload(rng: np.random.Generator, n: int, read_frac: float,
                 vertices: List[str]) -> List[dict]:
    """The paper's Table 1 mix scaled to ``read_frac`` reads.

    Reads:  get_edges 59.4%, count_edges 11.7%, get_node 28.9% (of reads)
    Writes: create_edge 80%, delete_edge 20%            (of writes)
    """
    ops = []
    for _ in range(n):
        v = vertices[int(rng.integers(0, len(vertices)))]
        if rng.random() < read_frac:
            r = rng.random()
            if r < 0.594:
                ops.append({"type": "get_edges", "v": v})
            elif r < 0.594 + 0.117:
                ops.append({"type": "count_edges", "v": v})
            else:
                ops.append({"type": "get_node", "v": v})
        else:
            if rng.random() < 0.8:
                u = vertices[int(rng.integers(0, len(vertices)))]
                ops.append({"type": "create_edge", "v": v, "u": u})
            else:
                ops.append({"type": "delete_edge", "v": v})
    return ops
