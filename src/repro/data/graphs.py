"""Graph containers and utilities: CSR build, batching of small graphs,
triplet construction for directional (DimeNet-style) message passing."""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray           # (N+1,)
    indices: np.ndarray          # (E,) neighbor ids (out-edges)
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    def degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]


def build_csr(edge_src: np.ndarray, edge_dst: np.ndarray,
              n_nodes: int) -> CSRGraph:
    order = np.argsort(edge_src, kind="stable")
    src = edge_src[order]
    dst = edge_dst[order]
    counts = np.bincount(src, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=dst.astype(np.int32),
                    n_nodes=n_nodes)


def sort_edges_by_dst(edge_src: np.ndarray, edge_dst: np.ndarray):
    """dst-sorted edge list (the layout the segment_mp kernel and the
    shard_map edge-partitioned GNN layer both want)."""
    order = np.argsort(edge_dst, kind="stable")
    return edge_src[order].astype(np.int32), edge_dst[order].astype(np.int32)


def random_graph(rng: np.random.Generator, n_nodes: int, n_edges: int,
                 power_law: bool = False) -> Tuple[np.ndarray, np.ndarray]:
    if power_law:
        # preferential-attachment-flavoured degree skew
        w = 1.0 / np.arange(1, n_nodes + 1)
        w /= w.sum()
        src = rng.choice(n_nodes, size=n_edges, p=w).astype(np.int32)
        dst = rng.choice(n_nodes, size=n_edges, p=w).astype(np.int32)
    else:
        src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
        dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    return src, dst


def batch_molecules(rng: np.random.Generator, n_graphs: int, n_nodes: int,
                    n_edges: int, d_feat: int,
                    with_pos: bool = False) -> dict:
    """Pack ``n_graphs`` identical-size molecules into one flat batch."""
    total_n = n_graphs * n_nodes
    total_e = n_graphs * n_edges
    src = np.zeros(total_e, dtype=np.int32)
    dst = np.zeros(total_e, dtype=np.int32)
    for g in range(n_graphs):
        s, d = random_graph(rng, n_nodes, n_edges)
        src[g * n_edges:(g + 1) * n_edges] = s + g * n_nodes
        dst[g * n_edges:(g + 1) * n_edges] = d + g * n_nodes
    batch = {
        "x": rng.normal(size=(total_n, d_feat)).astype(np.float32),
        "edge_src": src,
        "edge_dst": dst,
        "graph_ids": np.repeat(np.arange(n_graphs, dtype=np.int32), n_nodes),
        "n_graphs": n_graphs,
    }
    if with_pos:
        batch["pos"] = rng.normal(size=(total_n, 3)).astype(np.float32) * 2.0
        batch["species"] = rng.integers(0, 8, total_n).astype(np.int32)
    return batch


def build_triplets(edge_src: np.ndarray, edge_dst: np.ndarray,
                   max_per_edge: Optional[int] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Triplet index lists for directional MP: for each edge j->i, the
    incoming edges k->j (k != i).  Returns (trip_in, trip_out) as indices
    into the edge list: message of edge ``trip_in[t]`` feeds edge
    ``trip_out[t]``.  ``max_per_edge`` caps fan-in (cutoff analogue)."""
    e = edge_src.shape[0]
    by_dst: dict = {}
    for idx in range(e):
        by_dst.setdefault(int(edge_dst[idx]), []).append(idx)
    tin: List[int] = []
    tout: List[int] = []
    for ji in range(e):
        j = int(edge_src[ji])
        i = int(edge_dst[ji])
        incoming = by_dst.get(j, [])
        cnt = 0
        for kj in incoming:
            if int(edge_src[kj]) == i:
                continue                       # exclude backtracking k == i
            tin.append(kj)
            tout.append(ji)
            cnt += 1
            if max_per_edge is not None and cnt >= max_per_edge:
                break
    return (np.asarray(tin, dtype=np.int32),
            np.asarray(tout, dtype=np.int32))


def pad_triplets(trip_in: np.ndarray, trip_out: np.ndarray, target: int,
                 pad_edge: int) -> Tuple[np.ndarray, np.ndarray]:
    """Pad triplet lists to a static size; padding points at ``pad_edge``
    (a self-loop-free dummy whose contributions segment-sum to an unused
    slot is avoided by pointing in==out so the angle is 0 and the edge
    update adds a constant-zero after masking upstream)."""
    cur = trip_in.shape[0]
    if cur >= target:
        return trip_in[:target], trip_out[:target]
    fill = target - cur
    return (np.concatenate([trip_in, np.full(fill, pad_edge, np.int32)]),
            np.concatenate([trip_out, np.full(fill, pad_edge, np.int32)]))
