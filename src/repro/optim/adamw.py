"""AdamW + schedules + global-norm clipping, as pure pytree transforms.

No optax in this container — the optimizer substrate is implemented here
(per the scope rule: build every substrate the system depends on).
Optimizer states are fp32 regardless of parameter dtype (mixed-precision
training: bf16 params/grads, fp32 master moments).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"         # cosine | linear | const


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 1.0 - frac
    else:  # cosine
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params))


def update(cfg: AdamWConfig, grads, state: AdamWState, params
           ) -> Tuple[dict, AdamWState, dict]:
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    params = jax.tree_util.tree_unflatten(treedef, new_p)
    state = AdamWState(step=step,
                       mu=jax.tree_util.tree_unflatten(treedef, new_m),
                       nu=jax.tree_util.tree_unflatten(treedef, new_v))
    return params, state, {"lr": lr, "grad_norm": gnorm}


def make_train_step(loss_fn: Callable, cfg: AdamWConfig):
    """loss_fn(params, batch) -> (loss, metrics).  Returns a jit-able
    train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = update(cfg, grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["total_loss"] = loss
        return params, opt_state, metrics

    return train_step
