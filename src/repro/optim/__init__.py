from . import adamw, compress
from .adamw import AdamWConfig, AdamWState, make_train_step

__all__ = ["adamw", "compress", "AdamWConfig", "AdamWState",
           "make_train_step"]
