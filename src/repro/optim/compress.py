"""Gradient compression for the cross-pod hop: int8 quantization with
error feedback (1-bit-Adam-family trick adapted to int8).

At 512+ chips the pod-to-pod gradient all-reduce crosses the slowest
links; quantizing the cross-pod summand to int8 with per-tensor scales
cuts that traffic 4x (bf16) while error feedback keeps convergence
(residuals re-injected next step).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict          # same tree as grads, fp32


def init_error_feedback(params) -> EFState:
    return EFState(residual=jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef: EFState) -> Tuple[dict, EFState]:
    """Quantize grads+residual to int8; new residual = quantization error.

    The returned tree holds (q, scale) pairs; ``decompress_grads``
    reconstructs fp32.  In the distributed step this runs on the
    cross-pod axis only (see repro.dist.collectives.cross_pod_allreduce).
    """
    def one(g, r):
        tot = g.astype(jnp.float32) + r
        q, s = quantize_int8(tot)
        deq = dequantize_int8(q, s)
        return (q, s), tot - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    qs, rs = [], []
    for g, r in zip(flat_g, flat_r):
        (q, s), nr = one(g, r)
        qs.append((q, s))
        rs.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, qs),
            EFState(residual=jax.tree_util.tree_unflatten(treedef, rs)))


def decompress_grads(qtree) -> dict:
    return jax.tree_util.tree_map(
        lambda qs: dequantize_int8(*qs), qtree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and hasattr(x[0], "dtype"))
