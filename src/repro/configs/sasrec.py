"""sasrec [arXiv:1808.09781] — embed_dim=50, 2 blocks, 1 head, seq 50.

Item table sized at 1M rows so the retrieval_cand shape (1M candidates)
is well-defined at production scale (taxonomy §B.6: 10^6-10^9 rows)."""
from repro.configs.base import ArchSpec, recsys_shapes
from repro.models.sasrec import SASRecConfig

CONFIG = SASRecConfig(
    name="sasrec", n_items=1_000_000, seq_len=50, d_embed=50,
    n_blocks=2, n_heads=1,
)

SPEC = ArchSpec(arch_id="sasrec", family="recsys", config=CONFIG,
                shapes=recsys_shapes(), citation="arXiv:1808.09781")
