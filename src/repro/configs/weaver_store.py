"""The paper system's own deployment config (Weaver itself, §5).

44-machine cluster of the paper mapped to simulator parameters; used by
benchmarks and the serving examples.
"""
from repro.core.gatekeeper import CostModel
from repro.core.simulation import NetworkModel
from repro.core.weaver import WeaverConfig

PAPER_DEPLOYMENT = WeaverConfig(
    n_gatekeepers=4,
    n_shards=8,
    tau=0.2e-3,           # vector-clock announce period (swept in Fig. 14;
                          # §3.5: tuned to the workload — serving mixes
                          # run tight announce cadence)
    tau_nop=0.1e-3,
    gc_period=50e-3,
    cost=CostModel(),
    network=NetworkModel(base_latency=100e-6, bandwidth=125e6),
)
