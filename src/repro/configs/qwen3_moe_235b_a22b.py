"""qwen3-moe-235b-a22b — Qwen3-MoE [hf:Qwen/Qwen3-30B-A3B family].

94L d_model=4096 64H (GQA kv=4) d_ff=1536(expert) vocab=151936,
MoE 128 experts top-8.  d_head=128 (explicit; 64 heads x 128 > d_model).
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv=4, d_head=128,
    d_ff=1536, vocab=151936,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536),
)

SPEC = ArchSpec(
    arch_id="qwen3-moe-235b-a22b", family="lm", config=CONFIG,
    shapes=lm_shapes(pure_full_attention=True),
    citation="hf:Qwen/Qwen3-30B-A3B",
)
