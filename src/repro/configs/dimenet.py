"""dimenet [arXiv:2003.03123] — 6 blocks d=128, n_bilinear=8,
n_spherical=7, n_radial=6 (directional message passing over triplets).

Triplet counts are bounded by the radius cutoff in molecular practice;
the grid's non-molecular cells cap triplets at 8 per edge (DESIGN.md)."""
from repro.configs.base import ArchSpec, gnn_shapes
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="dimenet", kind="dimenet", n_layers=6, d_hidden=128,
    d_feat=16, n_classes=1, n_bilinear=8, n_spherical=7, n_radial=6,
    task="energy",
)

SPEC = ArchSpec(arch_id="dimenet", family="gnn", config=CONFIG,
                shapes=gnn_shapes(), citation="arXiv:2003.03123")
