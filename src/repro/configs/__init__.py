"""Architecture registry: ``--arch <id>`` resolution for the launcher.

Ten assigned architectures (5 LM, 4 GNN, 1 recsys) + the paper system's
own deployment config.  Each ArchSpec carries its own shape set, so every
(arch x shape) cell of the 40-cell dry-run grid is well-defined.
"""

from __future__ import annotations

from typing import Dict, List

from .base import ArchSpec, ShapeSpec

from . import (chatglm3_6b, dimenet, gat_cora, gemma3_1b, gin_tu,
               moonshot_v1_16b_a3b, phi4_mini_3p8b, pna, qwen3_moe_235b_a22b,
               sasrec)
from .weaver_store import PAPER_DEPLOYMENT

_MODULES = [moonshot_v1_16b_a3b, qwen3_moe_235b_a22b, phi4_mini_3p8b,
            gemma3_1b, chatglm3_6b, gin_tu, pna, dimenet, gat_cora, sasrec]

ARCHS: Dict[str, ArchSpec] = {m.SPEC.arch_id: m.SPEC for m in _MODULES}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells(include_skipped: bool = True) -> List[tuple]:
    """Every (arch_id, shape_name, ShapeSpec) cell of the grid."""
    out = []
    for aid, spec in ARCHS.items():
        for sname, sh in spec.shapes.items():
            if include_skipped or not sh.skip:
                out.append((aid, sname, sh))
    return out


__all__ = ["ARCHS", "get_arch", "all_cells", "ArchSpec", "ShapeSpec",
           "PAPER_DEPLOYMENT"]
