"""gin-tu [arXiv:1810.00826] — GIN, 5 layers d=64, sum agg, learnable eps."""
from repro.configs.base import ArchSpec, gnn_shapes
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="gin-tu", kind="gin", n_layers=5, d_hidden=64,
    d_feat=16, n_classes=2, eps_learnable=True, task="node",
)

SPEC = ArchSpec(arch_id="gin-tu", family="gnn", config=CONFIG,
                shapes=gnn_shapes(), citation="arXiv:1810.00826")
