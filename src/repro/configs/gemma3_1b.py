"""gemma3-1b [hf:google/gemma-3-1b-pt] — 5:1 local:global, 128k-capable.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, d_head=256,
sliding window 512 on local layers, embeddings scaled by sqrt(d).
Hybrid local/global => the ONE LM arch that runs long_500k decode.
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="gemma3-1b",
    n_layers=26, d_model=1152, n_heads=4, n_kv=1, d_head=256,
    d_ff=6912, vocab=262144,
    window_pattern=(512, 512, 512, 512, 512, 0),   # 5 local : 1 global
    embed_scale=True,
)

SPEC = ArchSpec(
    arch_id="gemma3-1b", family="lm", config=CONFIG,
    shapes=lm_shapes(pure_full_attention=False),
    citation="hf:google/gemma-3-1b-pt",
)
