"""Config schema: architectures x input shapes (the 40-cell grid)."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

from repro.models.gnn import GNNConfig
from repro.models.sasrec import SASRecConfig
from repro.models.transformer import LMConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell for an architecture.

    kind:
      lm_train / lm_prefill / lm_decode         (LM family)
      gnn_full / gnn_mini / gnn_mol             (GNN family)
      rec_train / rec_serve / rec_retrieval     (recsys family)
    dims: family-specific sizes (see repro.launch.input_specs).
    skip: non-empty => cell skipped, with the reason recorded in the
          roofline table (e.g. long_500k on pure full-attention archs).
    """

    name: str
    kind: str
    dims: Dict[str, int]
    skip: str = ""


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                     # lm | gnn | recsys
    config: Union[LMConfig, GNNConfig, SASRecConfig]
    shapes: Dict[str, ShapeSpec]
    citation: str = ""


# ---- canonical shape sets -------------------------------------------------

def lm_shapes(pure_full_attention: bool) -> Dict[str, ShapeSpec]:
    skip = ("pure full-attention arch: long_500k requires sub-quadratic "
            "attention (DESIGN.md §Arch-applicability)"
            if pure_full_attention else "")
    return {
        "train_4k": ShapeSpec("train_4k", "lm_train",
                              {"seq": 4096, "batch": 256}),
        "prefill_32k": ShapeSpec("prefill_32k", "lm_prefill",
                                 {"seq": 32768, "batch": 32}),
        "decode_32k": ShapeSpec("decode_32k", "lm_decode",
                                {"seq": 32768, "batch": 128}),
        "long_500k": ShapeSpec("long_500k", "lm_decode",
                               {"seq": 524288, "batch": 1}, skip=skip),
    }


def gnn_shapes() -> Dict[str, ShapeSpec]:
    # minibatch_lg: Reddit-scale graph, layer-wise fanout 15-10 from 1024
    # seeds -> 169,984 nodes / 168,960 edges in the sampled subgraph
    # (d_feat=602 per the Reddit dataset; the grid spec pins only the
    # full-graph cells' feature widths).
    return {
        "full_graph_sm": ShapeSpec("full_graph_sm", "gnn_full",
                                   {"n_nodes": 2708, "n_edges": 10556,
                                    "d_feat": 1433, "n_classes": 7}),
        "minibatch_lg": ShapeSpec("minibatch_lg", "gnn_mini",
                                  {"graph_nodes": 232965,
                                   "graph_edges": 114615892,
                                   "batch_nodes": 1024,
                                   "fanout1": 15, "fanout2": 10,
                                   "n_nodes": 169984, "n_edges": 168960,
                                   "d_feat": 602, "n_classes": 41}),
        "ogb_products": ShapeSpec("ogb_products", "gnn_full",
                                  {"n_nodes": 2449029, "n_edges": 61859140,
                                   "d_feat": 100, "n_classes": 47}),
        "molecule": ShapeSpec("molecule", "gnn_mol",
                              {"n_nodes": 30, "n_edges": 64, "batch": 128,
                               "d_feat": 16, "n_classes": 2}),
    }


def recsys_shapes() -> Dict[str, ShapeSpec]:
    return {
        "train_batch": ShapeSpec("train_batch", "rec_train",
                                 {"batch": 65536}),
        "serve_p99": ShapeSpec("serve_p99", "rec_serve", {"batch": 512}),
        "serve_bulk": ShapeSpec("serve_bulk", "rec_serve",
                                {"batch": 262144}),
        "retrieval_cand": ShapeSpec("retrieval_cand", "rec_retrieval",
                                    {"batch": 1, "n_candidates": 1_000_000}),
    }
