"""moonshot-v1-16b-a3b — Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (GQA kv=16 == MHA) d_ff=1408(expert) vocab=163840,
MoE 64 experts top-6 (+2 Moonlight shared experts).
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48, d_model=2048, n_heads=16, n_kv=16, d_head=128,
    d_ff=1408, vocab=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
)

SPEC = ArchSpec(
    arch_id="moonshot-v1-16b-a3b", family="lm", config=CONFIG,
    shapes=lm_shapes(pure_full_attention=True),
    citation="hf:moonshotai/Moonlight-16B-A3B",
)
