"""phi4-mini-3.8b [arXiv:2412.08905] — dense, RoPE SwiGLU GQA.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064, d_head=128.
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="phi4-mini-3.8b",
    n_layers=32, d_model=3072, n_heads=24, n_kv=8, d_head=128,
    d_ff=8192, vocab=200064,
)

SPEC = ArchSpec(
    arch_id="phi4-mini-3.8b", family="lm", config=CONFIG,
    shapes=lm_shapes(pure_full_attention=True),
    citation="arXiv:2412.08905",
)
