"""pna [arXiv:2004.05718] — 4 layers d=75, mean/max/min/std aggregators,
identity/amplification/attenuation scalers."""
from repro.configs.base import ArchSpec, gnn_shapes
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="pna", kind="pna", n_layers=4, d_hidden=75,
    d_feat=16, n_classes=2,
    aggregators=("mean", "max", "min", "std"),
    scalers=("identity", "amplification", "attenuation"),
    task="node",
)

SPEC = ArchSpec(arch_id="pna", family="gnn", config=CONFIG,
                shapes=gnn_shapes(), citation="arXiv:2004.05718")
