"""chatglm3-6b [arXiv:2406.12793] — RoPE 2d (partial rotary), GQA kv=2.

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024, d_head=128,
rotary on half the head dims, QKV bias.
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="chatglm3-6b",
    n_layers=28, d_model=4096, n_heads=32, n_kv=2, d_head=128,
    d_ff=13696, vocab=65024,
    rope_fraction=0.5, qkv_bias=True,
)

SPEC = ArchSpec(
    arch_id="chatglm3-6b", family="lm", config=CONFIG,
    shapes=lm_shapes(pure_full_attention=True),
    citation="arXiv:2406.12793",
)
