"""gat-cora [arXiv:1710.10903] — 2 layers, 8 heads x d=8, attn agg."""
from repro.configs.base import ArchSpec, gnn_shapes
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="gat-cora", kind="gat", n_layers=2, d_hidden=8, n_heads=8,
    d_feat=1433, n_classes=7, task="node",
)

SPEC = ArchSpec(arch_id="gat-cora", family="gnn", config=CONFIG,
                shapes=gnn_shapes(), citation="arXiv:1710.10903")
