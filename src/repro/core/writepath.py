"""Group-commit write engine (paper §4.1/§4.4 commit protocol, batched).

This module is to the write side what :mod:`repro.core.frontier` is to
the read side: PRs 1–3 columnarized snapshots, node programs and plan
maintenance, but transactions still flowed one at a time — one
gatekeeper ``_serve`` round per tx, one per-vertex ``last_update_of``
dict probe per write-set entry, one store round trip per tx and one
shard queue item per (tx, shard).  Write-optimized transactional graph
stores (LiveGraph's purely-sequential write path, GTX's delta-chain
group writes) batch exactly these four stages; this module provides the
data structures and vectorized kernels, and
:meth:`repro.core.gatekeeper.Gatekeeper._at_store_batch` drives them.

Group-commit contract
---------------------
* **Admission** — transactions arriving at one gatekeeper within a
  configurable window (``WeaverConfig.write_group_commit`` seconds,
  capped at ``write_group_max`` transactions) are stamped in ONE
  ``_serve`` round.  Every transaction still receives its own fresh
  ``_tick()`` stamp, so per-tx ``(gk, ctr)`` identity — and therefore
  multi-version visibility — is exactly the per-tx path's.
* **Commit point / durability** — the batch commits at the backing
  store in ONE round trip (:meth:`repro.core.store.BackingStore.
  apply_batch`): one group WAL record is the batch's single durability
  point, and each client reply is sent only after it (§4.4 part 2
  unchanged: the store is the commit point).
* **Intra-batch ordering** — a batch is applied in stamp order, which
  for one gatekeeper is admission order (the vector clock's own counter
  is monotone).  Same-vertex writers inside a batch therefore serialize
  by stamp with no validation traffic — the earlier stamp is strictly
  vector-before the later one — while independent writers commit
  together.  Logical errors (create of an existing vertex, …) abort
  only their own transaction; the rest of the batch commits.
* **Validation** — ``T_upd ≺ T_tx`` runs against
  :class:`LastUpdateTable`, a packed ``(N, G+1)`` int32 mirror of the
  store's per-vertex last-update stamps (same layout as
  ``PartitionColumns`` stamp matrices), with ONE vectorized compare for
  the entire batch's write-sets (numpy on CPU; the jnp path compiles
  the same elementwise compare the ``mv_visibility`` kernel uses when a
  device backend is active).  Rows ordered AFTER the transaction stamp
  retry with a fresh stamp (rejoining the next window); the truly
  concurrent residue falls out to ONE batched timeline-oracle round
  trip (`refine_commit`), committing ``T_upd ≺ T_tx`` per pair exactly
  like the per-tx path and retrying the transaction on ``CycleError``.
* **Shard apply** — each destination shard receives ONE packed
  :class:`WriteBatch` queue item per window (mirroring the read side's
  packed ``Frontier``), applied into ``MVGraphPartition`` as bulk
  column appends (one patch-log extend + one stamp-matrix append per
  batch, see ``PartitionColumns.begin_batch``).  Snapshot/plan delta
  refresh sees the identical cursor contract, just with fewer, larger
  patch tails.

The per-tx path (``write_group_commit = 0``) is preserved untouched as
the oracle/fallback; ``tests/test_writepath.py`` asserts randomized
batched == per-tx equivalence.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .clock import NO_STAMP, Order, Stamp, compare, pack
from .mvgraph import VidIntern, _GrowRows
from .oracle import CycleError, TimelineOracle


# ---------------------------------------------------------------------------
# Redo log records (replayable WAL; see BackingStore)
# ---------------------------------------------------------------------------

@dataclass
class WalRecord:
    """One redo-log record in :attr:`repro.core.store.BackingStore.wal`.

    ``kind`` is ``"tx"`` (one per-tx commit), ``"group"`` (one
    group-commit window — the window's single durability point), or
    ``"ckpt"`` (a checkpoint: the full per-shard redo stream at GC time,
    replacing all earlier records so the log stays bounded and replay
    agrees with the GC'd store).

    ``entries`` holds ``(stamp, txid, fwd)`` per committed transaction,
    where ``fwd`` is the transaction's forwarded ``(shard, op)`` list
    with each op dict carrying its commit stamp under ``"ts"`` — the
    exact redo stream a shard applies.  Only ``entries[:valid]`` are
    durable: a crash during the group append leaves a torn tail
    (``valid < len(entries)``) that replay MUST truncate."""

    kind: str
    entries: List[Tuple[Stamp, object, List[Tuple[int, dict]]]] = \
        field(default_factory=list)
    valid: int = 0
    ckpt: Optional[Dict[int, List[dict]]] = None


def wal_replay_shard(wal: Sequence[WalRecord], shard: int
                     ) -> Tuple[List[dict], int]:
    """Redo stream for one shard, up to the stable point.

    A ``ckpt`` record resets the stream (it subsumes everything before
    it); ``tx``/``group`` records contribute their durable prefix
    ``entries[:valid]`` in log order.  Returns ``(ops, torn)`` where
    ``torn`` counts the truncated torn-tail entries."""
    ops: List[dict] = []
    torn = 0
    for rec in wal:
        if rec.kind == "ckpt":
            ops = list(rec.ckpt.get(shard, ()))
            continue
        torn += len(rec.entries) - rec.valid
        for _, _, fwd in rec.entries[:rec.valid]:
            for sid, op in fwd:
                if sid == shard:
                    ops.append(op)
    return ops, torn


# ---------------------------------------------------------------------------
# Vectorized stamp-pair comparison (the batch analogue of clock.compare)
# ---------------------------------------------------------------------------

def _before_pairs_xp(xp, rows, qs):
    """``rows[i] ≺ qs[i]`` elementwise over two (M, G+1) stamp matrices,
    written once over the array module (``xp`` = numpy or jax.numpy) so
    the CPU and accelerator paths cannot drift.

    The pairwise form of :func:`repro.core.clock._np_before` (there the
    query stamp is shared); absent rows (``NO_STAMP``) are never
    before."""
    is_no = rows[:, 0] == NO_STAMP
    lower = rows[:, 0] < qs[:, 0]
    same = rows[:, 0] == qs[:, 0]
    le = xp.all(rows[:, 1:] <= qs[:, 1:], axis=1)
    eq = xp.all(rows[:, 1:] == qs[:, 1:], axis=1)
    return xp.where(is_no, False, lower | (same & le & ~eq))


def _np_before_pairs(rows: np.ndarray, qs: np.ndarray) -> np.ndarray:
    return _before_pairs_xp(np, rows, qs)


def before_pairs(rows: np.ndarray, qs: np.ndarray) -> np.ndarray:
    """Elementwise ``rows[i] ≺ qs[i]`` with the data plane's backend
    auto-switch: numpy on CPU; on an accelerator backend the identical
    elementwise compare runs as one fused jnp launch (the
    ``mv_visibility`` kernel's contract generalized to a per-row query
    stamp, which the single-``q`` Pallas kernel cannot express)."""
    if rows.shape[0] == 0:
        return np.zeros((0,), bool)
    from . import analytics
    if analytics._use_kernel():
        import jax.numpy as jnp
        return np.asarray(_before_pairs_xp(jnp, jnp.asarray(rows),
                                           jnp.asarray(qs)))
    return _before_pairs_xp(np, rows, qs)


# ---------------------------------------------------------------------------
# Last-update table
# ---------------------------------------------------------------------------

class LastUpdateTable:
    """Interned-vid-indexed packed last-update stamps (store-side).

    Replaces the gatekeeper commit path's per-vertex
    ``last_update_of`` dict walk: one row per vertex ever written, in
    the same ``[epoch, c_0..c_{G-1}]`` int32 layout as
    ``PartitionColumns`` stamp matrices, plus the original
    :class:`Stamp` objects for oracle refinement of truly concurrent
    rows.  :meth:`gather` materializes a batch's whole write-set as one
    (M, G+1) matrix for :func:`classify_write_sets`.

    The table mirrors ``StoredVertex.last_update`` exactly — it is
    updated at the same commit points (``BackingStore.apply`` /
    ``apply_batch``) over the same :meth:`BackingStore.write_set` vids;
    ``tests/test_writepath.py`` property-tests the equivalence.
    :meth:`collect` (driven by the store's GC hook) drops rows strictly
    before the global GC horizon — absence classifies identically, so
    the table stays bounded under churn instead of growing one row per
    vertex ever written.

    ``mutations`` is a monotone sequence number bumped at every
    :meth:`record` call: the gatekeeper's validate-at-commit loop
    snapshots it at admission-time classification and skips the second
    ``classify_write_sets`` pass at the durability instant when the
    table did not move in between (an unchanged table yields identical
    verdicts, and any already-refined residue is filtered by the
    caller's ``seen`` set).  :meth:`collect` deliberately does NOT bump
    it — GC only drops rows strictly before the global horizon, and
    absence classifies identically to the dropped row."""

    def __init__(self, intern: Optional[VidIntern] = None) -> None:
        self.intern = intern if intern is not None else VidIntern()
        self.c = 0                      # row width, sized on first record
        self.rows: Optional[_GrowRows] = None
        self.stamps: List[Stamp] = []
        self.slot: Dict[int, int] = {}  # gid -> row
        self.mutations = 0              # monotone record() sequence number

    def _ensure(self, ts: Stamp) -> None:
        if self.rows is None:
            self.c = len(ts.clock) + 1
            self.rows = _GrowRows(self.c)

    def record(self, vids: Sequence[str], ts: Stamp) -> None:
        """Set the last-update stamp of every vid (post-commit)."""
        if not vids:
            return
        self.mutations += 1
        self._ensure(ts)
        row = pack(ts, len(ts.clock))
        for vid in vids:
            g = self.intern.intern(vid)
            s = self.slot.get(g)
            if s is None:
                self.slot[g] = self.rows.append(row)
                self.stamps.append(ts)
            else:
                self.rows.set(s, row)
                self.stamps[s] = ts

    def get(self, vid: str) -> Optional[Stamp]:
        g = self.intern.ids.get(vid)
        if g is None:
            return None
        s = self.slot.get(g)
        return None if s is None else self.stamps[s]

    def collect(self, horizon: Stamp) -> int:
        """GC hook: drop every row whose stamp is strictly vector-before
        the global GC horizon.  Any stamp a future transaction can carry
        dominates the horizon, so for a dropped row ``upd ≺ tx`` holds
        by transitivity — absence ("no last update") classifies as OK in
        :func:`classify_write_sets`, exactly like the kept row would.
        Bounds the table at O(recently-written vertices) instead of one
        row per vertex ever written.  Returns the dropped-row count."""
        if self.rows is None or self.rows.n == 0:
            return 0
        from .clock import _np_before
        q = pack(horizon, len(horizon.clock))
        view = self.rows.view()
        if view.shape[1] != q.size:     # different G: epoch-0 leftovers
            return 0
        drop = _np_before(view, q)
        n_drop = int(drop.sum())
        if n_drop == 0:
            return 0
        keep = np.nonzero(~drop)[0]
        gid_of_row = np.full(view.shape[0], -1, np.int64)
        for g, r in self.slot.items():
            gid_of_row[r] = g
        nu = _GrowRows(self.c)
        nu.extend(view[keep])
        keep_l = keep.tolist()
        self.stamps = [self.stamps[i] for i in keep_l]
        self.slot = {int(gid_of_row[r]): i for i, r in enumerate(keep_l)
                     if gid_of_row[r] >= 0}
        self.rows = nu
        return n_drop

    def gather(self, vids: Sequence[str]
               ) -> Tuple[np.ndarray, List[Optional[Stamp]]]:
        """(M, G+1) packed rows + Stamp objects for ``vids`` (all-
        ``NO_STAMP`` row / None for never-updated vertices)."""
        m = len(vids)
        c = self.c if self.c else 2
        out = np.full((m, c), NO_STAMP, np.int32)
        stamps: List[Optional[Stamp]] = [None] * m
        if self.rows is not None:
            view = self.rows.view()
            for i, vid in enumerate(vids):
                g = self.intern.ids.get(vid)
                s = None if g is None else self.slot.get(g)
                if s is not None:
                    out[i] = view[s]
                    stamps[i] = self.stamps[s]
        return out, stamps


#: per-tx validation verdicts
OK, RETRY = 0, 1


@dataclass
class TxVerdict:
    """Outcome of batched last-update validation for one transaction."""

    status: int                                  # OK | RETRY
    concurrent: List[Stamp] = field(default_factory=list)


def classify_write_sets(table: LastUpdateTable,
                        write_sets: Sequence[Sequence[str]],
                        stamps: Sequence[Stamp]) -> Tuple[List[TxVerdict], int]:
    """Validate an entire batch's write-sets in one vectorized pass.

    For every (tx, written vid) pair, compare the vid's last-update
    stamp against the tx stamp — the batched form of the per-tx path's
    ``compare(upd, stamp)`` dict walk:

    * ``upd ≺ tx``  (or no last update)  -> row passes;
    * ``tx ≺ upd``                       -> the tx must RETRY with a
      fresh stamp (it was stamped behind an already-executed write);
    * truly concurrent                   -> the ``upd`` stamp joins the
      tx's refinement residue, resolved by ONE batched oracle round
      trip (:func:`refine_commit`).

    Returns (per-tx verdicts, rows checked).  Intra-batch overlaps need
    no rows here: batches are applied in stamp order and one
    gatekeeper's stamps are totally ordered, so an earlier tx's write
    is strictly before a later tx's stamp by construction.
    """
    flat: List[str] = []
    tx_of: List[int] = []
    for i, ws in enumerate(write_sets):
        for vid in ws:
            flat.append(vid)
            tx_of.append(i)
    verdicts = [TxVerdict(OK) for _ in write_sets]
    if not flat:
        return verdicts, 0
    rows, row_stamps = table.gather(flat)
    # pack once per TX, gather per row (write sets share their tx stamp)
    q_tx = np.stack([pack(s, len(s.clock)) for s in stamps])
    qs = q_tx[np.asarray(tx_of)]
    if rows.shape[1] != qs.shape[1]:    # table not sized yet (all absent)
        rows = np.full(qs.shape, NO_STAMP, np.int32)
    present = rows[:, 0] != NO_STAMP
    before = before_pairs(rows, qs)     # upd ≺ tx (kernel-capable path)
    # tx ≺ upd (rare residue, np); an absent row is "no last update",
    # never after — NO_STAMP in the target position must not read as
    # "later than everything"
    after = present & _np_before_pairs(qs, rows)
    conc = present & ~before & ~after   # incl. equal vectors, other gk
    for i in np.nonzero(after)[0].tolist():
        verdicts[tx_of[i]].status = RETRY
    for i in np.nonzero(conc)[0].tolist():
        v = verdicts[tx_of[i]]
        s = row_stamps[i]
        # packed rows carry no gatekeeper id, so equal vectors land here;
        # confirm true concurrency on the Stamp (EQUAL-same-gk passes,
        # matching clock.compare exactly) — the residue is tiny
        if v.status == OK and s is not None and compare(
                s, stamps[tx_of[i]]) is Order.CONCURRENT:
            v.concurrent.append(s)
    return verdicts, len(flat)


def refine_commit(oracle: TimelineOracle,
                  pending: Sequence[Tuple[int, Stamp, List[Stamp]]]
                  ) -> List[int]:
    """Commit ``upd ≺ tx`` for every concurrent residue pair, batched.

    ``pending`` holds ``(tx_index, tx_stamp, [upd stamps...])``; the
    whole residue ships to the oracle as ONE round trip (the caller
    charges a single ``oracle_rtt``), mirroring the per-tx path's
    ``create_event + assert_order`` semantics per pair.  Returns the tx
    indices whose commitment closed a cycle — those retry with a fresh
    stamp, exactly like the per-tx path's ``CycleError`` branch."""
    failed: List[int] = []
    for idx, tx_stamp, upds in pending:
        try:
            for upd in upds:
                oracle.create_event(upd)
                oracle.create_event(tx_stamp)
                oracle.assert_order(upd.key(), tx_stamp.key())
        except CycleError:
            failed.append(idx)
    return failed


# ---------------------------------------------------------------------------
# Packed shard delivery
# ---------------------------------------------------------------------------

class WriteBatch:
    """One gatekeeper window's committed writes for ONE shard.

    ``items`` is ``[(stamp, ops), ...]`` in commit-stamp order; the
    batch travels as a single sequence-numbered queue item (stamp = the
    first/lowest stamp, which is what the shard's head-ordering loop
    keys on) and applies via ``MVGraphPartition.apply_batch`` — the
    write-side mirror of the read side's packed ``Frontier``."""

    __slots__ = ("items",)

    def __init__(self, items: List[Tuple[Stamp, List[dict]]]):
        self.items = items

    def __len__(self) -> int:
        return len(self.items)

    @property
    def stamp(self) -> Stamp:
        return self.items[0][0]

    def n_ops(self) -> int:
        return sum(len(ops) for _, ops in self.items)

    def nbytes(self) -> int:
        """Simulated wire size: one header + packed per-op payload."""
        return 64 + 16 * len(self.items) + 48 * self.n_ops()
