"""Two-phase-locking distributed graph store — the Titan stand-in (§5.2).

Titan v0.4.2 "uses two-phase commit with distributed locking in the
commit phase to ensure serializability [and] always has to
pessimistically lock all objects in the transaction, irrespective of the
ratio of reads and writes".  This engine reproduces that cost model on
the same simulator, same cost constants and same graph sharding as
Weaver, so throughput/latency comparisons are apples-to-apples:

* every transaction (reads included) acquires locks on all touched
  vertices, in global vid order (deadlock-free), one lock-manager RPC per
  vertex to the owning shard;
* writes then apply at the owning shards; a two-phase commit (prepare +
  commit RPC per participant shard) finishes the transaction;
* locks release with the commit message.

Contention on hot vertices serializes behind the FIFO lock queues.
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from .gatekeeper import CostModel
from .simulation import NetworkModel, Simulator


class LockShard:
    """Shard server: lock table + graph data (single-version; 2PL needs none).

    ``LOCK_CLAIM`` models Titan's consistency protocol: a lock is a
    *claim column* written to the backing store (Cassandra quorum write +
    re-read to verify the claim won) before the transaction may proceed —
    milliseconds per locked key on the paper's hardware.  Titan's default
    ``storage.lock-wait-time`` is 100 ms per key; 5 ms here is
    deliberately conservative (favours the baseline).
    """

    LOCK_CLAIM = 5e-3

    def __init__(self, sim: Simulator, sid: int, cost: CostModel):
        self.sim = sim
        sim.register(self)
        self.sid = sid
        self.cost = cost
        self.locks: Dict[str, deque] = {}          # vid -> waiter queue
        self.holder: Dict[str, int] = {}           # vid -> tx id
        self.vertices: Dict[str, dict] = {}        # vid -> {edges, props}

    def acquire(self, requester, txid: int, vid: str, grant: Callable) -> None:
        q = self.locks.setdefault(vid, deque())
        if vid not in self.holder:
            self.holder[vid] = txid
            self.sim.schedule(self.cost.lock_op + self.LOCK_CLAIM,
                              lambda: self.sim.send(self, requester, grant))
        else:
            self.sim.counters.lock_waits += 1
            q.append((requester, txid, grant))

    def release(self, txid: int, vids: List[str]) -> None:
        for vid in vids:
            if self.holder.get(vid) == txid:
                del self.holder[vid]
                q = self.locks.get(vid)
                if q:
                    requester, ntx, grant = q.popleft()
                    self.holder[vid] = ntx
                    self.sim.schedule(self.cost.lock_op + self.LOCK_CLAIM,
                                      lambda g=grant, r=requester:
                                      self.sim.send(self, r, g))

    # ---- data ops (executed under locks) --------------------------------
    def apply_ops(self, ops: List[dict]) -> None:
        for op in ops:
            k = op["op"]
            if k == "create_vertex":
                self.vertices[op["vid"]] = {"edges": {}, "props": {}}
            elif k == "delete_vertex":
                self.vertices.pop(op["vid"], None)
            elif k == "create_edge":
                self.vertices[op["src"]]["edges"][op["eid"]] = op["dst"]
            elif k == "delete_edge":
                self.vertices[op["src"]]["edges"].pop(op["eid"], None)
            elif k == "set_vertex_prop":
                self.vertices[op["vid"]]["props"][op["key"]] = op["value"]

    def read_vertex(self, vid: str) -> Optional[dict]:
        return self.vertices.get(vid)


class TwoPLStore:
    """Client-facing coordinator implementing lock -> execute -> 2PC."""

    def __init__(self, n_shards: int = 4, cost: Optional[CostModel] = None,
                 network: Optional[NetworkModel] = None, seed: int = 0):
        self.sim = Simulator(seed=seed, network=network or NetworkModel())
        self.sim.register(self)
        self.cost = cost or CostModel()
        self.shards = [LockShard(self.sim, s, self.cost)
                       for s in range(n_shards)]
        self.n_shards = n_shards
        self._txids = itertools.count(1)
        self._eids = itertools.count(1)

    def place(self, vid: str) -> int:
        return hash(vid) % self.n_shards

    def fresh_eid(self) -> int:
        return next(self._eids)

    # ---- transaction: reads and writes all lock --------------------------
    def submit(self, ops: List[dict], callback: Callable) -> None:
        txid = next(self._txids)
        t0 = self.sim.now
        touched = sorted({self._vertex_of(op) for op in ops})
        by_shard: Dict[int, List[str]] = {}
        for vid in touched:
            by_shard.setdefault(self.place(vid), []).append(vid)

        lock_plan = [(self.place(vid), vid) for vid in touched]
        state = {"i": 0, "reads": {}}

        def acquire_next() -> None:
            if state["i"] >= len(lock_plan):
                execute()
                return
            sid, vid = lock_plan[state["i"]]
            state["i"] += 1
            shard = self.shards[sid]
            self.sim.send(self, shard, shard.acquire, self, txid, vid,
                          acquire_next, nbytes=64)

        def execute() -> None:
            # apply writes at owning shards; collect reads
            writes_by_shard: Dict[int, List[dict]] = {}
            for op in ops:
                if op["op"] == "get_vertex":
                    sid = self.place(op["vid"])
                    state["reads"][op["vid"]] = \
                        self.shards[sid].read_vertex(op["vid"])
                else:
                    sid = self.place(self._vertex_of(op))
                    writes_by_shard.setdefault(sid, []).append(op)
            participants = set(by_shard) | set(writes_by_shard)
            # two-phase commit: prepare RTT then commit+release RTT
            remaining = {"n": len(participants)}

            def prepared() -> None:
                remaining["n"] -= 1
                if remaining["n"] == 0:
                    commit()

            for sid in participants:
                shard = self.shards[sid]
                wops = writes_by_shard.get(sid, [])
                def _prep(shard=shard, wops=wops):
                    shard.apply_ops(wops)
                    self.sim.send(shard, self, prepared, nbytes=32)
                self.sim.send(self, shard, _prep, nbytes=64 + 48 * len(wops))

            def commit() -> None:
                done = {"n": len(by_shard)}
                def released() -> None:
                    done["n"] -= 1
                    if done["n"] == 0:
                        self.sim.counters.tx_committed += 1
                        callback({"ok": True, "reads": state["reads"],
                                  "latency": self.sim.now - t0})
                for sid, vids in by_shard.items():
                    shard = self.shards[sid]
                    def _rel(shard=shard, vids=vids):
                        shard.release(txid, vids)
                        self.sim.send(shard, self, released, nbytes=32)
                    self.sim.send(self, shard, _rel, nbytes=64)
                if not by_shard:
                    self.sim.counters.tx_committed += 1
                    callback({"ok": True, "reads": state["reads"],
                              "latency": self.sim.now - t0})

        acquire_next()

    @staticmethod
    def _vertex_of(op: dict) -> str:
        return op.get("vid") or op.get("src")

    # ---- synchronous bootstrap (benchmark setup) --------------------------
    def load_graph(self, edges: List[Tuple[str, str]]) -> None:
        seen = set()
        for s, d in edges:
            for v in (s, d):
                if v not in seen:
                    seen.add(v)
                    self.shards[self.place(v)].vertices[v] = {
                        "edges": {}, "props": {}}
        for s, d in edges:
            eid = self.fresh_eid()
            self.shards[self.place(s)].vertices[s]["edges"][eid] = d
