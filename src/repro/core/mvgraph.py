"""In-memory multi-version graph partition held by a shard server (§4.1).

Every write marks the object with the refinable timestamp of its
transaction instead of mutating in place:

* a vertex/edge has ``create_ts`` and (optionally) ``delete_ts``;
* a property is a list of timestamped versions per key; reads at stamp T
  return the latest version visible at T.

Visibility at stamp ``T`` (for snapshot reads by node programs, §4.2):
``create_ts ≺ T  and  not (delete_ts ≺ T)``.  If a relevant stamp is
*concurrent* with T, the caller (shard server) must refine through the
timeline oracle — this module reports concurrency instead of guessing.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from .clock import Order, Stamp, compare


@dataclass
class Versioned:
    value: object
    ts: Stamp


@dataclass
class MVEdge:
    eid: int
    src: str
    dst: str
    create_ts: Stamp
    delete_ts: Optional[Stamp] = None
    props: Dict[str, List[Versioned]] = field(default_factory=dict)


@dataclass
class MVVertex:
    vid: str
    create_ts: Stamp
    delete_ts: Optional[Stamp] = None
    out_edges: Dict[int, MVEdge] = field(default_factory=dict)
    props: Dict[str, List[Versioned]] = field(default_factory=dict)


class ConcurrencyUnresolved(Exception):
    """A visibility decision needs the timeline oracle."""

    def __init__(self, a: Stamp, b: Stamp):
        super().__init__(f"concurrent stamps {a} vs {b}")
        self.pair = (a, b)


def _before(a: Stamp, b: Stamp,
            refine: Optional[Callable[[Stamp, Stamp], Order]] = None) -> bool:
    o = compare(a, b)
    if o is Order.CONCURRENT:
        if refine is None:
            raise ConcurrencyUnresolved(a, b)
        o = refine(a, b)
    return o is Order.BEFORE


def visible(create_ts: Stamp, delete_ts: Optional[Stamp], at: Stamp,
            refine: Optional[Callable[[Stamp, Stamp], Order]] = None) -> bool:
    if not _before(create_ts, at, refine):
        return False
    if delete_ts is not None and _before(delete_ts, at, refine):
        return False
    return True


class MVGraphPartition:
    """One shard's partition of the multi-version graph."""

    def __init__(self) -> None:
        self.vertices: Dict[str, MVVertex] = {}
        self._eid = 0

    # ---- write path (called by shard at a transaction's stamp) ----------
    def create_vertex(self, vid: str, ts: Stamp) -> MVVertex:
        v = self.vertices.get(vid)
        if v is not None and v.delete_ts is None:
            # re-create of live vertex: id reuse is an application error
            raise KeyError(f"vertex {vid} already exists")
        v = MVVertex(vid, create_ts=ts)
        self.vertices[vid] = v
        return v

    def delete_vertex(self, vid: str, ts: Stamp) -> None:
        v = self.vertices[vid]
        v.delete_ts = ts
        for e in v.out_edges.values():
            if e.delete_ts is None:
                e.delete_ts = ts

    def create_edge(self, src: str, dst: str, ts: Stamp,
                    eid: Optional[int] = None) -> MVEdge:
        v = self.vertices[src]
        if eid is None:
            self._eid += 1
            eid = self._eid
        e = MVEdge(eid, src, dst, create_ts=ts)
        v.out_edges[eid] = e
        return e

    def delete_edge(self, src: str, eid: int, ts: Stamp) -> None:
        self.vertices[src].out_edges[eid].delete_ts = ts

    def set_vertex_prop(self, vid: str, key: str, value, ts: Stamp) -> None:
        self.vertices[vid].props.setdefault(key, []).append(Versioned(value, ts))

    def set_edge_prop(self, src: str, eid: int, key: str, value, ts: Stamp) -> None:
        self.vertices[src].out_edges[eid].props.setdefault(key, []).append(
            Versioned(value, ts))

    # ---- snapshot read path (node programs at T_prog) --------------------
    def vertex_at(self, vid: str, at: Stamp, refine=None) -> Optional[MVVertex]:
        v = self.vertices.get(vid)
        if v is None or not visible(v.create_ts, v.delete_ts, at, refine):
            return None
        return v

    def out_edges_at(self, vid: str, at: Stamp, refine=None) -> List[MVEdge]:
        v = self.vertex_at(vid, at, refine)
        if v is None:
            return []
        return [e for e in v.out_edges.values()
                if visible(e.create_ts, e.delete_ts, at, refine)]

    def prop_at(self, versions: List[Versioned], at: Stamp, refine=None):
        """Latest property version visible at ``at``."""
        best: Optional[Versioned] = None
        for ver in versions:
            if _before(ver.ts, at, refine):
                if best is None or _before(best.ts, ver.ts, refine):
                    best = ver
        return None if best is None else best.value

    def vertex_prop_at(self, vid: str, key: str, at: Stamp, refine=None):
        v = self.vertex_at(vid, at, refine)
        if v is None or key not in v.props:
            return None
        return self.prop_at(v.props[key], at, refine)

    def edge_prop_at(self, e: MVEdge, key: str, at: Stamp, refine=None):
        if key not in e.props:
            return None
        return self.prop_at(e.props[key], at, refine)

    # ---- GC (paper §4.5) --------------------------------------------------
    def collect(self, horizon: Stamp) -> int:
        """Drop versions deleted strictly before ``horizon``."""
        n = 0
        dead_v = []
        for vid, v in self.vertices.items():
            if v.delete_ts is not None and compare(v.delete_ts, horizon) is Order.BEFORE:
                dead_v.append(vid)
                n += 1
                continue
            dead_e = [eid for eid, e in v.out_edges.items()
                      if e.delete_ts is not None
                      and compare(e.delete_ts, horizon) is Order.BEFORE]
            for eid in dead_e:
                del v.out_edges[eid]
                n += 1
            for key, versions in list(v.props.items()):
                if len(versions) > 1:
                    keep = [ver for i, ver in enumerate(versions)
                            if i == len(versions) - 1
                            or not compare(versions[i + 1].ts, horizon) is Order.BEFORE]
                    n += len(versions) - len(keep)
                    v.props[key] = keep
        for vid in dead_v:
            del self.vertices[vid]
        return n

    # ---- stats ------------------------------------------------------------
    def n_live(self) -> Tuple[int, int]:
        nv = sum(1 for v in self.vertices.values() if v.delete_ts is None)
        ne = sum(sum(1 for e in v.out_edges.values() if e.delete_ts is None)
                 for v in self.vertices.values())
        return nv, ne
