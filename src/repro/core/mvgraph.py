"""In-memory multi-version graph partition held by a shard server (§4.1).

Every write marks the object with the refinable timestamp of its
transaction instead of mutating in place:

* a vertex/edge has ``create_ts`` and (optionally) ``delete_ts``;
* a property is a list of timestamped versions per key; reads at stamp T
  return the latest version visible at T.

Visibility at stamp ``T`` (for snapshot reads by node programs, §4.2):
``create_ts ≺ T  and  not (delete_ts ≺ T)``.  If a relevant stamp is
*concurrent* with T, the caller (shard server) must refine through the
timeline oracle — this module reports concurrency instead of guessing.

Columnar mirror (data-plane hot path)
-------------------------------------
Besides the per-object dict structures (which serve the shard's own
node-program reads), every partition incrementally maintains a
struct-of-arrays mirror, :class:`PartitionColumns`:

* vertices: ``v_gid`` (slot -> global interned vid id), packed
  ``v_create`` / ``v_delete`` stamp matrices of shape ``(N, G+1)`` int32
  (row = ``[epoch, c_0..c_{G-1}]``, all-``NO_STAMP`` = absent), plus the
  original :class:`~repro.core.clock.Stamp` objects for oracle
  refinement of truly concurrent rows;
* edges: ``e_src`` / ``e_dst`` interned-id columns with the same packed
  stamp matrices;
* a monotone ``version`` and per-table patch logs so snapshot caches can
  do **delta refresh**: re-evaluate only slots whose stamps changed since
  the cached build instead of rescanning O(V+E) objects.

Columns are append-mostly: creates append a slot, deletes/GC patch the
slot's stamp rows in place (GC "purges" a slot by writing all-``NO_STAMP``
rows, which no query stamp can ever see).  Vertex ids are interned
through a :class:`VidIntern` shared across all partitions of a deployment
so that edge endpoints are cross-shard-resolvable integers at write time
— the snapshot engine (``repro.core.analytics``) never touches a Python
string on the per-object path.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from . import clock as _clock
from .clock import NO_STAMP, Order, Stamp, compare, pack


@dataclass
class Versioned:
    value: object
    ts: Stamp


@dataclass
class MVEdge:
    eid: int
    src: str
    dst: str
    create_ts: Stamp
    delete_ts: Optional[Stamp] = None
    props: Dict[str, List[Versioned]] = field(default_factory=dict)


@dataclass
class MVVertex:
    vid: str
    create_ts: Stamp
    delete_ts: Optional[Stamp] = None
    out_edges: Dict[int, MVEdge] = field(default_factory=dict)
    props: Dict[str, List[Versioned]] = field(default_factory=dict)


class ConcurrencyUnresolved(Exception):
    """A visibility decision needs the timeline oracle."""

    def __init__(self, a: Stamp, b: Stamp):
        super().__init__(f"concurrent stamps {a} vs {b}")
        self.pair = (a, b)


def _before(a: Stamp, b: Stamp,
            refine: Optional[Callable[[Stamp, Stamp], Order]] = None) -> bool:
    o = compare(a, b)
    if o is Order.CONCURRENT:
        if refine is None:
            raise ConcurrencyUnresolved(a, b)
        o = refine(a, b)
    return o is Order.BEFORE


def visible(create_ts: Stamp, delete_ts: Optional[Stamp], at: Stamp,
            refine: Optional[Callable[[Stamp, Stamp], Order]] = None) -> bool:
    if not _before(create_ts, at, refine):
        return False
    if delete_ts is not None and _before(delete_ts, at, refine):
        return False
    return True


class VidIntern:
    """Process-wide vid -> dense int32 id table (shared by all partitions
    of one deployment so edge endpoints resolve across shards)."""

    __slots__ = ("ids", "vids")

    def __init__(self) -> None:
        self.ids: Dict[str, int] = {}
        self.vids: List[str] = []

    def intern(self, vid: str) -> int:
        i = self.ids.get(vid)
        if i is None:
            i = len(self.vids)
            self.ids[vid] = i
            self.vids.append(vid)
        return i

    def __len__(self) -> int:
        return len(self.vids)


class _GrowRows:
    """Growable (N, C) int32 matrix with amortized O(1) row appends."""

    __slots__ = ("c", "n", "buf")

    def __init__(self, c: int, cap: int = 64) -> None:
        self.c = c
        self.n = 0
        self.buf = np.empty((cap, c), np.int32)

    def _grow(self) -> None:
        nu = np.empty((max(2 * self.buf.shape[0], 64), self.c), np.int32)
        nu[:self.n] = self.buf[:self.n]
        self.buf = nu

    def append(self, row: np.ndarray) -> int:
        if self.n == self.buf.shape[0]:
            self._grow()
        self.buf[self.n] = row
        self.n += 1
        return self.n - 1

    def set(self, i: int, row: np.ndarray) -> None:
        self.buf[i] = row

    def view(self) -> np.ndarray:
        return self.buf[:self.n]


class _GrowInts:
    """Growable (N,) int32 vector with amortized O(1) appends."""

    __slots__ = ("n", "buf")

    def __init__(self, cap: int = 64) -> None:
        self.n = 0
        self.buf = np.empty((cap,), np.int32)

    def append(self, x: int) -> int:
        if self.n == self.buf.shape[0]:
            nu = np.empty((max(2 * self.buf.shape[0], 64),), np.int32)
            nu[:self.n] = self.buf[:self.n]
            self.buf = nu
        self.buf[self.n] = x
        self.n += 1
        return self.n - 1

    def view(self) -> np.ndarray:
        return self.buf[:self.n]


class PartitionColumns:
    """Struct-of-arrays mirror of one partition (see module docstring).

    Slots are stable: a vid (or (src, eid) edge key) keeps its slot across
    delete / GC / re-create; only its stamp rows are patched.  ``v_patch``
    / ``e_patch`` log every in-place patch (appends are implied by the
    growth of ``n_v`` / ``n_e``); consumers track their own read offsets.
    """

    def __init__(self, n_gk: int, intern: Optional[VidIntern] = None) -> None:
        self.n_gk = n_gk
        self.c = n_gk + 1
        self.intern = intern if intern is not None else VidIntern()
        self._no_row = np.full((self.c,), NO_STAMP, np.int32)
        # vertex table
        self.v_gid = _GrowInts()
        self.v_create = _GrowRows(self.c)
        self.v_delete = _GrowRows(self.c)
        self.v_create_stamp: List[Optional[Stamp]] = []
        self.v_delete_stamp: List[Optional[Stamp]] = []
        self.v_slot: Dict[int, int] = {}          # gid -> slot
        # edge table
        self.e_src = _GrowInts()
        self.e_dst = _GrowInts()
        self.e_create = _GrowRows(self.c)
        self.e_delete = _GrowRows(self.c)
        self.e_create_stamp: List[Optional[Stamp]] = []
        self.e_delete_stamp: List[Optional[Stamp]] = []
        self.e_slot: Dict[Tuple[int, int], int] = {}  # (src gid, eid) -> slot
        # change log
        self.version = 0
        self.v_patch: List[int] = []
        self.e_patch: List[int] = []

    @property
    def n_v(self) -> int:
        return self.v_gid.n

    @property
    def n_e(self) -> int:
        return self.e_src.n

    # ---- vertex events ---------------------------------------------------
    def vertex_created(self, vid: str, ts: Stamp) -> None:
        gid = self.intern.intern(vid)
        slot = self.v_slot.get(gid)
        row = pack(ts, self.n_gk)
        if slot is None:
            self.v_slot[gid] = self.v_gid.append(gid)
            self.v_create.append(row)
            self.v_delete.append(self._no_row)
            self.v_create_stamp.append(ts)
            self.v_delete_stamp.append(None)
        else:  # re-create after delete (slot reuse keeps ordering stable)
            self.v_create.set(slot, row)
            self.v_delete.set(slot, self._no_row)
            self.v_create_stamp[slot] = ts
            self.v_delete_stamp[slot] = None
            self.v_patch.append(slot)
        self.version += 1

    def vertex_deleted(self, vid: str, ts: Stamp) -> None:
        slot = self.v_slot[self.intern.intern(vid)]
        self.v_delete.set(slot, pack(ts, self.n_gk))
        self.v_delete_stamp[slot] = ts
        self.v_patch.append(slot)
        self.version += 1

    def vertex_purged(self, vid: str) -> None:
        """GC: the slot can never be visible again (all-NO_STAMP rows)."""
        slot = self.v_slot[self.intern.intern(vid)]
        self.v_create.set(slot, self._no_row)
        self.v_delete.set(slot, self._no_row)
        self.v_create_stamp[slot] = None
        self.v_delete_stamp[slot] = None
        self.v_patch.append(slot)
        self.version += 1

    # ---- edge events -----------------------------------------------------
    def edge_created(self, src: str, dst: str, eid: int, ts: Stamp) -> None:
        sg = self.intern.intern(src)
        dg = self.intern.intern(dst)
        key = (sg, eid)
        slot = self.e_slot.get(key)
        row = pack(ts, self.n_gk)
        if slot is None:
            self.e_slot[key] = self.e_src.append(sg)
            self.e_dst.append(dg)
            self.e_create.append(row)
            self.e_delete.append(self._no_row)
            self.e_create_stamp.append(ts)
            self.e_delete_stamp.append(None)
        else:
            self.e_create.set(slot, row)
            self.e_delete.set(slot, self._no_row)
            self.e_create_stamp[slot] = ts
            self.e_delete_stamp[slot] = None
            self.e_patch.append(slot)
        self.version += 1

    def edge_deleted(self, src: str, eid: int, ts: Stamp) -> None:
        slot = self.e_slot[(self.intern.intern(src), eid)]
        self.e_delete.set(slot, pack(ts, self.n_gk))
        self.e_delete_stamp[slot] = ts
        self.e_patch.append(slot)
        self.version += 1

    def edge_purged(self, src: str, eid: int) -> None:
        slot = self.e_slot[(self.intern.intern(src), eid)]
        self.e_create.set(slot, self._no_row)
        self.e_delete.set(slot, self._no_row)
        self.e_create_stamp[slot] = None
        self.e_delete_stamp[slot] = None
        self.e_patch.append(slot)
        self.version += 1


class MVGraphPartition:
    """One shard's partition of the multi-version graph."""

    def __init__(self, n_gk: Optional[int] = None,
                 intern: Optional[VidIntern] = None) -> None:
        self.vertices: Dict[str, MVVertex] = {}
        self._eid = 0
        self._n_gk = n_gk
        self._intern = intern
        self.columns: Optional[PartitionColumns] = None
        if n_gk is not None:
            self.columns = PartitionColumns(n_gk, intern)

    def _cols(self, ts: Stamp) -> PartitionColumns:
        """Column mirror, created lazily when G is first observable."""
        if self.columns is None:
            self.columns = PartitionColumns(len(ts.clock), self._intern)
        return self.columns

    # ---- write path (called by shard at a transaction's stamp) ----------
    def create_vertex(self, vid: str, ts: Stamp) -> MVVertex:
        v = self.vertices.get(vid)
        if v is not None and v.delete_ts is None:
            # re-create of live vertex: id reuse is an application error
            raise KeyError(f"vertex {vid} already exists")
        v = MVVertex(vid, create_ts=ts)
        self.vertices[vid] = v
        self._cols(ts).vertex_created(vid, ts)
        return v

    def delete_vertex(self, vid: str, ts: Stamp) -> None:
        v = self.vertices[vid]
        v.delete_ts = ts
        cols = self._cols(ts)
        cols.vertex_deleted(vid, ts)
        for e in v.out_edges.values():
            if e.delete_ts is None:
                e.delete_ts = ts
                cols.edge_deleted(vid, e.eid, ts)

    def create_edge(self, src: str, dst: str, ts: Stamp,
                    eid: Optional[int] = None) -> MVEdge:
        v = self.vertices[src]
        if eid is None:
            self._eid += 1
            eid = self._eid
        e = MVEdge(eid, src, dst, create_ts=ts)
        v.out_edges[eid] = e
        self._cols(ts).edge_created(src, dst, eid, ts)
        return e

    def delete_edge(self, src: str, eid: int, ts: Stamp) -> None:
        e = self.vertices[src].out_edges[eid]
        e.delete_ts = ts
        self._cols(ts).edge_deleted(src, eid, ts)

    def set_vertex_prop(self, vid: str, key: str, value, ts: Stamp) -> None:
        self.vertices[vid].props.setdefault(key, []).append(Versioned(value, ts))

    def set_edge_prop(self, src: str, eid: int, key: str, value, ts: Stamp) -> None:
        self.vertices[src].out_edges[eid].props.setdefault(key, []).append(
            Versioned(value, ts))

    # ---- snapshot read path (node programs at T_prog) --------------------
    def vertex_at(self, vid: str, at: Stamp, refine=None) -> Optional[MVVertex]:
        v = self.vertices.get(vid)
        if v is None or not visible(v.create_ts, v.delete_ts, at, refine):
            return None
        return v

    def out_edges_at(self, vid: str, at: Stamp, refine=None) -> List[MVEdge]:
        v = self.vertex_at(vid, at, refine)
        if v is None:
            return []
        return [e for e in v.out_edges.values()
                if visible(e.create_ts, e.delete_ts, at, refine)]

    def prop_at(self, versions: List[Versioned], at: Stamp, refine=None):
        """Latest property version visible at ``at``."""
        best: Optional[Versioned] = None
        for ver in versions:
            if _before(ver.ts, at, refine):
                if best is None or _before(best.ts, ver.ts, refine):
                    best = ver
        return None if best is None else best.value

    def vertex_prop_at(self, vid: str, key: str, at: Stamp, refine=None):
        v = self.vertex_at(vid, at, refine)
        if v is None or key not in v.props:
            return None
        return self.prop_at(v.props[key], at, refine)

    def edge_prop_at(self, e: MVEdge, key: str, at: Stamp, refine=None):
        if key not in e.props:
            return None
        return self.prop_at(e.props[key], at, refine)

    # ---- GC (paper §4.5) --------------------------------------------------
    def collect(self, horizon: Stamp) -> int:
        """Drop versions deleted strictly before ``horizon``."""
        n = 0
        cols = self.columns
        dead_v = []
        for vid, v in self.vertices.items():
            if v.delete_ts is not None and compare(v.delete_ts, horizon) is Order.BEFORE:
                dead_v.append(vid)
                n += 1
                continue
            dead_e = [eid for eid, e in v.out_edges.items()
                      if e.delete_ts is not None
                      and compare(e.delete_ts, horizon) is Order.BEFORE]
            for eid in dead_e:
                del v.out_edges[eid]
                if cols is not None:
                    cols.edge_purged(vid, eid)
                n += 1
            for key, versions in list(v.props.items()):
                if len(versions) > 1:
                    keep = [ver for i, ver in enumerate(versions)
                            if i == len(versions) - 1
                            or not compare(versions[i + 1].ts, horizon) is Order.BEFORE]
                    n += len(versions) - len(keep)
                    v.props[key] = keep
        for vid in dead_v:
            if cols is not None:
                for eid in self.vertices[vid].out_edges:
                    cols.edge_purged(vid, eid)
                cols.vertex_purged(vid)
            del self.vertices[vid]
        return n

    # ---- stats ------------------------------------------------------------
    def n_live(self) -> Tuple[int, int]:
        nv = sum(1 for v in self.vertices.values() if v.delete_ts is None)
        ne = sum(sum(1 for e in v.out_edges.values() if e.delete_ts is None)
                 for v in self.vertices.values())
        return nv, ne
