"""In-memory multi-version graph partition held by a shard server (§4.1).

Every write marks the object with the refinable timestamp of its
transaction instead of mutating in place:

* a vertex/edge has ``create_ts`` and (optionally) ``delete_ts``;
* a property is a list of timestamped versions per key; reads at stamp T
  return the latest version visible at T.

Visibility at stamp ``T`` (for snapshot reads by node programs, §4.2):
``create_ts ≺ T  and  not (delete_ts ≺ T)``.  If a relevant stamp is
*concurrent* with T, the caller (shard server) must refine through the
timeline oracle — this module reports concurrency instead of guessing.

Columnar mirror (data-plane hot path)
-------------------------------------
Besides the per-object dict structures (which serve the shard's own
node-program reads), every partition incrementally maintains a
struct-of-arrays mirror, :class:`PartitionColumns`:

* vertices: ``v_gid`` (slot -> global interned vid id), packed
  ``v_create`` / ``v_delete`` stamp matrices of shape ``(N, G+1)`` int32
  (row = ``[epoch, c_0..c_{G-1}]``, all-``NO_STAMP`` = absent), plus the
  original :class:`~repro.core.clock.Stamp` objects for oracle
  refinement of truly concurrent rows;
* edges: ``e_src`` / ``e_dst`` interned-id columns with the same packed
  stamp matrices;
* property *versions* as columns too: per table (vertex / edge) an
  append-only log of (owner slot, interned key id, interned value id,
  float mirror, packed stamp) rows — the latest row visible at ``T`` per
  (owner, key) is the property value at ``T``, so node programs that
  filter on edge properties or read weights run on the data plane
  (``repro.core.frontier``) without touching a Python dict;
* a monotone ``version`` and per-table patch logs so snapshot caches can
  do **delta refresh**: re-evaluate only slots whose stamps changed since
  the cached build instead of rescanning O(V+E) objects.

Columns are append-mostly: creates append a slot, deletes/GC patch the
slot's stamp rows in place (GC "purges" a slot by writing all-``NO_STAMP``
rows, which no query stamp can ever see).  Vertex ids are interned
through a :class:`VidIntern` shared across all partitions of a deployment
so that edge endpoints are cross-shard-resolvable integers at write time
— the snapshot engine (``repro.core.analytics``) never touches a Python
string on the per-object path.

GC compaction
-------------
Purged slots (all-``NO_STAMP`` rows) used to accumulate forever.  When
the dead fraction of a partition's columns exceeds
:data:`COMPACT_DEAD_FRAC`, :meth:`PartitionColumns.maybe_compact`
rewrites every table keeping only live slots and appends a
:class:`CompactionEvent` (old→new slot maps plus the pre-compaction
patch logs) to ``events`` so snapshot caches can *remap* their cached
rows instead of rebuilding cold — see
``analytics.SnapshotEngine._consume_changes``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

from . import clock as _clock
from .clock import NO_STAMP, Order, Stamp, compare, pack


@dataclass
class Versioned:
    value: object
    ts: Stamp
    slot: int = -1          # row in the partition's property columns


@dataclass
class MVEdge:
    eid: int
    src: str
    dst: str
    create_ts: Stamp
    delete_ts: Optional[Stamp] = None
    props: Dict[str, List[Versioned]] = field(default_factory=dict)


@dataclass
class MVVertex:
    vid: str
    create_ts: Stamp
    delete_ts: Optional[Stamp] = None
    out_edges: Dict[int, MVEdge] = field(default_factory=dict)
    props: Dict[str, List[Versioned]] = field(default_factory=dict)


class ConcurrencyUnresolved(Exception):
    """A visibility decision needs the timeline oracle."""

    def __init__(self, a: Stamp, b: Stamp):
        super().__init__(f"concurrent stamps {a} vs {b}")
        self.pair = (a, b)


def _before(a: Stamp, b: Stamp,
            refine: Optional[Callable[[Stamp, Stamp], Order]] = None) -> bool:
    o = compare(a, b)
    if o is Order.CONCURRENT:
        if refine is None:
            raise ConcurrencyUnresolved(a, b)
        o = refine(a, b)
    return o is Order.BEFORE


def visible(create_ts: Stamp, delete_ts: Optional[Stamp], at: Stamp,
            refine: Optional[Callable[[Stamp, Stamp], Order]] = None) -> bool:
    if not _before(create_ts, at, refine):
        return False
    if delete_ts is not None and _before(delete_ts, at, refine):
        return False
    return True


class VidIntern:
    """Process-wide vid -> dense int32 id table (shared by all partitions
    of one deployment so edge endpoints resolve across shards)."""

    __slots__ = ("ids", "vids")

    def __init__(self) -> None:
        self.ids: Dict[str, int] = {}
        self.vids: List[str] = []

    def intern(self, vid: str) -> int:
        i = self.ids.get(vid)
        if i is None:
            i = len(self.vids)
            self.ids[vid] = i
            self.vids.append(vid)
        return i

    def __len__(self) -> int:
        return len(self.vids)


class PropIntern:
    """Per-partition value/key intern table.

    Hashable objects are deduplicated (value -> dense id); unhashable
    values get a fresh id each time (they can never be filter targets
    anyway).  ``lookup`` probes without inserting — the frontier runtime
    uses it to translate a filter constant into this partition's id
    space (-1 = the partition has never seen the value)."""

    __slots__ = ("ids", "vals")

    def __init__(self) -> None:
        self.ids: Dict[object, int] = {}
        self.vals: List[object] = []

    def intern(self, v) -> int:
        try:
            i = self.ids.get(v)
        except TypeError:                 # unhashable: fresh id, no dedup
            self.vals.append(v)
            return len(self.vals) - 1
        if i is None:
            i = len(self.vals)
            self.ids[v] = i
            self.vals.append(v)
        return i

    def lookup(self, v) -> int:
        try:
            return self.ids.get(v, -1)
        except TypeError:
            return -1

    def __len__(self) -> int:
        return len(self.vals)


class _GrowRows:
    """Growable (N, C) int32 matrix with amortized O(1) row appends."""

    __slots__ = ("c", "n", "buf")

    def __init__(self, c: int, cap: int = 64) -> None:
        self.c = c
        self.n = 0
        self.buf = np.empty((cap, c), np.int32)

    def _reserve(self, k: int) -> None:
        if self.n + k > self.buf.shape[0]:
            nu = np.empty((max(2 * self.buf.shape[0], self.n + k, 64),
                           self.c), np.int32)
            nu[:self.n] = self.buf[:self.n]
            self.buf = nu

    def append(self, row: np.ndarray) -> int:
        self._reserve(1)
        self.buf[self.n] = row
        self.n += 1
        return self.n - 1

    def extend(self, rows: np.ndarray) -> int:
        """Append a whole (K, C) block in one memcpy; returns the first
        new row index (group-commit bulk apply)."""
        k = rows.shape[0]
        self._reserve(k)
        self.buf[self.n:self.n + k] = rows
        start = self.n
        self.n += k
        return start

    def set(self, i: int, row: np.ndarray) -> None:
        self.buf[i] = row

    def view(self) -> np.ndarray:
        return self.buf[:self.n]

    def reset_to(self, rows: np.ndarray) -> None:
        """Replace contents (compaction rebuild)."""
        self.n = rows.shape[0]
        if self.n > self.buf.shape[0]:
            self.buf = np.empty((max(64, self.n * 5 // 4), self.c), np.int32)
        self.buf[:self.n] = rows


class _GrowInts:
    """Growable (N,) int32 vector with amortized O(1) appends (the
    reallocation policy is dtype-agnostic, so the float subclass only
    overrides its buffer)."""

    __slots__ = ("n", "buf")

    def __init__(self, cap: int = 64) -> None:
        self.n = 0
        self.buf = np.empty((cap,), np.int32)

    def _reserve(self, k: int) -> None:
        if self.n + k > self.buf.shape[0]:
            nu = np.empty((max(2 * self.buf.shape[0], self.n + k, 64),),
                          self.buf.dtype)
            nu[:self.n] = self.buf[:self.n]
            self.buf = nu

    def append(self, x) -> int:
        self._reserve(1)
        self.buf[self.n] = x
        self.n += 1
        return self.n - 1

    def extend(self, xs: np.ndarray) -> int:
        """Append a (K,) block in one memcpy; returns the first new
        index."""
        k = xs.shape[0]
        self._reserve(k)
        self.buf[self.n:self.n + k] = xs
        start = self.n
        self.n += k
        return start

    def view(self) -> np.ndarray:
        return self.buf[:self.n]

    def reset_to(self, xs: np.ndarray) -> None:
        self.n = xs.shape[0]
        if self.n > self.buf.shape[0]:
            self.buf = np.empty((max(64, self.n * 5 // 4),), self.buf.dtype)
        self.buf[:self.n] = xs


class _GrowFloats(_GrowInts):
    """Growable (N,) float64 vector (numeric mirror of property values)."""

    def __init__(self, cap: int = 64) -> None:
        self.n = 0
        self.buf = np.empty((cap,), np.float64)


class _GrowInts64(_GrowInts):
    """Growable (N,) int64 vector (edge ids are client-side 64-bit:
    ``(client_id << 32) | counter``)."""

    def __init__(self, cap: int = 64) -> None:
        self.n = 0
        self.buf = np.empty((cap,), np.int64)


class _PropTable:
    """Append-only property-version columns for one owner table.

    One row per ``set_*_prop`` call: owner slot, interned key id,
    interned value id, float mirror (NaN when the value is not a real
    number), packed stamp row + original :class:`Stamp` for oracle
    refinement.  Purges (GC / owner re-create) overwrite the stamp row
    with all-``NO_STAMP`` and log the row in ``patch`` — the same
    delta-refresh contract as ``v_patch``/``e_patch``, consumed through
    :meth:`cursor` by :class:`~repro.core.frontier.ShardPlan` to keep
    its property views fresh at O(changed).  :meth:`compact` returns an
    old→new row map (mirrored into :class:`CompactionEvent` as
    ``vp_map``/``ep_map``), so consumers can remap cached property state
    across a compaction instead of re-reading the whole table.

    Group-commit batch mode (:meth:`begin_batch` / :meth:`end_batch`):
    appends between the two calls are buffered in Python lists (slot
    numbering assigned eagerly, so same-batch purges still resolve) and
    flushed as ONE column extend + ONE patch-log extend — a
    :class:`WriteBatch` applies with one stamp-matrix append per table
    instead of one per op.  Consumers never observe the open batch: the
    shard applies a batch atomically within one simulator event."""

    def __init__(self, c: int) -> None:
        self.c = c
        self._no_row = np.full((c,), NO_STAMP, np.int32)
        self.owner = _GrowInts()
        self.key = _GrowInts()
        self.val = _GrowInts()
        self.num = _GrowFloats()
        self.stamp = _GrowRows(c)
        self.stamp_obj: List[Optional[Stamp]] = []
        self.ver: List[Optional["Versioned"]] = []   # backrefs for remap
        self.by_owner: Dict[int, List[int]] = {}
        self.patch: List[int] = []
        self._batch: Optional[dict] = None     # open bulk-append buffer

    @property
    def n(self) -> int:
        return self.owner.n

    def cursor(self) -> List[int]:
        """Consume cursor ``[n_rows, len(patch)]`` for delta consumers
        (appends are implied by row growth, in-place purges by the patch
        log).  The patch log is cleared at compaction — a consumer that
        observes a new :class:`CompactionEvent` remaps its rows through
        the event's ``vp_map``/``ep_map`` and recovers the unread patch
        tail from ``old_vp_patch``/``old_ep_patch``."""
        return [self.n, len(self.patch)]

    @staticmethod
    def _as_num(value) -> float:
        if isinstance(value, bool) or not isinstance(
                value, (int, float, np.integer, np.floating)):
            return float("nan")
        try:
            return float(value)
        except (TypeError, OverflowError):  # pragma: no cover - exotic
            return float("nan")

    def append(self, owner_slot: int, key_id: int, val_id: int,
               value, row: np.ndarray, ts: Stamp,
               ver: Optional["Versioned"] = None) -> int:
        b = self._batch
        if b is None:
            slot = self.owner.append(owner_slot)
            self.key.append(key_id)
            self.val.append(val_id)
            self.num.append(self._as_num(value))
            self.stamp.append(row)
        else:
            slot = b["base"] + len(b["owner"])
            b["owner"].append(owner_slot)
            b["key"].append(key_id)
            b["val"].append(val_id)
            b["num"].append(self._as_num(value))
            b["stamp"].append(row)
        self.stamp_obj.append(ts)
        self.ver.append(ver)
        self.by_owner.setdefault(owner_slot, []).append(slot)
        return slot

    def purge(self, slot: int) -> None:
        if slot < 0:
            return
        b = self._batch
        if b is not None and slot >= b["base"]:   # row still buffered
            b["stamp"][slot - b["base"]] = self._no_row
        else:
            self.stamp.set(slot, self._no_row)
        self.stamp_obj[slot] = None
        self.ver[slot] = None
        (self.patch if b is None else b["patch"]).append(slot)

    # ---- group-commit bulk append (see class docstring) ------------------
    def begin_batch(self) -> None:
        assert self._batch is None, "nested property batch"
        self._batch = {"base": self.n, "owner": [], "key": [], "val": [],
                       "num": [], "stamp": [], "patch": []}

    def end_batch(self) -> None:
        b = self._batch
        self._batch = None
        if b["owner"]:
            self.owner.extend(np.asarray(b["owner"], np.int32))
            self.key.extend(np.asarray(b["key"], np.int32))
            self.val.extend(np.asarray(b["val"], np.int32))
            self.num.extend(np.asarray(b["num"], np.float64))
            self.stamp.extend(np.stack(b["stamp"]))
        if b["patch"]:
            self.patch.extend(b["patch"])

    def purge_owner(self, owner_slot: int) -> int:
        """Purge every version row of one owner (re-create / owner GC)."""
        rows = self.by_owner.pop(owner_slot, [])
        for r in rows:
            self.purge(r)
        return len(rows)

    def compact(self, owner_map: np.ndarray
                ) -> Tuple[np.ndarray, List[int], int]:
        """Drop purged rows / rows of dropped owners; remap the rest.

        Returns ``(row_map, old_patch, old_n)``: the old→new row map
        (-1 = dropped), the FULL pre-compaction patch log (old
        numbering) and the pre-compaction row count — the same remap
        contract as :class:`CompactionEvent`'s ``v_map``/``e_map``, so
        plan caches can carry property state across a compaction at
        O(changed) instead of re-reading the whole table."""
        n = self.n
        old_patch = self.patch
        if n == 0:
            self.by_owner = {}
            self.patch = []
            return np.empty((0,), np.int64), old_patch, 0
        owner = self.owner.view()
        live = self.stamp.view()[:, 0] != NO_STAMP
        ow = np.where(owner < owner_map.size, owner_map[owner], -1)
        live &= ow >= 0
        row_map = np.where(live, np.cumsum(live) - 1, -1).astype(np.int64)
        keep_l = np.nonzero(live)[0].tolist()
        drop_l = np.nonzero(~live)[0].tolist()
        keep = np.asarray(keep_l, np.int64)
        self.owner.reset_to(ow[keep].astype(np.int32))
        self.key.reset_to(self.key.view()[keep])
        self.val.reset_to(self.val.view()[keep])
        self.num.reset_to(self.num.view()[keep])
        self.stamp.reset_to(self.stamp.view()[keep])
        self.stamp_obj = [self.stamp_obj[i] for i in keep_l]
        for i in drop_l:
            if self.ver[i] is not None:
                self.ver[i].slot = -1
        self.ver = [self.ver[i] for i in keep_l]
        for new_row, ver in enumerate(self.ver):
            if ver is not None:
                ver.slot = new_row
        self.by_owner = {}
        for new_row, o in enumerate(self.owner.view().tolist()):
            self.by_owner.setdefault(o, []).append(new_row)
        self.patch = []
        return row_map, old_patch, n


@dataclass
class CompactionEvent:
    """One compaction, as seen by a snapshot cache.

    ``v_map`` / ``e_map`` translate pre-compaction slots to
    post-compaction slots (-1 = dropped); ``old_v_patch`` /
    ``old_e_patch`` are the FULL pre-compaction patch logs (old
    numbering) so a consumer that had only read a prefix can recover the
    unread tail; ``old_n_v`` / ``old_n_e`` are the pre-compaction table
    sizes.  ``vp_map`` / ``ep_map`` (and the matching ``old_*p_patch`` /
    ``old_n_*p`` fields) are the same contract for the vertex/edge
    PROPERTY tables, so crossing a compaction no longer forces a full
    property re-read."""

    v_map: np.ndarray
    e_map: np.ndarray
    old_v_patch: List[int]
    old_e_patch: List[int]
    old_n_v: int
    old_n_e: int
    vp_map: np.ndarray
    ep_map: np.ndarray
    old_vp_patch: List[int]
    old_ep_patch: List[int]
    old_n_vp: int
    old_n_ep: int


#: compact a partition's columns when this fraction of slots is purged
COMPACT_DEAD_FRAC = 0.25
#: ... but never bother below this many total slots
COMPACT_MIN_ROWS = 64
#: retained CompactionEvents (each holds O(n) maps); consumers that lag
#: further behind fall back to a cold rebuild
MAX_COMPACTION_EVENTS = 8


class PartitionColumns:
    """Struct-of-arrays mirror of one partition (see module docstring).

    Slots are stable between compactions: a vid (or (src, eid) edge key)
    keeps its slot across delete / GC / re-create; only its stamp rows
    are patched.  ``v_patch`` / ``e_patch`` log every in-place patch
    (appends are implied by the growth of ``n_v`` / ``n_e``); consumers
    track their own read offsets.  A compaction renumbers slots and
    resets the logs; consumers catch up through ``events`` (see
    :class:`CompactionEvent`).
    """

    def __init__(self, n_gk: int, intern: Optional[VidIntern] = None,
                 vals: Optional[PropIntern] = None) -> None:
        self.n_gk = n_gk
        self.c = n_gk + 1
        self.intern = intern if intern is not None else VidIntern()
        self._no_row = np.full((self.c,), NO_STAMP, np.int32)
        # vertex table
        self.v_gid = _GrowInts()
        self.v_create = _GrowRows(self.c)
        self.v_delete = _GrowRows(self.c)
        self.v_create_stamp: List[Optional[Stamp]] = []
        self.v_delete_stamp: List[Optional[Stamp]] = []
        self.v_slot: Dict[int, int] = {}          # gid -> slot
        # edge table
        self.e_src = _GrowInts()
        self.e_dst = _GrowInts()
        self.e_eid = _GrowInts64()        # edge id (get_edges replies)
        self.e_create = _GrowRows(self.c)
        self.e_delete = _GrowRows(self.c)
        self.e_create_stamp: List[Optional[Stamp]] = []
        self.e_delete_stamp: List[Optional[Stamp]] = []
        self.e_slot: Dict[Tuple[int, int], int] = {}  # (src gid, eid) -> slot
        # property version columns.  Keys are always interned
        # per-partition; VALUES may share one deployment-wide table
        # (Weaver passes it) so ragged replies can ship value IDS and
        # let the client decode — per-partition ids would be meaningless
        # off-shard and force eager value decode at the shard.
        self.keys = PropIntern()
        self.vals = vals if vals is not None else PropIntern()
        self.vals_shared = vals is not None
        self.v_props = _PropTable(self.c)
        self.e_props = _PropTable(self.c)
        # change log
        self.version = 0
        self.v_patch: List[int] = []
        self.e_patch: List[int] = []
        # compaction history (consumers remap through these); event
        # numbering is absolute: total events ever = events_dropped +
        # len(events), a consumer behind events_dropped must cold-rebuild
        self.events: List[CompactionEvent] = []
        self.events_dropped = 0
        self.n_compactions = 0
        # open group-commit buffer (see begin_batch)
        self._batch: Optional[dict] = None

    @property
    def n_v(self) -> int:
        return self.v_gid.n

    @property
    def n_e(self) -> int:
        return self.e_src.n

    def cursor(self) -> List[int]:
        """Consume cursor ``[n_v, n_e, len(v_patch), len(e_patch),
        total_compaction_events]`` — the delta-refresh contract shared by
        :class:`~repro.core.analytics.SnapshotEngine` and
        :class:`~repro.core.frontier.ShardPlan`.  A consumer whose stored
        event count falls behind ``events_dropped`` has lost remap
        history and must rebuild cold."""
        return [self.n_v, self.n_e, len(self.v_patch), len(self.e_patch),
                self.events_dropped + len(self.events)]

    # ---- group-commit bulk apply -----------------------------------------
    # Between begin_batch and end_batch, new-slot appends buffer in
    # Python lists (slots numbered eagerly so same-batch deletes/purges
    # resolve against the buffer) and in-place patch-log entries buffer
    # too; end_batch flushes ONE matrix extend per column and ONE
    # patch-log extend per table — a whole WriteBatch costs one append
    # instead of one per op.  Consumers never see the open buffer: the
    # shard applies a batch atomically within one simulator event.

    def begin_batch(self) -> None:
        assert self._batch is None, "nested column batch"
        self._batch = {
            "v_base": self.n_v, "e_base": self.n_e,
            "v_gid": [], "v_create": [], "v_delete": [],
            "e_src": [], "e_dst": [], "e_eid": [],
            "e_create": [], "e_delete": [],
            "v_patch": [], "e_patch": [],
        }
        self.v_props.begin_batch()
        self.e_props.begin_batch()

    def end_batch(self) -> None:
        b = self._batch
        self._batch = None
        if b["v_gid"]:
            self.v_gid.extend(np.asarray(b["v_gid"], np.int32))
            self.v_create.extend(np.stack(b["v_create"]))
            self.v_delete.extend(np.stack(b["v_delete"]))
        if b["e_src"]:
            self.e_src.extend(np.asarray(b["e_src"], np.int32))
            self.e_dst.extend(np.asarray(b["e_dst"], np.int32))
            self.e_eid.extend(np.asarray(b["e_eid"], np.int64))
            self.e_create.extend(np.stack(b["e_create"]))
            self.e_delete.extend(np.stack(b["e_delete"]))
        if b["v_patch"]:
            self.v_patch.extend(b["v_patch"])
        if b["e_patch"]:
            self.e_patch.extend(b["e_patch"])
        self.v_props.end_batch()
        self.e_props.end_batch()
        self.version += 1

    def _set_row(self, mat: _GrowRows, pend_key: str, base_key: str,
                 slot: int, row: np.ndarray) -> None:
        """In-place stamp write that lands in the batch buffer when the
        slot is still buffered."""
        b = self._batch
        if b is not None and slot >= b[base_key]:
            b[pend_key][slot - b[base_key]] = row
        else:
            mat.set(slot, row)

    def _log_patch(self, patch_key: str, slot: int) -> None:
        b = self._batch
        if b is not None:
            b[patch_key].append(slot)
        elif patch_key == "v_patch":
            self.v_patch.append(slot)
        else:
            self.e_patch.append(slot)

    # ---- vertex events ---------------------------------------------------
    def vertex_created(self, vid: str, ts: Stamp) -> None:
        gid = self.intern.intern(vid)
        slot = self.v_slot.get(gid)
        row = pack(ts, self.n_gk)
        b = self._batch
        if slot is None:
            if b is None:
                self.v_slot[gid] = self.v_gid.append(gid)
                self.v_create.append(row)
                self.v_delete.append(self._no_row)
            else:
                self.v_slot[gid] = b["v_base"] + len(b["v_gid"])
                b["v_gid"].append(gid)
                b["v_create"].append(row)
                b["v_delete"].append(self._no_row)
            self.v_create_stamp.append(ts)
            self.v_delete_stamp.append(None)
        else:  # re-create after delete (slot reuse keeps ordering stable)
            self._set_row(self.v_create, "v_create", "v_base", slot, row)
            self._set_row(self.v_delete, "v_delete", "v_base", slot,
                          self._no_row)
            self.v_create_stamp[slot] = ts
            self.v_delete_stamp[slot] = None
            self._log_patch("v_patch", slot)
            # the dict path replaces the MVVertex, dropping its property
            # history — mirror that (old versions must not resurface)
            self.v_props.purge_owner(slot)
        self.version += 1

    def vertex_deleted(self, vid: str, ts: Stamp) -> None:
        slot = self.v_slot[self.intern.intern(vid)]
        self._set_row(self.v_delete, "v_delete", "v_base", slot,
                      pack(ts, self.n_gk))
        self.v_delete_stamp[slot] = ts
        self._log_patch("v_patch", slot)
        self.version += 1

    def vertex_purged(self, vid: str) -> None:
        """GC: the slot can never be visible again (all-NO_STAMP rows)."""
        slot = self.v_slot[self.intern.intern(vid)]
        self._set_row(self.v_create, "v_create", "v_base", slot,
                      self._no_row)
        self._set_row(self.v_delete, "v_delete", "v_base", slot,
                      self._no_row)
        self.v_create_stamp[slot] = None
        self.v_delete_stamp[slot] = None
        self._log_patch("v_patch", slot)
        self.v_props.purge_owner(slot)
        self.version += 1

    # ---- edge events -----------------------------------------------------
    def edge_created(self, src: str, dst: str, eid: int, ts: Stamp) -> None:
        sg = self.intern.intern(src)
        dg = self.intern.intern(dst)
        key = (sg, eid)
        slot = self.e_slot.get(key)
        row = pack(ts, self.n_gk)
        b = self._batch
        if slot is None:
            if b is None:
                self.e_slot[key] = self.e_src.append(sg)
                self.e_dst.append(dg)
                self.e_eid.append(eid)
                self.e_create.append(row)
                self.e_delete.append(self._no_row)
            else:
                self.e_slot[key] = b["e_base"] + len(b["e_src"])
                b["e_src"].append(sg)
                b["e_dst"].append(dg)
                b["e_eid"].append(eid)
                b["e_create"].append(row)
                b["e_delete"].append(self._no_row)
            self.e_create_stamp.append(ts)
            self.e_delete_stamp.append(None)
        else:
            self._set_row(self.e_create, "e_create", "e_base", slot, row)
            self._set_row(self.e_delete, "e_delete", "e_base", slot,
                          self._no_row)
            self.e_create_stamp[slot] = ts
            self.e_delete_stamp[slot] = None
            self._log_patch("e_patch", slot)
            self.e_props.purge_owner(slot)   # dict path drops old versions
        self.version += 1

    def edge_deleted(self, src: str, eid: int, ts: Stamp) -> None:
        slot = self.e_slot[(self.intern.intern(src), eid)]
        self._set_row(self.e_delete, "e_delete", "e_base", slot,
                      pack(ts, self.n_gk))
        self.e_delete_stamp[slot] = ts
        self._log_patch("e_patch", slot)
        self.version += 1

    def edge_purged(self, src: str, eid: int) -> None:
        slot = self.e_slot[(self.intern.intern(src), eid)]
        self._set_row(self.e_create, "e_create", "e_base", slot,
                      self._no_row)
        self._set_row(self.e_delete, "e_delete", "e_base", slot,
                      self._no_row)
        self.e_create_stamp[slot] = None
        self.e_delete_stamp[slot] = None
        self._log_patch("e_patch", slot)
        self.e_props.purge_owner(slot)
        self.version += 1

    # ---- property events -------------------------------------------------
    def vertex_prop_set(self, vid: str, key: str, value, ts: Stamp,
                        ver: Optional[Versioned] = None) -> int:
        slot = self.v_slot[self.intern.intern(vid)]
        row = self.v_props.append(slot, self.keys.intern(key),
                                  self.vals.intern(value), value,
                                  pack(ts, self.n_gk), ts, ver)
        self.version += 1
        return row

    def edge_prop_set(self, src: str, eid: int, key: str, value, ts: Stamp,
                      ver: Optional[Versioned] = None) -> int:
        slot = self.e_slot[(self.intern.intern(src), eid)]
        row = self.e_props.append(slot, self.keys.intern(key),
                                  self.vals.intern(value), value,
                                  pack(ts, self.n_gk), ts, ver)
        self.version += 1
        return row

    def vertex_prop_purged(self, row: int) -> None:
        self.v_props.purge(row)
        self.version += 1

    def edge_prop_purged(self, row: int) -> None:
        self.e_props.purge(row)
        self.version += 1

    # ---- GC compaction ---------------------------------------------------
    def dead_fraction(self) -> float:
        n = self.n_v + self.n_e
        if n == 0:
            return 0.0
        dead = int((self.v_create.view()[:, 0] == NO_STAMP).sum()) \
            + int((self.e_create.view()[:, 0] == NO_STAMP).sum())
        return dead / n

    def maybe_compact(self, dead_frac: float = COMPACT_DEAD_FRAC,
                      min_rows: int = COMPACT_MIN_ROWS) -> bool:
        if self.n_v + self.n_e < min_rows \
                or self.dead_fraction() <= dead_frac:
            return False
        self.compact()
        return True

    def compact(self) -> None:
        """Drop every purged (all-``NO_STAMP``) slot and renumber.

        Row order is preserved, so snapshot compaction ordering is
        unaffected; the old→new maps plus the pre-compaction patch logs
        are appended to ``events`` for cache remapping."""
        assert self._batch is None, "compaction inside an open batch"
        v_live = self.v_create.view()[:, 0] != NO_STAMP
        e_live = self.e_create.view()[:, 0] != NO_STAMP
        v_map = np.where(v_live, np.cumsum(v_live) - 1, -1).astype(np.int64)
        e_map = np.where(e_live, np.cumsum(e_live) - 1, -1).astype(np.int64)
        old_v_patch, old_e_patch = self.v_patch, self.e_patch
        old_n_v, old_n_e = self.n_v, self.n_e
        # property tables follow their owners (compact first: the event
        # carries their row maps alongside the owner-table maps)
        vp_map, old_vp_patch, old_n_vp = self.v_props.compact(v_map)
        ep_map, old_ep_patch, old_n_ep = self.e_props.compact(e_map)
        self.events.append(CompactionEvent(
            v_map=v_map, e_map=e_map,
            old_v_patch=old_v_patch, old_e_patch=old_e_patch,
            old_n_v=old_n_v, old_n_e=old_n_e,
            vp_map=vp_map, ep_map=ep_map,
            old_vp_patch=old_vp_patch, old_ep_patch=old_ep_patch,
            old_n_vp=old_n_vp, old_n_ep=old_n_ep))
        while len(self.events) > MAX_COMPACTION_EVENTS:
            self.events.pop(0)
            self.events_dropped += 1
        vk = np.nonzero(v_live)[0]
        ek = np.nonzero(e_live)[0]
        # vertex table
        self.v_gid.reset_to(self.v_gid.view()[vk])
        self.v_create.reset_to(self.v_create.view()[vk])
        self.v_delete.reset_to(self.v_delete.view()[vk])
        vk_l = vk.tolist()
        self.v_create_stamp = [self.v_create_stamp[i] for i in vk_l]
        self.v_delete_stamp = [self.v_delete_stamp[i] for i in vk_l]
        self.v_slot = {g: int(v_map[s]) for g, s in self.v_slot.items()
                       if v_map[s] >= 0}
        # edge table
        self.e_src.reset_to(self.e_src.view()[ek])
        self.e_dst.reset_to(self.e_dst.view()[ek])
        self.e_eid.reset_to(self.e_eid.view()[ek])
        self.e_create.reset_to(self.e_create.view()[ek])
        self.e_delete.reset_to(self.e_delete.view()[ek])
        ek_l = ek.tolist()
        self.e_create_stamp = [self.e_create_stamp[i] for i in ek_l]
        self.e_delete_stamp = [self.e_delete_stamp[i] for i in ek_l]
        self.e_slot = {k: int(e_map[s]) for k, s in self.e_slot.items()
                       if e_map[s] >= 0}
        self.v_patch = []
        self.e_patch = []
        self.n_compactions += 1
        self.version += 1


class MVGraphPartition:
    """One shard's partition of the multi-version graph."""

    def __init__(self, n_gk: Optional[int] = None,
                 intern: Optional[VidIntern] = None,
                 prop_vals: Optional[PropIntern] = None) -> None:
        self.vertices: Dict[str, MVVertex] = {}
        self._eid = 0
        self._n_gk = n_gk
        self._intern = intern
        self._prop_vals = prop_vals
        self.columns: Optional[PartitionColumns] = None
        if n_gk is not None:
            self.columns = PartitionColumns(n_gk, intern, vals=prop_vals)

    def _cols(self, ts: Stamp) -> PartitionColumns:
        """Column mirror, created lazily when G is first observable."""
        if self.columns is None:
            self.columns = PartitionColumns(len(ts.clock), self._intern,
                                            vals=self._prop_vals)
        return self.columns

    # ---- write path (called by shard at a transaction's stamp) ----------
    def create_vertex(self, vid: str, ts: Stamp) -> MVVertex:
        v = self.vertices.get(vid)
        if v is not None and v.delete_ts is None:
            # re-create of live vertex: id reuse is an application error
            raise KeyError(f"vertex {vid} already exists")
        v = MVVertex(vid, create_ts=ts)
        self.vertices[vid] = v
        self._cols(ts).vertex_created(vid, ts)
        return v

    def delete_vertex(self, vid: str, ts: Stamp) -> None:
        v = self.vertices[vid]
        v.delete_ts = ts
        cols = self._cols(ts)
        cols.vertex_deleted(vid, ts)
        for e in v.out_edges.values():
            if e.delete_ts is None:
                e.delete_ts = ts
                cols.edge_deleted(vid, e.eid, ts)

    def create_edge(self, src: str, dst: str, ts: Stamp,
                    eid: Optional[int] = None) -> MVEdge:
        v = self.vertices[src]
        if eid is None:
            self._eid += 1
            eid = self._eid
        e = MVEdge(eid, src, dst, create_ts=ts)
        v.out_edges[eid] = e
        self._cols(ts).edge_created(src, dst, eid, ts)
        return e

    def delete_edge(self, src: str, eid: int, ts: Stamp) -> None:
        e = self.vertices[src].out_edges[eid]
        e.delete_ts = ts
        self._cols(ts).edge_deleted(src, eid, ts)

    def set_vertex_prop(self, vid: str, key: str, value, ts: Stamp) -> None:
        ver = Versioned(value, ts)
        self.vertices[vid].props.setdefault(key, []).append(ver)
        ver.slot = self._cols(ts).vertex_prop_set(vid, key, value, ts, ver)

    def set_edge_prop(self, src: str, eid: int, key: str, value, ts: Stamp) -> None:
        ver = Versioned(value, ts)
        self.vertices[src].out_edges[eid].props.setdefault(key, []).append(ver)
        ver.slot = self._cols(ts).edge_prop_set(src, eid, key, value, ts, ver)

    # ---- op-dict dispatch (shard replica apply) ---------------------------
    def apply_op(self, op: dict, ts: Stamp) -> None:
        """Apply one forwarded (store-validated) write op at its stamp."""
        k = op["op"]
        if k == "create_vertex":
            self.create_vertex(op["vid"], ts)
        elif k == "delete_vertex":
            self.delete_vertex(op["vid"], ts)
        elif k == "create_edge":
            self.create_edge(op["src"], op["dst"], ts, eid=op.get("eid"))
        elif k == "delete_edge":
            self.delete_edge(op["src"], op["eid"], ts)
        elif k == "set_vertex_prop":
            self.set_vertex_prop(op["vid"], op["key"], op["value"], ts)
        elif k == "set_edge_prop":
            self.set_edge_prop(op["src"], op["eid"], op["key"],
                               op["value"], ts)

    def apply_batch(self, items: List[Tuple[Stamp, List[dict]]]) -> int:
        """Apply a whole group-committed :class:`WriteBatch` payload —
        ``[(stamp, ops), ...]`` in commit-stamp order — flushing the
        column mirror ONCE (one stamp-matrix append + one patch-log
        extend per table instead of one per op, see
        :meth:`PartitionColumns.begin_batch`).  Returns the op count."""
        if not items:
            return 0
        n = 0
        cols = self._cols(items[0][0])
        cols.begin_batch()
        try:
            for ts, ops in items:
                for op in ops:
                    self.apply_op(op, ts)
                    n += 1
        finally:
            cols.end_batch()
        return n

    # ---- snapshot read path (node programs at T_prog) --------------------
    def vertex_at(self, vid: str, at: Stamp, refine=None) -> Optional[MVVertex]:
        v = self.vertices.get(vid)
        if v is None or not visible(v.create_ts, v.delete_ts, at, refine):
            return None
        return v

    def out_edges_at(self, vid: str, at: Stamp, refine=None) -> List[MVEdge]:
        v = self.vertex_at(vid, at, refine)
        if v is None:
            return []
        return [e for e in v.out_edges.values()
                if visible(e.create_ts, e.delete_ts, at, refine)]

    def prop_at(self, versions: List[Versioned], at: Stamp, refine=None):
        """Latest property version visible at ``at``."""
        best: Optional[Versioned] = None
        for ver in versions:
            if _before(ver.ts, at, refine):
                if best is None or _before(best.ts, ver.ts, refine):
                    best = ver
        return None if best is None else best.value

    def vertex_prop_at(self, vid: str, key: str, at: Stamp, refine=None):
        v = self.vertex_at(vid, at, refine)
        if v is None or key not in v.props:
            return None
        return self.prop_at(v.props[key], at, refine)

    def edge_prop_at(self, e: MVEdge, key: str, at: Stamp, refine=None):
        if key not in e.props:
            return None
        return self.prop_at(e.props[key], at, refine)

    # ---- GC (paper §4.5) --------------------------------------------------
    def collect(self, horizon: Stamp) -> int:
        """Drop versions deleted strictly before ``horizon``."""
        n = 0
        cols = self.columns
        dead_v = []
        for vid, v in self.vertices.items():
            if v.delete_ts is not None and compare(v.delete_ts, horizon) is Order.BEFORE:
                dead_v.append(vid)
                n += 1
                continue
            dead_e = [eid for eid, e in v.out_edges.items()
                      if e.delete_ts is not None
                      and compare(e.delete_ts, horizon) is Order.BEFORE]
            for eid in dead_e:
                del v.out_edges[eid]
                if cols is not None:
                    cols.edge_purged(vid, eid)
                n += 1
            for key, versions in list(v.props.items()):
                if len(versions) > 1:
                    keep = [ver for i, ver in enumerate(versions)
                            if i == len(versions) - 1
                            or not compare(versions[i + 1].ts, horizon) is Order.BEFORE]
                    if cols is not None:
                        kept = set(map(id, keep))
                        for ver in versions:
                            if id(ver) not in kept:
                                cols.vertex_prop_purged(ver.slot)
                    n += len(versions) - len(keep)
                    v.props[key] = keep
        for vid in dead_v:
            if cols is not None:
                # edge/vertex purge also purges their property rows
                for eid in self.vertices[vid].out_edges:
                    cols.edge_purged(vid, eid)
                cols.vertex_purged(vid)
            del self.vertices[vid]
        if cols is not None:
            cols.maybe_compact()
        return n

    # ---- stats ------------------------------------------------------------
    def n_live(self) -> Tuple[int, int]:
        nv = sum(1 for v in self.vertices.values() if v.delete_ts is None)
        ne = sum(sum(1 for e in v.out_edges.values() if e.delete_ts is None)
                 for v in self.vertices.values())
        return nv, ne
