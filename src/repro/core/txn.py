"""Client-side transaction API (paper §2.2, Fig. 2).

A :class:`Transaction` buffers write operations; reads (``get_vertex``)
execute directly against the backing store at call time, matching §4.1
("clients execute the reads that comprise the transaction directly on the
backing store and submit the entire read-write transaction to the
gatekeeper for commitment").

Edge ids are generated client-side as ``(client_id << 32) | counter`` so a
transaction can reference an edge it just created (e.g. to set a property
on it) without a round-trip.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class EdgeHandle:
    eid: int
    src: str
    dst: str


class Transaction:
    def __init__(self, client_id: int, eid_counter: itertools.count,
                 read_fn: Optional[Callable[[str], Optional[dict]]] = None):
        self._client_id = client_id
        self._eids = eid_counter
        self._read_fn = read_fn
        self.ops: List[dict] = []
        self._vid_counter = itertools.count()

    # ---- writes (buffered) -------------------------------------------------
    def create_vertex(self, vid: Optional[str] = None) -> str:
        if vid is None:
            vid = f"v{self._client_id}_{next(self._vid_counter)}"
        self.ops.append({"op": "create_vertex", "vid": vid})
        return vid

    def delete_vertex(self, vid: str) -> None:
        self.ops.append({"op": "delete_vertex", "vid": vid})

    def create_edge(self, src: str, dst: str) -> EdgeHandle:
        eid = (self._client_id << 32) | next(self._eids)
        self.ops.append({"op": "create_edge", "src": src, "dst": dst, "eid": eid})
        return EdgeHandle(eid, src, dst)

    def delete_edge(self, handle_or_src, eid: Optional[int] = None) -> None:
        if isinstance(handle_or_src, EdgeHandle):
            src, eid = handle_or_src.src, handle_or_src.eid
        else:
            src = handle_or_src
        self.ops.append({"op": "delete_edge", "src": src, "eid": eid})

    def set_vertex_prop(self, vid: str, key: str, value) -> None:
        self.ops.append({"op": "set_vertex_prop", "vid": vid, "key": key,
                         "value": value})

    def set_edge_prop(self, handle_or_src, key: str, value,
                      eid: Optional[int] = None) -> None:
        if isinstance(handle_or_src, EdgeHandle):
            src, eid = handle_or_src.src, handle_or_src.eid
        else:
            src = handle_or_src
        self.ops.append({"op": "set_edge_prop", "src": src, "eid": eid,
                         "key": key, "value": value})

    # ---- reads (immediate, against latest committed state) ------------------
    def get_vertex(self, vid: str) -> Optional[dict]:
        if self._read_fn is None:
            raise RuntimeError("transaction not bound to a store")
        return self._read_fn(vid)


@dataclass
class TxResult:
    ok: bool
    stamp: Optional[object] = None
    error: Optional[str] = None
    retries: int = 0
    latency: float = 0.0
