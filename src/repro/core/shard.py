"""Shard servers (paper §3.2, §4.1, §4.2, Fig. 6).

Each shard owns an in-memory multi-version partition of the graph and
obeys the refinable-timestamp order:

* one FIFO queue of incoming items per gatekeeper (sequence-numbered);
* the event loop executes the item with the *lowest* stamp once every
  queue is non-empty (NOPs guarantee this under light load);
* mutually concurrent queue heads are submitted to the timeline oracle in
  a single request; the returned (now committed) order is cached locally —
  oracle decisions are irreversible and monotonic;
* node programs wait until their stamp precedes every queue head, then
  execute against the multi-version snapshot at ``T_prog``; concurrent
  object stamps encountered during the snapshot read are refined through
  the oracle (default: program ordered *after* committed writes);
* programs scatter to other shards by emitting (vertex, params) pairs,
  grouped per destination shard, with coordinator-side termination
  counting.

Frontier-batched execution (plan / fallback contract)
-----------------------------------------------------
Programs with a registered ``frontier_step`` (see ``repro.core.
nodeprog``) run **batched**: the shard materializes a
:class:`~repro.core.frontier.ShardPlan` — a sorted-CSR snapshot slice of
its own ``PartitionColumns`` at ``T_prog`` — and executes the whole
delivered frontier in one vectorized step.  The next hop is exchanged as
ONE packed :class:`~repro.core.frontier.Frontier` message per
destination shard (O(shards) messages per hop) instead of one
``(dst, params)`` entry per emitted vertex.  Every built-in program has
a vectorized step — including the ragged-output ``get_edges`` (one
packed :class:`~repro.core.frontier.RaggedReply` per step) and the
3-phase ``clustering`` wedge-closing protocol (packed neighbour lists
in a :class:`~repro.core.frontier.Ragged` side table).  The path is
chosen per query from ``(name, root entries)`` — deterministic, so all
shards agree — and everything else (heterogeneous root params,
unhashable filter constants, non-phase-0 clustering roots, or
``use_frontier=False``) falls back to the scalar per-vertex interpreter
``nodeprog.run_entries_scalar``, which remains the semantic oracle.

Three mechanisms keep the batched path fast under live traffic:

* **plan delta refresh** — writes committing between program hops bump
  ``columns.version``; instead of rebuilding its plan cold, the shard
  delta-refreshes it from the partition's patch logs / compaction
  events at O(changed) stamp work (see :meth:`Shard._frontier_plan`);
* **plan LRU** — plans are cached per build stamp in a small LRU
  (``plan_cache_entries``), so interleaved programs at mutually
  concurrent stamps each keep a live plan instead of thrashing one
  slot cold;
* **delivery coalescing** — concurrent same-(prog, stamp) deliveries
  waiting in ``pending_progs`` are merged into ONE execution per hop
  per shard, charging the merged service cost once — packed frontiers
  concatenate into one ``frontier_step``, scalar entry lists into one
  ``run_entries_scalar`` (see :meth:`Shard._coalesce_pending`).

Group-committed writes (``repro.core.writepath``) arrive as ONE packed
``WriteBatch`` queue item per (gatekeeper window, shard) — kind
``"txbatch"``, queue-ordered by the batch's lowest remaining stamp —
and apply into the partition as bulk column appends (one stamp-matrix
append + one patch-log extend per table), in safe prefixes that never
overtake another queue's head (:meth:`Shard._exec_batch_prefix`).
Every op still carries its own commit stamp, so multi-version
visibility, program gating on queue heads, and plan/snapshot delta
refresh see exactly the per-tx contract, just with fewer, larger
patch tails.

Time model: the shard is a single-threaded server; each item charges a
service time from :class:`~repro.core.gatekeeper.CostModel`, and each
*uncached* oracle interaction stalls the loop by ``oracle_rtt``.
"""

from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .clock import Order, Stamp, compare
from .frontier import (Frontier, RaggedReply, ShardPlan, _merge_frontiers,
                       blank_ragged_rows, execute_step, fill_ragged_rows,
                       maintain_plan, reply_nbytes, route_frontier)
from .gatekeeper import CostModel
from .mvgraph import MVGraphPartition, VidIntern
from .nodeprog import REGISTRY, run_entries_scalar
from .obs import stamp_attr
from .oracle import KIND_PROG, KIND_TX, OracleServer
from .simulation import Simulator
from .writepath import WriteBatch


@dataclass
class _QueueItem:
    stamp: Stamp
    kind: str          # "tx" | "nop"
    payload: Optional[List[dict]]
    t: float = 0.0     # arrival time (queue-wait span attribution)


class Shard:
    def __init__(self, sim: Simulator, sid: int, n_gk: int,
                 oracle: OracleServer, cost: CostModel,
                 directory: Callable[[str], Optional[int]],
                 intern: Optional[VidIntern] = None,
                 use_frontier: bool = True,
                 plan_delta: bool = True,
                 coalesce: bool = True,
                 plan_cache_entries: int = 4,
                 ack_applies: bool = False,
                 device_plane=None,
                 incarnation: int = 0,
                 prop_vals=None):
        self.sim = sim
        sim.register(self)
        self.sid = sid
        self.name = f"shard{sid}"        # fault-injection crash-point id
        # bumped per backup promotion: the exactly-once trace invariant
        # allows one apply span per (shard, incarnation), and the wire-
        # dedup sender keys shipped rows by receiver incarnation so a
        # promoted (empty-cache) receiver never gets a blanked marker
        # for a row it lacks
        self.incarnation = incarnation
        self.n_gk = n_gk
        self.oracle = oracle
        self.cost = cost
        self.directory = directory       # vid -> shard id (cached map; §3.2)
        # vid intern table is deployment-wide so edge endpoints resolve
        # across partitions in the columnar snapshot path
        self.intern = intern if intern is not None else VidIntern()
        # optional deployment-wide property-VALUE intern (PropIntern):
        # when Weaver shares one, ragged replies ship packed value ids
        self.prop_vals = prop_vals
        self.partition = MVGraphPartition(n_gk, self.intern,
                                          prop_vals=prop_vals)
        self.use_frontier = use_frontier
        self.plan_delta = plan_delta     # ShardPlan delta refresh on/off
        self.coalesce = coalesce         # same-(prog, stamp) merge on/off
        # stamp-keyed plan LRU (budget = plan_cache_entries): interleaved
        # programs at mutually concurrent stamps each keep their own
        # delta-refreshed plan instead of thrashing one slot
        self.plan_cache_entries = max(1, plan_cache_entries)
        self._plans: "OrderedDict[tuple, ShardPlan]" = OrderedDict()
        self._plan_built_rows = 0                  # pending service charge
        self.queues: Dict[int, deque] = {g: deque() for g in range(n_gk)}
        self._expected_seq: Dict[int, int] = {g: 0 for g in range(n_gk)}
        self._stash: Dict[int, Dict[int, tuple]] = {g: {} for g in range(n_gk)}
        self.pending_progs: List[tuple] = []
        self._prog_cleared: Dict[Tuple, set] = {}
        self.prog_states: Dict[int, Dict[str, dict]] = {}
        self._finished_progs: set = set()
        self._order_cache: Dict[Tuple, Order] = {}
        # stamps this partition already holds (filled by recovery replay,
        # extended at every apply): re-forwarded slices of transactions
        # that were durable before a crash are skipped, never re-applied.
        # _applied_at records the apply time: GC must NOT prune an entry
        # a client retry session could still re-forward (dedup-gate
        # resubmission of a committed-but-unacked tx), or the re-forward
        # would double-apply — same retention contract as the store's
        # tx_results
        self._applied: Dict[Tuple, Stamp] = {}
        self._applied_at: Dict[Tuple, float] = {}
        self.busy = False
        self.alive = True
        self.peers: List["Shard"] = []   # indexable by sid
        self._stall = 0.0
        # read-your-writes support: ack applied tx stamps back to the
        # forwarding gatekeeper (list wired by Weaver; indexable by gid)
        self.ack_applies = ack_applies
        self.gatekeepers: List[object] = []
        # device-sharded column plane (repro.dist.columns): plan builds
        # evaluate visibility from device-resident blocks when set
        self.device_plane = device_plane
        # clustering phase-1 wire dedup (ISSUE 9 satellite): sender-side
        # shipped-row sets keyed (dst sid, dst incarnation, prog name,
        # stamp key) and receiver-side full-row cache keyed (prog name,
        # stamp key) -> {row key: (values, extra)}.  FIFO channels
        # guarantee a full row always precedes its blanked marker.
        self._shipped_rows: Dict[Tuple, set] = {}
        self._nbr_cache: Dict[Tuple, Dict] = {}
        self._last_plan_kind = "scalar"  # span attr: plan path per exec
        # deployment pod (None = unplaced; Simulator.send charges a
        # cross-pod surcharge only between two PLACED actors)
        self.pod: Optional[int] = None
        # ---- change-feed replication (see repro.core.replica) --------
        # When replicas exist (Weaver sets ``replicated``), every fresh
        # apply appends (stamp, ops) to a bounded feed log replicas pull
        # (absolute position = feed_base + len(feed_log)), and the drain
        # loop SETTLES each read stamp the first time a program at it
        # becomes runnable: at that instant every gatekeeper queue head
        # is (refined) after the stamp, so — per-gk stamp monotonicity +
        # oracle commitment — no future write can ever be ordered before
        # it here, and the current feed position permanently covers the
        # stamp's visible prefix.  The (stamp -> position) token is
        # broadcast to gatekeepers (routing) and rides feed responses
        # (replica read gating).
        self.replicated = False
        self.feed_log: List[Tuple[Stamp, List[dict]]] = []
        self.feed_base = 0
        self.settled: Dict[Tuple, int] = {}

    def start(self, peers: List["Shard"]) -> None:
        self.peers = peers

    def stop(self) -> None:
        self.alive = False

    def _crash_point(self, point: str) -> bool:
        """Fault-injection hook: die here if the plan says so."""
        f = self.sim.fault
        if f is not None and f.crash(point, self.name):
            self.alive = False
            return True
        return False

    # ------------------------------------------------------------------ enqueue
    def enqueue(self, gid: int, seq: int, stamp: Stamp, kind: str,
                payload) -> None:
        """FIFO channel receive with sequence-number reordering (§4.1)."""
        if not self.alive:
            return
        exp = self._expected_seq[gid]
        if seq == exp + 1:
            self.queues[gid].append(_QueueItem(stamp, kind, payload,
                                               self.sim.now))
            self._expected_seq[gid] = seq
            # drain stash
            stash = self._stash[gid]
            nxt = seq + 1
            while nxt in stash:
                s, k, p, t = stash.pop(nxt)
                self.queues[gid].append(_QueueItem(s, k, p, t))
                self._expected_seq[gid] = nxt
                nxt += 1
        elif seq > exp + 1:
            self._stash[gid][seq] = (stamp, kind, payload, self.sim.now)
        # duplicate/old -> drop
        self._kick()

    def deliver_prog(self, prog_id: int, delivery_id, name: str, stamp: Stamp,
                     entries: List[Tuple[str, object]], coordinator) -> None:
        if not self.alive:
            return
        if prog_id in self._finished_progs:
            self.sim.send(self, coordinator, coordinator.report, prog_id,
                          delivery_id, [], [], nbytes=32)
            return
        entries = self._reconstitute(name, stamp, entries)
        self.pending_progs.append({
            "prog_id": prog_id, "delivery_id": delivery_id, "name": name,
            "stamp": stamp, "entries": entries, "coordinator": coordinator,
            "t": self.sim.now,
            # queue-clearing state is PER PROGRAM per shard (monotone:
            # once every queue head dominated T_prog, all later arrivals
            # do too) — so follow-up deliveries of the same program run
            # immediately instead of re-waiting.
            "cleared": self._prog_cleared.setdefault(stamp.key(), set()),
        })
        self._kick()

    def deliver_prog_batch(self, deliveries: List[Tuple]) -> None:
        """One windowed read-admission flush's deliveries for this shard
        (``repro.core.gatekeeper._flush_rgroup``): a list of
        ``(prog_id, delivery_id, name, stamp, entries, coordinator)``
        sharing the window's stamp, shipped as ONE message instead of
        one per program.  Queue-clearing state is keyed by stamp, so the
        whole window clears (and refines) once."""
        if not self.alive:
            return
        for prog_id, delivery_id, name, stamp, entries, coordinator \
                in deliveries:
            if prog_id in self._finished_progs:
                self.sim.send(self, coordinator, coordinator.report, prog_id,
                              delivery_id, [], [], nbytes=32)
                continue
            self.pending_progs.append({
                "prog_id": prog_id, "delivery_id": delivery_id, "name": name,
                "stamp": stamp,
                "entries": self._reconstitute(name, stamp, entries),
                "coordinator": coordinator,
                "t": self.sim.now,
                "cleared": self._prog_cleared.setdefault(stamp.key(), set()),
            })
        self._kick()

    def finish_prog(self, prog_id: int) -> None:
        """Coordinator broadcast: GC per-query state (§4.5)."""
        self._finished_progs.add(prog_id)
        self.prog_states.pop(prog_id, None)
        if len(self._prog_cleared) > 10_000:
            self._prog_cleared.clear()
        if len(self._finished_progs) > 100_000:
            self._finished_progs.clear()

    # ---------------------------------------------- ragged wire dedup
    def _reconstitute(self, name: str, stamp: Stamp, entries):
        """Receiver half of the neighbour-list wire dedup: fill blanked
        marker rows from this shard's (prog, stamp)-keyed cache, then
        remember every full row for future markers.  A row's payload is
        a pure function of (prog, stamp, row key), so cross-sender cache
        hits are sound."""
        if not isinstance(entries, Frontier) or entries.ragged is None \
                or entries.ragged.keys is None:
            return entries
        cache = self._nbr_cache.setdefault((name, stamp.key()), {})
        rg, n = fill_ragged_rows(entries.ragged, cache)
        if n:
            self.sim.counters.nbr_rows_cached += n
            entries.ragged = rg
        ln = rg.lens()
        for i in np.nonzero(ln > 0)[0].tolist():
            k = int(rg.keys[i])
            if k not in cache:
                sl = slice(int(rg.offsets[i]), int(rg.offsets[i + 1]))
                cache[k] = (rg.values[sl].copy(),
                            {c: v[sl].copy()
                             for c, v in rg.extra.items()})
        return entries

    def _dedup_ship(self, fr: Frontier, sid: int, target,
                    name: str, stamp: Stamp) -> Frontier:
        """Sender half: rows already shipped to this (shard,
        incarnation) under this (prog, stamp) go out as zero-length
        markers (keys kept); the FIFO channel guarantees the earlier
        full row arrives first, and a promoted receiver's fresh
        incarnation never matches old shipped sets."""
        rg = fr.ragged
        if rg is None or rg.keys is None or len(rg) == 0:
            return fr
        shipped = self._shipped_rows.setdefault(
            (sid, getattr(target, "incarnation", 0), name, stamp.key()),
            set())
        ln = rg.lens()
        mask = np.zeros(len(rg), bool)
        for i, k in enumerate(rg.keys.tolist()):
            if ln[i] == 0:
                continue
            if k in shipped:
                mask[i] = True
            else:
                shipped.add(k)
        if mask.any():
            fr.ragged = blank_ragged_rows(rg, mask)
        return fr

    # ------------------------------------------------------------------ ordering
    def _order(self, a: Stamp, b: Stamp, kind_a: int, kind_b: int) -> Order:
        """Order two stamps, refining through the oracle when concurrent.

        Charges ``oracle_rtt`` stall on cache miss.  Returns BEFORE if a ≺ b.
        """
        o = compare(a, b)
        if o is not Order.CONCURRENT:
            return o
        ck = (a.key(), b.key())
        hit = self._order_cache.get(ck)
        if hit is not None:
            self.sim.counters.oracle_cache_hits += 1
            return hit
        self.sim.counters.oracle_calls += 1
        self._stall += self.cost.oracle_rtt
        chain = self.oracle.oracle.order_events([a, b], [kind_a, kind_b])
        o = Order.BEFORE if chain[0] == a.key() else Order.AFTER
        self._order_cache[ck] = o
        self._order_cache[(b.key(), a.key())] = (
            Order.AFTER if o is Order.BEFORE else Order.BEFORE)
        return o

    def _order_heads(self, heads: List[Tuple[int, _QueueItem]]) -> int:
        """Pick the gatekeeper id whose head executes next."""
        gid, best = heads[0]
        conc: List[Tuple[int, _QueueItem]] = []
        for g, item in heads[1:]:
            o = compare(item.stamp, best.stamp)
            if o is Order.BEFORE:
                gid, best = g, item
                conc = [c for c in conc
                        if compare(c[1].stamp, best.stamp) is Order.CONCURRENT]
            elif o is Order.CONCURRENT:
                conc.append((g, item))
        if not conc:
            return gid
        # Fast path: NOPs are effect-free and never conflict, so a NOP in
        # the concurrent-minimal set can execute first without the oracle
        # (the paper's oracle is only for transactions that may conflict).
        for g, item in [(gid, best)] + conc:
            if item.kind == "nop":
                return g
        # one oracle request for the whole concurrent set (paper §4.1)
        group = [(gid, best)] + conc
        stamps = [it.stamp for _, it in group]
        keys = [s.key() for s in stamps]
        # local cache: all pairs known?
        known = all(
            self._order_cache.get((keys[i], keys[j])) is not None
            for i in range(len(keys)) for j in range(i + 1, len(keys)))
        if known:
            self.sim.counters.oracle_cache_hits += 1
        else:
            self.sim.counters.oracle_calls += 1
            self._stall += self.cost.oracle_rtt
            chain = self.oracle.oracle.order_events(stamps,
                                                    [KIND_TX] * len(stamps))
            pos = {k: i for i, k in enumerate(chain)}
            for i in range(len(keys)):
                for j in range(len(keys)):
                    if i != j:
                        self._order_cache[(keys[i], keys[j])] = (
                            Order.BEFORE if pos[keys[i]] < pos[keys[j]]
                            else Order.AFTER)
        # winner = minimal under cached order
        win_g, win = group[0]
        for g, item in group[1:]:
            if self._order_cache.get((item.stamp.key(), win.stamp.key())) is Order.BEFORE:
                win_g, win = g, item
        return win_g

    # ------------------------------------------------------------------ drain
    def _kick(self) -> None:
        if not self.busy and self.alive:
            self._drain()

    def _drain(self) -> None:
        if self.busy or not self.alive:
            return
        self._stall = 0.0
        # 1) runnable node program? (stamp ≺ every queue head; §4.2)
        idx = self._runnable_prog_index()
        if idx is not None:
            prog = self.pending_progs.pop(idx)
            if self.replicated:
                self._settle_stamp(prog["stamp"])
            extra = self._coalesce_pending(prog) if self.coalesce else []
            service = self._exec_prog(
                prog["prog_id"], prog["delivery_id"], prog["name"],
                prog["stamp"], prog["entries"], prog["coordinator"],
                extra_ids=extra)
            tr = self.sim.tracer
            if tr is not None:
                ctx = tr.ctx_for_prog(prog["prog_id"])
                if ctx is not None:
                    now = self.sim.now
                    st = stamp_attr(prog["stamp"])
                    tr.span("shard_queue", prog.get("t", now), now,
                            actor=self.name, ctx=ctx, shard=self.sid,
                            stamp=st)
                    t = now
                    if self._stall > 0:
                        tr.span("oracle_refine", t, t + self._stall,
                                actor=self.name, ctx=ctx, stamp=st)
                        t += self._stall
                    e = prog["entries"]
                    tr.span("frontier_hop", t, t + service,
                            actor=self.name, ctx=ctx, shard=self.sid,
                            stamp=st, plan=self._last_plan_kind,
                            depth=getattr(e, "depth", 0), entries=len(e))
            self._finish_after(service + self._stall)
            return
        # 2) transactions: need every queue non-empty (Fig. 6)
        if all(self.queues[g] for g in range(self.n_gk)):
            heads = [(g, self.queues[g][0]) for g in range(self.n_gk)]
            g = self._order_heads(heads)
            if self.queues[g][0].kind == "txbatch":
                service = self._exec_batch_prefix(g)
            else:
                item = self.queues[g].popleft()
                service = self._exec_item(item, g)
            self._finish_after(service + self._stall)
            return
        # idle: wait for the next enqueue/NOP

    def _finish_after(self, service: float) -> None:
        self.busy = True
        self.sim.schedule(max(service, 1e-7), self._finished)

    def _finished(self) -> None:
        self.busy = False
        self._drain()

    def _runnable_prog_index(self) -> Optional[int]:
        """A program runs once every gatekeeper queue is *cleared*:
        its head stamp is (or is refined to be) after T_prog.  Per-GK
        stamps are monotone and oracle decisions transitive, so a queue
        cleared once stays cleared for this program — each program pays
        at most one refinement per gatekeeper (§4.2 + transitivity).
        Concurrent NOP heads are ordered AFTER the program (they are
        effect-free; the commitment at the oracle is what pins all later
        transactions from that gatekeeper behind the program).  Concurrent
        *transaction* heads take the paper's default — write before
        program — so the program waits for them.
        """
        if not self.pending_progs:
            return None
        for g in range(self.n_gk):
            if not self.queues[g]:
                return None
        from .oracle import CycleError
        for i, prog in enumerate(self.pending_progs):
            stamp = prog["stamp"]
            cleared = prog["cleared"]
            ok = True
            for g in range(self.n_gk):
                if g in cleared:
                    continue
                head = self.queues[g][0]
                o = compare(stamp, head.stamp)
                if o is Order.BEFORE:
                    cleared.add(g)
                    continue
                if o is not Order.CONCURRENT:
                    ok = False
                    continue
                if head.kind == "nop":
                    # A concurrent NOP head needs NO oracle: it is
                    # effect-free and will pop quickly; once the announce
                    # gossip makes a later head dominate T_prog, per-GK
                    # clock monotonicity pins every later item after the
                    # program with no commitment needed.  Just wait.
                    ok = False
                else:
                    # real transaction: paper default, write ≺ program
                    o = self._order(stamp, head.stamp, KIND_PROG, KIND_TX)
                    if o is Order.BEFORE:
                        cleared.add(g)
                    else:
                        ok = False
            if ok and len(cleared) == self.n_gk:
                return i
        return None

    # ------------------------------------------------------------------ execute
    def _ack_applied(self, gid: int, stamps: List[Stamp]) -> None:
        """Read-your-writes: tell the forwarding gatekeeper these tx
        stamps are applied here (it releases deferred client acks).
        Dedup-skipped stamps ack too — the write IS in the partition."""
        if not self.ack_applies or not stamps:
            return
        gk = (self.gatekeepers[gid]
              if gid < len(self.gatekeepers) else None)
        if gk is None or not getattr(gk, "alive", False):
            return
        keys = [s.key() for s in stamps]
        self.sim.send(self, gk, gk.on_shard_ack, keys, self.sid,
                      nbytes=32 + 16 * len(keys))

    def _exec_item(self, item: _QueueItem, gid: int) -> float:
        if item.kind == "nop":
            return 0.2e-6
        if self._crash_point("mid_shard_apply"):
            return 0.0                   # died mid-drain; recovery replays
        ops = item.payload or []
        ts = item.stamp
        tr = self.sim.tracer
        ctx = tr.ctx_for_stamp(ts) if tr is not None else None
        if ts.key() in self._applied:    # re-forwarded after a recovery
            self.sim.counters.shard_dedup_skips += 1
            if ctx is not None:
                tr.span("shard_dedup", self.sim.now, self.sim.now,
                        actor=self.name, ctx=ctx, shard=self.sid,
                        stamp=stamp_attr(ts))
            self._ack_applied(gid, [ts])
            return 0.2e-6
        for op in ops:
            # KeyError here would be replica divergence (store validated)
            self.partition.apply_op(op, ts)
        self._applied[ts.key()] = ts
        self._applied_at[ts.key()] = self.sim.now
        if self.replicated:
            self.feed_log.append((ts, list(ops)))
        self._ack_applied(gid, [ts])
        service = self.cost.shard_op * max(1, len(ops))
        if ctx is not None:
            now = self.sim.now
            st = stamp_attr(ts)
            tr.span("shard_queue", item.t, now, actor=self.name, ctx=ctx,
                    shard=self.sid, stamp=st)
            # oracle stall from head ordering precedes the apply work
            tr.span("shard_apply", now + self._stall,
                    now + self._stall + service, actor=self.name, ctx=ctx,
                    shard=self.sid, incarnation=self.incarnation, stamp=st)
        return service

    def _exec_batch_prefix(self, g: int) -> float:
        """Apply the safe prefix of the ``txbatch`` at queue ``g``'s head
        as ONE bulk column append.

        A ``WriteBatch`` delivers a whole gatekeeper window as one queue
        item, but executing it atomically would let a LATER item of an
        earlier-ordered batch jump ahead of a cross-gatekeeper
        dependency still waiting at another queue head (the store
        committed ``T_a ≺ T_b`` — e.g. delete-then-recreate — but only
        per-item head ordering enforces it here).  So: the head item
        always runs (it just won ``_order_heads``, and no program was
        runnable this turn), and the prefix extends while the next
        item's stamp is strictly vector-before EVERY other queue head
        AND every pending program stamp — unambiguous with no oracle
        traffic.  The program bound matters because the per-tx loop
        re-checks runnable programs between every applied item: an
        item merely CONCURRENT with a gated program may be
        oracle-ordered after it (and e.g. a re-create would destroy
        property history the program must still read), so it has to
        wait for the normal loop; items strictly before the program
        are visible at its stamp either way, so applying them early is
        indistinguishable.  The remainder is requeued as the new head
        (its first stamp becomes the head stamp for program gating /
        head ordering), and the normal loop — oracle refinement
        included — interleaves it against the other queues, which is
        exactly per-tx semantics.  The uncontended case (all other
        heads dominate the window, no gated programs) applies the
        whole batch in one ``MVGraphPartition.apply_batch`` — one
        stamp-matrix append + one patch-log extend per table.

        Reorder buffer (cross-gatekeeper contention): under heavy
        concurrency every other queue head is ALSO a txbatch whose
        stamps interleave with ours, so the strict-before prefix above
        collapses to one item per turn — per-item interleaving with a
        full service round each.  Instead, treat this batch plus every
        other queue's head txbatch as candidate streams and k-way-merge
        their runnable prefixes into ONE bulk apply: an item is
        consumed when its stamp is strictly vector-before every other
        stream's next unconsumed item, every non-batch queue head, and
        every pending program stamp.  Per-gatekeeper stamps are
        elementwise monotone (a gk's vector only grows), so a stream's
        next item bounds everything later in that stream, and strict
        vector-before is transitive — the consumed sequence is exactly
        the order the per-item loop would have applied, with no oracle
        traffic.  A stream that runs dry is bounded by the next item
        behind it in its queue; with nothing behind it the merge stops
        (a future item from that gatekeeper could order anywhere).
        Acks are routed per ORIGIN gatekeeper, and partially consumed
        foreign batches are requeued as their queue's new head —
        identical to the single-stream remainder contract."""
        item = self.queues[g].popleft()
        wb: WriteBatch = item.payload
        items = wb.items
        if self._crash_point("mid_shard_apply"):
            # die partway through the window: a prefix of the batch is
            # applied, the rest is lost with the server (recovery
            # replays the whole window from the store's log)
            self._apply_deduped(items[:max(1, len(items) // 2)])
            return 0.0
        fixed_bounds = [p["stamp"] for p in self.pending_progs]
        streams: Dict[int, List[Tuple[Stamp, List[dict]]]] = {g: items}
        arr: Dict[int, float] = {g: item.t}   # queue arrival per stream
        for h in range(self.n_gk):
            if h == g or not self.queues[h]:
                continue
            head = self.queues[h][0]
            if head.kind == "txbatch":
                streams[h] = head.payload.items
                arr[h] = head.t
            else:
                fixed_bounds.append(head.stamp)
        ci = {h: 0 for h in streams}     # consumed-prefix cursor per stream
        consumed: List[Tuple[Stamp, List[dict]]] = [items[0]]
        origin: List[int] = [g]
        ci[g] = 1

        def bound_of(h: int) -> Optional[Stamp]:
            s = streams[h]
            if ci[h] < len(s):
                return s[ci[h]][0]
            if h == g:                   # this batch was popped already
                return self.queues[g][0].stamp if self.queues[g] else None
            if len(self.queues[h]) > 1:  # next item behind the head batch
                return self.queues[h][1].stamp
            return None                  # unknown future: blocks the merge

        progress = True
        while progress:
            progress = False
            for h in streams:
                i = ci[h]
                s = streams[h]
                if i >= len(s):
                    continue
                cand = s[i][0]
                ok = all(compare(cand, b) is Order.BEFORE
                         for b in fixed_bounds)
                if ok:
                    for k in streams:
                        if k == h:
                            continue
                        b = bound_of(k)
                        if b is None or compare(cand, b) is not Order.BEFORE:
                            ok = False
                            break
                if ok:
                    consumed.append(s[i])
                    origin.append(h)
                    ci[h] = i + 1
                    progress = True
        n_merged = sum(1 for h in origin if h != g)
        if n_merged:
            self.sim.counters.crossgk_batch_merges += 1
            self.sim.counters.crossgk_merged_txs += n_merged
        pre_applied = {s.key() for s, _ in consumed
                       if s.key() in self._applied}
        n_ops = self._apply_deduped(consumed)
        tr = self.sim.tracer
        if tr is not None:
            now = self.sim.now
            t = now + self._stall        # head-ordering stall, then apply
            for (s, ops), h in zip(consumed, origin):
                ctx = tr.ctx_for_stamp(s)
                if s.key() in pre_applied:
                    if ctx is not None:
                        tr.span("shard_dedup", now, now, actor=self.name,
                                ctx=ctx, shard=self.sid,
                                stamp=stamp_attr(s))
                    continue
                dt = self.cost.shard_op * max(1, len(ops))
                if ctx is not None:
                    st = stamp_attr(s)
                    tr.span("shard_queue", arr.get(h, now), now,
                            actor=self.name, ctx=ctx, shard=self.sid,
                            stamp=st)
                    tr.span("shard_apply", t, t + dt, actor=self.name,
                            ctx=ctx, shard=self.sid,
                            incarnation=self.incarnation, stamp=st,
                            batched=True)
                t += dt
        by_origin: Dict[int, List[Stamp]] = {}
        for (s, _), h in zip(consumed, origin):
            by_origin.setdefault(h, []).append(s)
        for h, stamps in by_origin.items():
            self._ack_applied(h, stamps)
        if ci[g] < len(items):
            self.queues[g].appendleft(_QueueItem(
                items[ci[g]][0], "txbatch", WriteBatch(items[ci[g]:])))
        for h in streams:
            if h == g or ci[h] == 0:
                continue
            self.queues[h].popleft()
            rest = streams[h][ci[h]:]
            if rest:
                self.queues[h].appendleft(_QueueItem(
                    rest[0][0], "txbatch", WriteBatch(rest)))
        return self.cost.shard_op * max(1, n_ops)

    def _apply_deduped(self, items: List[Tuple[Stamp, List[dict]]]) -> int:
        """Bulk-apply skipping stamps the partition already holds."""
        fresh = [(s, ops) for s, ops in items
                 if s.key() not in self._applied]
        if len(fresh) < len(items):
            self.sim.counters.shard_dedup_skips += len(items) - len(fresh)
        n = self.partition.apply_batch(fresh)
        for s, ops in fresh:
            self._applied[s.key()] = s
            self._applied_at[s.key()] = self.sim.now
            if self.replicated:
                self.feed_log.append((s, list(ops)))
        return n

    def _refine_batch(self, stamps: List[Stamp], at: Stamp) -> Dict:
        """ONE oracle round trip for a batch of stamps truly concurrent
        with ``at``; returns {stamp.key(): True iff stamp ≺ at}.  Uses
        (and fills) the pairwise order cache, charging ``oracle_rtt``
        only when at least one pair is unknown."""
        out: Dict = {}
        missing: List[Stamp] = []
        for s in stamps:
            hit = self._order_cache.get((s.key(), at.key()))
            if hit is None:
                missing.append(s)
            else:
                self.sim.counters.oracle_cache_hits += 1
                out[s.key()] = hit is Order.BEFORE
        if missing:
            self.sim.counters.oracle_calls += 1
            self._stall += self.cost.oracle_rtt
            chain = self.oracle.oracle.order_events(
                missing + [at], [KIND_TX] * len(missing) + [KIND_PROG])
            pos = {k: i for i, k in enumerate(chain)}
            p_at = pos[at.key()]
            for s in missing:
                before = pos[s.key()] < p_at
                o = Order.BEFORE if before else Order.AFTER
                self._order_cache[(s.key(), at.key())] = o
                self._order_cache[(at.key(), s.key())] = (
                    Order.AFTER if before else Order.BEFORE)
                out[s.key()] = before
        return out

    def _frontier_plan(self, stamp: Stamp) -> ShardPlan:
        """Cached sorted-CSR snapshot slice at ``stamp``, served from a
        small stamp-keyed LRU (budget ``plan_cache_entries``).

        A plan is reused as-is when the partition columns are unchanged
        AND (same stamp, or the cached plan is *settled* — every stamp
        in the columns strictly precedes its build stamp, so visibility
        is identical at every later stamp).  The settled case is the
        point-read hot path: a quiescent shard serves
        get_node/count_edges streams from ONE plan.

        When writes committed since the last build (``version`` moved),
        the plan is **delta-refreshed** (:meth:`ShardPlan.refresh`):
        patch-log tails and compaction remaps are consumed at O(changed)
        stamp work, so write traffic between program hops no longer
        degrades the batched path to cold rebuilds.  A cold rebuild
        happens only when (a) no cached plan's stamp is dominated by the
        query stamp (plans only move forward), or (b) the columns'
        bounded compaction-event history no longer covers the candidate
        plan's cursor — in which case the stale plan (settled or not) is
        DISCARDED, never reused for later stamps.

        The LRU replaces PR 3's single cached plan: two interleaved
        programs at mutually CONCURRENT stamps keep separate entries
        (neither stamp dominates the other, so neither plan can serve
        the other's query) instead of thrashing cold rebuilds per
        alternation; evictions beyond the budget count
        ``plan_cache_evictions``.  Service cost: a cold build charges
        ``prog_plan_row`` per column row, a delta refresh the same rate
        per re-evaluated row (``_plan_built_rows`` is drained by
        ``_exec_prog``)."""
        cols = self.partition.columns
        ctr = self.sim.counters
        key = stamp.key()
        cand: Optional[ShardPlan] = None
        cand_key = None
        hit = self._plans.get(key)
        if hit is not None and hit.cols is cols:
            cand, cand_key = hit, key
        else:
            # most-recently-used plan this stamp dominates (delta/reuse
            # candidate); concurrent-stamp plans stay untouched
            for k in reversed(self._plans):
                p = self._plans[k]
                if p.cols is cols and compare(p.at, stamp) in (
                        Order.BEFORE, Order.EQUAL):
                    cand, cand_key = p, k
                    break
        plan, kind = maintain_plan(
            cand, cols, stamp, self.n_gk,
            lambda ss, at=stamp: self._refine_batch(ss, at),
            allow_delta=self.plan_delta,
            device_plane=self.device_plane)
        self._last_plan_kind = kind or "reuse"
        if kind == "delta":
            ctr.plan_delta_refreshes += 1
            ctr.plan_rows_refreshed += plan.last_refresh_rows
            self._plan_built_rows += plan.last_refresh_rows
        elif kind == "cold":
            ctr.plan_cold_builds += 1
            self._plan_built_rows += plan.built_rows
            if cand is not None:
                # the candidate's cursor fell off the compaction
                # history: stale, must not serve any later stamp
                self._plans.pop(cand_key, None)
        if cand_key is not None and cand_key != plan.at.key():
            self._plans.pop(cand_key, None)   # re-key advanced plan
        self._plans[plan.at.key()] = plan
        self._plans.move_to_end(plan.at.key())
        while len(self._plans) > self.plan_cache_entries:
            self._plans.popitem(last=False)
            ctr.plan_cache_evictions += 1
        return plan

    def _coalesce_pending(self, prog: dict) -> List:
        """Merge every waiting same-(prog, stamp) Frontier delivery into
        ``prog``'s execution; returns the absorbed delivery ids.

        Without this, N source shards emitting to this shard in one hop
        queue N separate executions of the SAME program step — the event
        loop pays O(source shards) per hop per shard.  Merging
        concatenates the packed frontiers into ONE ``frontier_step``
        (O(1) executions per hop per shard) and charges the merged
        service cost once; the absorbed deliveries still report to the
        coordinator (empty, zero-entry reports) so termination counting
        is unaffected.

        Merging is legal only within one hop of one query: same prog_id,
        same stamp, same depth and identical meta (programs may rewrite
        meta between hops, e.g. ``block_render``), same payload
        presence, and only for programs whose ``coalesce_ok`` asserts
        step-concatenation invariance (see ``nodeprog.NodeProgram``).
        The runnable check already passed for ``prog``; queue-clearing
        state is shared per (shard, stamp), so every absorbed delivery
        was runnable too.

        Scalar deliveries coalesce symmetrically: waiting
        same-(prog_id, stamp) entry LISTS concatenate into one
        ``run_entries_scalar`` execution (the interpreter processes
        entries independently against shared per-program state, so
        concatenation invariance is the same ``coalesce_ok`` contract);
        scalar and packed deliveries never merge with each other."""
        base = prog["entries"]
        if not REGISTRY[prog["name"]].coalesce_ok:
            return []
        if not isinstance(base, Frontier):
            # ---- scalar path: concatenate same-(prog, stamp) lists
            merged_e: List = list(base)
            extra_s: List = []
            keep_s: List[dict] = []
            for p in self.pending_progs:
                if (p["prog_id"] == prog["prog_id"]
                        and p["name"] == prog["name"]
                        and p["stamp"].key() == prog["stamp"].key()
                        # a message-dup of an already-merged delivery must
                        # NOT concatenate its entries again; left queued,
                        # it re-executes and the coordinator dedups its
                        # same-id report
                        and p["delivery_id"] != prog["delivery_id"]
                        and p["delivery_id"] not in extra_s
                        and not isinstance(p["entries"], Frontier)):
                    merged_e.extend(p["entries"])
                    extra_s.append(p["delivery_id"])
                else:
                    keep_s.append(p)
            if extra_s:
                self.pending_progs = keep_s
                prog["entries"] = merged_e
                self.sim.counters.scalar_coalesced += len(extra_s)
            return extra_s
        merged = [base]
        extra: List = []
        keep: List[dict] = []
        for p in self.pending_progs:
            e = p["entries"]
            mergeable = (p["prog_id"] == prog["prog_id"]
                         and p["name"] == prog["name"]
                         and p["stamp"].key() == prog["stamp"].key()
                         # message-dup guard: same contract as the scalar
                         # branch above
                         and p["delivery_id"] != prog["delivery_id"]
                         and p["delivery_id"] not in extra
                         and isinstance(e, Frontier)
                         and e.depth == base.depth
                         and (e.vals is None) == (base.vals is None)
                         # ragged payload kinds merge only with their
                         # own kind: tags/ragged concatenate row-wise
                         # with tag re-base (_merge_frontiers)
                         and (e.tags is None) == (base.tags is None)
                         and (e.ragged is None) == (base.ragged is None))
            if mergeable:
                try:
                    mergeable = bool(e.meta == base.meta)
                except (TypeError, ValueError):   # exotic meta: keep apart
                    mergeable = False
            if mergeable:
                merged.append(e)
                extra.append(p["delivery_id"])
            else:
                keep.append(p)
        if extra:
            self.pending_progs = keep
            prog["entries"] = _merge_frontiers(merged)
            self.sim.counters.frontier_coalesced += len(extra)
        return extra

    def _frontier_of(self, name: str, entries) -> Optional[Frontier]:
        """Batched-path decision per delivery: already-packed frontiers
        stay batched; root entry lists pack iff the program has a
        vectorized step and accepts the (uniform) root params."""
        if isinstance(entries, Frontier):
            return entries
        if not self.use_frontier:
            return None
        prog = REGISTRY[name]
        if prog.frontier_step is None or prog.pack_root is None:
            return None
        if entries and not prog.frontier_ok(entries[0][1]):
            return None
        return prog.pack_root(entries, self.intern)

    def _exec_prog(self, prog_id: int, delivery_id, name: str, stamp: Stamp,
                   entries, coordinator, extra_ids: Optional[List] = None
                   ) -> float:
        prog = REGISTRY[name]
        states = self.prog_states.setdefault(prog_id, {})
        frontier = self._frontier_of(name, entries)
        children = []
        self._last_plan_kind = "scalar"  # _frontier_plan overwrites
        if frontier is not None:
            # ---- batched path: one vectorized step over the shard plan
            plan = self._frontier_plan(stamp)
            outputs, nxt, service = execute_step(
                plan, prog, frontier,
                states.setdefault("__frontier__", {}), self.intern,
                self.cost)
            if self._plan_built_rows:     # charge the (vectorized) build
                service += self.cost.prog_plan_row * self._plan_built_rows
                self._plan_built_rows = 0
            n_entries = len(frontier)
            self.sim.counters.prog_entries_delivered += n_entries
            for o in outputs:
                if isinstance(o, RaggedReply):
                    self.sim.counters.ragged_replies += 1
                    self.sim.counters.ragged_values += o.total()
            if nxt is not None:
                for sid, out_fr in self._route(nxt).items():
                    self.sim.counters.shard_hops += 1
                    child_id = (self.sid, self._next_delivery())
                    children.append(child_id)
                    target = self.peers[sid]
                    out_fr = self._dedup_ship(out_fr, sid, target, name,
                                              stamp)
                    self.sim.send(self, target, target.deliver_prog,
                                  prog_id, child_id, name, stamp, out_fr,
                                  coordinator, nbytes=out_fr.nbytes())
        else:
            # ---- scalar fallback: per-vertex interpreter
            refine = lambda a, b: self._order(a, b, KIND_TX, KIND_PROG)
            emits, outputs, service = run_entries_scalar(
                self.partition, prog, entries, stamp, refine, states,
                self.cost)
            n_entries = len(entries)
            self.sim.counters.prog_entries_delivered += n_entries
            # group scatter by destination shard (one message per shard;
            # §2.3) — but one ENTRY per emitted vertex
            by_shard: Dict[int, List[Tuple[str, object]]] = {}
            for dst_vid, params in emits:
                sid = self.directory(dst_vid)
                if sid is None:
                    continue
                by_shard.setdefault(sid, []).append((dst_vid, params))
            for sid, ent in by_shard.items():
                self.sim.counters.shard_hops += 1
                child_id = (self.sid, self._next_delivery())
                children.append(child_id)
                target = self.peers[sid]
                self.sim.send(self, target, target.deliver_prog, prog_id,
                              child_id, name, stamp, ent, coordinator,
                              nbytes=64 + 48 * len(ent))
        # termination detection: announced/reported delivery-id sets at the
        # coordinator (premature-zero-safe, unlike naive credit counting)
        self.sim.send(self, coordinator, coordinator.report, prog_id,
                      delivery_id, children, outputs,
                      frontier is not None, n_entries,
                      nbytes=64 + reply_nbytes(outputs))
        # deliveries absorbed by coalescing: their entries/outputs/children
        # were charged to the merged execution above; they still must
        # report so the coordinator's delivery-id sets close (zero-entry,
        # non-batched reports: counters see ONE execution)
        for did in (extra_ids or ()):
            self.sim.send(self, coordinator, coordinator.report, prog_id,
                          did, [], [], False, 0, nbytes=32)
        return service

    def _route(self, fr: Frontier) -> Dict[int, Frontier]:
        """Split a next-hop frontier into one packed message per
        destination shard (shared groupby with the synchronous driver;
        ragged side tables are subset per destination)."""
        return route_frontier(fr, self.intern, self.directory)

    def _next_delivery(self) -> int:
        self._delivery_ctr = getattr(self, "_delivery_ctr", 0) + 1
        return self._delivery_ctr

    # ------------------------------------------------------ change feed
    FEED_RETAIN = 1024       # feed entries kept past GC; a replica whose
    #                          cursor falls off the tail cold-resyncs

    @property
    def feed_pos(self) -> int:
        """Absolute change-feed position (monotone per incarnation)."""
        return self.feed_base + len(self.feed_log)

    def _settle_stamp(self, stamp: Stamp) -> None:
        """First runnable program at ``stamp``: bind it to the current
        feed position and tell the gatekeepers — any replica whose
        applied position reaches the token can serve reads at this stamp
        bit-identically (no write ordered before the stamp can appear
        after this instant; see the class-level feed comment)."""
        k = stamp.key()
        if k in self.settled:
            return
        if len(self.settled) > 10_000:    # size cap, like _prog_cleared;
            self.settled.clear()          # lost tokens just re-settle
        pos = self.feed_pos
        self.settled[k] = pos
        self.sim.counters.stamps_settled += 1
        for gk in self.gatekeepers:
            if getattr(gk, "alive", False):
                self.sim.send(self, gk, gk.on_settled, self.sid, k, pos,
                              self.incarnation, nbytes=48)

    def feed_pull(self, replica, cursor: int, sub_inc: int,
                  seq: int) -> None:
        """Serve a replica's change-feed pull: entries from ``cursor``
        plus the current settlement-token map.  A subscriber behind the
        truncated log tail — or subscribed to a previous incarnation —
        gets a full-state reset (redo-op walk of the live partition)."""
        if not self.alive:
            return
        self.sim.counters.replica_feed_pulls += 1
        tokens = dict(self.settled)
        if sub_inc != self.incarnation or cursor < self.feed_base:
            ops = self._walk_redo()
            self.sim.send(self, replica, replica.feed_reset,
                          self.incarnation, self.feed_pos, ops, tokens,
                          seq, nbytes=64 + 48 * len(ops))
            return
        entries = self.feed_log[cursor - self.feed_base:]
        self.sim.counters.replica_feed_entries += len(entries)
        nbytes = (64 + sum(32 + 48 * len(ops) for _, ops in entries)
                  + 24 * len(tokens))
        self.sim.send(self, replica, replica.feed_apply, cursor,
                      entries, tokens, self.incarnation, seq,
                      nbytes=nbytes)

    def _walk_redo(self) -> List[dict]:
        """Redo-op stream equivalent to replaying this partition's full
        history (the same multi-version rebuild contract as the store's
        ``recover_shard_walk``): applying these ops in order onto a fresh
        partition reproduces the current state bit-identically."""
        ops: List[dict] = []
        for vid in sorted(self.partition.vertices):
            v = self.partition.vertices[vid]
            ops.append({"op": "create_vertex", "vid": vid,
                        "ts": v.create_ts})
            for key, vers in sorted(v.props.items()):
                for ver in vers:
                    ops.append({"op": "set_vertex_prop", "vid": vid,
                                "key": key, "value": ver.value,
                                "ts": ver.ts})
            for eid in sorted(v.out_edges):
                e = v.out_edges[eid]
                ops.append({"op": "create_edge", "src": vid, "dst": e.dst,
                            "eid": eid, "ts": e.create_ts})
                for key, vers in sorted(e.props.items()):
                    for ver in vers:
                        ops.append({"op": "set_edge_prop", "src": vid,
                                    "eid": eid, "key": key,
                                    "value": ver.value, "ts": ver.ts})
                if e.delete_ts is not None:
                    ops.append({"op": "delete_edge", "src": vid,
                                "eid": eid, "ts": e.delete_ts})
            if v.delete_ts is not None:
                ops.append({"op": "delete_vertex", "vid": vid,
                            "ts": v.delete_ts})
        return ops

    def adopt_replica(self, rep, ops: List[dict]) -> int:
        """Failover fast path: promote a caught-up read replica by
        adopting its partition + applied map, then top up from the
        store's redo stream with only the ops the replica had not yet
        pulled — MTTR proportional to replica lag, not partition size.
        Returns the number of topped-up ops."""
        self.partition = rep.partition
        self._plans.clear()
        self._applied = dict(rep._applied)
        self._applied_at = dict(rep._applied_at)
        missing = [op for op in ops
                   if op["ts"].key() not in self._applied]
        tr = self.sim.tracer
        for op in missing:
            ts = op["ts"]
            self.partition.apply_op(op, ts)
            self._applied[ts.key()] = ts
            self._applied_at[ts.key()] = self.sim.now
            if tr is not None:
                ctx = tr.ctx_for_stamp(ts)
                if ctx is not None:
                    # same recovered-apply exemption as recover_from
                    tr.span("shard_apply", self.sim.now, self.sim.now,
                            actor=self.name, ctx=ctx, shard=self.sid,
                            incarnation=self.incarnation, recovered=True,
                            stamp=stamp_attr(ts))
        return len(missing)

    # ------------------------------------------------------------------ GC / recovery
    def collect(self, horizon: Stamp) -> int:
        # past-horizon dedup entries stay until no client retry session
        # can re-forward them anymore (BackingStore.RESULT_RETENTION is
        # the same bound for recorded tx outcomes)
        from .store import BackingStore
        keep_after = self.sim.now - BackingStore.RESULT_RETENTION
        drop = [k for k, s in self._applied.items()
                if compare(s, horizon) is Order.BEFORE
                and self._applied_at.get(k, self.sim.now) < keep_after]
        for k in drop:
            del self._applied[k]
            self._applied_at.pop(k, None)
        # wire-dedup caches: a stamp strictly before the horizon has no
        # outstanding program (the horizon is bounded by active stamps),
        # so its shipped sets / cached rows can never be referenced again
        for k in [k for k in self._shipped_rows
                  if compare(Stamp(k[3][0], k[3][1], k[3][2], 0),
                             horizon) is Order.BEFORE]:
            del self._shipped_rows[k]
        for k in [k for k in self._nbr_cache
                  if compare(Stamp(k[1][0], k[1][1], k[1][2], 0),
                             horizon) is Order.BEFORE]:
            del self._nbr_cache[k]
        # change-feed truncation: keep a bounded tail; a replica whose
        # cursor falls behind feed_base rebuilds via the redo walk
        if len(self.feed_log) > self.FEED_RETAIN:
            cut = len(self.feed_log) - self.FEED_RETAIN
            del self.feed_log[:cut]
            self.feed_base += cut
        return self.partition.collect(horizon)

    def recover_from(self, ops: List[dict]) -> None:
        """Backup promotion: rebuild the partition from the store's redo
        stream (WAL replay, or the ``vertices``-walk oracle when replay
        is off).  Every op dispatches through ``apply_op`` — including
        ``set_edge_prop`` — and its stamp is remembered, so slices of
        already-durable transactions re-forwarded by the exactly-once
        retry path are skipped, never double-applied."""
        self.partition = MVGraphPartition(self.n_gk, self.intern,
                                          prop_vals=self.prop_vals)
        self._plans.clear()              # plans referenced the old columns
        self._applied = {}
        self._applied_at = {}
        # fresh incarnation, fresh feed: subscribers detect the
        # incarnation change on their next pull and cold-resync
        self.feed_log = []
        self.feed_base = 0
        self.settled = {}
        for op in ops:
            ts = op["ts"]
            self.partition.apply_op(op, ts)
            self._applied[ts.key()] = ts
            self._applied_at[ts.key()] = self.sim.now
        tr = self.sim.tracer
        if tr is not None:
            # zero-width recovered-apply spans: the exactly-once checker
            # counts them toward shard coverage but exempts them from
            # the one-per-incarnation rule (replay is re-application by
            # design, not a double-apply bug)
            now = self.sim.now
            for ts in self._applied.values():
                ctx = tr.ctx_for_stamp(ts)
                if ctx is not None:
                    tr.span("shard_apply", now, now, actor=self.name,
                            ctx=ctx, shard=self.sid,
                            incarnation=self.incarnation, recovered=True,
                            stamp=stamp_attr(ts))

    def enter_epoch(self, epoch: int) -> None:
        """Cluster-manager barrier: fresh FIFO channels in the new epoch."""
        self._expected_seq = {g: 0 for g in range(self.n_gk)}
        self._stash = {g: {} for g in range(self.n_gk)}
