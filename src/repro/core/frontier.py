"""Frontier-batched node-program runtime on the columnar data plane.

The per-vertex path (``nodeprog.run_entries_scalar``) interprets one
Python callback per delivered vertex against the multi-version dicts.
This module executes a whole per-shard *frontier* in one vectorized step
against the stamped columns the partition already maintains
(:class:`~repro.core.mvgraph.PartitionColumns`):

* :class:`ShardPlan` — a per-shard sorted-CSR snapshot *slice* at the
  program stamp ``T_prog``: one batched visibility pass over the packed
  stamp matrices (the same `mv_visibility` contract the global snapshot
  engine uses, truly-concurrent stamps refined through the shard's
  timeline-oracle cache in ONE request), the visible out-edges sorted by
  ``(src gid, dst gid)``, and lazily-materialized latest-visible
  property columns per key (edge filters, weights, vertex values).
  Plans are cached per (columns.version, stamp) — every hop of a
  multi-hop program reuses one plan, and concurrent writes invalidate it
  because every column mutation bumps ``version``.
* :class:`Frontier` — the packed exchange unit: a gid array plus an
  optional per-entry float payload (e.g. sssp distances) and a shared
  ``meta`` dict.  Shards exchange ONE such message per destination shard
  per hop instead of one ``(dst, params)`` tuple per emitted vertex.
* :func:`execute_step` — runs a program's registered ``frontier_step``
  (see ``nodeprog.frontier_impl``) over one plan + frontier, returning
  the batch outputs, the global next frontier and the charged service
  time.  Per-destination neighbour aggregation goes through
  ``repro.kernels.segment_mp.ops.segment_reduce_sorted`` — the
  CSR-sorted plan makes the sorted-segment contract free.

The plan/fallback contract: a program participates iff it registered a
``frontier_step`` AND ``frontier_ok(params)`` accepts the root
parameters (e.g. an unhashable edge-filter constant forces the scalar
path).  The decision is a pure function of ``(name, root params)``, so
every shard of one query independently agrees; follow-up hops carry
:class:`Frontier` objects, which imply the batched path.  Results are
identical to the scalar path at the same stamp (randomized equivalence
is enforced by ``tests/test_frontier_prog.py``); the only caveat is
``sssp`` under a *binding* ``max_depth``, where the scalar path itself
is delivery-order dependent.

:func:`run_local` drives a whole program synchronously outside the
simulator (equivalence tests, wall-clock benchmarks).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from . import clock
from .clock import NO_STAMP, Order, Stamp, compare


@dataclass
class Frontier:
    """Packed per-hop delivery: one message per destination shard."""

    gids: np.ndarray                       # (F,) int64 vertex intern ids
    vals: Optional[np.ndarray] = None      # (F,) float64 payload (sssp dist)
    depth: int = 0                         # hop depth (shared)
    meta: dict = field(default_factory=dict)   # shared params

    def __len__(self) -> int:
        return int(self.gids.size)

    def nbytes(self) -> int:
        """Simulated wire size: packed arrays, not per-entry tuples."""
        n = 64 + 8 * self.gids.size
        if self.vals is not None:
            n += 8 * self.vals.size
        return n


def _before_rows(rows: np.ndarray, q: np.ndarray) -> np.ndarray:
    """rows ≺ q with the kernel/numpy auto-switch of the snapshot engine."""
    from . import analytics
    return np.array(analytics._before_batch(rows, q))


class ShardPlan:
    """Sorted-CSR snapshot slice of ONE partition at one stamp.

    ``refine_batch(stamps) -> {stamp.key(): bool}`` resolves stamps that
    are truly concurrent with ``at`` (True = before the program); the
    shard passes a closure over its oracle cache so a plan build costs at
    most one oracle round trip.
    """

    def __init__(self, cols, at: Stamp, n_gk: int,
                 refine_batch: Optional[Callable] = None):
        self.at = at
        self.version = cols.version
        self.cols = cols
        self.n_gk = n_gk
        self.q = clock.pack(at, n_gk)
        self._refine_batch = refine_batch
        self._prop_cache: Dict[Tuple[str, str], tuple] = {}
        # settled: every stamp present in the columns (incl. property
        # versions) is strictly vector-before ``at`` — then visibility is
        # identical at EVERY later stamp, and the shard may reuse this
        # plan for new queries without rebuilding (point-read hot path).
        self._all_before = True
        #: rows evaluated by this build (simulated-cost accounting)
        self.built_rows = (cols.n_v + cols.n_e
                           + cols.v_props.n + cols.e_props.n)

        nv = cols.n_v
        v_create = cols.v_create.view()
        v_delete = cols.v_delete.view()
        cb = self._vis_half(v_create, cols.v_create_stamp)
        db = self._vis_half(v_delete, cols.v_delete_stamp)
        self.v_visible = cb & ~db if nv else np.zeros(0, bool)

        # gid -> vertex slot (dense over the intern table seen so far)
        gids = cols.v_gid.view()
        self._slot_of = np.full(int(gids.max()) + 1 if nv else 1, -1,
                                np.int64)
        self._slot_of[gids] = np.arange(nv, dtype=np.int64)

        # visible out-edges of visible sources, sorted by (src, dst) gid
        ne = cols.n_e
        if ne:
            ecb = self._vis_half(cols.e_create.view(), cols.e_create_stamp)
            edb = self._vis_half(cols.e_delete.view(), cols.e_delete_stamp)
            e_vis = ecb & ~edb
            src = cols.e_src.view().astype(np.int64)
            sslot = np.where(src < self._slot_of.size,
                             self._slot_of[np.minimum(src,
                                                      self._slot_of.size - 1)],
                             -1)
            keep = e_vis & (sslot >= 0)
            keep[keep] &= self.v_visible[sslot[keep]]
            rows = np.nonzero(keep)[0]
            dst = cols.e_dst.view().astype(np.int64)[rows]
            order = np.lexsort((dst, src[rows]))
            self.esrc = src[rows][order]
            self.edst = dst[order]
            self.eslot = rows[order]          # edge slot per CSR position
        else:
            self.esrc = np.zeros(0, np.int64)
            self.edst = np.zeros(0, np.int64)
            self.eslot = np.zeros(0, np.int64)

        # fold the property stamps into the settledness check eagerly
        # (prop arrays themselves stay lazy per key)
        for pt in (cols.v_props, cols.e_props):
            if pt.n:
                rows = pt.stamp.view()
                raw = _before_rows(rows, self.q)
                self._all_before &= bool(
                    np.all(raw | (rows[:, 0] == NO_STAMP)))
        self.settled = self._all_before

    # ------------------------------------------------------------ visibility
    def _vis_half(self, rows: np.ndarray, stamp_of: List) -> np.ndarray:
        if rows.shape[0] == 0:
            return np.zeros(0, bool)
        out = _before_rows(rows, self.q)
        # a present stamp not strictly vector-before q can flip at a
        # later query stamp: the plan is then stamp-specific
        self._all_before &= bool(np.all(out | (rows[:, 0] == NO_STAMP)))
        if self._refine_batch is not None:
            cand = np.nonzero(clock.concurrent_mask_np(rows, self.q))[0]
            if cand.size:
                pend = [(int(i), stamp_of[int(i)]) for i in cand
                        if stamp_of[int(i)] is not None
                        and compare(stamp_of[int(i)], self.at)
                        is Order.CONCURRENT]
                if pend:
                    got = self._refine_batch([s for _, s in pend])
                    for i, s in pend:
                        out[i] = got[s.key()]
        return out

    # ------------------------------------------------------------- lookups
    def vertex_visible(self, gids: np.ndarray) -> np.ndarray:
        """(F,) bool — is each frontier gid a visible vertex here?"""
        g = np.asarray(gids, np.int64)
        ok = (g >= 0) & (g < self._slot_of.size)
        slot = np.where(ok, self._slot_of[np.minimum(g, self._slot_of.size - 1)],
                        -1)
        ok &= slot >= 0
        ok[ok] = self.v_visible[slot[ok]]
        return ok

    def edge_ranges(self, gids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """CSR [lo, hi) into esrc/edst per frontier gid."""
        g = np.asarray(gids, np.int64)
        return (np.searchsorted(self.esrc, g, side="left"),
                np.searchsorted(self.esrc, g, side="right"))

    def gather_edges(self, gids: np.ndarray):
        """Ragged expansion: all CSR edge positions of ``gids``, plus the
        index of the source frontier entry per position."""
        lo, hi = self.edge_ranges(gids)
        ln = hi - lo
        total = int(ln.sum())
        if total == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64), ln
        off = np.repeat(np.cumsum(ln) - ln, ln)
        pos = np.arange(total, dtype=np.int64) - off + np.repeat(lo, ln)
        src_idx = np.repeat(np.arange(g_len(gids), dtype=np.int64), ln)
        return pos, src_idx, ln

    def out_degree(self, gids: np.ndarray) -> np.ndarray:
        lo, hi = self.edge_ranges(gids)
        return hi - lo

    # ------------------------------------------------------------ properties
    def _prop_arrays(self, table: str, key: str):
        """(val_id, num) of the latest visible version per OWNER SLOT."""
        ck = (table, key)
        hit = self._prop_cache.get(ck)
        if hit is not None:
            return hit
        cols = self.cols
        pt = cols.v_props if table == "v" else cols.e_props
        n_owner = cols.n_v if table == "v" else cols.n_e
        ids = np.full(n_owner, -1, np.int64)
        num = np.full(n_owner, np.nan)
        kid = cols.keys.lookup(key)
        if kid >= 0 and pt.n:
            krows = np.nonzero(pt.key.view() == kid)[0]
            if krows.size:
                vis = self._vis_half(pt.stamp.view()[krows],
                                     [pt.stamp_obj[int(i)] for i in krows])
                rows = krows[vis]
                owners = pt.owner.view()[rows].astype(np.int64)
                # ascending row order == version order: last write wins
                ids[owners] = pt.val.view()[rows]
                num[owners] = pt.num.view()[rows]
        self._prop_cache[ck] = (ids, num)
        return ids, num

    def edge_prop(self, key: str):
        """(val_id, num) per CSR edge position (-1 / NaN = absent)."""
        ids, num = self._prop_arrays("e", key)
        return ids[self.eslot], num[self.eslot]

    def vertex_prop_of(self, gids: np.ndarray, key: str):
        """(val_id, num) per gid; caller guarantees visibility."""
        ids, num = self._prop_arrays("v", key)
        slot = self._slot_of[np.asarray(gids, np.int64)]
        return ids[slot], num[slot]

    def value_id(self, value) -> int:
        """This partition's intern id for a filter constant (-1 = never
        stored here, matches nothing)."""
        return self.cols.vals.lookup(value)

    def value_of(self, val_id: int):
        return self.cols.vals.vals[val_id] if val_id >= 0 else None


def g_len(a: np.ndarray) -> int:
    return int(np.asarray(a).size)


class BatchContext:
    """What a ``frontier_step`` sees: the plan, vid resolution, output
    and emit sinks, and service-time accounting mirroring the scalar
    cost model (prog_vertex / prog_revisit / prog_edge)."""

    def __init__(self, plan: ShardPlan, intern, cost):
        self.plan = plan
        self.intern = intern
        self.cost = cost
        self.outputs: List[object] = []
        self.emit_gids: List[np.ndarray] = []
        self.emit_vals: List[Optional[np.ndarray]] = []
        self.next_meta: Optional[dict] = None
        self.service = 0.0

    def vid(self, gid: int) -> str:
        return self.intern.vids[gid]

    def vids_of(self, gids: np.ndarray) -> List[str]:
        vs = self.intern.vids
        return [vs[g] for g in np.asarray(gids).tolist()]

    def output(self, value) -> None:
        self.outputs.append(value)

    def emit(self, gids: np.ndarray, vals: Optional[np.ndarray] = None,
             meta: Optional[dict] = None) -> None:
        self.emit_gids.append(np.asarray(gids, np.int64))
        self.emit_vals.append(None if vals is None
                              else np.asarray(vals, np.float64))
        if meta is not None:
            self.next_meta = meta

    def charge(self, n_visit: int = 0, n_revisit: int = 0,
               n_edges: int = 0) -> None:
        self.service += (self.cost.prog_vertex * n_visit
                         + self.cost.prog_revisit * n_revisit
                         + self.cost.prog_edge * n_edges)


def execute_step(plan: ShardPlan, prog, frontier: Frontier, state: dict,
                 intern, cost) -> Tuple[List[object], Optional[Frontier],
                                        float]:
    """Run one batched hop.  Returns (outputs, next_frontier, service)."""
    ctx = BatchContext(plan, intern, cost)
    prog.frontier_step(plan, frontier, state, ctx)
    nxt = None
    if ctx.emit_gids:
        gids = np.concatenate(ctx.emit_gids)
        if gids.size:
            if any(v is not None for v in ctx.emit_vals):
                vals = np.concatenate([
                    v if v is not None else np.zeros(g.size)
                    for g, v in zip(ctx.emit_gids, ctx.emit_vals)])
            else:
                vals = None
            nxt = Frontier(gids=gids, vals=vals, depth=frontier.depth + 1,
                           meta=(ctx.next_meta if ctx.next_meta is not None
                                 else frontier.meta))
    return ctx.outputs, nxt, ctx.service


def ensure_state(state: dict, name: str, n: int, fill, dtype) -> np.ndarray:
    """Grow-on-demand per-program state array indexed by gid."""
    arr = state.get(name)
    if arr is None or arr.size < n:
        nu = np.full(max(n, 64, 0 if arr is None else arr.size * 2),
                     fill, dtype)
        if arr is not None:
            nu[:arr.size] = arr
        state[name] = arr = nu
    return arr


# ---------------------------------------------------------------------------
# Synchronous driver (tests / wall-clock benchmarks): executes a whole
# program hop-by-hop against the shard partitions directly, without the
# simulator.  ``use_frontier=False`` drives the scalar per-vertex path
# over the same stamps — the equivalence oracle.
# ---------------------------------------------------------------------------

def run_local(weaver, name: str, entries, at: Stamp,
              use_frontier: bool = True,
              shard_of: Optional[Callable[[str], Optional[int]]] = None,
              refine_oracle: bool = True):
    """Execute program ``name`` at stamp ``at`` synchronously.

    Returns ``(result, stats)`` where stats counts hops, messages and
    delivered entries — the benchmark's message-reduction evidence.
    """
    from .nodeprog import REGISTRY, run_entries_scalar
    from .oracle import KIND_PROG, KIND_TX

    prog = REGISTRY[name]
    shards = weaver.shards
    place = shard_of or (lambda vid: weaver.store.place(vid))
    intern = weaver.intern
    cache: Dict[tuple, bool] = {}

    def refine_pair(a: Stamp, b: Stamp) -> Order:
        if b.key() == at.key():     # object stamp vs program stamp
            got = refine_many([a])
            return Order.BEFORE if got[a.key()] else Order.AFTER
        if not refine_oracle:       # conservative default: a after b
            return Order.AFTER
        # version-vs-version (prop_at ordering): pairwise refinement
        chain = weaver.oracle.oracle.order_events([a, b],
                                                  [KIND_TX, KIND_TX])
        weaver.sim.counters.oracle_calls += 1
        return Order.BEFORE if chain[0] == a.key() else Order.AFTER

    def refine_many(stamps: List[Stamp]) -> Dict[tuple, bool]:
        missing = [s for s in stamps if s.key() not in cache]
        if missing:
            if refine_oracle:
                oracle = weaver.oracle.oracle
                chain = oracle.order_events(
                    missing + [at], [KIND_TX] * len(missing) + [KIND_PROG])
                weaver.sim.counters.oracle_calls += 1
                pos = {k: i for i, k in enumerate(chain)}
                for s in missing:
                    cache[s.key()] = pos[s.key()] < pos[at.key()]
            else:
                for s in missing:
                    cache[s.key()] = False     # conservative: write after
        return {s.key(): cache[s.key()] for s in stamps}

    stats = {"hops": 0, "messages": 0, "entries": 0, "batches": 0}
    outputs: List[object] = []

    batched = (use_frontier and prog.frontier_step is not None
               and prog.pack_root is not None)
    if batched:
        # all root entries must share one params dict (else scalar path)
        froot = prog.pack_root(entries, intern)
        batched = froot is not None

    if batched:
        plans: Dict[int, ShardPlan] = {}
        states: Dict[int, dict] = {}
        # route roots
        pending: Dict[int, Frontier] = {}
        for sid, gs in _route_gids(froot.gids, froot.vals, intern,
                                   place).items():
            pending[sid] = Frontier(gs[0], gs[1], froot.depth, froot.meta)
        while pending:
            stats["hops"] += 1
            nxt: Dict[int, List[Frontier]] = {}
            for sid, fr in pending.items():
                stats["messages"] += 1
                stats["batches"] += 1
                stats["entries"] += len(fr)
                sh = shards[sid]
                cols = sh.partition.columns
                plan = plans.get(sid)
                if plan is None or plan.version != cols.version:
                    plans[sid] = plan = ShardPlan(
                        cols, at, sh.n_gk,
                        refine_batch=refine_many if refine_oracle else None)
                outs, out_fr, _ = execute_step(
                    plan, prog, fr, states.setdefault(sid, {}),
                    intern, sh.cost)
                outputs.extend(outs)
                if out_fr is not None:
                    for nsid, gs in _route_gids(out_fr.gids, out_fr.vals,
                                                intern, place).items():
                        nxt.setdefault(nsid, []).append(
                            Frontier(gs[0], gs[1], out_fr.depth,
                                     out_fr.meta))
            pending = {sid: _merge_frontiers(frs)
                       for sid, frs in nxt.items()}
    else:
        states = {}
        pending_s: Dict[int, list] = {}
        for vid, params in entries:
            sid = place(vid)
            if sid is not None:
                pending_s.setdefault(sid, []).append((vid, params))
        while pending_s:
            stats["hops"] += 1
            nxt_s: Dict[int, list] = {}
            for sid, ent in pending_s.items():
                stats["messages"] += 1
                stats["entries"] += len(ent)
                sh = shards[sid]
                emits, outs, _ = run_entries_scalar(
                    sh.partition, prog, ent, at, refine_pair,
                    states.setdefault(sid, {}), sh.cost)
                outputs.extend(outs)
                for vid, params in emits:
                    nsid = place(vid)
                    if nsid is not None:
                        nxt_s.setdefault(nsid, []).append((vid, params))
            pending_s = nxt_s

    return prog.reduce(outputs), stats


def _route_gids(gids: np.ndarray, vals: Optional[np.ndarray], intern, place):
    """Split a global frontier by destination shard (vectorized groupby
    over a lazily-extended gid -> shard map)."""
    out: Dict[int, tuple] = {}
    if gids.size == 0:
        return out
    vids = intern.vids
    lst = []
    for g in gids.tolist():
        s = place(vids[g]) if g < len(vids) else None
        lst.append(-1 if s is None else s)
    sids = np.asarray(lst, np.int64)
    order = np.argsort(sids, kind="stable")
    sg = sids[order]
    starts = np.flatnonzero(np.r_[True, sg[1:] != sg[:-1]])
    bounds = np.r_[starts, sg.size]
    for i, st in enumerate(starts.tolist()):
        sid = int(sg[st])
        if sid < 0:
            continue
        sel = order[st:bounds[i + 1]]
        out[sid] = (gids[sel], None if vals is None else vals[sel])
    return out


def _merge_frontiers(frs: List[Frontier]) -> Frontier:
    if len(frs) == 1:
        return frs[0]
    gids = np.concatenate([f.gids for f in frs])
    vals = (np.concatenate([f.vals for f in frs])
            if frs[0].vals is not None else None)
    return Frontier(gids, vals, frs[0].depth, frs[0].meta)
