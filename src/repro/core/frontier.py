"""Frontier-batched node-program runtime on the columnar data plane.

The per-vertex path (``nodeprog.run_entries_scalar``) interprets one
Python callback per delivered vertex against the multi-version dicts.
This module executes a whole per-shard *frontier* in one vectorized step
against the stamped columns the partition already maintains
(:class:`~repro.core.mvgraph.PartitionColumns`):

* :class:`ShardPlan` — a per-shard sorted-CSR snapshot *slice* at the
  program stamp ``T_prog``: one batched visibility pass over the packed
  stamp matrices (the same `mv_visibility` contract the global snapshot
  engine uses, truly-concurrent stamps refined through the shard's
  timeline-oracle cache in ONE request), the visible out-edges sorted by
  ``(src gid, dst gid)``, and lazily-materialized latest-visible
  property columns per key (edge filters, weights, vertex values).
  Plans survive write traffic: instead of rebuilding whenever
  ``PartitionColumns.version`` bumps, :meth:`ShardPlan.refresh`
  delta-consumes the partition's patch logs and
  :class:`~repro.core.mvgraph.CompactionEvent` remaps — the same
  O(changed) contract the global ``SnapshotEngine`` has — re-evaluating
  only changed and unsettled stamps (at most one incremental oracle
  round trip) and splicing the sorted-CSR slice in place.  A cold
  rebuild happens only on first contact, when the compaction-event
  history no longer covers the plan's cursor, or when the new query
  stamp does not dominate the plan stamp.
* :class:`Frontier` — the packed exchange unit: a gid array plus
  optional per-entry float (``vals``, e.g. sssp distances) and int
  (``tags``) payload columns, an optional :class:`Ragged` side table
  (per-entry variable-length payloads, e.g. the clustering protocol's
  packed neighbour lists), and a shared ``meta`` dict.  Shards exchange
  ONE such message per destination shard per hop instead of one
  ``(dst, params)`` tuple per emitted vertex.
* :class:`Ragged` / :class:`RaggedReply` — segment-offset ragged
  columns for neighbourhood-returning queries: ``Ragged`` rides inside
  a frontier (wire side), ``RaggedReply`` is the *output* payload kind
  (``get_edges`` returns every delivered entry's full edge list —
  eids, endpoints, optional property columns — from one batched gather
  over the plan's sorted-CSR slice).
* :func:`execute_step` — runs a program's registered ``frontier_step``
  (see ``nodeprog.frontier_impl``) over one plan + frontier, returning
  the batch outputs, the global next frontier and the charged service
  time.  Per-destination neighbour aggregation goes through
  ``repro.kernels.segment_mp.ops.segment_reduce_sorted`` — the
  CSR-sorted plan makes the sorted-segment contract free.

The plan/fallback contract: a program participates iff it registered a
``frontier_step`` AND ``frontier_ok(params)`` accepts the root
parameters (e.g. an unhashable edge-filter constant forces the scalar
path).  The decision is a pure function of ``(name, root params)``, so
every shard of one query independently agrees; follow-up hops carry
:class:`Frontier` objects, which imply the batched path.  Results are
identical to the scalar path at the same stamp (randomized equivalence
is enforced by ``tests/test_frontier_prog.py``); the only caveat is
``sssp`` under a *binding* ``max_depth``, where the scalar path itself
is delivery-order dependent.

:func:`run_local` drives a whole program synchronously outside the
simulator (equivalence tests, wall-clock benchmarks).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from . import clock
from .clock import NO_STAMP, Order, Stamp, compare


@dataclass
class Ragged:
    """Segment-offset ragged columns: R rows of variable length packed as
    CSR-style ``(offsets, values)``.

    The exchange unit for *per-entry variable-length* payloads (the
    structural gap between OLTP-style point replies and analytics-style
    neighbourhood-returning queries): ``offsets`` has shape ``(R+1,)``
    and row ``i`` is ``values[offsets[i]:offsets[i+1]]``.  ``keys`` is an
    optional per-ROW int64 column (e.g. the origin gid of each packed
    neighbour list) and ``extra`` holds named per-POSITION int64 columns
    aligned with ``values``.  A :class:`Frontier` carrying a ``Ragged``
    uses its ``tags`` as row indices into it (see ``Frontier``)."""

    offsets: np.ndarray                    # (R+1,) int64 row bounds
    values: np.ndarray                     # (T,) int64, T = offsets[-1]
    keys: Optional[np.ndarray] = None      # (R,) int64 per-row key
    extra: Dict[str, np.ndarray] = field(default_factory=dict)

    def __len__(self) -> int:              # number of rows
        return int(self.offsets.size - 1)

    def lens(self) -> np.ndarray:
        return self.offsets[1:] - self.offsets[:-1]

    def nbytes(self) -> int:
        n = 8 * (self.offsets.size + self.values.size)
        if self.keys is not None:
            n += 8 * self.keys.size
        for col in self.extra.values():
            n += 8 * col.size
        return n

    def take(self, rows: np.ndarray) -> "Ragged":
        """Row subset (new compact numbering) — used to pack ONE message
        per destination shard with only the rows its entries reference."""
        rows = np.asarray(rows, np.int64)
        ln = self.lens()[rows]
        off = np.concatenate([np.zeros(1, np.int64), np.cumsum(ln)])
        total = int(off[-1])
        if total:
            pos = (np.arange(total, dtype=np.int64)
                   - np.repeat(off[:-1], ln) + np.repeat(self.offsets[rows], ln))
        else:
            pos = np.zeros(0, np.int64)
        return Ragged(
            offsets=off, values=self.values[pos],
            keys=None if self.keys is None else self.keys[rows],
            extra={k: v[pos] for k, v in self.extra.items()})

    @staticmethod
    def concat(parts: List["Ragged"]) -> "Ragged":
        """Row-wise concatenation (coalesced deliveries); a consumer's
        ``tags`` into part ``i`` must be rebased by the row offset
        ``sum(len(parts[:i]))`` — :func:`_merge_frontiers` does."""
        if len(parts) == 1:
            return parts[0]
        totals = [int(p.offsets[-1]) for p in parts]
        starts = [0] + list(np.cumsum(totals[:-1]))
        offsets = np.concatenate(
            [p.offsets[:-1] + s for p, s in zip(parts, starts)]
            + [np.asarray([sum(totals)], np.int64)]).astype(np.int64)
        keys = (None if parts[0].keys is None
                else np.concatenate([p.keys for p in parts]))
        extra = {k: np.concatenate([p.extra[k] for p in parts])
                 for k in parts[0].extra}
        return Ragged(offsets=offsets,
                      values=np.concatenate([p.values for p in parts]),
                      keys=keys, extra=extra)


def ragged_offsets(lens: np.ndarray) -> np.ndarray:
    """(R+1,) segment offsets from per-row lengths."""
    return np.concatenate([np.zeros(1, np.int64),
                           np.cumsum(np.asarray(lens, np.int64))])


def blank_ragged_rows(rg: Ragged, mask: np.ndarray) -> Ragged:
    """Ship rows the receiver already caches as zero-length MARKERS:
    keys stay (the receiver reconstitutes by key), values and aligned
    ``extra`` positions drop — the wire dedup for per-row side tables
    (clustering phase 1 ships each origin's packed neighbour list once
    per destination shard).  ``mask`` is a per-row boolean: True rows
    are blanked."""
    keep = ~np.asarray(mask, bool)
    ln = rg.lens() * keep
    off = ragged_offsets(ln)
    total = int(off[-1])
    if total:
        pos = (np.arange(total, dtype=np.int64)
               - np.repeat(off[:-1], ln) + np.repeat(rg.offsets[:-1], ln))
    else:
        pos = np.zeros(0, np.int64)
    return Ragged(offsets=off, values=rg.values[pos], keys=rg.keys,
                  extra={k: v[pos] for k, v in rg.extra.items()})


def fill_ragged_rows(rg: Ragged, lookup: dict) -> Tuple[Ragged, int]:
    """Receiver-side inverse of :func:`blank_ragged_rows`: zero-length
    rows whose key is in ``lookup`` (key -> ``(values, extra_dict)``)
    get their packed payload re-inserted.  Returns the filled ragged
    and the number of reconstituted rows (0 leaves ``rg`` untouched —
    legitimately empty keyed rows without a cache entry pass through)."""
    if rg.keys is None or len(rg) == 0:
        return rg, 0
    ln = rg.lens()
    fills = {}
    for i in np.nonzero(ln == 0)[0].tolist():
        hit = lookup.get(int(rg.keys[i]))
        if hit is not None:
            fills[i] = hit
    if not fills:
        return rg, 0
    vals: List[np.ndarray] = []
    extras: Dict[str, List[np.ndarray]] = {k: [] for k in rg.extra}
    new_ln = ln.copy()
    for i in range(len(rg)):
        hit = fills.get(i)
        if hit is not None:
            v, ex = hit
            vals.append(v)
            new_ln[i] = v.size
            for k in extras:
                extras[k].append(ex[k])
        else:
            sl = slice(int(rg.offsets[i]), int(rg.offsets[i + 1]))
            vals.append(rg.values[sl])
            for k in extras:
                extras[k].append(rg.extra[k][sl])
    filled = Ragged(
        offsets=ragged_offsets(new_ln),
        values=(np.concatenate(vals).astype(np.int64) if vals
                else np.zeros(0, np.int64)),
        keys=rg.keys,
        extra={k: (np.concatenate(v).astype(np.int64) if v
                   else np.zeros(0, np.int64))
               for k, v in extras.items()})
    return filled, len(fills)


class RaggedReply:
    """Ragged per-entry program OUTPUT: every delivered entry's full edge
    list (ids + endpoints + optional property columns) from ONE batched
    gather over the shard plan's sorted-CSR slice.

    This is the reply-side payload *kind* (``kind == "ragged"``): the
    scalar path ships one Python list per visited entry, the batched path
    ships one of these per ``frontier_step`` — the coordinator (or
    ``reduce``) decodes rows lazily via :meth:`lists`.  Gid→vid decoding
    goes through the deployment-wide :class:`~repro.core.mvgraph.
    VidIntern` (shared by construction, so the reference costs nothing on
    the simulated wire); ``nbytes`` models the packed columns."""

    kind = "ragged"

    __slots__ = ("intern", "roots", "offsets", "eids", "dsts", "props",
                 "vals")

    def __init__(self, intern, roots: np.ndarray, offsets: np.ndarray,
                 eids: np.ndarray, dsts: np.ndarray,
                 props: Optional[Dict[str, list]] = None,
                 vals=None):
        self.intern = intern
        self.roots = roots                 # (R,) int64 root gids
        self.offsets = offsets             # (R+1,) int64
        self.eids = eids                   # (T,) edge ids
        self.dsts = dsts                   # (T,) int64 dst gids
        self.props = props                 # key -> (T,)-aligned value list,
        #                                    OR (T,) int64 value-id columns
        #                                    when ``vals`` is set
        # deployment-wide PropIntern value table (shared by construction,
        # like ``intern``): when present, property columns stay packed
        # value IDS end to end and rows decode lazily in lists()
        self.vals = vals

    def __len__(self) -> int:
        return int(self.roots.size)

    def total(self) -> int:
        return int(self.eids.size)

    def nbytes(self) -> int:
        n = 64 + 8 * (self.roots.size + self.offsets.size
                      + self.eids.size + self.dsts.size)
        if self.props:
            n += 8 * self.total() * len(self.props)
        return n

    def lists(self) -> List[list]:
        """Decode to the scalar path's per-entry form: one
        ``[(eid, dst_vid), ...]`` list per root (plus a per-edge property
        dict when property columns were requested)."""
        vids = self.intern.vids
        eids = self.eids.tolist()
        dsts = self.dsts.tolist()
        props = self.props
        if props is not None and self.vals is not None:
            table = self.vals.vals
            props = {k: [table[i] if i >= 0 else None
                         for i in np.asarray(col).tolist()]
                     for k, col in props.items()}
        out: List[list] = []
        for i in range(len(self)):
            lo, hi = int(self.offsets[i]), int(self.offsets[i + 1])
            if props is None:
                out.append([(eids[p], vids[dsts[p]]) for p in range(lo, hi)])
            else:
                out.append([(eids[p], vids[dsts[p]],
                             {k: col[p] for k, col in props.items()})
                            for p in range(lo, hi)])
        return out


def reply_nbytes(outputs: List[object]) -> int:
    """Simulated wire size of a report's output payload: ragged replies
    model their packed columns, everything else the legacy 32B/output."""
    n = 0
    for o in outputs:
        n += o.nbytes() if isinstance(o, RaggedReply) else 32
    return n


@dataclass
class Frontier:
    """Packed per-hop delivery: one message per destination shard.

    ``tags`` is an optional per-entry int64 column; when ``ragged`` is
    present, tags are ROW INDICES into it (the clustering protocol ships
    each origin's packed neighbour list once per destination shard and
    tags every (neighbour, origin) entry with its origin's row), and
    routing/coalescing re-base them when rows are subset or
    concatenated.  Without ``ragged``, tags are a plain integer payload
    (e.g. per-origin reply counts on the wedge-closing return hop)."""

    gids: np.ndarray                       # (F,) int64 vertex intern ids
    vals: Optional[np.ndarray] = None      # (F,) float64 payload (sssp dist)
    depth: int = 0                         # hop depth (shared)
    meta: dict = field(default_factory=dict)   # shared params
    tags: Optional[np.ndarray] = None      # (F,) int64 payload / ragged rows
    ragged: Optional[Ragged] = None        # shared ragged side table

    def __len__(self) -> int:
        return int(self.gids.size)

    def nbytes(self) -> int:
        """Simulated wire size: packed arrays, not per-entry tuples."""
        n = 64 + 8 * self.gids.size
        if self.vals is not None:
            n += 8 * self.vals.size
        if self.tags is not None:
            n += 8 * self.tags.size
        if self.ragged is not None:
            n += self.ragged.nbytes()
        return n


def _before_rows(rows: np.ndarray, q: np.ndarray) -> np.ndarray:
    """rows ≺ q with the kernel/numpy auto-switch of the snapshot engine."""
    from . import analytics
    return np.array(analytics._before_batch(rows, q))


def _edge_key(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """Packed (src << 32 | dst) sort keys — the engine's convention."""
    from . import analytics
    return analytics._sort_key(src, dst)


def _remap_ids(smap: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Translate a sorted-unique slot-id set through a compaction map,
    dropping dead slots."""
    from . import analytics
    r = analytics.remap_slots(smap, ids)
    return np.unique(r[r >= 0])


def _merge_unsettled(old: np.ndarray, ids: np.ndarray,
                     mask: np.ndarray) -> np.ndarray:
    """Replace the membership of ``ids`` in the sorted-unique unsettled
    set ``old`` according to ``mask``."""
    return np.union1d(np.setdiff1d(old, ids, assume_unique=True), ids[mask])


class ShardPlan:
    """Sorted-CSR snapshot slice of ONE partition at one stamp.

    ``refine_batch(stamps) -> {stamp.key(): bool}`` resolves stamps that
    are truly concurrent with ``at`` (True = before the program); all
    concurrent stamps of a build — create/delete AND property versions —
    are queued and resolved in ONE such call, so a plan build (or delta
    refresh) costs at most one oracle round trip.

    Delta refresh contract
    ----------------------
    A plan records consume cursors into its partition's change feed
    (``PartitionColumns.cursor()`` + the per-``_PropTable`` cursors).
    :meth:`refresh` brings the plan up to date at O(changed) stamp work
    instead of an O(V+E) rebuild:

    * in-place stamp patches (delete / GC purge / re-create) are read
      from the ``v_patch`` / ``e_patch`` / prop ``patch`` tails;
    * appended rows extend the visibility arrays and the gid→slot map;
    * :class:`~repro.core.mvgraph.CompactionEvent` entries remap every
      cached slot pointer (CSR ``eslot``, unsettled sets, visibility
      arrays) to the post-compaction numbering, recovering the unread
      pre-compaction patch tails; a consumer whose cursor lags the
      bounded event history returns False — the caller MUST then build a
      fresh plan (a stale *settled* plan is no longer trustworthy: the
      missed patches may have changed visibility at every stamp);
    * the stamp may advance (``plan.at ≼ at``): previously *unsettled*
      rows (present stamps not strictly vector-before the old stamp) are
      re-evaluated at the new stamp, everything settled is reused as-is;
    * changed rows' keep-decisions are spliced into the sorted CSR slice
      by ``np.delete`` / ``np.insert`` (O(changed) decisions + one
      memcpy), and cached property views are patched per affected owner.

    ``settled`` means NO unsettled rows remain anywhere (vertex, edge or
    property stamps) — visibility is then identical at every later
    stamp, and the shard may serve later query stamps from this plan
    without refreshing (point-read hot path).
    """

    def __init__(self, cols, at: Stamp, n_gk: int,
                 refine_batch: Optional[Callable] = None,
                 device_plane=None):
        self.at = at
        self.version = cols.version
        self.cols = cols
        self.n_gk = n_gk
        self.q = clock.pack(at, n_gk)
        self._refine_batch = refine_batch
        self._plane = device_plane
        self._prop_cache: Dict[Tuple[str, str], tuple] = {}
        #: rows evaluated by this build (simulated-cost accounting)
        self.built_rows = (cols.n_v + cols.n_e
                           + cols.v_props.n + cols.e_props.n)
        #: rows re-evaluated by the latest :meth:`refresh`
        self.last_refresh_rows = 0
        # change-feed consume cursors (see class docstring)
        self._consumed = cols.cursor()
        self._p_consumed = {"v": cols.v_props.cursor(),
                            "e": cols.e_props.cursor()}

        nv, ne = cols.n_v, cols.n_e
        pend: List[tuple] = []
        # device-sharded path: the create/delete stamp masks come from
        # the resident blocks (one shard_map launch, shared across every
        # consumer of the same query stamp); property stamps are not on
        # the plane and evaluate host-side either way
        mk = None
        if device_plane is not None:
            device_plane.sync([cols])
            device_plane.before_all(self.q)
            mk = device_plane.masks_for(cols)
        vc, vd = cols.v_create.view(), cols.v_delete.view()
        cb = self._eval(vc, cols.v_create_stamp, pend,
                        pre=None if mk is None else mk[0])
        db = self._eval(vd, cols.v_delete_stamp, pend,
                        pre=None if mk is None else mk[1])
        ec, ed = cols.e_create.view(), cols.e_delete.view()
        ecb = self._eval(ec, cols.e_create_stamp, pend,
                         pre=None if mk is None else mk[2])
        edb = self._eval(ed, cols.e_delete_stamp, pend,
                         pre=None if mk is None else mk[3])
        # property stamps are evaluated eagerly (one bool per version
        # row) — the per-key views are derived lazily from these masks
        # with no further oracle traffic
        self._p_before = {
            t: self._eval(pt.stamp.view(), pt.stamp_obj, pend)
            for t, pt in (("v", cols.v_props), ("e", cols.e_props))}
        self._resolve(pend)

        self.v_visible = (cb & ~db) if nv else np.zeros(0, bool)
        self.e_vis = (ecb & ~edb) if ne else np.zeros(0, bool)

        # gid -> vertex slot (dense over the intern table seen so far)
        gids = cols.v_gid.view()
        self._slot_of = np.full(int(gids.max()) + 1 if nv else 1, -1,
                                np.int64)
        self._slot_of[gids] = np.arange(nv, dtype=np.int64)

        # visible out-edges of visible sources, sorted by (src, dst) gid
        if ne:
            src = cols.e_src.view().astype(np.int64)
            sslot = np.where(src < self._slot_of.size,
                             self._slot_of[np.minimum(src,
                                                      self._slot_of.size - 1)],
                             -1)
            keep = self.e_vis & (sslot >= 0)
            keep[keep] &= self.v_visible[sslot[keep]]
            self.e_keep = keep
            rows = np.nonzero(keep)[0]
            dst = cols.e_dst.view().astype(np.int64)[rows]
            key = _edge_key(src[rows], dst)
            order = np.argsort(key, kind="stable")
            self._ekey = key[order]
            self.esrc = src[rows][order]
            self.edst = dst[order]
            self.eslot = rows[order]          # edge slot per CSR position
        else:
            self.e_keep = np.zeros(0, bool)
            self._ekey = np.zeros(0, np.int64)
            self.esrc = np.zeros(0, np.int64)
            self.edst = np.zeros(0, np.int64)
            self.eslot = np.zeros(0, np.int64)

        self._uadj: Optional[tuple] = None   # lazy dedup'd adjacency

        # rows whose visibility can still change as the stamp advances
        self.v_unsettled = np.nonzero(self._unsett(vc, vd, cb, db))[0]
        self.e_unsettled = np.nonzero(self._unsett(ec, ed, ecb, edb))[0]
        self.p_unsettled = {}
        for t, pt in (("v", cols.v_props), ("e", cols.e_props)):
            rows = pt.stamp.view()
            pres = rows[:, 0] != NO_STAMP if pt.n else np.zeros(0, bool)
            self.p_unsettled[t] = np.nonzero(pres & ~self._p_before[t])[0]
        self._recheck_settled()

    # ------------------------------------------------------------ visibility
    @staticmethod
    def _unsett(create_rows, delete_rows, cb, db) -> np.ndarray:
        if create_rows.shape[0] == 0:
            return np.zeros(0, bool)
        return (((create_rows[:, 0] != NO_STAMP) & ~cb)
                | ((delete_rows[:, 0] != NO_STAMP) & ~db))

    def _recheck_settled(self) -> None:
        self.settled = not (self.v_unsettled.size or self.e_unsettled.size
                            or self.p_unsettled["v"].size
                            or self.p_unsettled["e"].size)

    def _eval(self, rows: np.ndarray, stamp_of, pend: List[tuple],
              ids: Optional[np.ndarray] = None,
              pre: Optional[np.ndarray] = None) -> np.ndarray:
        """rows ≺ q, queueing truly-concurrent stamps on ``pend`` for the
        single batched resolution.  ``ids`` maps local row positions back
        to table slots when ``rows`` is a gathered subset.  ``pre`` is a
        precomputed ≺-mask (the device plane's sharded launch) — bit-
        identical to the host evaluation, so only the concurrent-residue
        queueing runs here."""
        if rows.shape[0] == 0:
            return np.zeros(0, bool)
        out = (np.array(pre, dtype=bool) if pre is not None
               else _before_rows(rows, self.q))
        if self._refine_batch is not None:
            for li in np.nonzero(
                    clock.concurrent_mask_np(rows, self.q))[0].tolist():
                s = stamp_of[li if ids is None else int(ids[li])]
                if s is not None and compare(s, self.at) is Order.CONCURRENT:
                    pend.append((out, li, s))
        return out

    def _resolve(self, pend: List[tuple]) -> None:
        """ONE oracle round trip for every queued concurrent stamp."""
        if not pend:
            return
        got = self._refine_batch([s for _, _, s in pend])
        for arr, i, s in pend:
            arr[i] = bool(got[s.key()])

    # --------------------------------------------------------- delta refresh
    def _consume_compactions(self, ch_v: List[np.ndarray],
                             ch_e: List[np.ndarray],
                             ch_p: Dict[str, List[np.ndarray]]
                             ) -> Tuple[int, int, Dict[str, int]]:
        """Catch up with column compactions (cursor known to be covered
        by the event history).  Remaps every cached slot pointer —
        including the property-row masks, via the event's
        ``vp_map``/``ep_map`` — to the new numbering and recovers the
        unread pre-compaction patch tails into ``ch_v`` / ``ch_e`` /
        ``ch_p``.  Returns the consume cursors in post-compaction
        numbering (the dict maps ``"v"``/``"e"`` to the consumed
        property-row count)."""
        from . import analytics
        cols = self.cols
        nv0, ne0, lv0, le0, ev0 = self._consumed
        p_cur = {t: list(self._p_consumed[t]) for t in ("v", "e")}
        for ev in cols.events[ev0 - cols.events_dropped:]:
            ch_v.append(analytics.patch_tail(ev.old_v_patch, lv0, nv0))
            ch_e.append(analytics.patch_tail(ev.old_e_patch, le0, ne0))
            lv0 = le0 = 0
            v_kept = ev.v_map[:nv0] >= 0
            e_kept = ev.e_map[:ne0] >= 0
            self.v_visible = self.v_visible[v_kept]
            self.e_vis = self.e_vis[e_kept]
            self.e_keep = self.e_keep[e_kept]
            # property rows: same remap contract as the owner tables —
            # O(changed) across the compaction, no full re-read
            for t, pm, pp in (("v", ev.vp_map, ev.old_vp_patch),
                              ("e", ev.ep_map, ev.old_ep_patch)):
                pn0, pl0 = p_cur[t]
                ch_p[t].append(analytics.patch_tail(pp, pl0, pn0))
                p_kept = pm[:pn0] >= 0
                self._p_before[t] = self._p_before[t][p_kept]
                self.p_unsettled[t] = _remap_ids(pm, self.p_unsettled[t])
                for i in range(len(ch_p[t])):
                    ch_p[t][i] = _remap_ids(pm, ch_p[t][i])
                p_cur[t] = [int(p_kept.sum()), 0]
            # CSR slice: renumber eslot, drop edges the compaction killed
            new_slot = analytics.remap_slots(ev.e_map, self.eslot)
            dead = np.nonzero(new_slot < 0)[0]
            if dead.size:
                self._ekey = np.delete(self._ekey, dead)
                self.esrc = np.delete(self.esrc, dead)
                self.edst = np.delete(self.edst, dead)
                new_slot = np.delete(new_slot, dead)
            self.eslot = new_slot
            self.v_unsettled = _remap_ids(ev.v_map, self.v_unsettled)
            self.e_unsettled = _remap_ids(ev.e_map, self.e_unsettled)
            for lst, smap in ((ch_v, ev.v_map), (ch_e, ev.e_map)):
                for i in range(len(lst)):
                    lst[i] = _remap_ids(smap, lst[i])
            nv0 = int(v_kept.sum())
            ne0 = int(e_kept.sum())
        # vertex slots renumbered: rebuild the gid -> slot map (gids are
        # stable, so this is pure integer scatter, no stamp work)
        gids = self.cols.v_gid.view()
        top = int(gids.max()) + 1 if cols.n_v else 1
        self._slot_of = np.full(top, -1, np.int64)
        self._slot_of[gids] = np.arange(cols.n_v, dtype=np.int64)
        # owner slots renumbered, so the per-owner cached views are
        # stale; dropped and rebuilt lazily on next access (pure column
        # gather over the already-maintained _p_before masks — no stamp
        # or oracle work)
        self._prop_cache = {}
        return nv0, ne0, {t: p_cur[t][0] for t in ("v", "e")}

    def refresh(self, at: Stamp,
                refine_batch: Optional[Callable] = None) -> bool:
        """Delta-consume column changes (optionally advancing the plan
        stamp to a later ``at``).  Returns False when a cold rebuild is
        required: the compaction-event history no longer covers this
        plan's cursor, or ``at`` does not dominate the plan stamp.  On
        True, the plan is exactly equal to ``ShardPlan(cols, at, ...)``
        built fresh (same visibility, same CSR edge multiset, same
        property views), and ``last_refresh_rows`` holds the number of
        rows whose stamps were re-evaluated."""
        cols = self.cols
        o = compare(self.at, at)
        if o not in (Order.EQUAL, Order.BEFORE):
            return False
        if self._consumed[4] < cols.events_dropped:
            return False
        if refine_batch is not None:
            self._refine_batch = refine_batch
        if self._plane is not None:
            # keep the device-resident block tracking the change feed
            # (O(changed) row scatters per device); the gathered-subset
            # re-evaluation below stays host-side — the delta set is
            # tiny by contract, and the masks are bit-identical
            self._plane.sync([cols])
        stamp_moved = o is Order.BEFORE
        self.at = at
        self.q = clock.pack(at, self.n_gk)

        ch_v: List[np.ndarray] = []
        ch_e: List[np.ndarray] = []
        ch_p: Dict[str, List[np.ndarray]] = {"v": [], "e": []}
        compacted = self._consumed[4] < cols.events_dropped + len(cols.events)
        if compacted:
            nv0, ne0, p_n0 = self._consume_compactions(ch_v, ch_e, ch_p)
            lv0 = le0 = 0
        else:
            nv0, ne0, lv0, le0, _ = self._consumed
            p_n0 = None
        from . import analytics
        if len(cols.v_patch) > lv0:
            ch_v.append(analytics.patch_tail(cols.v_patch, lv0, nv0))
        if len(cols.e_patch) > le0:
            ch_e.append(analytics.patch_tail(cols.e_patch, le0, ne0))
        nv, ne = cols.n_v, cols.n_e
        if nv > nv0:
            self.v_visible = np.concatenate(
                [self.v_visible, np.zeros(nv - nv0, bool)])
            new_gids = cols.v_gid.view()[nv0:nv].astype(np.int64)
            top = int(new_gids.max()) + 1
            if top > self._slot_of.size:
                self._slot_of = np.concatenate(
                    [self._slot_of,
                     np.full(top - self._slot_of.size, -1, np.int64)])
            self._slot_of[new_gids] = np.arange(nv0, nv, dtype=np.int64)
            ch_v.append(np.arange(nv0, nv, dtype=np.int64))
        if ne > ne0:
            self.e_vis = np.concatenate(
                [self.e_vis, np.zeros(ne - ne0, bool)])
            self.e_keep = np.concatenate(
                [self.e_keep, np.zeros(ne - ne0, bool)])
            ch_e.append(np.arange(ne0, ne, dtype=np.int64))

        cat = lambda parts: (np.unique(np.concatenate(parts)) if parts
                             else np.zeros(0, np.int64))
        ids_v, ids_e = cat(ch_v), cat(ch_e)
        if stamp_moved:
            ids_v = np.union1d(ids_v, self.v_unsettled)
            ids_e = np.union1d(ids_e, self.e_unsettled)
        p_ids: Dict[str, np.ndarray] = {}
        for t, pt in (("v", cols.v_props), ("e", cols.e_props)):
            # compacted: _consume_compactions already remapped the masks
            # and recovered the unread pre-compaction tail into ch_p, so
            # the same O(changed) delta path applies either way
            n0, lp0 = (p_n0[t], 0) if compacted else self._p_consumed[t]
            chp = ch_p[t]
            if len(pt.patch) > lp0:
                chp.append(analytics.patch_tail(pt.patch, lp0, n0))
            if pt.n > n0:
                self._p_before[t] = np.concatenate(
                    [self._p_before[t], np.zeros(pt.n - n0, bool)])
                chp.append(np.arange(n0, pt.n, dtype=np.int64))
            ids = cat(chp)
            if stamp_moved:
                ids = np.union1d(ids, self.p_unsettled[t])
            p_ids[t] = ids
            self._p_consumed[t] = pt.cursor()

        # ---- evaluate every changed/unsettled row, ONE oracle pass -----
        pend: List[tuple] = []
        vc = cols.v_create.view()[ids_v]
        vd = cols.v_delete.view()[ids_v]
        cb = self._eval(vc, cols.v_create_stamp, pend, ids=ids_v)
        db = self._eval(vd, cols.v_delete_stamp, pend, ids=ids_v)
        ec = cols.e_create.view()[ids_e]
        ed = cols.e_delete.view()[ids_e]
        ecb = self._eval(ec, cols.e_create_stamp, pend, ids=ids_e)
        edb = self._eval(ed, cols.e_delete_stamp, pend, ids=ids_e)
        p_eval = {}
        for t, pt in (("v", cols.v_props), ("e", cols.e_props)):
            p_eval[t] = self._eval(pt.stamp.view()[p_ids[t]], pt.stamp_obj,
                                   pend, ids=p_ids[t])
        self._resolve(pend)

        # ---- apply: vertices ------------------------------------------
        old_v = self.v_visible[ids_v]
        new_v = cb & ~db
        self.v_visible[ids_v] = new_v
        self.v_unsettled = _merge_unsettled(
            self.v_unsettled, ids_v, self._unsett(vc, vd, cb, db))
        flipped = ids_v[new_v != old_v]

        # ---- apply: edges (keep = visible edge of visible source) -----
        self.e_vis[ids_e] = ecb & ~edb
        self.e_unsettled = _merge_unsettled(
            self.e_unsettled, ids_e, self._unsett(ec, ed, ecb, edb))
        if flipped.size:
            # one vectorized membership scan over the int32 src column —
            # O(E) memcpy-class, NOT stamp work (same pattern as
            # SnapshotEngine._refresh); runs only when a vertex flipped
            fg = cols.v_gid.view()[flipped]
            cand = np.nonzero(np.isin(cols.e_src.view(), fg))[0]
            aff = np.union1d(ids_e, cand.astype(np.int64))
        else:
            aff = ids_e
        if aff.size:
            src = cols.e_src.view()[aff].astype(np.int64)
            sslot = np.where(src < self._slot_of.size,
                             self._slot_of[np.minimum(src,
                                                      self._slot_of.size - 1)],
                             -1)
            new_keep = self.e_vis[aff] & (sslot >= 0)
            new_keep[new_keep] &= self.v_visible[sslot[new_keep]]
            old_keep = self.e_keep[aff]
            self.e_keep[aff] = new_keep
            rem = aff[old_keep & ~new_keep]
            add = aff[new_keep & ~old_keep]
            if rem.size:
                pos = np.nonzero(np.isin(self.eslot, rem))[0]
                self._ekey = np.delete(self._ekey, pos)
                self.esrc = np.delete(self.esrc, pos)
                self.edst = np.delete(self.edst, pos)
                self.eslot = np.delete(self.eslot, pos)
            if add.size:
                asrc = cols.e_src.view()[add].astype(np.int64)
                adst = cols.e_dst.view()[add].astype(np.int64)
                akey = _edge_key(asrc, adst)
                order = np.argsort(akey, kind="stable")
                akey, asrc = akey[order], asrc[order]
                adst, aslot = adst[order], add[order]
                ins = np.searchsorted(self._ekey, akey, side="right")
                self._ekey = np.insert(self._ekey, ins, akey)
                self.esrc = np.insert(self.esrc, ins, asrc)
                self.edst = np.insert(self.edst, ins, adst)
                self.eslot = np.insert(self.eslot, ins, aslot)

        # consume cursors advance BEFORE the property application: the
        # per-key views are sized by the consumed owner count, which now
        # includes this refresh's appends
        self.version = cols.version
        self._consumed = cols.cursor()

        # ---- apply: property views ------------------------------------
        n_prop = 0
        for t, pt in (("v", cols.v_props), ("e", cols.e_props)):
            ids = p_ids[t]
            n_prop += int(ids.size)
            if ids.size:
                pb = p_eval[t]
                self._p_before[t][ids] = pb
                pres = pt.stamp.view()[ids][:, 0] != NO_STAMP
                self.p_unsettled[t] = _merge_unsettled(
                    self.p_unsettled[t], ids, pres & ~pb)
            # always: cached per-key views must track owner-table growth
            # even when no property row changed
            self._refresh_prop_cache(t, pt, ids)

        self._recheck_settled()
        self._uadj = None                  # CSR slice may have changed
        self.last_refresh_rows = int(ids_v.size + ids_e.size) + n_prop
        return True

    def _refresh_prop_cache(self, t: str, pt, ids: np.ndarray) -> None:
        """Patch cached per-key property views for the owners touched by
        the changed version rows (O(affected owners), not O(key rows))."""
        cols = self.cols
        n_owner = self._consumed_owner(t)
        key_col = pt.key.view()
        owner_col = pt.owner.view()
        val_col = pt.val.view()
        num_col = pt.num.view()
        pb = self._p_before[t]
        for ck in list(self._prop_cache):
            tt, key = ck
            if tt != t:
                continue
            idarr, numarr = self._prop_cache[ck]
            if idarr.size < n_owner:
                idarr = np.concatenate(
                    [idarr, np.full(n_owner - idarr.size, -1, np.int64)])
                numarr = np.concatenate(
                    [numarr, np.full(n_owner - numarr.size, np.nan)])
                self._prop_cache[ck] = (idarr, numarr)
            kid = cols.keys.lookup(key)
            if kid < 0:
                continue
            aff = ids[key_col[ids] == kid]
            if aff.size == 0:
                continue
            for o in np.unique(owner_col[aff]).tolist():
                rows_o = np.asarray(pt.by_owner.get(int(o), ()), np.int64)
                sel = (rows_o[(key_col[rows_o] == kid) & pb[rows_o]]
                       if rows_o.size else rows_o)
                if sel.size:        # append order == version order
                    last = int(sel[-1])
                    idarr[o] = val_col[last]
                    numarr[o] = num_col[last]
                else:
                    idarr[o] = -1
                    numarr[o] = np.nan

    def _consumed_owner(self, table: str) -> int:
        return self._consumed[0] if table == "v" else self._consumed[1]

    # ------------------------------------------------------------- lookups
    def vertex_visible(self, gids: np.ndarray) -> np.ndarray:
        """(F,) bool — is each frontier gid a visible vertex here?"""
        g = np.asarray(gids, np.int64)
        ok = (g >= 0) & (g < self._slot_of.size)
        slot = np.where(ok, self._slot_of[np.minimum(g, self._slot_of.size - 1)],
                        -1)
        ok &= slot >= 0
        ok[ok] = self.v_visible[slot[ok]]
        return ok

    def edge_ranges(self, gids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """CSR [lo, hi) into esrc/edst per frontier gid."""
        g = np.asarray(gids, np.int64)
        return (np.searchsorted(self.esrc, g, side="left"),
                np.searchsorted(self.esrc, g, side="right"))

    def gather_edges(self, gids: np.ndarray):
        """Ragged expansion: all CSR edge positions of ``gids``, plus the
        index of the source frontier entry per position."""
        lo, hi = self.edge_ranges(gids)
        ln = hi - lo
        total = int(ln.sum())
        if total == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64), ln
        off = np.repeat(np.cumsum(ln) - ln, ln)
        pos = np.arange(total, dtype=np.int64) - off + np.repeat(lo, ln)
        src_idx = np.repeat(np.arange(g_len(gids), dtype=np.int64), ln)
        return pos, src_idx, ln

    def out_degree(self, gids: np.ndarray) -> np.ndarray:
        lo, hi = self.edge_ranges(gids)
        return hi - lo

    def edge_eids(self, pos: np.ndarray) -> np.ndarray:
        """Edge id per CSR position (``get_edges`` ragged replies)."""
        return self.cols.e_eid.view()[self.eslot[np.asarray(pos, np.int64)]]

    def unique_adj(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Sorted-UNIQUE adjacency over the CSR slice: ``(keys, src,
        dst)`` with ``keys = (src gid << 32) | dst gid`` ascending
        (parallel edges collapse to one neighbour — set semantics for
        wedge closing).  Cached per plan state; a delta refresh
        invalidates it.  Row slices come from ``searchsorted`` on the
        ``src`` half; the key array doubles as the membership-probe
        target of :func:`repro.core.analytics.intersect_counts`."""
        if self._uadj is None:
            ukey = np.unique(self._ekey)
            self._uadj = (ukey, ukey >> 32,
                          ukey & np.int64(0xFFFFFFFF))
        return self._uadj

    # ------------------------------------------------------------ properties
    def _prop_arrays(self, table: str, key: str):
        """(val_id, num) of the latest visible version per OWNER SLOT.

        Derived from the eagerly-maintained per-row ``_p_before`` masks
        (no oracle traffic here); delta refreshes keep cached entries
        fresh per affected owner (:meth:`_refresh_prop_cache`)."""
        ck = (table, key)
        hit = self._prop_cache.get(ck)
        if hit is not None:
            return hit
        cols = self.cols
        pt = cols.v_props if table == "v" else cols.e_props
        n_owner = self._consumed_owner(table)
        n_rows = self._p_consumed[table][0]
        ids = np.full(n_owner, -1, np.int64)
        num = np.full(n_owner, np.nan)
        kid = cols.keys.lookup(key)
        if kid >= 0 and n_rows:
            krows = np.nonzero((pt.key.view()[:n_rows] == kid)
                               & self._p_before[table][:n_rows])[0]
            if krows.size:
                owners = pt.owner.view()[krows].astype(np.int64)
                # ascending row order == version order: last write wins
                ids[owners] = pt.val.view()[krows]
                num[owners] = pt.num.view()[krows]
        self._prop_cache[ck] = (ids, num)
        return ids, num

    def edge_prop(self, key: str):
        """(val_id, num) per CSR edge position (-1 / NaN = absent)."""
        ids, num = self._prop_arrays("e", key)
        return ids[self.eslot], num[self.eslot]

    def vertex_prop_of(self, gids: np.ndarray, key: str):
        """(val_id, num) per gid; caller guarantees visibility."""
        ids, num = self._prop_arrays("v", key)
        slot = self._slot_of[np.asarray(gids, np.int64)]
        return ids[slot], num[slot]

    def value_id(self, value) -> int:
        """This partition's intern id for a filter constant (-1 = never
        stored here, matches nothing)."""
        return self.cols.vals.lookup(value)

    def value_of(self, val_id: int):
        return self.cols.vals.vals[val_id] if val_id >= 0 else None


def maintain_plan(plan: Optional[ShardPlan], cols, at: Stamp, n_gk: int,
                  refine_batch: Optional[Callable],
                  allow_delta: bool = True,
                  device_plane=None
                  ) -> Tuple[ShardPlan, str]:
    """The three-way plan maintenance policy, shared by the shard event
    loop (``Shard._frontier_plan``) and the synchronous driver
    (:func:`run_local`) so benchmarks measure exactly what the
    simulated system runs.  Returns ``(plan, kind)``:

    * ``"reuse"`` — columns unchanged AND (same stamp, or the plan is
      settled and the stamp dominates it);
    * ``"delta"`` — :meth:`ShardPlan.refresh` consumed the change feed
      (``plan.last_refresh_rows`` holds the re-evaluated row count);
    * ``"cold"``  — a fresh build (first contact, stamp regression, or
      the compaction-event history no longer covers the plan's cursor —
      the stale plan, settled or not, must be discarded).
    """
    if plan is not None and plan.cols is cols:
        same = plan.at.key() == at.key()
        later = same or compare(plan.at, at) in (Order.BEFORE, Order.EQUAL)
        if plan.version == cols.version and (
                same or (plan.settled and later)):
            return plan, "reuse"
        if later and allow_delta and plan.refresh(
                at, refine_batch=refine_batch):
            return plan, "delta"
    return ShardPlan(cols, at, n_gk, refine_batch=refine_batch,
                     device_plane=device_plane), "cold"


def g_len(a: np.ndarray) -> int:
    return int(np.asarray(a).size)


class BatchContext:
    """What a ``frontier_step`` sees: the plan, vid resolution, output
    and emit sinks, and service-time accounting mirroring the scalar
    cost model (prog_vertex / prog_revisit / prog_edge)."""

    def __init__(self, plan: ShardPlan, intern, cost):
        self.plan = plan
        self.intern = intern
        self.cost = cost
        self.outputs: List[object] = []
        self.emits: List[tuple] = []       # (gids, vals, tags, ragged)
        self.next_meta: Optional[dict] = None
        self.service = 0.0

    def vid(self, gid: int) -> str:
        return self.intern.vids[gid]

    def vids_of(self, gids: np.ndarray) -> List[str]:
        vs = self.intern.vids
        return [vs[g] for g in np.asarray(gids).tolist()]

    def output(self, value) -> None:
        self.outputs.append(value)

    def emit(self, gids: np.ndarray, vals: Optional[np.ndarray] = None,
             meta: Optional[dict] = None,
             tags: Optional[np.ndarray] = None,
             ragged: Optional[Ragged] = None) -> None:
        self.emits.append((
            np.asarray(gids, np.int64),
            None if vals is None else np.asarray(vals, np.float64),
            None if tags is None else np.asarray(tags, np.int64),
            ragged))
        if meta is not None:
            self.next_meta = meta

    def charge(self, n_visit: int = 0, n_revisit: int = 0,
               n_edges: int = 0) -> None:
        self.service += (self.cost.prog_vertex * n_visit
                         + self.cost.prog_revisit * n_revisit
                         + self.cost.prog_edge * n_edges)


def execute_step(plan: ShardPlan, prog, frontier: Frontier, state: dict,
                 intern, cost) -> Tuple[List[object], Optional[Frontier],
                                        float]:
    """Run one batched hop.  Returns (outputs, next_frontier, service)."""
    ctx = BatchContext(plan, intern, cost)
    prog.frontier_step(plan, frontier, state, ctx)
    nxt = None
    meta = ctx.next_meta if ctx.next_meta is not None else frontier.meta
    parts = [Frontier(gids=g, vals=v, depth=frontier.depth + 1, meta=meta,
                      tags=t, ragged=r)
             for g, v, t, r in ctx.emits if g.size]
    if parts:
        nxt = _merge_frontiers(parts)
    return ctx.outputs, nxt, ctx.service


def ensure_state(state: dict, name: str, n: int, fill, dtype) -> np.ndarray:
    """Grow-on-demand per-program state array indexed by gid."""
    arr = state.get(name)
    if arr is None or arr.size < n:
        nu = np.full(max(n, 64, 0 if arr is None else arr.size * 2),
                     fill, dtype)
        if arr is not None:
            nu[:arr.size] = arr
        state[name] = arr = nu
    return arr


# ---------------------------------------------------------------------------
# Synchronous driver (tests / wall-clock benchmarks): executes a whole
# program hop-by-hop against the shard partitions directly, without the
# simulator.  ``use_frontier=False`` drives the scalar per-vertex path
# over the same stamps — the equivalence oracle.
# ---------------------------------------------------------------------------

def run_local(weaver, name: str, entries, at: Stamp,
              use_frontier: bool = True,
              shard_of: Optional[Callable[[str], Optional[int]]] = None,
              refine_oracle: bool = True,
              on_hop: Optional[Callable[[int], None]] = None,
              plan_delta: bool = True,
              plans: Optional[Dict[int, "ShardPlan"]] = None,
              persistent_plans: bool = True):
    """Execute program ``name`` at stamp ``at`` synchronously.

    Returns ``(result, stats)`` where stats counts hops, messages and
    delivered entries — the benchmark's message-reduction evidence —
    plus plan-maintenance accounting: ``plan_cold`` / ``plan_delta``
    builds, ``plan_rows`` re-evaluated by delta refreshes, and
    ``plan_seconds`` of wall clock spent building/refreshing plans.

    ``on_hop(hop_index)`` fires after every hop (both paths) — tests and
    benchmarks use it to commit writes *between* hops; snapshot
    isolation at ``at`` means results must not change.  ``plan_delta=
    False`` forces a cold plan rebuild whenever a shard's columns
    changed (the benchmark's write-churn baseline).  ``plans`` is an
    optional PERSISTENT per-shard plan dict — the synchronous analogue
    of the shard event loop's stamp-keyed plan LRU: a read stream passes
    the same dict across calls so settled plans are reused (or
    delta-refreshed) instead of cold-rebuilt per query, exactly like the
    simulated system (``Shard._frontier_plan``).

    When ``plans`` is not given, a per-weaver dict is used by DEFAULT
    (``persistent_plans=True``): repeated queries against the same
    weaver reuse/delta-refresh plans across calls, mirroring the shard
    plan LRU.  Pass ``persistent_plans=False`` (or an explicit fresh
    ``plans={}``) to force per-call plan builds — benchmarks measuring
    the cold path do.  The persistent dict is only used on the default
    refined path (``refine_oracle=True``) so conservative-mode calls
    never reuse a refined plan.
    """
    import time as _time
    from .nodeprog import REGISTRY, run_entries_scalar
    from .oracle import KIND_PROG, KIND_TX

    prog = REGISTRY[name]
    shards = weaver.shards
    place = shard_of or (lambda vid: weaver.store.place(vid))
    intern = weaver.intern
    cache: Dict[tuple, bool] = {}

    def refine_pair(a: Stamp, b: Stamp) -> Order:
        if b.key() == at.key():     # object stamp vs program stamp
            got = refine_many([a])
            return Order.BEFORE if got[a.key()] else Order.AFTER
        if not refine_oracle:       # conservative default: a after b
            return Order.AFTER
        # version-vs-version (prop_at ordering): pairwise refinement
        chain = weaver.oracle.oracle.order_events([a, b],
                                                  [KIND_TX, KIND_TX])
        weaver.sim.counters.oracle_calls += 1
        return Order.BEFORE if chain[0] == a.key() else Order.AFTER

    def refine_many(stamps: List[Stamp]) -> Dict[tuple, bool]:
        missing = [s for s in stamps if s.key() not in cache]
        if missing:
            if refine_oracle:
                oracle = weaver.oracle.oracle
                chain = oracle.order_events(
                    missing + [at], [KIND_TX] * len(missing) + [KIND_PROG])
                weaver.sim.counters.oracle_calls += 1
                pos = {k: i for i, k in enumerate(chain)}
                for s in missing:
                    cache[s.key()] = pos[s.key()] < pos[at.key()]
            else:
                for s in missing:
                    cache[s.key()] = False     # conservative: write after
        return {s.key(): cache[s.key()] for s in stamps}

    stats = {"hops": 0, "messages": 0, "entries": 0, "batches": 0,
             "plan_cold": 0, "plan_delta": 0, "plan_rows": 0,
             "plan_seconds": 0.0, "plan_seconds_by_hop": []}
    outputs: List[object] = []

    batched = (use_frontier and prog.frontier_step is not None
               and prog.pack_root is not None)
    if batched:
        # all root entries must share one params dict (else scalar path)
        froot = prog.pack_root(entries, intern)
        batched = froot is not None

    if batched:
        if plans is None:
            if persistent_plans and refine_oracle:
                plans = weaver.__dict__.setdefault("_run_local_plans", {})
            else:
                plans = {}
        states: Dict[int, dict] = {}
        # route roots
        pending: Dict[int, Frontier] = route_frontier(froot, intern, place)
        while pending:
            stats["hops"] += 1
            hop_plan = 0.0
            nxt: Dict[int, List[Frontier]] = {}
            # ascending-sid iteration keeps output order deterministic
            # AND aligned with the scalar branch (same shard sequence)
            for sid, fr in sorted(pending.items()):
                stats["messages"] += 1
                stats["batches"] += 1
                stats["entries"] += len(fr)
                sh = shards[sid]
                cols = sh.partition.columns
                rb = refine_many if refine_oracle else None
                t0 = _time.perf_counter()
                plan, kind = maintain_plan(plans.get(sid), cols, at,
                                           sh.n_gk, rb,
                                           allow_delta=plan_delta)
                plans[sid] = plan
                if kind == "delta":
                    stats["plan_delta"] += 1
                    stats["plan_rows"] += plan.last_refresh_rows
                elif kind == "cold":
                    stats["plan_cold"] += 1
                dt = _time.perf_counter() - t0
                stats["plan_seconds"] += dt
                hop_plan += dt
                outs, out_fr, _ = execute_step(
                    plan, prog, fr, states.setdefault(sid, {}),
                    intern, sh.cost)
                outputs.extend(outs)
                if out_fr is not None:
                    for nsid, nfr in route_frontier(out_fr, intern,
                                                    place).items():
                        nxt.setdefault(nsid, []).append(nfr)
            pending = {sid: _merge_frontiers(frs)
                       for sid, frs in nxt.items()}
            stats["plan_seconds_by_hop"].append(hop_plan)
            if on_hop is not None:
                on_hop(stats["hops"])
    else:
        states = {}
        pending_s: Dict[int, list] = {}
        for vid, params in entries:
            sid = place(vid)
            if sid is not None:
                pending_s.setdefault(sid, []).append((vid, params))
        while pending_s:
            stats["hops"] += 1
            nxt_s: Dict[int, list] = {}
            for sid, ent in sorted(pending_s.items()):
                stats["messages"] += 1
                stats["entries"] += len(ent)
                sh = shards[sid]
                emits, outs, _ = run_entries_scalar(
                    sh.partition, prog, ent, at, refine_pair,
                    states.setdefault(sid, {}), sh.cost)
                outputs.extend(outs)
                for vid, params in emits:
                    nsid = place(vid)
                    if nsid is not None:
                        nxt_s.setdefault(nsid, []).append((vid, params))
            pending_s = nxt_s
            if on_hop is not None:
                on_hop(stats["hops"])

    return prog.reduce(outputs), stats


def _shard_groups(gids: np.ndarray, intern, place) -> Dict[int, np.ndarray]:
    """Destination shard -> entry-index array (stable order)."""
    out: Dict[int, np.ndarray] = {}
    if gids.size == 0:
        return out
    vids = intern.vids
    lst = []
    for g in gids.tolist():
        s = place(vids[g]) if g < len(vids) else None
        lst.append(-1 if s is None else s)
    sids = np.asarray(lst, np.int64)
    order = np.argsort(sids, kind="stable")
    sg = sids[order]
    starts = np.flatnonzero(np.r_[True, sg[1:] != sg[:-1]])
    bounds = np.r_[starts, sg.size]
    for i, st in enumerate(starts.tolist()):
        sid = int(sg[st])
        if sid < 0:
            continue
        out[sid] = order[st:bounds[i + 1]]
    return out


def route_frontier(fr: Frontier, intern, place) -> Dict[int, Frontier]:
    """Split a next-hop frontier into ONE packed message per destination
    shard.  Per-entry columns (gids / vals / tags) are sliced; a shared
    ``ragged`` side table is SUBSET to the rows the destination's entries
    reference (``Ragged.take``) and the tags re-based to the compact row
    numbering — each shard receives every origin's packed list exactly
    once, never the whole table."""
    out: Dict[int, Frontier] = {}
    for sid, sel in _shard_groups(fr.gids, intern, place).items():
        tags = None if fr.tags is None else fr.tags[sel]
        ragged = fr.ragged
        if ragged is not None and tags is not None:
            rows = np.unique(tags)
            ragged = ragged.take(rows)
            tags = np.searchsorted(rows, tags)
        out[sid] = Frontier(fr.gids[sel],
                            None if fr.vals is None else fr.vals[sel],
                            fr.depth, fr.meta, tags=tags, ragged=ragged)
    return out


def _merge_frontiers(frs: List[Frontier]) -> Frontier:
    """Concatenate same-(prog, stamp, depth, meta) frontiers into one
    execution unit.  Mixed optional columns backfill (0.0 vals / -1
    tags); ragged side tables concatenate row-wise with the owning
    frontier's tags re-based by its row offset."""
    if len(frs) == 1:
        return frs[0]
    gids = np.concatenate([f.gids for f in frs])
    vals = None
    if any(f.vals is not None for f in frs):
        vals = np.concatenate([
            f.vals if f.vals is not None else np.zeros(f.gids.size)
            for f in frs])
    tags = None
    ragged = None
    if any(f.ragged is not None for f in frs):
        withr = [f for f in frs if f.ragged is not None]
        assert len(withr) == len(frs), "mixed ragged/plain frontiers"
        ragged = Ragged.concat([f.ragged for f in frs])
        row_off = np.cumsum([0] + [len(f.ragged) for f in frs[:-1]])
        tags = np.concatenate([f.tags + off
                               for f, off in zip(frs, row_off)])
    elif any(f.tags is not None for f in frs):
        tags = np.concatenate([
            f.tags if f.tags is not None
            else np.full(f.gids.size, -1, np.int64) for f in frs])
    return Frontier(gids, vals, frs[0].depth, frs[0].meta,
                    tags=tags, ragged=ragged)
