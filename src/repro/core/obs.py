"""Deployment-wide observability: causal tracing, metrics, attribution.

The paper's evaluation (Figs. 9-14) is entirely about *where time goes*
— admission vs. oracle refinement vs. shard apply.  This module is the
layer that can answer that for a single request: a :class:`Tracer`
records causally-linked spans on *simulated* time as requests flow
through gatekeepers, the store, shards and the timeline oracle; a
:class:`MetricsRegistry` keeps counters / gauges / histograms plus a
periodic time series; analysis helpers export Chrome trace-event JSON
(loadable in Perfetto / chrome://tracing), attribute a request's
end-to-end latency to pipeline stages via a critical-path walk over its
span tree, and check trace-level invariants (exactly-once apply, stamp
monotonicity) over traces produced under fault injection.

Design constraints
------------------
* **Pure observation.**  Recording a span must not draw from any RNG,
  send any message, or change any timing — traced and untraced runs
  must produce bit-identical results and counters (minus the obs
  counters listed in :data:`OBS_COUNTER_FIELDS`; tests assert this).
  Head-based sampling is therefore a deterministic counter stride, not
  a random draw.
* **Retrospective spans.**  Actors already carry the timestamps they
  need (submit time, window join time, queue arrival time), so spans
  are recorded *closed* — ``span(stage, t0, t1, ...)`` at the moment
  the work completes — instead of via open/close handles that would
  have to survive crashes and retries.
* **Context flows with events.**  ``Simulator.send``/``schedule``
  carry the ambient ``(trace_id, span_id)`` context on each heap entry
  and restore it around the callback, so child spans recorded inside a
  delivery parent correctly without any per-message plumbing.  Where
  batching merges many requests into one event (group-commit windows,
  shard batches), contexts ride explicitly: the tracer keeps
  ``stamp_ctx`` (timestamp key -> context) and ``prog_ctx``
  (program id -> context) registries so downstream actors can recover
  the owning request's context from data they already carry.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "Span", "Tracer", "MetricsRegistry", "OBS_COUNTER_FIELDS",
    "to_chrome_trace", "validate_trace_events", "span_tree",
    "critical_path", "attribution_table", "format_stage_table",
    "check_exactly_once", "check_stamp_monotonic", "check_completeness",
    "run_invariant_checks",
]

# Counters fields written by the observability layer itself; equivalence
# tests compare Counters snapshots with these removed.
OBS_COUNTER_FIELDS = ("spans_recorded", "metrics_samples")


def stamp_attr(stamp) -> list:
    """Span-attr encoding of a refinable timestamp: ``[epoch, *clock]``
    (what :func:`check_stamp_monotonic` compares as a vector clock)."""
    return [int(stamp.epoch), *(int(c) for c in stamp.clock)]


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

@dataclass
class Span:
    """One closed span on simulated time.

    ``trace`` groups spans of one sampled request; ``sid`` is unique
    within the trace; ``parent`` is the ``sid`` of the causal parent
    (``None`` for the root).  ``attrs`` carries stage-specific detail
    (stamp key, shard id, plan kind, window id, ...).
    """

    trace: int
    sid: int
    parent: Optional[int]
    stage: str
    actor: str
    t0: float
    t1: float
    attrs: dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class Tracer:
    """Head-sampled causal span recorder.

    ``sample_rate`` in (0, 1]: request *k* is sampled iff
    ``k % round(1/rate) == 0`` — deterministic, no RNG.  A deployment
    with tracing disabled simply has no Tracer installed
    (``sim.tracer is None``); every hook site guards on that, so the
    disabled cost is one attribute check.
    """

    def __init__(self, sim, sample_rate: float = 1.0):
        self.sim = sim
        self.sample_rate = float(sample_rate)
        self._stride = max(1, int(round(1.0 / self.sample_rate))) \
            if self.sample_rate > 0 else 0
        self._req_count = 0
        self._next_trace = 1
        self._next_sid = 1
        self.spans: List[Span] = []
        # ambient context for the event being executed: (trace, sid)
        self.current: Optional[Tuple[int, int]] = None
        # explicit-context registries for batched paths
        self.stamp_ctx: Dict[tuple, Tuple[int, int]] = {}
        self.prog_ctx: Dict[int, Tuple[int, int]] = {}
        sim.register(self)  # participates in actor-id space for debug

    # -- root / sampling -------------------------------------------------

    def maybe_start(self) -> Optional[Tuple[int, int]]:
        """Sampling decision for a new client request.  Returns a fresh
        root context (trace_id, 0) if sampled, else None.  The root
        span itself is recorded later, retrospectively, by the client
        session when the request finishes (stage ``request``)."""
        k = self._req_count
        self._req_count += 1
        if self._stride == 0 or (k % self._stride) != 0:
            return None
        tid = self._next_trace
        self._next_trace += 1
        return (tid, 0)

    # -- recording -------------------------------------------------------

    def span(self, stage: str, t0: float, t1: float, actor: str = "",
             ctx: Optional[Tuple[int, int]] = None,
             **attrs) -> Optional[Tuple[int, int]]:
        """Record a closed span under ``ctx`` (default: ambient context).

        Returns the new span's context ``(trace, sid)`` so callers can
        parent further children under it, or None if there is no
        context (request not sampled)."""
        if ctx is None:
            ctx = self.current
        if ctx is None:
            return None
        trace, parent = ctx
        sid = self._next_sid
        self._next_sid += 1
        self.spans.append(Span(trace, sid, parent, stage, actor,
                               float(t0), float(t1), attrs))
        self.sim.counters.spans_recorded += 1
        return (trace, sid)

    def root_span(self, ctx: Tuple[int, int], stage: str, t0: float,
                  t1: float, actor: str = "", **attrs) -> None:
        """Record the trace's root span (parent None).  ``ctx`` must be
        a root context from :meth:`maybe_start` (sid == 0 means 'the
        root slot'); children recorded under ``ctx`` parent to sid 0,
        and the root span claims sid 0 here."""
        trace, sid = ctx
        self.spans.append(Span(trace, sid, None, stage, actor,
                               float(t0), float(t1), attrs))
        self.sim.counters.spans_recorded += 1

    # -- registries ------------------------------------------------------

    def bind_stamp(self, stamp, ctx: Optional[Tuple[int, int]]) -> None:
        if ctx is not None:
            self.stamp_ctx[stamp.key()] = ctx

    def ctx_for_stamp(self, stamp) -> Optional[Tuple[int, int]]:
        return self.stamp_ctx.get(stamp.key())

    def bind_prog(self, prog_id: int,
                  ctx: Optional[Tuple[int, int]]) -> None:
        if ctx is not None:
            self.prog_ctx[prog_id] = ctx

    def ctx_for_prog(self, prog_id: int) -> Optional[Tuple[int, int]]:
        return self.prog_ctx.get(prog_id)

    # -- views -----------------------------------------------------------

    def traces(self) -> Dict[int, List[Span]]:
        out: Dict[int, List[Span]] = {}
        for s in self.spans:
            out.setdefault(s.trace, []).append(s)
        return out


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def _bucket(v: float) -> int:
    """Power-of-two bucket (>=1) for histogram keys."""
    b = 1
    while b < v:
        b *= 2
    return b


class MetricsRegistry:
    """Counters / gauges / histograms on simulated time + a sampled
    timeline.

    * ``count(name, n)`` — monotone counter.
    * ``gauge(name, value, t)`` — last-write-wins sample with its
      simulated timestamp; ``recent(name, horizon, now)`` reads it back
      but returns 0.0 for samples older than ``horizon`` (a stale
      saturated-peer gauge must not keep windows open forever).
    * ``observe(name, value)`` — power-of-two bucketed histogram
      (replaces the ad-hoc ``Counters.admission_*_hist`` dicts).
    * ``sample(t, extra)`` — append one timeline row: every gauge's
      current value plus caller-provided extras (queue depths etc.).
    """

    def __init__(self):
        self.counters: Dict[str, int] = {}
        self.gauges: Dict[str, Tuple[float, float]] = {}   # name -> (t, v)
        self.hists: Dict[str, Dict[int, int]] = {}
        self.timeline: List[dict] = []

    def count(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value: float, t: float) -> None:
        self.gauges[name] = (float(t), float(value))

    def recent(self, name: str, horizon: float, now: float) -> float:
        tv = self.gauges.get(name)
        if tv is None or now - tv[0] > horizon:
            return 0.0
        return tv[1]

    def gauge_values(self, prefix: str, horizon: float,
                     now: float) -> Dict[str, float]:
        """All non-stale gauges whose name starts with ``prefix``."""
        out = {}
        for name, (t, v) in self.gauges.items():
            if name.startswith(prefix) and now - t <= horizon:
                out[name] = v
        return out

    def observe(self, name: str, value: float) -> None:
        h = self.hists.setdefault(name, {})
        b = _bucket(value)
        h[b] = h.get(b, 0) + 1

    def sample(self, t: float, extra: Optional[dict] = None) -> None:
        row = {"t": float(t)}
        for name, (_, v) in sorted(self.gauges.items()):
            row[name] = v
        if extra:
            row.update(extra)
        self.timeline.append(row)

    def export(self) -> dict:
        return {"counters": dict(sorted(self.counters.items())),
                "gauges": {k: v for k, (_, v)
                           in sorted(self.gauges.items())},
                "histograms": {k: {str(b): n for b, n in sorted(v.items())}
                               for k, v in sorted(self.hists.items())},
                "timeline": self.timeline}

    def hist_snapshot(self, name: str, key_suffix: str = "") -> dict:
        """Histogram as a plain dict with string bucket keys, e.g.
        ``{"r:64us": 3}`` for (name="admission_window", suffix="us")."""
        return {f"{k}{key_suffix}": n
                for k, n in sorted(self.hists.get(name, {}).items())}


# ---------------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------------

def to_chrome_trace(spans: List[Span]) -> dict:
    """Spans -> Chrome trace-event JSON (``{"traceEvents": [...]}``).

    Complete ``"ph": "X"`` events on microsecond timestamps; ``pid`` is
    the trace id (one request per Perfetto process track), ``tid`` the
    recording actor (hashed to a small int), so a request's fan-out
    across shards reads as parallel tracks.
    """
    events = []
    actors = {}
    for s in spans:
        tid = actors.setdefault(s.actor or "root", len(actors) + 1)
        args = {"span_id": s.sid}
        if s.parent is not None:
            args["parent_id"] = s.parent
        for k, v in s.attrs.items():
            args[k] = v if isinstance(v, (int, float, str, bool)) else str(v)
        events.append({
            "name": s.stage,
            "cat": "weaver",
            "ph": "X",
            "ts": s.t0 * 1e6,
            "dur": max(s.dur, 0.0) * 1e6,
            "pid": int(s.trace),
            "tid": int(tid),
            "args": args,
        })
    meta = [{"name": "thread_name", "ph": "M", "pid": int(pid),
             "tid": int(tid), "args": {"name": actor}}
            for actor, tid in actors.items()
            for pid in sorted({s.trace for s in spans})]
    return {"traceEvents": events + meta,
            "displayTimeUnit": "ms"}


def validate_trace_events(doc: dict) -> List[str]:
    """Schema check for Chrome trace-event JSON.  Returns a list of
    problems (empty == valid)."""
    errs: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing traceEvents array"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents is not a list"]
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M", "B", "E", "i"):
            errs.append(f"event {i}: bad ph {ph!r}")
            continue
        for k in ("name", "pid", "tid"):
            if k not in ev:
                errs.append(f"event {i}: missing {k}")
        if ph == "X":
            if not isinstance(ev.get("ts"), (int, float)):
                errs.append(f"event {i}: missing/bad ts")
            if not isinstance(ev.get("dur"), (int, float)) \
                    or ev.get("dur", -1) < 0:
                errs.append(f"event {i}: missing/negative dur")
    return errs


# ---------------------------------------------------------------------------
# critical-path attribution
# ---------------------------------------------------------------------------

def span_tree(spans: List[Span]):
    """(root, children-map sid -> [Span]) for one trace's span list.

    Raises ValueError if there is no root or a span references a
    missing parent (the completeness checker reports these as orphan
    findings instead)."""
    by_sid = {s.sid: s for s in spans}
    root = None
    children: Dict[int, List[Span]] = {}
    for s in spans:
        if s.parent is None:
            if root is not None:
                raise ValueError("trace has multiple roots")
            root = s
        else:
            if s.parent not in by_sid:
                raise ValueError(f"span {s.sid} has missing parent "
                                 f"{s.parent}")
            children.setdefault(s.parent, []).append(s)
    if root is None:
        raise ValueError("trace has no root span")
    return root, children


def critical_path(spans: List[Span],
                  network_stage: str = "network") -> Dict[str, float]:
    """Attribute a request's end-to-end latency to stages.

    Backward sweep: within a parent interval, walk from the end toward
    the start; time covered by a child is attributed (recursively) to
    that child's stages, gaps between children — and the remainder
    before the earliest child — to the *parent's* stage.  The root's
    own stage is reported as ``network_stage`` (un-instrumented time on
    the wire / in replies).  By construction the attribution tiles the
    root interval exactly, so ``sum(values) == root.dur`` up to float
    rounding — asserted by callers within epsilon.
    """
    root, children = span_tree(spans)
    out: Dict[str, float] = {}

    def _add(stage: str, dt: float) -> None:
        if dt > 0:
            out[stage] = out.get(stage, 0.0) + dt

    def _walk(s: Span, lo: float, hi: float, stage: str) -> None:
        kids = [k for k in children.get(s.sid, [])
                if k.t1 > lo and k.t0 < hi]
        kids.sort(key=lambda k: k.t1, reverse=True)
        cursor = hi
        for k in kids:
            k1 = min(k.t1, cursor)
            k0 = max(k.t0, lo)
            if k1 <= k0:
                continue  # fully shadowed by a later child
            _add(stage, cursor - k1)
            _walk(k, k0, k1, k.stage)
            cursor = k0
        _add(stage, cursor - lo)

    _walk(root, root.t0, root.t1, network_stage)
    return out


def attribution_table(tracer: Tracer,
                      network_stage: str = "network") -> dict:
    """Per-trace critical paths + aggregate stage totals.

    Returns ``{"requests": [{trace, e2e, stages, err}], "stages":
    {stage: total}, "max_rel_err": float}`` where ``err`` is the
    relative difference between the stage sum and the measured e2e
    (must be ~0; the CI stage asserts < 1%)."""
    requests = []
    totals: Dict[str, float] = {}
    max_err = 0.0
    for trace, spans in sorted(tracer.traces().items()):
        roots = [s for s in spans if s.parent is None]
        if not roots or any(s.attrs.get("infra") for s in roots):
            continue  # infra traces (window spans) have no request root
        try:
            stages = critical_path(spans, network_stage)
        except ValueError as e:
            requests.append({"trace": trace, "error": str(e)})
            continue
        e2e = roots[0].dur
        ssum = sum(stages.values())
        err = abs(ssum - e2e) / max(e2e, 1e-12)
        max_err = max(max_err, err)
        for k, v in stages.items():
            totals[k] = totals.get(k, 0.0) + v
        requests.append({"trace": trace, "e2e": e2e,
                         "stages": stages, "err": err})
    return {"requests": requests,
            "stages": dict(sorted(totals.items(),
                                  key=lambda kv: -kv[1])),
            "max_rel_err": max_err}


def format_stage_table(attr: dict, title: str = "stage") -> str:
    """Human-readable aggregate stage table (benchmark output)."""
    total = sum(attr["stages"].values()) or 1.0
    lines = [f"{'stage':<22} {'total_ms':>10} {'share':>7}"]
    for stage, v in attr["stages"].items():
        lines.append(f"{stage:<22} {v*1e3:>10.3f} {v/total:>6.1%}")
    lines.append(f"{'TOTAL':<22} {total*1e3:>10.3f} "
                 f"(max rel err {attr['max_rel_err']:.2e})")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# invariant checkers (run over fault-injected traces)
# ---------------------------------------------------------------------------

def check_completeness(tracer: Tracer) -> List[str]:
    """Every span's parent exists within its trace; exactly one root
    per trace.  Returns findings (empty == pass)."""
    errs = []
    for trace, spans in tracer.traces().items():
        sids = {s.sid for s in spans}
        roots = [s for s in spans if s.parent is None]
        if len(roots) != 1:
            errs.append(f"trace {trace}: {len(roots)} roots")
        for s in spans:
            if s.parent is not None and s.parent not in sids \
                    and s.parent != 0:
                errs.append(f"trace {trace}: span {s.sid} "
                            f"({s.stage}) orphaned, parent {s.parent}")
            if s.parent == 0 and 0 not in sids:
                errs.append(f"trace {trace}: span {s.sid} parented to "
                            f"missing root slot")
            if s.t1 < s.t0:
                errs.append(f"trace {trace}: span {s.sid} ({s.stage}) "
                            f"negative duration")
    return errs


def check_exactly_once(tracer: Tracer) -> List[str]:
    """Every *acked* tx trace has, per owning shard, >= 1 apply span
    overall and <= 1 per shard incarnation (recovery may legitimately
    re-apply into a *new* incarnation; duplicates within one
    incarnation would be a double-apply bug).  Apply spans carry attrs
    ``shard``/``incarnation``; shed/given-up requests are skipped."""
    errs = []
    for trace, spans in tracer.traces().items():
        roots = [s for s in spans if s.parent is None]
        if not roots:
            continue
        root = roots[0]
        if root.stage != "request" or root.attrs.get("kind") != "tx" \
                or not root.attrs.get("ok"):
            continue
        applies: Dict[Tuple[int, int], int] = {}
        shards = set()
        owning = None
        for s in spans:
            if s.stage == "shard_apply":
                shards.add(s.attrs.get("shard"))
                if not s.attrs.get("recovered"):
                    key = (s.attrs.get("shard"),
                           s.attrs.get("incarnation", 0))
                    applies[key] = applies.get(key, 0) + 1
            elif s.stage == "store_commit" and s.attrs.get("committed"):
                # the latest successful commit attempt knows the fan-out
                n = s.attrs.get("n_shards")
                if n is not None:
                    owning = n
        if owning is not None and len(shards) < owning:
            errs.append(f"trace {trace}: acked tx applied on "
                        f"{len(shards)}/{owning} owning shards")
        for (shard, inc), n in applies.items():
            if n > 1:
                errs.append(f"trace {trace}: {n} apply spans on shard "
                            f"{shard} incarnation {inc} (double apply)")
    return errs


def check_stamp_monotonic(tracer: Tracer) -> List[str]:
    """Along every root->leaf path, a span's stamp must never be
    strictly BEFORE an ancestor's stamp (concurrent is fine: retries
    through different gatekeepers are vector-clock-concurrent).  Spans
    carry the stamp as ``attrs["stamp"]`` = the clock tuple."""
    errs = []
    for trace, spans in tracer.traces().items():
        try:
            root, children = span_tree(spans)
        except ValueError:
            continue  # completeness checker reports structure problems

        def _desc(s, anc_stamp):
            st = s.attrs.get("stamp")
            if st is not None:
                if anc_stamp is not None and _strictly_before(st, anc_stamp):
                    errs.append(f"trace {trace}: span {s.sid} "
                                f"({s.stage}) stamp {st} precedes "
                                f"ancestor stamp {anc_stamp}")
                anc_stamp = st
            for k in children.get(s.sid, []):
                _desc(k, anc_stamp)

        _desc(root, None)
    return errs


def _strictly_before(a, b) -> bool:
    """Vector-clock strictly-before on (epoch, clocks...) tuples."""
    a, b = tuple(a), tuple(b)
    if a[0] != b[0]:
        return a[0] < b[0]
    av, bv = a[1:], b[1:]
    n = max(len(av), len(bv))
    av = av + (0,) * (n - len(av))
    bv = bv + (0,) * (n - len(bv))
    return all(x <= y for x, y in zip(av, bv)) and av != bv


def check_replica_staleness(tracer: Tracer) -> List[str]:
    """No read may be served by a replica at a stamp beyond the
    replica's applied frontier.  Every ``replica_read`` span records the
    stamp's settlement token (``settle_pos``, the primary feed position
    that covers the stamp's visible writes) and the serving replica's
    ``applied_pos`` at execution time; a read served with a missing
    token or with ``applied_pos < settle_pos`` would be reading a state
    older than the stamp requires — a staleness violation the
    frontier-gating protocol exists to prevent."""
    errs = []
    for s in tracer.spans:
        if s.stage != "replica_read":
            continue
        settle = s.attrs.get("settle_pos", -1)
        applied = s.attrs.get("applied_pos", -1)
        if settle is None or settle < 0:
            errs.append(f"replica_read span {s.sid} on {s.actor}: "
                        f"served without a settlement token "
                        f"(stamp {s.attrs.get('stamp')})")
        elif applied is None or applied < settle:
            errs.append(f"replica_read span {s.sid} on {s.actor}: "
                        f"applied_pos {applied} behind settle_pos "
                        f"{settle} (stamp {s.attrs.get('stamp')})")
    return errs


def run_invariant_checks(tracer: Tracer) -> Dict[str, List[str]]:
    return {"completeness": check_completeness(tracer),
            "exactly_once": check_exactly_once(tracer),
            "stamp_monotonic": check_stamp_monotonic(tracer),
            "replica_staleness": check_replica_staleness(tracer)}


# ---------------------------------------------------------------------------
# file export
# ---------------------------------------------------------------------------

def export_trace(tracer: Tracer, path: str) -> dict:
    """Write Chrome trace-event JSON for all recorded spans; returns
    the document (already schema-validated — raises on violations,
    which would mean a recorder bug)."""
    doc = to_chrome_trace(tracer.spans)
    errs = validate_trace_events(doc)
    if errs:
        raise ValueError("invalid trace export: " + "; ".join(errs[:5]))
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc
