"""Backing store — HyperDex/Warp stand-in (paper §3.2, §4.1).

A strictly serializable, transactional key-value store that (a) holds the
durable copy of the multi-version graph, (b) maps vertices to shard
servers, and (c) records each vertex's last-update stamp, which the
gatekeepers use to keep timestamps consistent with the store's execution
order (``T_upd ≺ T_tx`` check; retry/refine otherwise).

In the simulator it is a single actor executing multi-key transactions
atomically at message-delivery time (that *is* strict serializability for
a single-copy store), with a write-ahead log for recovery and an optional
on-disk checkpoint used by the fault-tolerance tests.

Recovery (§4.3): the WAL is a replayable redo log.  Every commit appends
a :class:`~repro.core.writepath.WalRecord` carrying the transaction's
full forwarded ops (stamps included), so :meth:`BackingStore.
recover_shard` rebuilds a failed shard's partition by replaying the log
up to the stable point — truncating any torn tail a crash left behind a
group record's ``valid`` watermark.  Store-side GC rewrites the log as
one checkpoint record (the per-shard walk at the horizon) so replay
stays bounded and agrees with the GC'd store.  The old ``vertices``-walk
recovery is kept verbatim as :meth:`recover_shard_walk`, the equivalence
oracle the recovery tests compare replay against.

Exactly-once retry: committed (and aborted) transaction outcomes are
recorded in :attr:`BackingStore.tx_results` keyed by the client-assigned
transaction id, *at the same commit point as the WAL append*, so a
resubmitted transaction whose ack was lost is answered from the table
instead of re-executing — it commits once, never twice.

Group commit (``repro.core.writepath``): last-update stamps are mirrored
into a packed :class:`~repro.core.writepath.LastUpdateTable` at every
commit point, so the gatekeeper's batched admission path validates a
whole window's write-sets with one vectorized compare instead of one
dict probe per vertex; :meth:`BackingStore.apply_batch` then commits the
validated batch in ONE store round trip — one group WAL record is the
batch's single durability point, and each transaction's reply is sent
only after it.  A logical error (``ValueError``) aborts only its own
transaction; the rest of the batch commits.  The per-tx :meth:`apply`
is unchanged and remains the semantic oracle.
"""

from __future__ import annotations

import json
import os
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from .clock import Order, Stamp, compare
from .mvgraph import VidIntern
from .simulation import Simulator
from .writepath import LastUpdateTable, WalRecord, wal_replay_shard


@dataclass
class StoredVertex:
    vid: str
    shard: int
    create_ts: Stamp
    delete_ts: Optional[Stamp] = None
    # durable mirror of edges/properties: eid -> (dst, create_ts, delete_ts)
    edges: Dict[int, Tuple[str, Stamp, Optional[Stamp]]] = field(default_factory=dict)
    props: Dict[str, List[Tuple[object, Stamp]]] = field(default_factory=dict)
    # eid -> key -> [(value, ts), ...]; mirrored so walk recovery (and the
    # checkpoint rewrite) can re-emit set_edge_prop with original stamps
    edge_props: Dict[int, Dict[str, List[Tuple[object, Stamp]]]] = \
        field(default_factory=dict)
    last_update: Optional[Stamp] = None


class BackingStore:
    """Strictly serializable KV + vertex->shard directory + redo WAL."""

    #: a txid marked in-flight this long ago with no recorded outcome is
    #: presumed lost (its gatekeeper died pre-WAL) — a resubmission may
    #: re-attempt it
    INFLIGHT_STALE = 4e-3
    #: recorded tx outcomes older than this are pruned at GC (longer than
    #: any client session: budget * backoff_cap plus slack)
    RESULT_RETENTION = 2.0

    def __init__(self, sim: Simulator, n_shards: int,
                 intern: Optional[VidIntern] = None,
                 wal_checkpoint_every: int = 256):
        self.sim = sim
        sim.register(self)
        self.n_shards = n_shards
        self.vertices: Dict[str, StoredVertex] = {}
        self.wal: List[WalRecord] = []
        self.wal_checkpoint_every = wal_checkpoint_every
        self._next_eid = 0
        # exactly-once: txid -> (ok, error, stamp, fwd, recorded_at);
        # written at the WAL durability point, read by the gatekeeper's
        # dedup check before re-executing a resubmitted transaction
        self.tx_results: Dict[object, Tuple] = {}
        self._inflight: Dict[object, float] = {}
        # packed mirror of per-vertex last-update stamps (group-commit
        # validation path; kept exactly in sync with StoredVertex.
        # last_update at every commit point)
        self.last_updates = LastUpdateTable(intern)

    # ---- directory -------------------------------------------------------
    def place(self, vid: str) -> int:
        """Static placement hash; dynamic migration (§4.6) is out of scope
        for the evaluation (the paper disables it too).  crc32, not
        ``hash()``: placement must be identical across processes (Python
        randomizes str hashing per process) or traces and counters from
        the same seeded workload would not be comparable run-to-run."""
        return zlib.crc32(vid.encode()) % self.n_shards

    def shard_of(self, vid: str) -> Optional[int]:
        v = self.vertices.get(vid)
        return None if v is None else v.shard

    def fresh_eid(self) -> int:
        self._next_eid += 1
        return self._next_eid

    # ---- transactional execution ------------------------------------------
    # Executed atomically by the gatekeeper's commit path; returns the list
    # of (shard, op) to forward, or raises on logical error (-> abort).
    def last_update_of(self, vid: str) -> Optional[Stamp]:
        v = self.vertices.get(vid)
        return None if v is None else v.last_update

    def apply(self, ops: List[dict], ts: Stamp,
              txid: object = None) -> List[Tuple[int, dict]]:
        """Validate + execute a whole transaction atomically.

        Validation runs against an *overlay* of the staged writes so a
        transaction sees its own earlier operations (e.g. Fig. 2 creates a
        vertex and immediately hangs edges off it).  A logical error aborts
        with no side effects (§4.1).
        """
        return self._apply_one(ops, ts, log=True, txid=txid)

    def apply_batch(self, items: List[Tuple[List[dict], Stamp, object]],
                    torn_limit: Optional[int] = None
                    ) -> List[Tuple[bool, Optional[str],
                                    Optional[List[Tuple[int, dict]]]]]:
        """Commit a validated group — ``[(ops, stamp, txid), ...]`` in
        stamp order — in one store round trip.

        Per-transaction result: ``(ok, error, fwd)``.  Each transaction
        keeps its own atomicity (a logical error rolls back that tx
        only); the batch shares ONE group WAL record appended after the
        last transaction — the group's single durability point (the
        gatekeeper replies to every client after this call returns), and
        tx outcomes are recorded for dedup at the same point.

        ``torn_limit`` is the fault-injection hook for a crash DURING
        the group append: only the first ``torn_limit`` transactions
        commit; the next entry is written to the log but left beyond the
        record's ``valid`` watermark (a torn tail recovery must
        truncate) and the rest of the window is lost entirely."""
        out = []
        entries: List[Tuple[Stamp, object, List[Tuple[int, dict]]]] = []
        cut = len(items) if torn_limit is None else min(torn_limit, len(items))
        for ops, ts, txid in items[:cut]:
            try:
                fwd = self._apply_one(ops, ts, log=False)
            except ValueError as e:
                out.append((False, str(e), None))
                self.record_result(txid, False, str(e), ts, None)
                continue
            out.append((True, None, fwd))
            entries.append((ts, txid, fwd))
        valid = len(entries)
        if cut < len(items):                     # torn tail: garbled entry
            ops, ts, txid = items[cut]
            entries.append((ts, txid, self._torn_fwd(ops, ts)))
            out.extend((False, "torn", None) for _ in items[cut:])
        if entries:
            self.wal.append(WalRecord("group", entries, valid=valid))
            self.sim.counters.wal_records += 1
            for ts, _txid, _fwd in entries[:valid]:
                self._wal_span(ts, group=True)
        # durability point: the group record is on the log, so the
        # outcomes become answerable to resubmissions exactly now
        for ts, txid, fwd in entries[:valid]:
            self.record_result(txid, True, None, ts, fwd)
        return out

    def _wal_span(self, ts: Stamp, group: bool) -> None:
        """Zero-width durability marker on a sampled trace: the instant
        this stamp's redo record hit the log (stage ``wal_append``)."""
        tr = self.sim.tracer
        if tr is None:
            return
        ctx = tr.ctx_for_stamp(ts)
        if ctx is not None:
            from .obs import stamp_attr
            tr.span("wal_append", self.sim.now, self.sim.now,
                    actor="store", ctx=ctx, group=group,
                    stamp=stamp_attr(ts))

    def _torn_fwd(self, ops: List[dict], ts: Stamp) -> List[Tuple[int, dict]]:
        """Best-effort forward list for a half-written (never applied)
        entry — what a torn tail would physically contain on the log."""
        fwd = []
        for op in ops:
            vid = op.get("vid") or op.get("src")
            sid = self.shard_of(vid)
            fwd.append((self.place(vid) if sid is None else sid,
                        dict(op, ts=ts)))
        return fwd

    # ---- exactly-once bookkeeping -------------------------------------------
    def record_result(self, txid: object, ok: bool, err: Optional[str],
                      stamp: Stamp, fwd=None) -> None:
        """Record a transaction's final outcome for dedup (no-op without
        a client-assigned txid)."""
        if txid is None:
            return
        self._inflight.pop(txid, None)
        self.tx_results[txid] = (ok, err, stamp, fwd, self.sim.now)

    def begin_tx_attempt(self, txid: object) -> str:
        """Dedup gate for a fresh client submission of ``txid``.

        ``"done"``: an outcome is recorded — answer from the table.
        ``"inflight"``: another attempt is being validated right now —
        drop this one (the client's next timeout covers the race).
        ``"new"``: proceed (and mark in-flight)."""
        if txid is None:
            return "new"
        if txid in self.tx_results:
            return "done"
        t = self._inflight.get(txid)
        if t is not None and self.sim.now - t < self.INFLIGHT_STALE:
            return "inflight"
        self._inflight[txid] = self.sim.now
        return "new"

    def touch_inflight(self, txid: object) -> None:
        """Keep a txid's in-flight marker fresh across internal
        validation retries so a concurrent client resubmission cannot
        slip past the gate mid-retry-loop."""
        if txid is not None:
            self._inflight[txid] = self.sim.now

    def _apply_one(self, ops: List[dict], ts: Stamp, log: bool,
                   txid: object = None) -> List[Tuple[int, dict]]:
        fwd: List[Tuple[int, dict]] = []
        staged: List[Callable[[], None]] = []
        new_v: Dict[str, StoredVertex] = {}       # created in this tx
        del_v: set = set()                        # deleted in this tx
        new_e: Dict[str, Dict[int, str]] = {}     # src -> eid -> dst
        del_e: set = set()                        # (src, eid)

        def live(vid: str) -> bool:
            if vid in del_v:
                return False
            if vid in new_v:
                return True
            v = self.vertices.get(vid)
            return v is not None and v.delete_ts is None

        def edge_live(src: str, eid: int) -> bool:
            if (src, eid) in del_e:
                return False
            if eid in new_e.get(src, {}):
                return True
            v = self.vertices.get(src)
            return (v is not None and eid in v.edges
                    and v.edges[eid][2] is None)

        def shard_for(vid: str) -> int:
            if vid in new_v:
                return new_v[vid].shard
            return self.vertices[vid].shard

        for op in ops:
            kind = op["op"]
            if kind == "create_vertex":
                vid = op["vid"]
                if live(vid):
                    raise ValueError(f"vertex {vid} exists")
                shard = op.get("shard", self.place(vid))
                new_v[vid] = StoredVertex(vid, shard, ts, last_update=ts)
                del_v.discard(vid)
                fwd.append((shard, dict(op, ts=ts)))
            elif kind == "delete_vertex":
                vid = op["vid"]
                if not live(vid):
                    raise ValueError(f"vertex {vid} not live")
                del_v.add(vid)
                if vid not in new_v:
                    v = self.vertices[vid]
                    def _d(v=v):
                        v.delete_ts = ts
                        v.last_update = ts
                    staged.append(_d)
                fwd.append((shard_for(vid), dict(op, ts=ts)))
            elif kind == "create_edge":
                src, dst = op["src"], op["dst"]
                if not live(src):
                    raise ValueError(f"src {src} not live")
                if not live(dst):
                    raise ValueError(f"dst {dst} not live")
                eid = op.get("eid") or self.fresh_eid()
                new_e.setdefault(src, {})[eid] = dst
                if src not in new_v:
                    vs = self.vertices[src]
                    def _e(vs=vs, eid=eid, dst=dst):
                        vs.edges[eid] = (dst, ts, None)
                        vs.last_update = ts
                    staged.append(_e)
                fwd.append((shard_for(src), dict(op, eid=eid, ts=ts)))
            elif kind == "delete_edge":
                src, eid = op["src"], op["eid"]
                if not edge_live(src, eid):
                    raise ValueError(f"edge {src}/{eid} not live")
                del_e.add((src, eid))
                if eid in new_e.get(src, {}):
                    dst = new_e[src].pop(eid)
                    def _de0(src=src, eid=eid, dst=dst):
                        self.vertices[src].edges[eid] = (dst, ts, ts)
                    staged.append(_de0)
                else:
                    vs = self.vertices[src]
                    def _de(vs=vs, eid=eid):
                        dst, cts, _ = vs.edges[eid]
                        vs.edges[eid] = (dst, cts, ts)
                        vs.last_update = ts
                    staged.append(_de)
                fwd.append((shard_for(src), dict(op, ts=ts)))
            elif kind == "set_vertex_prop":
                vid = op["vid"]
                if not live(vid):
                    raise ValueError(f"vertex {vid} not live")
                if vid in new_v:
                    new_v[vid].props.setdefault(op["key"], []).append(
                        (op["value"], ts))
                else:
                    v = self.vertices[vid]
                    def _p(v=v, op=op):
                        v.props.setdefault(op["key"], []).append(
                            (op["value"], ts))
                        v.last_update = ts
                    staged.append(_p)
                fwd.append((shard_for(vid), dict(op, ts=ts)))
            elif kind == "set_edge_prop":
                src, eid = op["src"], op["eid"]
                if not edge_live(src, eid):
                    raise ValueError(f"edge {src}/{eid} missing")
                if src in new_v:
                    new_v[src].edge_props.setdefault(eid, {}).setdefault(
                        op["key"], []).append((op["value"], ts))
                else:
                    vs = self.vertices[src]
                    def _pe(vs=vs, op=op):
                        vs.edge_props.setdefault(op["eid"], {}).setdefault(
                            op["key"], []).append((op["value"], ts))
                        vs.last_update = ts
                    staged.append(_pe)
                fwd.append((shard_for(src), dict(op, ts=ts)))
            elif kind == "get_vertex":       # reads execute here (paper §4.1)
                vid = op["vid"]
                if not live(vid):
                    raise ValueError(f"vertex {vid} not live")
                # read-only: nothing forwarded, nothing staged
            else:
                raise ValueError(f"unknown op {kind}")

        # ---- commit point: merge new vertices, run staged writes, WAL ----
        for vid, v in new_v.items():
            for eid, dst in new_e.get(vid, {}).items():
                v.edges[eid] = (dst, ts, None)
            if vid in del_v:
                v.delete_ts = ts
            self.vertices[vid] = v
        for s in staged:
            s()
        # packed mirror follows the dict exactly: every vid whose
        # last_update the staged writes (or new-vertex creation) set
        self.last_updates.record(self.write_set(ops), ts)
        if log:
            if fwd:
                self.wal.append(WalRecord("tx", [(ts, txid, fwd)], valid=1))
                self.sim.counters.wal_records += 1
                self._wal_span(ts, group=False)
            self.record_result(txid, True, None, ts, fwd)
        return fwd

    # ---- touched vertices (for last-update validation) ---------------------
    @staticmethod
    def write_set(ops: List[dict]) -> List[str]:
        out = []
        for op in ops:
            k = op["op"]
            if k in ("create_vertex", "delete_vertex", "set_vertex_prop"):
                out.append(op["vid"])
            elif k in ("create_edge", "delete_edge", "set_edge_prop"):
                out.append(op["src"])
        return out

    # ---- GC (paper §4.5, at the store) --------------------------------------
    def collect(self, horizon: Stamp) -> Tuple[int, int]:
        """Store-side GC at the global horizon (every future stamp
        dominates it):

        * :class:`~repro.core.writepath.LastUpdateTable` rows strictly
          before the horizon are dropped — absence means "no last
          update", which validates identically (``upd ≺ tx`` holds by
          transitivity), so the packed table stays bounded;
        * ``StoredVertex.last_update`` stamps strictly before the
          horizon are cleared to keep the dict mirror == packed table
          (the per-tx path's ``compare`` walk reaches the same verdict
          either way);
        * :class:`StoredVertex` records DELETED strictly before the
          horizon are dropped entirely — the shards purged those
          versions at the same horizon, so recovery replay and the
          vid -> shard directory agree (a dangling directory lookup now
          returns None, same as a vertex that never existed);
        * the WAL is rewritten as ONE checkpoint record (the per-shard
          walk) whenever vertices were dropped — full-history replay
          would otherwise resurrect them — or when the log outgrew
          ``wal_checkpoint_every`` records, keeping replay bounded;
        * recorded tx outcomes older than ``RESULT_RETENTION`` (longer
          than any client retry session) are pruned.

        Returns ``(lastupdate_rows_dropped, vertices_dropped)``."""
        n_rows = self.last_updates.collect(horizon)
        dead = [vid for vid, v in self.vertices.items()
                if v.delete_ts is not None
                and compare(v.delete_ts, horizon) is Order.BEFORE]
        for vid in dead:
            del self.vertices[vid]
        for v in self.vertices.values():
            if v.last_update is not None and compare(
                    v.last_update, horizon) is Order.BEFORE:
                v.last_update = None
        if dead or len(self.wal) > self.wal_checkpoint_every:
            self._checkpoint_wal()
        stale = [txid for txid, r in self.tx_results.items()
                 if self.sim.now - r[4] > self.RESULT_RETENTION]
        for txid in stale:
            del self.tx_results[txid]
        self.sim.counters.store_txresults_gcd += len(stale)
        self.sim.counters.store_lastupdate_gcd += n_rows
        self.sim.counters.store_vertices_gcd += len(dead)
        return n_rows, len(dead)

    # ---- recovery support ---------------------------------------------------
    def _walk_vertex(self, vid: str, v: StoredVertex, out: List[dict]) -> None:
        """Append one vertex's redo stream (original stamps) to ``out``."""
        out.append({"op": "create_vertex", "vid": vid, "ts": v.create_ts})
        for eid, (dst, cts, dts) in v.edges.items():
            out.append({"op": "create_edge", "src": vid, "dst": dst,
                        "eid": eid, "ts": cts})
            for key, versions in v.edge_props.get(eid, {}).items():
                for value, pts in versions:
                    out.append({"op": "set_edge_prop", "src": vid,
                                "eid": eid, "key": key, "value": value,
                                "ts": pts})
            if dts is not None:
                out.append({"op": "delete_edge", "src": vid, "eid": eid,
                            "ts": dts})
        for key, versions in v.props.items():
            for value, ts in versions:
                out.append({"op": "set_vertex_prop", "vid": vid, "key": key,
                            "value": value, "ts": ts})
        if v.delete_ts is not None:
            out.append({"op": "delete_vertex", "vid": vid, "ts": v.delete_ts})

    def recover_shard_walk(self, shard: int) -> List[dict]:
        """Rebuild one shard's redo stream by walking ``vertices`` —
        the original recovery path, kept as the equivalence oracle for
        WAL replay (``tests/test_recovery.py``)."""
        out: List[dict] = []
        for vid, v in self.vertices.items():
            if v.shard == shard:
                self._walk_vertex(vid, v, out)
        return out

    def recover_shard(self, shard: int, use_wal: bool = True) -> List[dict]:
        """Redo stream for one shard's partition (backup promotion,
        §4.3): replay the WAL up to the stable point, truncating any
        torn tail; ``use_wal=False`` falls back to the store walk."""
        if not use_wal:
            return self.recover_shard_walk(shard)
        ops, torn = wal_replay_shard(self.wal, shard)
        self.sim.counters.wal_torn_truncated += torn
        self.sim.counters.wal_replay_ops += len(ops)
        return ops

    def _checkpoint_wal(self) -> None:
        """Rewrite the log as one checkpoint record: the full per-shard
        walk at this instant subsumes every earlier record (and agrees
        with what GC just dropped)."""
        shards: Dict[int, List[dict]] = {s: [] for s in range(self.n_shards)}
        for vid, v in self.vertices.items():
            self._walk_vertex(vid, v, shards[v.shard])
        self.wal = [WalRecord("ckpt", ckpt=shards)]
        self.sim.counters.wal_ckpts += 1

    # ---- durability to disk (used by checkpoint tests) ----------------------
    def checkpoint_to(self, path: str) -> None:
        data = {
            "wal_len": len(self.wal),
            "vertices": {
                vid: {
                    "shard": v.shard,
                    "create": v.create_ts.key(),
                    "deleted": None if v.delete_ts is None else v.delete_ts.key(),
                    "n_edges": len(v.edges),
                }
                for vid, v in self.vertices.items()
            },
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(data, f)
        os.replace(tmp, path)
