"""Backing store — HyperDex/Warp stand-in (paper §3.2, §4.1).

A strictly serializable, transactional key-value store that (a) holds the
durable copy of the multi-version graph, (b) maps vertices to shard
servers, and (c) records each vertex's last-update stamp, which the
gatekeepers use to keep timestamps consistent with the store's execution
order (``T_upd ≺ T_tx`` check; retry/refine otherwise).

In the simulator it is a single actor executing multi-key transactions
atomically at message-delivery time (that *is* strict serializability for
a single-copy store), with a write-ahead log for recovery and an optional
on-disk checkpoint used by the fault-tolerance tests.

Group commit (``repro.core.writepath``): last-update stamps are mirrored
into a packed :class:`~repro.core.writepath.LastUpdateTable` at every
commit point, so the gatekeeper's batched admission path validates a
whole window's write-sets with one vectorized compare instead of one
dict probe per vertex; :meth:`BackingStore.apply_batch` then commits the
validated batch in ONE store round trip — one group WAL record is the
batch's single durability point, and each transaction's reply is sent
only after it.  A logical error (``ValueError``) aborts only its own
transaction; the rest of the batch commits.  The per-tx :meth:`apply`
is unchanged and remains the semantic oracle.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from .clock import Order, Stamp, compare
from .mvgraph import VidIntern
from .simulation import Simulator
from .writepath import LastUpdateTable


@dataclass
class StoredVertex:
    vid: str
    shard: int
    create_ts: Stamp
    delete_ts: Optional[Stamp] = None
    # durable mirror of edges/properties: eid -> (dst, create_ts, delete_ts)
    edges: Dict[int, Tuple[str, Stamp, Optional[Stamp]]] = field(default_factory=dict)
    props: Dict[str, List[Tuple[object, Stamp]]] = field(default_factory=dict)
    last_update: Optional[Stamp] = None


class BackingStore:
    """Strictly serializable KV + vertex->shard directory + WAL."""

    def __init__(self, sim: Simulator, n_shards: int,
                 intern: Optional[VidIntern] = None):
        self.sim = sim
        sim.register(self)
        self.n_shards = n_shards
        self.vertices: Dict[str, StoredVertex] = {}
        self.wal: List[dict] = []
        self._next_eid = 0
        # packed mirror of per-vertex last-update stamps (group-commit
        # validation path; kept exactly in sync with StoredVertex.
        # last_update at every commit point)
        self.last_updates = LastUpdateTable(intern)

    # ---- directory -------------------------------------------------------
    def place(self, vid: str) -> int:
        """Static placement hash; dynamic migration (§4.6) is out of scope
        for the evaluation (the paper disables it too)."""
        return hash(vid) % self.n_shards

    def shard_of(self, vid: str) -> Optional[int]:
        v = self.vertices.get(vid)
        return None if v is None else v.shard

    def fresh_eid(self) -> int:
        self._next_eid += 1
        return self._next_eid

    # ---- transactional execution ------------------------------------------
    # Executed atomically by the gatekeeper's commit path; returns the list
    # of (shard, op) to forward, or raises on logical error (-> abort).
    def last_update_of(self, vid: str) -> Optional[Stamp]:
        v = self.vertices.get(vid)
        return None if v is None else v.last_update

    def apply(self, ops: List[dict], ts: Stamp) -> List[Tuple[int, dict]]:
        """Validate + execute a whole transaction atomically.

        Validation runs against an *overlay* of the staged writes so a
        transaction sees its own earlier operations (e.g. Fig. 2 creates a
        vertex and immediately hangs edges off it).  A logical error aborts
        with no side effects (§4.1).
        """
        return self._apply_one(ops, ts, log=True)

    def apply_batch(self, items: List[Tuple[List[dict], Stamp]]
                    ) -> List[Tuple[bool, Optional[str],
                                    Optional[List[Tuple[int, dict]]]]]:
        """Commit a validated group — ``[(ops, stamp), ...]`` in stamp
        order — in one store round trip.

        Per-transaction result: ``(ok, error, fwd)``.  Each transaction
        keeps its own atomicity (a logical error rolls back that tx
        only); the batch shares ONE group WAL record appended after the
        last transaction — the group's single durability point (the
        gatekeeper replies to every client after this call returns)."""
        out = []
        ts_keys, op_names = [], []
        for ops, ts in items:
            try:
                fwd = self._apply_one(ops, ts, log=False)
            except ValueError as e:
                out.append((False, str(e), None))
                continue
            out.append((True, None, fwd))
            if fwd:
                ts_keys.append((ts.epoch, ts.gk, ts.ctr))
                op_names.extend(o["op"] for o in ops)
        if op_names:
            self.wal.append({"group": True, "ts": ts_keys,
                             "ops": op_names})
        return out

    def _apply_one(self, ops: List[dict], ts: Stamp,
                   log: bool) -> List[Tuple[int, dict]]:
        fwd: List[Tuple[int, dict]] = []
        staged: List[Callable[[], None]] = []
        new_v: Dict[str, StoredVertex] = {}       # created in this tx
        del_v: set = set()                        # deleted in this tx
        new_e: Dict[str, Dict[int, str]] = {}     # src -> eid -> dst
        del_e: set = set()                        # (src, eid)

        def live(vid: str) -> bool:
            if vid in del_v:
                return False
            if vid in new_v:
                return True
            v = self.vertices.get(vid)
            return v is not None and v.delete_ts is None

        def edge_live(src: str, eid: int) -> bool:
            if (src, eid) in del_e:
                return False
            if eid in new_e.get(src, {}):
                return True
            v = self.vertices.get(src)
            return (v is not None and eid in v.edges
                    and v.edges[eid][2] is None)

        def shard_for(vid: str) -> int:
            if vid in new_v:
                return new_v[vid].shard
            return self.vertices[vid].shard

        for op in ops:
            kind = op["op"]
            if kind == "create_vertex":
                vid = op["vid"]
                if live(vid):
                    raise ValueError(f"vertex {vid} exists")
                shard = op.get("shard", self.place(vid))
                new_v[vid] = StoredVertex(vid, shard, ts, last_update=ts)
                del_v.discard(vid)
                fwd.append((shard, dict(op, ts=ts)))
            elif kind == "delete_vertex":
                vid = op["vid"]
                if not live(vid):
                    raise ValueError(f"vertex {vid} not live")
                del_v.add(vid)
                if vid not in new_v:
                    v = self.vertices[vid]
                    def _d(v=v):
                        v.delete_ts = ts
                        v.last_update = ts
                    staged.append(_d)
                fwd.append((shard_for(vid), dict(op, ts=ts)))
            elif kind == "create_edge":
                src, dst = op["src"], op["dst"]
                if not live(src):
                    raise ValueError(f"src {src} not live")
                if not live(dst):
                    raise ValueError(f"dst {dst} not live")
                eid = op.get("eid") or self.fresh_eid()
                new_e.setdefault(src, {})[eid] = dst
                if src not in new_v:
                    vs = self.vertices[src]
                    def _e(vs=vs, eid=eid, dst=dst):
                        vs.edges[eid] = (dst, ts, None)
                        vs.last_update = ts
                    staged.append(_e)
                fwd.append((shard_for(src), dict(op, eid=eid, ts=ts)))
            elif kind == "delete_edge":
                src, eid = op["src"], op["eid"]
                if not edge_live(src, eid):
                    raise ValueError(f"edge {src}/{eid} not live")
                del_e.add((src, eid))
                if eid in new_e.get(src, {}):
                    dst = new_e[src].pop(eid)
                    def _de0(src=src, eid=eid, dst=dst):
                        self.vertices[src].edges[eid] = (dst, ts, ts)
                    staged.append(_de0)
                else:
                    vs = self.vertices[src]
                    def _de(vs=vs, eid=eid):
                        dst, cts, _ = vs.edges[eid]
                        vs.edges[eid] = (dst, cts, ts)
                        vs.last_update = ts
                    staged.append(_de)
                fwd.append((shard_for(src), dict(op, ts=ts)))
            elif kind == "set_vertex_prop":
                vid = op["vid"]
                if not live(vid):
                    raise ValueError(f"vertex {vid} not live")
                if vid in new_v:
                    new_v[vid].props.setdefault(op["key"], []).append(
                        (op["value"], ts))
                else:
                    v = self.vertices[vid]
                    def _p(v=v, op=op):
                        v.props.setdefault(op["key"], []).append(
                            (op["value"], ts))
                        v.last_update = ts
                    staged.append(_p)
                fwd.append((shard_for(vid), dict(op, ts=ts)))
            elif kind == "set_edge_prop":
                src, eid = op["src"], op["eid"]
                if not edge_live(src, eid):
                    raise ValueError(f"edge {src}/{eid} missing")
                if src not in new_v:
                    vs = self.vertices[src]
                    def _pe(vs=vs):
                        vs.last_update = ts
                    staged.append(_pe)
                fwd.append((shard_for(src), dict(op, ts=ts)))
            elif kind == "get_vertex":       # reads execute here (paper §4.1)
                vid = op["vid"]
                if not live(vid):
                    raise ValueError(f"vertex {vid} not live")
                # read-only: nothing forwarded, nothing staged
            else:
                raise ValueError(f"unknown op {kind}")

        # ---- commit point: merge new vertices, run staged writes, WAL ----
        for vid, v in new_v.items():
            for eid, dst in new_e.get(vid, {}).items():
                v.edges[eid] = (dst, ts, None)
            if vid in del_v:
                v.delete_ts = ts
            self.vertices[vid] = v
        for s in staged:
            s()
        # packed mirror follows the dict exactly: every vid whose
        # last_update the staged writes (or new-vertex creation) set
        self.last_updates.record(self.write_set(ops), ts)
        if fwd and log:
            self.wal.append({"ts": (ts.epoch, ts.gk, ts.ctr),
                             "ops": [o["op"] for o in ops]})
        return fwd

    # ---- touched vertices (for last-update validation) ---------------------
    @staticmethod
    def write_set(ops: List[dict]) -> List[str]:
        out = []
        for op in ops:
            k = op["op"]
            if k in ("create_vertex", "delete_vertex", "set_vertex_prop"):
                out.append(op["vid"])
            elif k in ("create_edge", "delete_edge", "set_edge_prop"):
                out.append(op["src"])
        return out

    # ---- GC (paper §4.5, at the store) --------------------------------------
    def collect(self, horizon: Stamp) -> Tuple[int, int]:
        """Store-side GC at the global horizon (every future stamp
        dominates it):

        * :class:`~repro.core.writepath.LastUpdateTable` rows strictly
          before the horizon are dropped — absence means "no last
          update", which validates identically (``upd ≺ tx`` holds by
          transitivity), so the packed table stays bounded;
        * ``StoredVertex.last_update`` stamps strictly before the
          horizon are cleared to keep the dict mirror == packed table
          (the per-tx path's ``compare`` walk reaches the same verdict
          either way);
        * :class:`StoredVertex` records DELETED strictly before the
          horizon are dropped entirely — the shards purged those
          versions at the same horizon, so recovery replay and the
          vid -> shard directory agree (a dangling directory lookup now
          returns None, same as a vertex that never existed).

        Returns ``(lastupdate_rows_dropped, vertices_dropped)``."""
        n_rows = self.last_updates.collect(horizon)
        dead = [vid for vid, v in self.vertices.items()
                if v.delete_ts is not None
                and compare(v.delete_ts, horizon) is Order.BEFORE]
        for vid in dead:
            del self.vertices[vid]
        for v in self.vertices.values():
            if v.last_update is not None and compare(
                    v.last_update, horizon) is Order.BEFORE:
                v.last_update = None
        self.sim.counters.store_lastupdate_gcd += n_rows
        self.sim.counters.store_vertices_gcd += len(dead)
        return n_rows, len(dead)

    # ---- recovery support ---------------------------------------------------
    def recover_shard(self, shard: int) -> List[dict]:
        """Replay ops for one shard's partition (backup promotion, §4.3)."""
        out = []
        for vid, v in self.vertices.items():
            if v.shard != shard:
                continue
            out.append({"op": "create_vertex", "vid": vid, "ts": v.create_ts})
            for eid, (dst, cts, dts) in v.edges.items():
                out.append({"op": "create_edge", "src": vid, "dst": dst,
                            "eid": eid, "ts": cts})
                if dts is not None:
                    out.append({"op": "delete_edge", "src": vid, "eid": eid,
                                "ts": dts})
            for key, versions in v.props.items():
                for value, ts in versions:
                    out.append({"op": "set_vertex_prop", "vid": vid, "key": key,
                                "value": value, "ts": ts})
            if v.delete_ts is not None:
                out.append({"op": "delete_vertex", "vid": vid, "ts": v.delete_ts})
        return out

    # ---- durability to disk (used by checkpoint tests) ----------------------
    def checkpoint_to(self, path: str) -> None:
        data = {
            "wal_len": len(self.wal),
            "vertices": {
                vid: {
                    "shard": v.shard,
                    "create": v.create_ts.key(),
                    "deleted": None if v.delete_ts is None else v.delete_ts.key(),
                    "n_edges": len(v.edges),
                }
                for vid, v in self.vertices.items()
            },
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(data, f)
        os.replace(tmp, path)
