"""Deterministic fault injection for the Weaver control plane (§4.3).

A seeded :class:`FaultPlan` is a list of :class:`FaultAction` firing
rules; :class:`FaultInjector` (installed as ``Simulator.fault``, wired
by ``WeaverConfig.fault_plan``) evaluates them at two kinds of sites:

* **Named crash points** — actors call ``_crash_point(point)`` at the
  protocol steps the recovery machinery must survive:

  - ``mid_window``       a gatekeeper dies with an admitted-but-unflushed
                         group-commit window (``Gatekeeper.submit_tx``);
  - ``pre_wal``          a gatekeeper dies after validation, before the
                         store apply — nothing durable, nothing forwarded;
  - ``mid_wal``          the store's group append is cut short: a torn
                         tail is left on the log (``valid`` watermark)
                         and the writing gatekeeper dies with it;
  - ``post_wal``         a gatekeeper dies after the WAL durability
                         point but before forwarding/replying — the
                         classic lost-ack window exactly-once dedup
                         must close;
  - ``mid_shard_apply``  a shard dies while draining its queues;
  - ``epoch_barrier``    a *second* actor (the action's ``target``) is
                         killed while the cluster manager commits a new
                         epoch.

* **Message faults** — ``Simulator.send`` asks :meth:`on_send` whether
  to drop, duplicate or delay a message.  Drops and dups are restricted
  to client-boundary and read-path handlers (``reply``, ``submit_tx``,
  ``_resubmit``, ``submit_program``, ``deliver_prog_batch``) because
  gatekeeper->shard write channels are FIFO-with-sequence-numbers: a
  dropped ``enqueue`` would stall the channel forever, which models a
  TCP connection loss, not a packet fault.  Read deliveries carry no
  sequence numbers: a dropped window is recovered by the client read
  sessions (``read_retry_timeout``), a duplicated one is absorbed by
  shard coalescing plus the coordinator's per-delivery report guard
  (single-hop programs; multi-hop dup semantics are not modeled).
  Replica change-feed handlers (``feed_pull`` / ``feed_apply`` /
  ``feed_reset``) are also faultable: strict cursor matching makes a
  dropped, duplicated or delayed feed response a no-op beyond added
  lag, which the replica-consistency battery exercises directly
  (``replica_faults``).

Occurrence counting (``after`` / ``count``) makes every plan
deterministic for a given workload; :meth:`FaultPlan.random` draws a
randomized kill schedule from a seed for the chaos property test.  An
injector starts armed; tests that need fault-free setup traffic
construct it disarmed and :meth:`FaultInjector.arm` it when ready.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

#: crash points an actor may hit itself (epoch_barrier is fired by the
#: cluster manager against the action's target instead)
CRASH_POINTS = ("mid_window", "pre_wal", "mid_wal", "post_wal",
                "mid_shard_apply", "epoch_barrier")


@dataclass
class FaultAction:
    """One firing rule.

    ``kind``: ``"crash"`` (kill ``target`` at ``point``), ``"torn"``
    (cut the group WAL append short — ``arg`` entries survive — and kill
    the writing gatekeeper), or a message fault ``"drop"`` / ``"dup"`` /
    ``"delay"`` (``target`` is then the handler function name).
    ``after`` skips that many matching occurrences before firing;
    ``count`` bounds how many times the rule fires."""

    kind: str
    point: str = ""
    target: str = "*"
    after: int = 0
    count: int = 1
    delay: float = 0.0
    arg: int = 1
    _seen: int = field(default=0, repr=False)
    _fired: int = field(default=0, repr=False)

    def matches(self, point: str, target: str) -> bool:
        return (self.point == point
                and (self.target == "*" or self.target == target))

    def fire(self) -> bool:
        """Occurrence-count one matching site; True when the rule fires."""
        self._seen += 1
        if self._seen <= self.after or self._fired >= self.count:
            return False
        self._fired += 1
        return True


@dataclass
class FaultPlan:
    actions: List[FaultAction] = field(default_factory=list)
    seed: int = 0

    @staticmethod
    def random(seed: int, n_gk: int, n_shards: int, n_crashes: int = 2,
               msg_faults: bool = True, max_after: int = 6,
               replica_faults: bool = False) -> "FaultPlan":
        """A seeded randomized kill schedule over every named crash
        point (the chaos property test's generator).  With
        ``replica_faults`` the plan also hits the change-feed channel:
        random drop/dup/delay on each feed handler plus one sustained
        delayed-``feed_apply`` burst that models a lagging replica."""
        rng = np.random.default_rng(seed)
        actors = [f"gk{g}" for g in range(n_gk)] + \
                 [f"shard{s}" for s in range(n_shards)]
        actions: List[FaultAction] = []
        for _ in range(n_crashes):
            point = CRASH_POINTS[int(rng.integers(len(CRASH_POINTS)))]
            if point == "mid_shard_apply":
                target = f"shard{int(rng.integers(n_shards))}"
            elif point == "epoch_barrier":
                target = actors[int(rng.integers(len(actors)))]
            else:
                target = f"gk{int(rng.integers(n_gk))}"
            kind = "torn" if point == "mid_wal" else "crash"
            actions.append(FaultAction(kind, point=point, target=target,
                                       after=int(rng.integers(max_after)),
                                       arg=1 + int(rng.integers(3))))
        if msg_faults:
            for fn in ("reply", "submit_tx"):
                k = ("drop", "dup", "delay")[int(rng.integers(3))]
                actions.append(FaultAction(
                    k, target=fn, after=int(rng.integers(max_after)),
                    count=1 + int(rng.integers(3)),
                    delay=float(rng.uniform(0.5e-3, 3e-3))))
        if replica_faults:
            for fn in ("feed_pull", "feed_apply", "feed_reset"):
                k = ("drop", "dup", "delay")[int(rng.integers(3))]
                actions.append(FaultAction(
                    k, target=fn, after=int(rng.integers(max_after)),
                    count=1 + int(rng.integers(4)),
                    delay=float(rng.uniform(0.5e-3, 3e-3))))
            # sustained replica lag: a burst of delayed feed responses
            actions.append(FaultAction(
                "delay", target="feed_apply",
                after=int(rng.integers(max_after)),
                count=8 + int(rng.integers(8)),
                delay=float(rng.uniform(2e-3, 8e-3))))
        return FaultPlan(actions, seed=seed)


class FaultInjector:
    """Evaluates a :class:`FaultPlan` deterministically; install as
    ``sim.fault``.  All hits are tallied into the simulator counters."""

    #: handlers message faults may touch: client boundary plus the
    #: replica change-feed channel (see module docstring for why shard
    #: write channels are exempt)
    FAULTABLE_FNS = ("reply", "submit_tx", "_resubmit", "submit_program",
                     "deliver_prog_batch",
                     "feed_pull", "feed_apply", "feed_reset")

    def __init__(self, plan: FaultPlan, sim, armed: bool = True):
        self.plan = plan
        self.sim = sim
        self.armed = armed

    def arm(self) -> None:
        self.armed = True

    def disarm(self) -> None:
        self.armed = False

    # ---- crash points ------------------------------------------------------
    def crash(self, point: str, name: str) -> bool:
        """Should the actor ``name`` die at ``point`` now?"""
        if not self.armed:
            return False
        for a in self.plan.actions:
            if a.kind == "crash" and a.matches(point, name) and a.fire():
                self.sim.counters.crashes_injected += 1
                return True
        return False

    def torn_limit(self, name: str) -> Optional[int]:
        """Entries that survive gatekeeper ``name``'s next group append,
        or None for no mid-WAL fault."""
        if not self.armed:
            return None
        for a in self.plan.actions:
            if a.kind == "torn" and a.matches("mid_wal", name) and a.fire():
                self.sim.counters.crashes_injected += 1
                return a.arg
        return None

    def barrier_victims(self) -> List[str]:
        """Actors to kill while the epoch barrier commits."""
        if not self.armed:
            return []
        out = []
        for a in self.plan.actions:
            if (a.kind == "crash" and a.point == "epoch_barrier"
                    and a.fire()):
                self.sim.counters.crashes_injected += 1
                out.append(a.target)
        return out

    # ---- message faults ----------------------------------------------------
    def on_send(self, fn_name: str) -> Tuple[str, float]:
        """Verdict for one outgoing message: ``("pass"|"drop"|"dup"|
        "delay", extra_delay)``."""
        if self.armed and fn_name in self.FAULTABLE_FNS:
            for a in self.plan.actions:
                if a.kind in ("drop", "dup", "delay") \
                        and (a.target == "*" or a.target == fn_name) \
                        and a.fire():
                    return a.kind, (a.delay if a.kind == "delay" else 0.0)
        return "pass", 0.0
