"""Refinable timestamps: epoch-extended vector clocks (paper §3.3, §4.3).

A stamp is ``(epoch, clock[G], gk, ctr)`` where ``clock`` is the issuing
gatekeeper's vector clock at issue time, ``gk`` the issuing gatekeeper id
and ``ctr`` that gatekeeper's local counter (== clock[gk]); ``(gk, ctr)``
uniquely identifies the transaction, matching the paper's "transactions
are identified by their unique vector clocks".

Ordering rules (X ≺ Y):
* lower epoch  ≺  higher epoch (cluster-manager barrier guarantees all
  pre-failure stamps precede all post-failure stamps, §4.3);
* same epoch: vector-clock happens-before — X[i] <= Y[i] for all i and
  X != Y.  Incomparable stamps are CONCURRENT and may need the oracle.

``visibility_mask`` is the batched (jnp) form used by the analytics/data
plane: given per-object creation/deletion stamps as int32 arrays, compute
which objects exist in the snapshot at a query stamp.  The Pallas kernel
``repro.kernels.mv_visibility`` implements the same contract; this module
is its semantic reference.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

try:  # jnp ops are optional at import time (control-plane only users)
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jnp = None


class Order(enum.Enum):
    BEFORE = -1
    EQUAL = 0
    AFTER = 1
    CONCURRENT = 2


@dataclass(frozen=True)
class Stamp:
    """A refinable timestamp."""

    epoch: int
    clock: Tuple[int, ...]
    gk: int          # issuing gatekeeper
    ctr: int         # issuing gatekeeper's counter at issue (== clock[gk])

    def key(self) -> Tuple[int, Tuple[int, ...], int]:
        """Unique transaction identity — the paper identifies transactions
        by their (unique) vector clocks; the issuing gatekeeper
        disambiguates identical vectors from different gatekeepers."""
        return (self.epoch, self.clock, self.gk)

    def __repr__(self) -> str:  # compact for debugging
        return f"S(e{self.epoch},{list(self.clock)},g{self.gk})"


def compare(a: Stamp, b: Stamp) -> Order:
    if a.epoch != b.epoch:
        return Order.BEFORE if a.epoch < b.epoch else Order.AFTER
    if a.clock == b.clock:
        # identical vectors: same transaction iff same issuing gatekeeper;
        # otherwise indistinguishable but distinct -> concurrent
        return Order.EQUAL if a.gk == b.gk else Order.CONCURRENT
    le = all(x <= y for x, y in zip(a.clock, b.clock))
    if le:
        return Order.BEFORE
    ge = all(x >= y for x, y in zip(a.clock, b.clock))
    if ge:
        return Order.AFTER
    return Order.CONCURRENT


def happens_before(a: Stamp, b: Stamp) -> bool:
    return compare(a, b) is Order.BEFORE


def concurrent(a: Stamp, b: Stamp) -> bool:
    return compare(a, b) is Order.CONCURRENT


def merge(clock_a: Sequence[int], clock_b: Sequence[int]) -> Tuple[int, ...]:
    """Elementwise max (gatekeeper announce handling)."""
    return tuple(max(x, y) for x, y in zip(clock_a, clock_b))


ZERO = None  # set below


def zero(n_gk: int, epoch: int = 0) -> Stamp:
    return Stamp(epoch=epoch, clock=(0,) * n_gk, gk=-1, ctr=0)


# --------------------------------------------------------------------------
# Batched (data-plane) forms.  Stamps are packed as int32 rows:
#   row = [epoch, c_0, ..., c_{G-1}]                       (width G + 1)
# A sentinel row of all INT32_MAX means "no stamp" (e.g. never-deleted).
# --------------------------------------------------------------------------

NO_STAMP = np.iinfo(np.int32).max


def pack(stamp: Optional[Stamp], n_gk: int) -> np.ndarray:
    if stamp is None:
        return np.full((n_gk + 1,), NO_STAMP, dtype=np.int32)
    return np.asarray([stamp.epoch, *stamp.clock], dtype=np.int32)


def pack_many(stamps: Sequence[Optional[Stamp]], n_gk: int) -> np.ndarray:
    if len(stamps) == 0:
        return np.zeros((0, n_gk + 1), dtype=np.int32)
    return np.stack([pack(s, n_gk) for s in stamps])


def _np_before(rows: np.ndarray, q: np.ndarray) -> np.ndarray:
    """rows[i] ≺ q, elementwise over a (N, G+1) array vs a (G+1,) stamp."""
    is_no = rows[:, 0] == NO_STAMP
    lower_epoch = rows[:, 0] < q[0]
    same_epoch = rows[:, 0] == q[0]
    le = np.all(rows[:, 1:] <= q[1:], axis=1)
    eq = np.all(rows[:, 1:] == q[1:], axis=1)
    return np.where(is_no, False, lower_epoch | (same_epoch & le & ~eq))


def visibility_mask_np(create_rows: np.ndarray, delete_rows: np.ndarray,
                       q: np.ndarray) -> np.ndarray:
    """Object visible at q  <=>  create ≺ q  and  not(delete ≺ q).

    Conservative: concurrent creates are NOT visible, concurrent deletes
    ARE visible (the shard resolves true concurrency via the oracle; the
    batched path only answers the comparable majority — paper §4.2).
    """
    return _np_before(create_rows, q) & ~_np_before(delete_rows, q)


def concurrent_mask_np(rows: np.ndarray, q: np.ndarray) -> np.ndarray:
    """rows[i] possibly concurrent with q (numpy analog of
    :func:`concurrent_mask`).

    Same epoch and vector-incomparable, plus the equal-vector case (equal
    vectors from *different* gatekeepers are distinct-but-concurrent; the
    packed row does not carry the gatekeeper id, so callers must resolve
    equal-vector hits against the original :class:`Stamp`).
    """
    is_no = rows[:, 0] == NO_STAMP
    same_epoch = rows[:, 0] == q[0]
    le = np.all(rows[:, 1:] <= q[1:], axis=1)
    ge = np.all(rows[:, 1:] >= q[1:], axis=1)
    eq = le & ge
    return (~is_no) & same_epoch & ((~le & ~ge) | eq)


if jnp is not None:

    def _jnp_before(rows, q):
        is_no = rows[:, 0] == NO_STAMP
        lower_epoch = rows[:, 0] < q[0]
        same_epoch = rows[:, 0] == q[0]
        le = jnp.all(rows[:, 1:] <= q[1:], axis=1)
        eq = jnp.all(rows[:, 1:] == q[1:], axis=1)
        return jnp.where(is_no, False, lower_epoch | (same_epoch & le & ~eq))

    def visibility_mask(create_rows, delete_rows, q):
        """jnp version of :func:`visibility_mask_np` (jit/vmap friendly)."""
        return _jnp_before(create_rows, q) & ~_jnp_before(delete_rows, q)

    def concurrent_mask(rows, q):
        """rows[i] ≈ q (same epoch, vector-incomparable)."""
        is_no = rows[:, 0] == NO_STAMP
        same_epoch = rows[:, 0] == q[0]
        le = jnp.all(rows[:, 1:] <= q[1:], axis=1)
        ge = jnp.all(rows[:, 1:] >= q[1:], axis=1)
        eq = le & ge
        return (~is_no) & same_epoch & ~le & ~ge | ((~is_no) & same_epoch & eq)
